"""Fleet topology design space (paper Tables 3-6) + measured cross-check.

Evaluates Homo / Pool / FleetOpt on H100 & B200 over all three workload
archetypes, decomposes topology x generation gains (§4.2), compares
semantic vs context routing (§5.1), closes the loop with the event-driven
fleet simulator measuring the Azure topologies end-to-end (serving
.fleetsim) against the closed-form sizing that provisioned them — now
including §10.3 prefill/decode disaggregation with its KV-handoff hop and
the model-heterogeneous topologies (§5.1 semantic 8B/70B routing with
misroutes + escalation, §3.2 MoE active-parameter pools with the expert
dispatch floor) — and ends with the SLO-constrained sizing loop
(core.slo): the fleets re-provisioned until their *measured* TTFT p99
actually meets the paper's 500 ms target (then trimmed back down to the
compliance frontier), including a K = 3 multipool ladder and a
disaggregated fleet whose prefill/decode sides re-provision
independently (§10.3) — and closes with the declarative topology IR
(DESIGN.md §12): a custom mixed-generation spec built by hand from raw
PoolSpecs and an optimize_topology search over the spec space on Azure —
and finally a compressed diurnal day (DESIGN.md §13): the same
SLO-sized fleet serving an Azure-style day/night envelope static vs
autoscaled, whole-day tok/W measured with every scale-up lag, weight
load and warm spare charged.

  PYTHONPATH=src python examples/fleet_topology.py [--sim-requests N]
"""
from repro.core import (AGENT, AZURE, LMSYS, B200_LLAMA70B_FLEET,
                        H100_LLAMA70B, FleetOpt, Homogeneous, Semantic,
                        TwoPool, computed_profile, gain_decomposition,
                        ladder_windows, optimize_gamma, size_to_slo)
from repro.core.hardware import H100
from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B
from repro.core.power import H100_POWER


def simulated_crosscheck(n_requests: int = 4000) -> None:
    """Measure the Azure topologies by actually running the fleet."""
    from repro.serving import simulate_topology

    print(f"\n=== measured (fleet simulator, {n_requests} requests) ===")
    sim_tpw = {}
    for kind in ("homo", "two_pool", "fleetopt"):
        cell = simulate_topology(kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
                                 b_short=4096, n_requests=n_requests)
        f = cell.report["fleet"]
        sim_tpw[kind] = cell.sim_decode_tok_per_watt
        print(f"  {kind:9s} analytical {cell.analytical_tok_per_watt:5.2f}"
              f" | simulated {cell.sim_decode_tok_per_watt:5.2f} tok/W"
              f" ({cell.delta_pct:+.1f}%)"
              f" | all-in {cell.sim_tok_per_watt:5.2f}"
              f" | TTFT p99 {f.get('ttft_p99_s', 0.0):.2f}s"
              f" | {f['migrations']} migrations")
    print(f"  measured fleetopt/homo gain: "
          f"{sim_tpw['fleetopt'] / sim_tpw['homo']:.2f}x")


def disaggregated_serving(n_requests: int = 4000) -> None:
    """§10.3 Splitwise: prefill/decode disaggregation served end-to-end —
    dedicated prefill pools, the KV-handoff hop over the interconnect,
    decode pools with zero prefill interference."""
    from repro.serving import simulate_topology

    print(f"\n=== disaggregated prefill/decode (Azure, H100, "
          f"{n_requests} requests) ===")
    for kind in ("disagg", "disagg_fleetopt"):
        cell = simulate_topology(kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
                                 b_short=4096, n_requests=n_requests)
        f = cell.report["fleet"]
        print(f"  {kind:15s} analytical fleet "
              f"{cell.analytical_fleet_tok_per_watt:5.2f}"
              f" / decode-only {cell.analytical_tok_per_watt:5.2f}"
              f" | measured decode {cell.sim_decode_tok_per_watt:5.2f}"
              f" ({cell.delta_pct:+.1f}%) all-in {cell.sim_tok_per_watt:5.2f}"
              f"\n{'':17s} TTFT p99 {f.get('ttft_p99_s', 0.0):.3f}s"
              f" | {f['handoffs']} KV handoffs moved {f['kv_handoff_gb']:.1f}"
              f" GB costing {f['kv_handoff_joules']:.1f} J"
              f" ({100 * f['kv_handoff_energy_frac']:.3f}% of fleet energy)")


def model_heterogeneous_serving(n_requests: int = 4000) -> None:
    """§5.1 semantic routing and §3.2 MoE pools served end-to-end: every
    pool binds its own (model, profile) through the ModelProfileRegistry,
    the semantic classifier misroutes at a configurable rate (detected
    misroutes escalate to the large model and are re-served from
    scratch), and the MoE pool streams active params under an expert
    dispatch floor."""
    from repro.core.modelspec import QWEN3_235B_A22B
    from repro.core.moe import moe_profile
    from repro.serving import simulate_topology

    print(f"\n=== model-heterogeneous serving (Azure, H100, "
          f"{n_requests} requests) ===")
    for kind, kw in (("semantic", {}),
                     ("semantic_fleetopt", dict(misroute_rate=0.1))):
        cell = simulate_topology(kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
                                 b_short=4096, n_requests=n_requests, **kw)
        f = cell.report["fleet"]
        print(f"  {kind:17s} mr={kw.get('misroute_rate', 0.0):4.2f}"
              f" | analytical {cell.analytical_tok_per_watt:5.2f}"
              f" | measured {cell.sim_decode_tok_per_watt:5.2f} tok/W"
              f" ({cell.delta_pct:+.1f}%) all-in {cell.sim_tok_per_watt:5.2f}"
              f" | {f['escalations']} escalations,"
              f" {f['migrations']} migrations")
    moe_prof = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    for d in (0.0, 10.0):
        cell = simulate_topology("moe_pool", AZURE, moe_prof,
                                 QWEN3_235B_A22B, n_requests=n_requests,
                                 dispatch_ms=d)
        f = cell.report["fleet"]
        print(f"  moe_pool          d={d:4.0f}ms"
              f" | analytical {cell.analytical_tok_per_watt:5.2f}"
              f" | measured {cell.sim_decode_tok_per_watt:5.2f} tok/W"
              f" ({cell.delta_pct:+.1f}%) all-in {cell.sim_tok_per_watt:5.2f}"
              f" | dispatch = {100 * f['moe_dispatch_energy_frac']:.1f}%"
              f" of fleet energy")


def slo_constrained_sizing(n_requests: int = 2000) -> None:
    """Fix the TTFT-SLO violation: re-provision until the measured p99
    complies, and report the tok/W price of compliance."""
    print(f"\n=== SLO-constrained sizing (P99 TTFT <= 500 ms, "
          f"{n_requests} requests) ===")
    cells = (("H100", H100_LLAMA70B, "fleetopt",
              dict(b_short=4096)),
             ("H100", H100_LLAMA70B, "multipool",
              dict(windows=ladder_windows(3))),
             ("H100", H100_LLAMA70B, "disagg_fleetopt",
              dict(b_short=4096)),
             ("B200", B200_LLAMA70B_FLEET, "fleetopt",
              dict(b_short=4096)))
    for gen, prof, kind, kw in cells:
        res = size_to_slo(kind, AZURE, prof, LLAMA31_70B,
                          n_requests=n_requests, **kw)
        cal = ", ".join(f"{r}={v:.2f}"
                        for r, v in res.calibrated_prefill_mfu.items())
        print(f"  {gen} {kind:9s} Eq.4 {res.unconstrained.tok_per_watt:5.2f}"
              f" -> SLO-feasible {res.slo_tok_per_watt:5.2f} tok/W"
              f" (cost {res.compliance_cost_pct:+.1f}%,"
              f" +{res.instances_added} inst,"
              f" {len(res.rounds)} rounds)"
              f" | measured TTFT p99 {res.ttft_p99_s:.3f}s"
              + (f" | calibrated prefill MFU: {cal}" if cal else ""))


def declarative_topology_ir(n_requests: int = 2000) -> None:
    """§12: topologies as data.  Build a custom 3-rung spec by hand from
    raw PoolSpecs (no kind string exists for it — a B200 terminal rung
    behind two H100 short rungs), measure it end-to-end, then let
    optimize_topology search the spec space on Azure."""
    from repro.core import SLOSpec, optimize_topology
    from repro.core.topospec import PoolSpec, TopologySpec
    from repro.serving import simulate_spec

    print(f"\n=== declarative topology IR + search (Azure, "
          f"{n_requests} requests) ===")
    # hand-built: admit<=4K on H100, <=16K on H100, rest on B200 —
    # a mixed-generation ladder no legacy kind can express
    spec = TopologySpec(
        kind="custom", label="H100[4K,16K]+B200[64K]",
        pools=(
            PoolSpec(role="short", window=4096, profile=H100_LLAMA70B,
                     admit=4096.0, evict_on_overflow=True,
                     overflow_to="mid"),
            PoolSpec(role="mid", window=16384, profile=H100_LLAMA70B,
                     admit=16384.0, evict_on_overflow=True,
                     overflow_to="long"),
            PoolSpec(role="long", window=65536,
                     profile=B200_LLAMA70B_FLEET, admit=float("inf")),
        ),
        models={"default": LLAMA31_70B})
    cell = simulate_spec(spec, AZURE, n_requests=n_requests, seed=0)
    print(f"  {spec.label:28s} analytical {cell.analytical_tok_per_watt:5.2f}"
          f" | measured {cell.sim_decode_tok_per_watt:5.2f} tok/W"
          f" ({cell.delta_pct:+.1f}%)")
    # search: highest measured-SLO-compliant tok/W over (windows, gamma,
    # per-rung chip, small-model rung, disagg) — seeded at the hand-built
    # multipool K=3 incumbent, so the result can only tie or beat it
    res = optimize_topology(
        AZURE, H100_LLAMA70B, LLAMA31_70B, slo=SLOSpec(),
        chips={"H100": H100_LLAMA70B, "B200": B200_LLAMA70B_FLEET},
        small_model=LLAMA31_8B, n_requests=n_requests, seed=0, budget=12)
    print(f"  searched: {res.best_spec.label}"
          f" -> {res.best_score:.2f} SLO-compliant tok/W"
          f" ({res.evaluations} evaluations, {res.restarts} restarts,"
          f" TTFT p99 {res.best_result.ttft_p99_s:.3f}s)")


def diurnal_autoscaling(peak_rate: float = 150.0, day_s: float = 160.0):
    """A compressed diurnal day, static vs autoscaled (DESIGN.md §13)."""
    import dataclasses

    from repro.core import AutoscalePolicy, TopologySpec
    from repro.core.workloads import DiurnalProfile
    from repro.serving import prepare_spec, sample_diurnal_trace

    print(f"\n=== diurnal day (peak {peak_rate:g}/s compressed into "
          f"{day_s:g}s), static vs autoscaled ===")
    dprof = DiurnalProfile(peak_rate=peak_rate, day_s=day_s)
    wl = dataclasses.replace(AZURE, arrival_rate=peak_rate)
    pol = AutoscalePolicy(control_interval_s=day_s / 40.0,
                          target_utilization=0.7,
                          scaleup_lag_s=day_s / 120.0,
                          scaledown_delay_s=day_s / 13.0, min_frac=0.2,
                          spare_instances=0)
    spec = dataclasses.replace(
        TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                               b_short=4096), autoscale=pol)
    trace = sample_diurnal_trace(wl, dprof, day_s, seed=0,
                                 max_total=spec.max_window)
    for autoscale in (False, True):
        sim, reqs, plan = prepare_spec(spec, wl, seed=0, trace=trace,
                                       autoscale=autoscale)
        f = sim.run(reqs, warmup_frac=0.0)["fleet"]
        mode = "autoscaled" if autoscale else "static    "
        online = ""
        if sim.schedules:
            avg = sum(s.online_instance_seconds(0.0, sim._window[1])
                      for s in sim.schedules.values()) / sim._window[1]
            online = f", avg {avg:.1f}/{plan.instances} instances online"
        print(f"  {mode}: {f['tok_per_watt']:5.2f} tok/W whole-day "
              f"(idle {100 * f['idle_energy_frac']:.0f}% of energy, "
              f"{f['completed']} completed{online})")


def main(sim_requests: int = 4000):
    tpw = {}
    print("=== Table 3: fleet tok/W ===")
    for wl, bs in ((AZURE, 4096), (LMSYS, 1536), (AGENT, 8192)):
        for gname, prof in (("H100", H100_LLAMA70B),
                            ("B200", B200_LLAMA70B_FLEET)):
            row = {}
            for tname, topo in (
                    ("homo", Homogeneous()), ("pool", TwoPool(b_short=bs)),
                    ("fleetopt", FleetOpt(b_short=bs, gamma=2.0))):
                rep = topo.provision(wl, prof, LLAMA31_70B)
                row[tname] = rep
            if wl is AZURE:
                tpw[gname] = {t: r.tok_per_watt for t, r in row.items()}
            cells = " | ".join(
                f"{t}: {r.instances:>3} inst {r.tok_per_watt:5.2f} tok/W"
                for t, r in row.items())
            print(f"{wl.name:12s} {gname}: {cells}")

    print("\n=== §4.2 gain decomposition (Azure) ===")
    for k, v in gain_decomposition(tpw).items():
        print(f"  {k:20s} {v:.2f}")

    print("\n=== gamma* optimization ===")
    g, rep = optimize_gamma(AZURE, H100_LLAMA70B, LLAMA31_70B, 4096)
    print(f"  gamma* = {g}, fleet tok/W = {rep.tok_per_watt:.2f} "
          f"(paper: gamma* = 2)")

    print("\n=== §5.1 semantic vs context routing (analytical) ===")
    prof8b = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)
    sem = Semantic(b_short=4096, small_profile=prof8b,
                   small_model=LLAMA31_8B).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    ctx = FleetOpt(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    print(f"  context routing : {ctx.tok_per_watt:.2f} tok/W "
          f"({ctx.instances} instances)")
    print(f"  semantic routing: {sem.tok_per_watt:.2f} tok/W "
          f"({sem.instances} instances; the 8B answers must be good "
          f"enough — §5.1's quality caveat, priced via misroute_rate)")

    simulated_crosscheck(n_requests=sim_requests)
    disaggregated_serving(n_requests=sim_requests)
    model_heterogeneous_serving(n_requests=sim_requests)
    slo_constrained_sizing(n_requests=max(sim_requests // 2, 1000))
    declarative_topology_ir(n_requests=max(sim_requests // 2, 1000))
    diurnal_autoscaling()


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim-requests", type=int, default=4000)
    main(sim_requests=ap.parse_args().sim_requests)
