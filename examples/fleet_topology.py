"""Fleet topology design space (paper Tables 3-6).

Evaluates Homo / Pool / FleetOpt on H100 & B200 over all three workload
archetypes, decomposes topology x generation gains (§4.2), compares
semantic vs context routing (§5.1), and sweeps quantization (§5.2).

  PYTHONPATH=src python examples/fleet_topology.py
"""
from repro.core import (AGENT, AZURE, LMSYS, B200_LLAMA70B_FLEET,
                        H100_LLAMA70B, FleetOpt, Homogeneous, Semantic,
                        TwoPool, computed_profile, gain_decomposition,
                        optimize_gamma)
from repro.core.hardware import H100
from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B
from repro.core.power import H100_POWER


def main():
    tpw = {}
    print("=== Table 3: fleet tok/W ===")
    for wl, bs in ((AZURE, 4096), (LMSYS, 1536), (AGENT, 8192)):
        for gname, prof in (("H100", H100_LLAMA70B),
                            ("B200", B200_LLAMA70B_FLEET)):
            row = {}
            for tname, topo in (
                    ("homo", Homogeneous()), ("pool", TwoPool(b_short=bs)),
                    ("fleetopt", FleetOpt(b_short=bs, gamma=2.0))):
                rep = topo.provision(wl, prof, LLAMA31_70B)
                row[tname] = rep
            if wl is AZURE:
                tpw[gname] = {t: r.tok_per_watt for t, r in row.items()}
            cells = " | ".join(
                f"{t}: {r.instances:>3} inst {r.tok_per_watt:5.2f} tok/W"
                for t, r in row.items())
            print(f"{wl.name:12s} {gname}: {cells}")

    print("\n=== §4.2 gain decomposition (Azure) ===")
    for k, v in gain_decomposition(tpw).items():
        print(f"  {k:20s} {v:.2f}")

    print("\n=== gamma* optimization ===")
    g, rep = optimize_gamma(AZURE, H100_LLAMA70B, LLAMA31_70B, 4096)
    print(f"  gamma* = {g}, fleet tok/W = {rep.tok_per_watt:.2f} "
          f"(paper: gamma* = 2)")

    print("\n=== §5.1 semantic vs context routing ===")
    prof8b = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)
    sem = Semantic(b_short=4096, small_profile=prof8b,
                   small_model=LLAMA31_8B).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    ctx = FleetOpt(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    print(f"  context routing : {ctx.tok_per_watt:.2f} tok/W "
          f"({ctx.instances} instances)")
    print(f"  semantic routing: {sem.tok_per_watt:.2f} tok/W "
          f"({sem.instances} instances; quality question, not tok/W — §5.1)")


if __name__ == "__main__":
    main()
