"""Training driver example: train a small decoder on the synthetic Markov
corpus and watch the loss drop (use --preset 100m --steps 300 for the
full-scale run; the default is CPU-demo sized).

  PYTHONPATH=src python examples/train_demo.py [--preset 100m --steps 300]
"""
import os
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    args = sys.argv[1:] or ["--preset", "10m", "--steps", "60",
                            "--batch", "4", "--seq", "64"]
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.train", "--arch", "yi-6b",
         *args],
        env={"PYTHONPATH": str(ROOT / "src"), **os.environ}))
