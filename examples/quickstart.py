"""Quickstart: the 1/W law in five minutes.

Reproduces paper Table 1 (tok/W halves per context-window doubling),
fits the law, and runs the Appendix-B fleet analyzer on the Azure trace.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (AZURE, B200_LLAMA70B, H100_LLAMA70B, context_sweep,
                        fit_one_over_w, fleet_tpw_analysis)


def main():
    print("=== The 1/W law (paper Table 1) ===")
    print(f"{'ctx':>6} | {'H100 n_max':>10} {'tok/W':>7} | "
          f"{'B200 n_max':>10} {'tok/W':>7}")
    for rh, rb in zip(context_sweep(H100_LLAMA70B),
                      context_sweep(B200_LLAMA70B)):
        print(f"{rh.context // 1024:>5}K | {rh.n_max:>10} "
              f"{rh.tok_per_watt:>7.2f} | {rb.n_max:>10} "
              f"{rb.tok_per_watt:>7.2f}")
    fit = fit_one_over_w(H100_LLAMA70B)
    print(f"\nlog2(tok/W) ~ {fit.slope:.2f} * log2(W)  (law predicts -1; "
          f"idle power bends the tail)")
    print("per-doubling ratios:",
          [round(r, 2) for r in fit.halving_ratios])

    print("\n=== Fleet topology analysis (Appendix B API, Azure trace) ===")
    res = fleet_tpw_analysis(workload=AZURE, profile=H100_LLAMA70B,
                             b_short=4096)
    for row in res.table():
        print(" ", row)
    print(f"gamma* = {res.gamma_star}")


if __name__ == "__main__":
    main()
