"""End-to-end serving driver (the paper is a serving paper, so this is the
deliverable-b e2e example): a real reduced model served with batched
requests through context-length-routed pools, energy metered per decode
iteration, comparing homogeneous vs FleetOpt routing.

  PYTHONPATH=src python examples/serve_demo.py
"""
import subprocess
import sys
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "yi-6b",
         "--requests", "24"],
        env={"PYTHONPATH": str(ROOT / "src"),
             **__import__("os").environ}))
