"""Inspecting a fleet run with FleetScope (DESIGN.md §14).

Traces a small FleetOpt cell at detail level, then reads everything the
recorder knows: the lifecycle event mix, the per-phase energy
decomposition reconciled against the energy meters, a fixed-grid
timeline (watts / tok/W over the run), SLO violation forensics, and a
Perfetto-viewable Chrome trace dumped next to this script.

  PYTHONPATH=src python examples/inspect_run.py
"""
import json
import pathlib

import numpy as np

from repro.core import AZURE, H100_LLAMA70B, SLOSpec, explain_slo
from repro.core.modelspec import LLAMA31_70B
from repro.core.topospec import TopologySpec
from repro.serving import (TraceRecorder, build_timeline, prepare_spec,
                           reconcile_energy, to_perfetto)


def main():
    rec = TraceRecorder(level="detail")   # "lifecycle" = events only
    spec = TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                                  b_short=4096)
    sim, reqs, _ = prepare_spec(spec, AZURE, n_requests=600, seed=0,
                                telemetry=rec)
    report = sim.run(reqs)

    print("=== lifecycle events ===")
    print(" ", {k: v for k, v in rec.counts().items() if v})
    print(f"  fleet tok/W {report['fleet']['tok_per_watt']:.2f}, "
          f"completed {report['fleet']['completed']}")

    print("\n=== energy by phase (trace vs meters) ===")
    banks = [g.engine.bank for g in sim.groups.values()]
    for phase, row in reconcile_energy(rec, banks).items():
        print(f"  {phase:>8}: {row['meter_j']:>12.1f} J  "
              f"(rel err vs trace {row['rel_err']:.1e})")

    print("\n=== timeline: fleet watts / tok/W per bin ===")
    tl = build_timeline(rec, n_bins=12)
    watts, tpw = tl.fleet("watts"), tl.tok_per_watt()
    for b, c in enumerate(tl.centers):
        bar = "#" * int(watts[b] / max(watts.max(), 1.0) * 40)
        t = f"{tpw[b]:.2f}" if np.isfinite(tpw[b]) else "no data"
        print(f"  t={c:6.2f}s {watts[b]:>9.0f} W  tok/W {t:>8}  {bar}")

    print("\n=== SLO forensics (which pool was late, and when) ===")
    for row in explain_slo(sim, SLOSpec(ttft_p99_s=0.5)):
        print(f"  {row['role']:>16}: {row['n_late']}/{row['n_obs']} late"
              + (f", peak window {row['peak_window_s']}"
                 if row["n_late"] else ""))

    out = pathlib.Path(__file__).resolve().parent / "fleet_trace.json"
    out.write_text(json.dumps(to_perfetto(rec)))
    print(f"\nperfetto trace -> {out}  (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
