"""Benchmark harness: one module per paper table + kernel/engine timing +
the roofline report.  Prints ``name,us_per_call,derived`` CSV and writes
full row dumps to benchmarks/results/*.json.

Run: PYTHONPATH=src python -m benchmarks.run [--only tableN]
"""
import argparse
import json
import pathlib
import sys
import time

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def _suites():
    from . import (beyond_paper, engine_bench, extra_sweeps,
                   fleet_diurnal_bench, fleet_grid_bench, fleet_sim_bench,
                   fleet_trace_report, kernel_bench, roofline_report,
                   table1_context_law, table2_model_archs,
                   table3_fleet_topology, table4_semantic_routing,
                   table5_gpu_generations, table6_archetypes,
                   table7_power_params, topology_search_bench)
    return {
        # harness_run also records the full-run wall-clock trajectory to
        # results/BENCH_fleet_sim_full.json (the committed quick-config
        # baselines fleet_sim.json / BENCH_fleet_sim.json are refreshed
        # only by a deliberate `fleet_sim_bench.py --quick --json ...
        # --time`; see dump_name below)
        "fleet_sim": fleet_sim_bench.harness_run,
        # Table E sensitivity surface; self-skips on numpy-only hosts
        "fleet_grid": fleet_grid_bench.harness_run,
        # searched vs hand-built TopologySpec fleets (optimize_topology);
        # the committed --quick baseline results/topology_search.json is
        # likewise refreshed only by a deliberate bench --quick --json run
        "topology_search": topology_search_bench.harness_run,
        # Table F diurnal day, static vs autoscaled; the committed
        # --quick baseline results/fleet_diurnal.json follows the same
        # deliberate-refresh rule
        "fleet_diurnal": fleet_diurnal_bench.harness_run,
        # FleetScope: Table F cells re-run with detail tracing on —
        # phase-decomposed energy (reconciled <0.1% against the meters),
        # autoscaler ramp lag and peak-window zoom read off the timeline
        "fleet_trace_report": fleet_trace_report.harness_run,
        "table1_context_law": table1_context_law.run,
        "table2_model_archs": table2_model_archs.run,
        "table3_fleet_topology": table3_fleet_topology.run,
        "table4_semantic_routing": table4_semantic_routing.run,
        "table5_gpu_generations": table5_gpu_generations.run,
        "table6_archetypes": table6_archetypes.run,
        "table7_power_params": table7_power_params.run,
        "quantization_sweep": extra_sweeps.quantization,
        "moe_dispatch_sensitivity": extra_sweeps.moe_dispatch,
        "per_arch_one_over_w": extra_sweeps.per_arch_law,
        "beyond_paper": beyond_paper.run,
        "opt_vs_baseline": _opt_vs_baseline,
        "kernel_bench": kernel_bench.run,
        "engine_bench": engine_bench.run,
        "roofline_report": roofline_report.run,
    }


def _opt_vs_baseline():
    from . import opt_vs_baseline
    return opt_vs_baseline.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in _suites().items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            rows, derived = fn()
        except Exception as e:  # pragma: no cover
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        us = (time.perf_counter() - t0) * 1e6
        # suites may redirect their generic rows dump (fleet_sim: the
        # harness runs the *full* config, which must never overwrite the
        # committed --quick CI perf-regression baseline fleet_sim.json)
        dump = getattr(fn, "dump_name", name)
        (RESULTS / f"{dump}.json").write_text(json.dumps(rows, indent=1))
        # kernel/engine suites carry their own per-call timings
        if rows and isinstance(rows[0], dict) and "us_per_call" in rows[0]:
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        else:
            print(f'{name},{us:.1f},"{derived}"')
    if failed:
        sys.exit(f"FAILED: {failed}")


if __name__ == "__main__":
    main()
