"""Table 2: single-GPU tok/W across model families at 8K context.

ComputedProfile throughout (replicated-KV storage mode, per the
reverse-engineered Table-2 convention — DESIGN.md §4); MoE rows use the
active-parameter W override (upper bound, dispatch excluded).
"""
from repro.core import computed_profile
from repro.core.hardware import B200, H100
from repro.core.modelspec import (DEEPSEEK_V3, LLAMA31_8B, LLAMA31_70B,
                                  LLAMA31_405B, QWEN3_235B_A22B)
from repro.core.moe import moe_profile
from repro.core.power import B200_POWER, H100_POWER

PAPER_TPW = {  # (model, gpu) -> paper tok/W
    ("Llama-3.1-8B", "H100"): 6.46, ("Llama-3.1-8B", "B200"): 12.18,
    ("Llama-3.1-70B", "H100"): 7.41, ("Llama-3.1-70B", "B200"): 20.93,
    ("Llama-3.1-405B", "H100"): 0.09, ("Llama-3.1-405B", "B200"): 2.16,
    ("Qwen3-235B-A22B", "H100"): 37.82, ("Qwen3-235B-A22B", "B200"): 177.73,
    ("DeepSeek-V3", "H100"): 2.14, ("DeepSeek-V3", "B200"): 18.37,
}

MODELS = [(LLAMA31_8B, 1), (LLAMA31_70B, 8), (LLAMA31_405B, 8),
          (QWEN3_235B_A22B, 8), (DEEPSEEK_V3, 8)]


def run():
    rows = []
    for model, tp in MODELS:
        for gname, chip, pm in (("H100", H100, H100_POWER),
                                ("B200", B200, B200_POWER)):
            mk = moe_profile if model.is_moe else computed_profile
            prof = mk(model, chip, pm, tp=tp, kv_sharded=False)
            n = prof.n_max(8192)
            tpw = prof.tok_per_watt_at_window(8192)
            rows.append(dict(
                model=model.name, gpu=gname, tp=tp, n_max=n,
                tok_s=round(prof.tokens_per_s(n, 8192), 0),
                tok_per_watt=round(tpw, 2),
                tok_per_watt_paper=PAPER_TPW[(model.name, gname)],
                moe_upper_bound=model.is_moe))
    # The paper's 5.1x cell divides n_max-throughput by ~P(1) power (its
    # 405B row implies 289 W < the 300 W idle floor — internally
    # inconsistent).  The physical §3.2 claim is the fixed-concurrency
    # advantage in the weight-stream-bound regime:
    dense = computed_profile(LLAMA31_70B, H100, H100_POWER, tp=8)
    moe = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    adv8 = moe.tok_per_watt(8, 8192) / dense.tok_per_watt(8, 8192)
    adv1 = moe.tokens_per_s(1, 8192) / dense.tokens_per_s(1, 8192)
    return rows, (f"qwen3_vs_70b: {adv1:.1f}x at n=1 (W-ratio bound), "
                  f"{adv8:.1f}x at n=8; collapses at n_max (KV-bound) — "
                  "paper's 5.1x cell uses sub-idle power, see EXPERIMENTS")
