"""Table 4: context-window routing vs semantic routing per-pool tok/W
(H100, rho = 0.85)."""
from repro.core import H100_LLAMA70B, computed_profile
from repro.core.hardware import H100
from repro.core.modelspec import LLAMA31_8B
from repro.core.power import H100_POWER

PAPER = {  # pool -> (n_active, P_W, tok/W)
    "context-short-70B@8K": (109, 578, 8.77),
    "context-long-70B@64K": (14, 413, 1.52),
    "semantic-small-8B@8K": (49, 506, 6.24),
    "semantic-large-70B@64K": (14, 413, 1.52),
}
RHO = 0.85


def run():
    prof8b = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)
    pools = [
        ("context-short-70B@8K", H100_LLAMA70B, 8192),
        ("context-long-70B@64K", H100_LLAMA70B, 65536),
        ("semantic-small-8B@8K", prof8b, 8192),
        ("semantic-large-70B@64K", H100_LLAMA70B, 65536),
    ]
    rows = []
    for name, prof, window in pools:
        n_act = RHO * prof.n_max(window)
        p = prof.power_w(n_act)
        tpw = prof.tok_per_watt(n_act, window)
        pn, pp, pt = PAPER[name]
        rows.append(dict(pool=name, n_active=round(n_act, 0),
                         n_active_paper=pn,
                         power_w=round(p, 0), power_w_paper=pp,
                         tok_per_watt=round(tpw, 2),
                         tok_per_watt_paper=pt,
                         delta_pct=round(100 * (tpw / pt - 1), 0)))
    long_tie = abs(rows[1]["tok_per_watt"] - rows[3]["tok_per_watt"]) < 1e-9
    return rows, f"long_pool_tie={long_tie} (paper: both 1.52)"
