"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape)
three-term roofline table with MODEL_FLOPS utilisation ratios."""
import json
import pathlib

from repro.configs import get_config
from repro.launch.shapes import SHAPES

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
CHIPS = 256  # single-pod roofline table per the brief


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode D = global_batch tokens."""
    cfg = get_config(arch.replace("-swa", "") if arch.endswith("-swa")
                     else arch)
    spec = cfg.analytical_spec()
    n = spec.streamed_params
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq


def rows_for(mesh: str = "pod16x16"):
    rows = []
    for f in sorted(RESULTS.glob(f"*_{mesh}.json")):
        r = json.loads(f.read_text())
        if r["status"] != "ok":
            rows.append(dict(arch=r["arch"], shape=r["shape"],
                             status=r["status"],
                             note=r.get("reason", r.get("error", ""))[:60]))
            continue
        rf = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_total = rf["flops"] * CHIPS
        rows.append(dict(
            arch=r["arch"], shape=r["shape"], status="ok",
            compute_ms=round(rf["compute_s"] * 1e3, 3),
            memory_ms=round(rf["memory_s"] * 1e3, 3),
            collective_ms=round(rf["collective_s"] * 1e3, 3),
            dominant=rf["dominant"],
            model_flops=f"{mf:.2e}",
            useful_flops_ratio=round(mf / hlo_total, 3) if hlo_total else 0,
            gib_per_device=round(
                r["bytes_per_device"]["peak_estimate"] / 2 ** 30, 2)))
    return rows


def run():
    rows = rows_for("pod16x16")
    ok = [r for r in rows if r["status"] == "ok"]
    if not ok:
        return rows, "dry-run sweep not yet executed"
    dom = {}
    for r in ok:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return rows, f"pairs={len(rows)} ok={len(ok)} dominant_terms={dom}"
