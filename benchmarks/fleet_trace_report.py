"""Trace-driven diurnal report: Table F cells rendered as timelines.

The diurnal bench (fleet_diurnal_bench) answers *how much* a reactive
autoscaler claws back over a simulated day; this report answers *how* —
by running every Table F cell with FleetScope detail tracing on
(serving.telemetry.TraceRecorder) and reading the answers off the
recorded timeline instead of scalar roll-ups:

  * energy decomposition stacked by phase (decode / prefill / idle /
    handoff / dispatch) per cell, from the trace's charge channel —
    gated to reconcile with the EnergyMeter lifetime totals to <0.1%
    per phase (the charge hooks record the same float64 values the
    meters accumulate, so any drift is a bug, not noise);
  * peak-window zoom: the bins where the diurnal envelope is >= 90% of
    peak, with the window's own tok/W and TTFT percentiles
    (`strict_keys=True` — an empty window renders "no data", never a
    fake 0.0);
  * autoscaler actuation lag, measured from the timeline: on the
    morning ramp (after the overnight trough), when demand re-crossed
    70% of its swing vs when the online-instance count did.  Positive =
    capacity trails demand; negative = scale-down hysteresis held
    capacity online through the trough, so the ramp found it already
    provisioned (the conservative-friction default's signature).

Artifacts (the nightly CI uploads both):
  --out PATH       markdown report   (default results/fleet_trace_report.md)
  --json PATH      rows + per-cell timeline JSON (core.timeline schema)
  --perfetto PATH  Chrome trace-event JSON of the first cell, viewable
                   at ui.perfetto.dev (one track per pool/instance,
                   power + occupancy counter tracks)

Standalone:  PYTHONPATH=src python benchmarks/fleet_trace_report.py
             [--quick] [--out PATH] [--json PATH] [--perfetto PATH]
Harness:     PYTHONPATH=src python -m benchmarks.run --only fleet_trace
"""
import dataclasses
import json
import math
import pathlib
import sys

import numpy as np

from repro.core.slo import SLOSpec, size_to_slo_spec
from repro.core.workloads import AZURE, DiurnalProfile
from repro.serving import (TraceRecorder, build_timeline, reconcile_energy,
                           to_perfetto)
from repro.serving.fleetsim import prepare_spec
from repro.serving.request import (latency_percentiles_arrays,
                                   sample_diurnal_trace)

try:
    from .fleet_diurnal_bench import (GENERATIONS, KINDS, PEAK_FRAC,
                                      _spec)
except ImportError:                       # direct script execution
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    from fleet_diurnal_bench import GENERATIONS, KINDS, PEAK_FRAC, _spec

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
N_BINS = 48                  # timeline grid per cell
RAMP_FRAC = 0.7              # demand / actuation crossing threshold
RECONCILE_RTOL = 1e-3        # <0.1% per phase per cell (hard gate)
PHASE_COLS = ("decode", "prefill", "idle", "handoff", "dispatch")


def _fmt(v, nd=3) -> str:
    """Numbers for the markdown table; NaN renders honestly."""
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "no data"
    return f"{v:.{nd}f}"


def _first_crossing(centers: np.ndarray, curve: np.ndarray,
                    frac: float, after: float = 0.0) -> float:
    """First bin center >= `after` where `curve` reaches
    lo + frac * (hi - lo) of its *whole-day* swing; NaN when the curve
    never swings (static provisioning) or never crosses again."""
    lo, hi = float(curve.min()), float(curve.max())
    if hi <= lo:
        return float("nan")
    idx = np.flatnonzero((curve >= lo + frac * (hi - lo))
                         & (centers >= after))
    return float(centers[idx[0]]) if len(idx) else float("nan")


def _peak_window_stats(sim, mask_fn) -> dict:
    """Latency percentiles over requests that *arrived* inside the peak
    envelope window, from the cached per-pool summary columns."""
    arrival = np.concatenate([s.arrival for s in sim.summaries.values()])
    first = np.concatenate([s.first_token for s in sim.summaries.values()])
    finish = np.concatenate([s.finish for s in sim.summaries.values()])
    ngen = np.concatenate([s.n_generated for s in sim.summaries.values()]) \
        if sim.summaries else np.empty(0, np.int64)
    m = mask_fn(arrival)
    return latency_percentiles_arrays(arrival[m], first[m], finish[m],
                                      ngen[m], strict_keys=True)


def run_cell(gen: str, prof, kind: str, provisioning: str, *,
             peak_rate: float, day_s: float, slo_requests: int,
             seed: int, sized_cache: dict):
    """One traced Table F cell -> (row, timeline, recorder, sim)."""
    dprof = DiurnalProfile(peak_rate=peak_rate, day_s=day_s)
    wl = dataclasses.replace(AZURE, arrival_rate=peak_rate)
    spec = _spec(kind, prof, day_s)
    key = (gen, kind)
    if key not in sized_cache:
        sized_cache[key] = size_to_slo_spec(
            spec, wl, slo=SLOSpec(ttft_p99_s=0.2),
            n_requests=slo_requests, seed=seed)
    res = sized_cache[key]
    trace = sample_diurnal_trace(wl, dprof, day_s, seed=seed,
                                 max_total=spec.max_window)
    rec = TraceRecorder(level="detail")
    sim, reqs, plan = prepare_spec(
        spec, wl, seed=seed, trace=trace, pool_overrides=res.overrides,
        autoscale=provisioning == "autoscaled", telemetry=rec)
    rep = sim.run(reqs, warmup_frac=0.0)

    # --- hard gate: trace energy must reconcile with the meters --------
    banks = [g.engine.bank for g in sim.groups.values()]
    rc = reconcile_energy(rec, banks)
    max_rel = max(d["rel_err"] for d in rc.values())

    # --- timeline + timeline-derived measurements ----------------------
    # engine names key the recorder pools; schedules are keyed by role
    scheds = {sim.groups[role].engine.name: s
              for role, s in sim.schedules.items()}
    tl = build_timeline(rec, n_bins=N_BINS, schedules=scheds or None)
    centers = tl.centers
    rate = dprof.rate_at(centers)
    online = tl.fleet("online")
    # actuation lag on the morning ramp: the day *starts* provisioned
    # (sized at peak), so measure both crossings after the overnight
    # trough — when demand re-crossed 70% of its swing vs when the
    # online-instance count followed it back up
    t_trough = float(centers[int(np.argmin(rate))])
    t_demand = _first_crossing(centers, rate, RAMP_FRAC, after=t_trough)
    t_actuate = _first_crossing(centers, online, RAMP_FRAC,
                                after=t_trough)
    ramp_lag = t_actuate - t_demand \
        if math.isfinite(t_demand) and math.isfinite(t_actuate) \
        else float("nan")

    peak_bins = rate >= PEAK_FRAC * dprof.peak_rate
    tok_bins = tl.fleet("tokens")
    j_bins = tl.fleet("joules")
    pk_tok, pk_j = float(tok_bins[peak_bins].sum()), \
        float(j_bins[peak_bins].sum())
    peak_lat = _peak_window_stats(
        sim, lambda a: (dprof.rate_at(a) >= PEAK_FRAC * dprof.peak_rate))

    phases = rec.energy_by_phase()
    total = phases["total"] or 1.0
    f = rep["fleet"]
    row = dict(
        table="trace_report", generation=gen, workload=wl.name,
        topology=kind, provisioning=provisioning,
        peak_rate=peak_rate, day_s=day_s,
        tok_per_watt=f["tok_per_watt"],
        reconcile_max_rel_err=max_rel,
        **{f"{p}_j": round(phases[p], 1) for p in PHASE_COLS},
        **{f"{p}_frac": round(phases[p] / total, 4) for p in PHASE_COLS},
        ramp_lag_s=ramp_lag,
        peak_tok_per_watt=(pk_tok / pk_j) if pk_j else float("nan"),
        peak_ttft_p99_s=peak_lat["ttft_p99_s"],
        peak_tpot_p99_ms=peak_lat["tpot_p99_ms"],
        n_events=len(rec.events),
        instances_peak=plan.instances)
    return row, tl, rec, sim


def run(peak_rate: float = 250.0, day_s: float = 240.0,
        slo_requests: int = 1500, seed: int = 0, quick: bool = True):
    """(rows, derived, timelines, first_cell_recorder)."""
    gens = GENERATIONS[:1] if quick else GENERATIONS   # quick: H100 only
    sized: dict = {}
    rows, timelines = [], {}
    first_rec = None
    for gen, prof in gens:
        for kind in KINDS:
            for provisioning in ("static", "autoscaled"):
                row, tl, rec, _ = run_cell(
                    gen, prof, kind, provisioning, peak_rate=peak_rate,
                    day_s=day_s, slo_requests=slo_requests, seed=seed,
                    sized_cache=sized)
                rows.append(row)
                timelines[f"{gen}/{kind}/{provisioning}"] = tl
                if first_rec is None:
                    first_rec = rec
    worst = max(r["reconcile_max_rel_err"] for r in rows)
    lags = [r["ramp_lag_s"] for r in rows
            if r["provisioning"] == "autoscaled"
            and math.isfinite(r["ramp_lag_s"])]
    derived = (f"worst phase-energy reconciliation over "
               f"{len(rows)} cells = {worst:.2e} (gate {RECONCILE_RTOL:g})"
               + (f"; autoscaler ramp lag "
                  f"{min(lags):.1f}-{max(lags):.1f}s" if lags else ""))
    return rows, derived, timelines, first_rec


def gate(rows) -> list:
    """Acceptance failures (empty = green)."""
    return [f"{r['generation']}/{r['topology']}/{r['provisioning']}: "
            f"trace energy does not reconcile with the meters "
            f"(rel err {r['reconcile_max_rel_err']:.2e} >= "
            f"{RECONCILE_RTOL:g})"
            for r in rows if r["reconcile_max_rel_err"] >= RECONCILE_RTOL]


def render_markdown(rows, timelines) -> str:
    out = ["# FleetScope trace report: the diurnal day, by phase\n"]
    hdr = ("| cell | tok/W | decode | prefill | idle | handoff | "
           "dispatch | ramp lag (s) | peak tok/W | peak TTFT p99 (s) |")
    out += [hdr, "|" + "---|" * 10]
    for r in rows:
        cell = f"{r['generation']}/{r['topology']}/{r['provisioning']}"
        out.append(
            f"| {cell} | {_fmt(r['tok_per_watt'])} | "
            + " | ".join(f"{100 * r[f'{p}_frac']:.1f}%"
                         for p in PHASE_COLS)
            + f" | {_fmt(r['ramp_lag_s'], 1)} |"
            f" {_fmt(r['peak_tok_per_watt'])} |"
            f" {_fmt(r['peak_ttft_p99_s'])} |")
    out.append("\nRamp lag: online-instance 70%-of-swing crossing minus "
               "demand's, after the overnight trough (negative = "
               "scale-down hysteresis kept capacity online through the "
               "trough, so the morning ramp found it already there).")
    out.append("\nPhase columns are shares of traced lifetime energy; "
               "every cell reconciles with the meter totals to "
               f"<{100 * RECONCILE_RTOL:g}% per phase "
               "(worst: "
               f"{max(r['reconcile_max_rel_err'] for r in rows):.2e}).\n")
    out.append("## Peak-window zoom (envelope >= "
               f"{int(100 * PEAK_FRAC)}% of peak)\n")
    for name, tl in timelines.items():
        tok = tl.fleet("tokens").sum()
        out.append(f"- **{name}**: {int(tok)} decode tokens over "
                   f"{tl.n_bins} bins of {tl.bin_s:.1f}s; online "
                   f"instances {tl.fleet('online').min():.0f}"
                   f"-{tl.fleet('online').max():.0f}")
    return "\n".join(out) + "\n"


def harness_run():
    """benchmarks.run entry point (full config, mirroring the diurnal
    bench's nightly ladder)."""
    rows, derived, timelines, _ = run(peak_rate=500.0, day_s=480.0,
                                      slo_requests=3000, quick=False)
    fails = gate(rows)
    if fails:
        raise AssertionError("; ".join(fails))
    (RESULTS / "fleet_trace_report.md").write_text(
        render_markdown(rows, timelines))
    return rows, derived


harness_run.dump_name = "fleet_trace_report_full"


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="H100-only cells at the CI diurnal config")
    ap.add_argument("--peak-rate", type=float, default=500.0)
    ap.add_argument("--day-s", type=float, default=480.0)
    ap.add_argument("--slo-requests", type=int, default=3000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", metavar="PATH",
                    default=str(RESULTS / "fleet_trace_report.md"))
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="rows + per-cell timeline JSON")
    ap.add_argument("--perfetto", metavar="PATH", default=None,
                    help="dump the first cell's Chrome trace-event JSON")
    args = ap.parse_args(argv)
    if args.quick:
        peak, day, n_slo = 250.0, 240.0, 1500
    else:
        peak, day, n_slo = args.peak_rate, args.day_s, args.slo_requests
    rows, derived, timelines, first_rec = run(
        peak_rate=peak, day_s=day, slo_requests=n_slo, seed=args.seed,
        quick=args.quick)
    md = render_markdown(rows, timelines)
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.out).write_text(md)
    print(md)
    print(derived)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"meta": dict(peak_rate=peak, day_s=day,
                                    slo_requests=n_slo, seed=args.seed,
                                    quick=args.quick),
                       "rows": rows,
                       "timelines": {k: tl.to_json()
                                     for k, tl in timelines.items()}},
                      fh, indent=1)
    if args.perfetto and first_rec is not None:
        with open(args.perfetto, "w") as fh:
            json.dump(to_perfetto(first_rec), fh)
        print(f"perfetto trace -> {args.perfetto}")
    fails = gate(rows)
    if fails:
        sys.exit("ACCEPTANCE FAIL: " + "; ".join(fails))


if __name__ == "__main__":
    main()
