"""Beyond-paper benchmark: multi-pool sweep, carbon/cost ranking, TPU-v5e,
prefill-decode disaggregation, speculative decoding — every §10.3
future-work item, quantified."""
from repro.core import (AGENT, AZURE, GRIDS, H100_LLAMA70B, V5E_LLAMA70B,
                        Disaggregated, FleetOpt, Homogeneous, MultiPool,
                        bill, computed_profile, speculative_tok_per_watt,
                        sweep_pool_counts)
from repro.core.hardware import H100
from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B
from repro.core.power import H100_POWER


def run():
    rows = []
    for wl in (AZURE, AGENT):
        for k, tpw in sweep_pool_counts(wl, H100_LLAMA70B, LLAMA31_70B):
            rows.append(dict(kind="multipool", workload=wl.name, pools=k,
                             tok_per_watt=round(tpw, 2)))
    reps = {"homo": Homogeneous().provision(AZURE, H100_LLAMA70B,
                                            LLAMA31_70B),
            "fleetopt": FleetOpt(b_short=4096, gamma=2.0).provision(
                AZURE, H100_LLAMA70B, LLAMA31_70B)}
    for grid_name, grid in GRIDS.items():
        for topo, rep in reps.items():
            b = bill(rep, grid)
            rows.append(dict(kind="carbon", grid=grid_name, topology=topo,
                             g_co2_per_mtok=round(b.g_co2_per_mtok, 1),
                             usd_per_mtok=round(b.usd_total_per_mtok, 2)))
    # the framework's own TPU target
    rows.append(dict(kind="tpu-v5e", profile=V5E_LLAMA70B.name,
                     tpw_8k=round(V5E_LLAMA70B.tok_per_watt_at_window(8192),
                                  2)))
    # §10.3 prefill-decode disaggregation (finding: loses on output tok/W)
    fo = reps["fleetopt"]
    dis = Disaggregated(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    rows.append(dict(kind="disagg", interleaved_tpw=round(fo.tok_per_watt, 2),
                     disagg_tpw=round(dis.tok_per_watt, 2),
                     note="dedicated prefill fleet burns P_nom watts that "
                          "interleaving absorbed"))
    # §10.3 speculative decoding within P(b)
    draft = computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)
    for a, L in ((0.8, 4), (0.5, 8)):
        sp = speculative_tok_per_watt(H100_LLAMA70B, draft, accept_rate=a,
                                      speculation_len=L)
        rows.append(dict(kind="speculative", accept=a, spec_len=L,
                         tok_per_watt=round(sp.tok_per_watt, 2),
                         speedup=round(sp.speedup_vs_plain, 2)))
    k_tpw = {r["pools"]: r["tok_per_watt"] for r in rows
             if r.get("workload") == "agent-heavy"}
    return rows, (f"agent-heavy: K=1..5 pools -> "
                  f"{[k_tpw.get(k) for k in (1, 2, 3, 4, 5)]} tok/W "
                  "(finer topologies compound, with diminishing returns)")
