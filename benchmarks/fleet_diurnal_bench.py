"""Table F: whole-day tok/W under diurnal traffic, static vs autoscaled.

Every other table measures steady-state Poisson arrivals at the peak
rate; this one rides a compressed simulated day through the Azure-style
diurnal envelope (core.workloads.DiurnalProfile, ~5x peak/trough swing)
and asks the question the ROADMAP names: how much of FleetOpt's
steady-state tok/W advantage survives a real day, and how much of the
night-time idle power an ordinary reactive autoscaler
(core.autoscale.AutoscalePolicy via serving.autoscale) can claw back.

Per (chip x topology) cell the fleet is first SLO-sized at the *peak*
rate exactly like Table B (steady Poisson, measured TTFT p99 <= 500 ms),
then the identical sized fleet serves the identical whole-day diurnal
trace twice:

  static      — every peak-provisioned instance powered all day (what
                the steady-state tables implicitly assume);
  autoscaled  — instance counts tracked against each pool's observed
                arrival rate with realistic friction: one-epoch reaction
                lag, scale-up actuation lag, weight-load time from the
                model's byte size, scale-down hysteresis, and warm-spare
                idle power — all charged through the meters.

The day is compressed (seconds per "hour", `--day-s`) so whole-day cells
stay CI-sized; the *shape* — and with it the overprovision arithmetic
relative to peak — is compression-invariant.  The weight-load time stays
physical (bytes / PCIe bandwidth), which *overstates* scale-up friction
on a compressed day: the autoscaling win reported here is conservative.

Acceptance gates (enforced in main()):
  * autoscaled fleetopt whole-day tok/W >= static fleetopt (the knob
    must pay for itself where the paper's headline topology lives);
  * every cell's measured TTFT p99 over peak-window arrivals (rate >=
    90% of peak) <= 500 ms — autoscaling may not bust the SLO the fleet
    was sized for.

`--json PATH` dumps {"meta", "rows"} for the CI perf-regression diff
(benchmarks/perf_diff.py --fleet against the committed
benchmarks/results/fleet_diurnal.json, regenerated deliberately with
`--quick --json benchmarks/results/fleet_diurnal.json`).

Standalone:  PYTHONPATH=src python benchmarks/fleet_diurnal_bench.py
             [--quick] [--json PATH] [--seed N]
Harness:     PYTHONPATH=src python -m benchmarks.run --only fleet_diurnal
"""
import dataclasses
import json
import sys

import numpy as np

from repro.core import ladder_windows
from repro.core.autoscale import AutoscalePolicy
from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import B200_LLAMA70B_FLEET, H100_LLAMA70B
from repro.core.slo import SLOSpec, size_to_slo_spec
from repro.core.topospec import TopologySpec
from repro.core.workloads import AZURE, DiurnalProfile
from repro.serving.fleetsim import prepare_spec
from repro.serving.request import sample_diurnal_trace

GENERATIONS = (("H100", H100_LLAMA70B), ("B200", B200_LLAMA70B_FLEET))
B_SHORT = 4096          # Azure split boundary (paper)
K_POOLS = 3
# kind -> from_kind kwargs, declaratively (no kind dispatch in the bench)
KIND_KWARGS = {"homo": dict(b_short=B_SHORT),
               "fleetopt": dict(b_short=B_SHORT),
               "multipool": dict(windows=ladder_windows(K_POOLS))}
KINDS = tuple(KIND_KWARGS)
PEAK_FRAC = 0.9         # "at peak" = arrivals where rate >= 90% of peak


def _autoscale_policy(day_s: float) -> AutoscalePolicy:
    """Controller knobs scaled to the compressed day: the control epoch
    is ~1/40 of a day (36 real minutes), hysteresis ~3 epochs, actuation
    lag ~1/3 epoch.  Weight-load bandwidth stays physical (the load
    time is NOT compressed — conservative, see module docstring)."""
    epoch = day_s / 40.0
    return AutoscalePolicy(control_interval_s=epoch,
                           target_utilization=0.65,
                           scaleup_lag_s=epoch / 3.0,
                           scaledown_delay_s=3.0 * epoch,
                           min_frac=0.15)


def _spec(kind: str, profile, day_s: float) -> TopologySpec:
    spec = TopologySpec.from_kind(kind, profile, LLAMA31_70B,
                                  **KIND_KWARGS[kind])
    return dataclasses.replace(spec, autoscale=_autoscale_policy(day_s))


def _peak_ttft_p99(sim, dprof: DiurnalProfile) -> float:
    """Measured TTFT p99 over the requests that *arrived* while the
    envelope was within PEAK_FRAC of peak — the gate's 'at peak'."""
    arrival = np.concatenate([s.arrival for s in sim.summaries.values()])
    first = np.concatenate([s.first_token for s in sim.summaries.values()])
    mask = (dprof.rate_at(arrival) >= PEAK_FRAC * dprof.peak_rate) \
        & (first >= 0)
    if not mask.any():
        return 0.0
    return round(float(np.quantile(first[mask] - arrival[mask], 0.99)), 4)


def run(peak_rate: float = 250.0, day_s: float = 240.0,
        slo_requests: int = 1500, seed: int = 0, quick: bool = True):
    dprof = DiurnalProfile(peak_rate=peak_rate, day_s=day_s)
    wl = dataclasses.replace(AZURE, arrival_rate=peak_rate)
    rows = []
    for gen, prof in GENERATIONS:
        for kind in KINDS:
            spec = _spec(kind, prof, day_s)
            # size at PEAK, steady Poisson, like Table B — the spec
            # contract: provisioning never sees the envelope.  The
            # internal sizing target is tighter than the 500 ms gate:
            # a short steady sizing run trims to *just barely*
            # compliant, and a fleet sized at the edge random-walks its
            # queue over the much longer sustained diurnal peak.  The
            # slack (sizing at 200 ms, gating at 500 ms) is the
            # sustained-peak headroom.
            res = size_to_slo_spec(spec, wl, slo=SLOSpec(ttft_p99_s=0.2),
                                   n_requests=slo_requests, seed=seed)
            trace = sample_diurnal_trace(wl, dprof, day_s, seed=seed,
                                         max_total=spec.max_window)
            for provisioning in ("static", "autoscaled"):
                sim, reqs, plan = prepare_spec(
                    spec, wl, seed=seed, trace=trace,
                    pool_overrides=res.overrides,
                    autoscale=provisioning == "autoscaled")
                rep = sim.run(reqs, warmup_frac=0.0)
                f = rep["fleet"]
                span = max(sim._window[1], 1e-9)
                if sim.schedules:
                    avg_online = sum(
                        s.online_instance_seconds(0.0, span)
                        for s in sim.schedules.values()) / span
                else:
                    avg_online = float(plan.instances)
                rows.append(dict(
                    table="diurnal", generation=gen, workload=wl.name,
                    topology=kind, provisioning=provisioning,
                    peak_rate=peak_rate, day_s=day_s,
                    tok_per_watt=f["tok_per_watt"],
                    idle_energy_frac=f["idle_energy_frac"],
                    ttft_p99_s=f.get("ttft_p99_s", 0.0),
                    peak_ttft_p99_s=_peak_ttft_p99(sim, dprof),
                    completed=f["completed"],
                    migrations=f["migrations"],
                    instances_peak=plan.instances,
                    avg_online_instances=round(avg_online, 2),
                    slo_compliant_at_peak=res.compliant))
    cell = {(r["generation"], r["topology"], r["provisioning"]):
            r["tok_per_watt"] for r in rows}
    h = {k: cell[("H100",) + k] for k in
         [(t, p) for t in KINDS for p in ("static", "autoscaled")]}
    derived = (
        f"whole-day autoscaled/static tok/W on H100: "
        + ", ".join(f"{t} {h[(t, 'autoscaled')] / h[(t, 'static')]:.2f}x"
                    for t in KINDS)
        + f"; fleetopt/homo over the day: "
          f"static {h[('fleetopt', 'static')] / h[('homo', 'static')]:.2f}x,"
          f" autoscaled {h[('fleetopt', 'autoscaled')] / h[('homo', 'autoscaled')]:.2f}x"
        + f"; B200/H100 fleetopt autoscaled "
          f"{cell[('B200', 'fleetopt', 'autoscaled')] / h[('fleetopt', 'autoscaled')]:.2f}x")
    return rows, derived


def harness_run():
    """benchmarks.run entry point (full config: a longer compressed day
    at a higher peak).  Rows dump redirected away from the committed
    --quick CI baseline results/fleet_diurnal.json."""
    rows, derived = run(peak_rate=500.0, day_s=480.0, slo_requests=3000,
                        quick=False)
    return rows, derived


harness_run.dump_name = "fleet_diurnal_full"


def gate(rows) -> list:
    """Acceptance failures (empty = green) — shared by main() and the
    bench's own unit test."""
    fails = []
    cell = {(r["generation"], r["topology"], r["provisioning"]): r
            for r in rows}
    for gen, _ in GENERATIONS:
        a = cell[(gen, "fleetopt", "autoscaled")]["tok_per_watt"]
        s = cell[(gen, "fleetopt", "static")]["tok_per_watt"]
        if a < s:
            fails.append(f"{gen}: autoscaled fleetopt whole-day tok/W "
                         f"{a:.3f} < static {s:.3f}")
    bad = [f"{r['generation']}/{r['topology']}/{r['provisioning']}"
           f" ({r['peak_ttft_p99_s']:.3f}s)"
           for r in rows if r["peak_ttft_p99_s"] > 0.5]
    if bad:
        fails.append("peak-window TTFT p99 > 500 ms: " + ", ".join(bad))
    return fails


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI config (the committed-baseline config)")
    ap.add_argument("--peak-rate", type=float, default=500.0)
    ap.add_argument("--day-s", type=float, default=480.0)
    ap.add_argument("--slo-requests", type=int, default=3000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args(argv)
    if args.quick:
        peak, day, n_slo = 250.0, 240.0, 1500
    else:
        peak, day, n_slo = args.peak_rate, args.day_s, args.slo_requests
    rows, derived = run(peak_rate=peak, day_s=day, slo_requests=n_slo,
                        seed=args.seed, quick=args.quick)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"meta": dict(peak_rate=peak, day_s=day,
                                    slo_requests=n_slo, seed=args.seed,
                                    quick=args.quick),
                       "rows": rows}, fh, indent=1)
    hdr = (f"{'gen':5s} {'topology':10s} {'prov':11s} {'tok/W':>7s}"
           f" {'idle%':>6s} {'ttft_p99':>9s} {'peak_ttft':>10s}"
           f" {'inst(peak)':>11s} {'avg_online':>11s}")
    print("=== Table F: diurnal day, static vs autoscaled ===")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['generation']:5s} {r['topology']:10s}"
              f" {r['provisioning']:11s} {r['tok_per_watt']:7.3f}"
              f" {100 * r['idle_energy_frac']:6.1f}"
              f" {r['ttft_p99_s']:9.3f} {r['peak_ttft_p99_s']:10.3f}"
              f" {r['instances_peak']:11d} {r['avg_online_instances']:11.2f}")
    print(derived)
    fails = gate(rows)
    if fails:
        sys.exit("ACCEPTANCE FAIL: " + "; ".join(fails))


if __name__ == "__main__":
    main()
