"""Serving-engine step timing + simulated fleet tok/W on the CPU demo."""
import math
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.profiles import H100_LLAMA70B
from repro.models import model as M
from repro.serving import ContextRouter, PoolEngine, Request, RouterPolicy


def run():
    cfg = get_config("yi-6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = PoolEngine(cfg, params, window=64, profile=H100_LLAMA70B,
                     n_slots=8, name="bench")
    for i in range(8):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 12),
                           max_new_tokens=40))
    eng._admit()
    eng.step()  # compile
    t0 = time.perf_counter()
    iters = 20
    for _ in range(iters):
        eng.step()
    us = (time.perf_counter() - t0) / iters * 1e6
    rows = [dict(name="engine_step_b8_w64", us_per_call=round(us, 1),
                 derived=f"analytic_tok_per_watt={eng.meter.tok_per_watt:.3f}")]

    # two-pool routed mini-fleet
    pools = {
        "short": PoolEngine(cfg, params, window=32, profile=H100_LLAMA70B,
                            n_slots=8, name="short"),
        "long": PoolEngine(cfg, params, window=128, profile=H100_LLAMA70B,
                           n_slots=2, name="long")}
    router = ContextRouter(pools, RouterPolicy(
        kind="fleetopt", b_short=16, gamma=2.0,
        ladder=[("short", 32.0), ("long", math.inf)]))
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab,
                                               6 if i % 4 else 90),
                    max_new_tokens=6) for i in range(12)]
    t0 = time.perf_counter()
    rep = router.run(reqs, max_iters=2000)
    wall = time.perf_counter() - t0
    rows.append(dict(name="routed_fleet_12req",
                     us_per_call=round(wall * 1e6, 0),
                     derived=f"fleet_tok_per_watt={rep['fleet']['tok_per_watt']}"))
    return rows, "serving engine operational"
