"""Table E: dense Azure sensitivity surfaces around the paper's headline
claims (ROADMAP's top open item), measured through the fleet simulator.

Every headline number the repo reproduces — FleetOpt ~2.5x, the B200/H100
generation gain, the semantic-routing and MoE active-parameter advantages —
is a single cell.  Table E measures its *neighborhood*: 260 cells over
misroute_rate x dispatch_ms x chip generation x pool-count K (plus the
b_short/gamma split-boundary axes FleetOpt is sensitive to), so a claim
like "semantic routing wins 3x" comes with the classifier-error rate at
which it stops being true and the dispatch floor at which the MoE bound
collapses, on every chip generation at once.

The grid is unaffordable with the numpy engine driving every cell
(~0.7 s/cell serial); it exists because `serving.jax_engine` drains each
scenario stage as one jitted XLA program whose event-free spans fast-
forward in closed form, and `serving.run_fleet_grid` batches the drains of
many prepared scenarios per topological stage.  All cells share one seeded
Azure trace (common random numbers), so cross-cell differences are pure
config effects, not sampling noise — which is what lets a modest
n_requests trace out a smooth surface.

Cell families (workload: Azure; 4 chips H100/H200/B200/GB200):

  moe_semantic       misroute(6) x dispatch_ms(5) x chip(4)      = 120
  semantic_fleetopt  misroute(6) x b_short(3)     x chip(4)      =  72
  fleetopt           gamma(3)    x b_short(3)     x chip(4)      =  36
  moe_pool           dispatch_ms(5)               x chip(4)      =  20
  multipool          K(3)                         x chip(4)      =  12

Acceptance: the full 260-cell grid completes in no more wall-clock than
the committed --quick fleet_sim bench budget
(benchmarks/results/BENCH_fleet_sim.json total) — the bench prints the
verdict against that number.

`--json PATH` dumps {"meta", "rows"}; the harness dump goes to
benchmarks/results/fleet_grid.json — never the perf-regression gate's
fleet_sim.json.  `--time [PATH]` records per-family wall-clock to
benchmarks/results/BENCH_fleet_grid.json (again: never the committed
BENCH_fleet_sim.json the CI wall gate reads).

Standalone:  PYTHONPATH=src python benchmarks/fleet_grid_bench.py
             [--n-requests N] [--seed S] [--engine jax|numpy]
             [--width W] [--json PATH] [--time [PATH]]
Harness:     PYTHONPATH=src python -m benchmarks.run --only fleet_grid
"""
import json
import sys

from repro.core.hardware import B200, GB200, H100, H200
from repro.core.modelspec import LLAMA31_70B, QWEN3_235B_A22B
from repro.core.moe import moe_profile
from repro.core.multipool import ladder_windows
from repro.core.power import B200_POWER, GB200_POWER, H100_POWER, H200_POWER
from repro.core.profiles import (B200_LLAMA70B_FLEET, GB200_LLAMA70B,
                                 H100_LLAMA70B, H200_LLAMA70B)
from repro.core.workloads import AZURE
from repro.serving import prepare_topology, run_fleet_grid

from .fleet_sim_bench import BENCH_JSON, _TableTimer, write_bench_json

GRID_BENCH_JSON = BENCH_JSON.with_name("BENCH_fleet_grid.json")

CHIPS = (("H100", H100, H100_POWER, H100_LLAMA70B),
         ("H200", H200, H200_POWER, H200_LLAMA70B),
         ("B200", B200, B200_POWER, B200_LLAMA70B_FLEET),
         ("GB200", GB200, GB200_POWER, GB200_LLAMA70B))
MISROUTES = (0.0, 0.02, 0.05, 0.08, 0.10, 0.15)
DISPATCH_MS = (0.0, 1.0, 2.0, 5.0, 10.0)
B_SHORTS = (2048, 4096, 8192)
GAMMAS = (1.5, 2.0, 3.0)
K_POOLS = (2, 3, 4)
# cells drained per run_fleet_grid call: XLA:CPU is memory-bound, so wide
# vmap batches pay more per iteration than they amortize — small groups
# just cap padding waste and per-call dispatch overhead
DEFAULT_WIDTH = 4
DEFAULT_N_REQUESTS = 400

# (row_floor, n_slots, queue) padding classes for the compiled drains.
# The grid's 260 cells span 66 natural power-of-two pool shapes, and on
# the single-core CI runner every distinct shape costs a ~2 s XLA build —
# an order of magnitude more than actually *running* the warmed program —
# so each pool joins the cheapest class below that fits its (S, Q), the
# class's pools concatenate along the instance axis (`jax_engine` keeps
# per-pool constants in (I,) rows, so instance counts never pad), and the
# whole grid reuses ~9 compiled programs.  The list is *tuned*, not
# hand-drawn: a drain-call composition log over every cell at the default
# n_requests feeds a local search minimizing (signatures x build cost +
# padded elements x measured per-element-iteration cost) — signature
# count and padding waste pull in opposite directions, and the optimum
# sits at ~4x padded-over-actual across the whole grid (the old
# hand-picked list sat at ~15x, which made the *warm* executions, not
# the compiles, the grid's bottleneck).  The row floor rounds a chunk's
# summed instance count up so mixtures land on few signatures; a pool
# that outgrows every class (larger --n-requests fattening queues) falls
# back to its natural buckets — correct, just one extra compile.
SHAPE_CLASSES = ((256, 32, 4),      # MoE expert pools, tiny slots/queues
                 (128, 48, 24),     # tail stages: second/overflow pools
                 (128, 96, 24),     # small dense pools
                 (64, 256, 64),     # semantic/16K first pools
                 (32, 768, 96),     # fleetopt short pools, 8K ladder
                 (8, 1536, 96))     # b_short=2048 / 4K-ladder slot monsters


def grid_cells():
    """(row-label dict, kind, profile, model, prepare kwargs) per cell."""
    cells = []
    for gen, chip, power, prof in CHIPS:
        moe = moe_profile(QWEN3_235B_A22B, chip, power, tp=8)

        def cell(kind, profile, model, **kw):
            cells.append((dict(table="grid", generation=gen,
                               workload=AZURE.name, topology=kind,
                               model=model.name,
                               dispatch_ms=float(kw.get("dispatch_ms", 0.0)),
                               misroute_rate=float(
                                   kw.get("misroute_rate", 0.0)),
                               b_short=int(kw.get("b_short", 0)),
                               gamma=float(kw.get("gamma", 0.0)),
                               k_pools=len(kw.get("windows", ()))),
                          kind, profile, model, kw))

        for mr in MISROUTES:
            for d in DISPATCH_MS:
                cell("moe_semantic", moe, QWEN3_235B_A22B, b_short=4096,
                     misroute_rate=mr, dispatch_ms=d)
            for bs in B_SHORTS:
                cell("semantic_fleetopt", prof, LLAMA31_70B, b_short=bs,
                     misroute_rate=mr)
        for g in GAMMAS:
            for bs in B_SHORTS:
                cell("fleetopt", prof, LLAMA31_70B, b_short=bs, gamma=g)
        for d in DISPATCH_MS:
            cell("moe_pool", moe, QWEN3_235B_A22B, dispatch_ms=d)
        for k in K_POOLS:
            cell("multipool", prof, LLAMA31_70B,
                 windows=ladder_windows(k))
    return cells


def _enable_compile_cache() -> None:
    """Persist XLA builds under benchmarks/results/.xla_cache (never
    committed): the handful of drain programs compile once per machine,
    so re-measuring the surface after the first run pays only warmed
    execution.  Best-effort — an old jax without CPU cache support just
    compiles every run."""
    try:                                               # pragma: no cover
        import jax
        cache = GRID_BENCH_JSON.parent / ".xla_cache"
        cache.mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(cache))
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        pass


def run(n_requests: int = DEFAULT_N_REQUESTS, seed: int = 0,
        engine: str = "jax", width: int = DEFAULT_WIDTH):
    if engine == "jax":
        _enable_compile_cache()
    cells = grid_cells()
    timer = _TableTimer(dict(n_requests=n_requests, seed=seed,
                             engine=engine, width=width))
    rows = []
    by_family = {}
    for label, kind, prof, mdl, kw in cells:
        by_family.setdefault(kind, []).append((label, kind, prof, mdl, kw))
    for family, fam_cells in by_family.items():
        for i in range(0, len(fam_cells), max(width, 1)):
            chunk = fam_cells[i:i + max(width, 1)]
            scenarios = [prepare_topology(kind, AZURE, prof, mdl,
                                          n_requests=n_requests, seed=seed,
                                          engine=engine, **kw)
                         for _, kind, prof, mdl, kw in chunk]
            floors = SHAPE_CLASSES if engine == "jax" else None
            for (label, *_), cell in zip(
                    chunk, run_fleet_grid(scenarios, pad_floors=floors)):
                f = cell.report["fleet"]
                rows.append(dict(
                    label,
                    analytical=round(cell.analytical_tok_per_watt, 3),
                    simulated=round(cell.sim_decode_tok_per_watt, 3),
                    all_in=round(cell.sim_tok_per_watt, 3),
                    delta_pct=round(cell.delta_pct, 1),
                    completed=f["completed"],
                    escalations=f["escalations"],
                    migrations=f["migrations"]))
        timer.lap(family)
    timer.total()
    return rows, derive(rows), timer.rows


def _by(rows, **match):
    out = [r for r in rows
           if all(r.get(k) == v for k, v in match.items())]
    assert out, match
    return out


def derive(rows) -> str:
    """Sensitivity one-liners: each headline claim with its measured
    neighborhood boundaries."""
    fo = {(r["generation"], r["gamma"], r["b_short"]): r["simulated"]
          for r in _by(rows, topology="fleetopt")}
    gain = [fo[("B200", g, b)] / fo[("H100", g, b)]
            for g in GAMMAS for b in B_SHORTS]
    # misroute rate at which the semantic split stops beating plain
    # fleetopt (same chip, the paper's 4K boundary)
    fo_ref = fo[("H100", 2.0, 4096)]
    sem = sorted((r["misroute_rate"], r["simulated"]) for r in
                 _by(rows, topology="semantic_fleetopt",
                     generation="H100", b_short=4096))
    crossover = next((mr for mr, v in sem if v < fo_ref), None)
    cross_txt = f">{sem[-1][0]:g}" if crossover is None else f"{crossover:g}"
    moe = {(r["generation"], r["dispatch_ms"]): r["simulated"]
           for r in _by(rows, topology="moe_pool")}
    slope = moe[("H100", DISPATCH_MS[-1])] / moe[("H100", 0.0)]
    mp = {(r["generation"], r["k_pools"]): r["simulated"]
          for r in _by(rows, topology="multipool")}
    best_k = {gen: max(K_POOLS, key=lambda k: mp[(gen, k)])
              for gen, *_ in CHIPS}
    return (f"B200/H100 fleetopt gain across gamma x b_short: "
            f"{min(gain):.2f}-{max(gain):.2f}x; "
            f"semantic_fleetopt(H100,4K) falls below fleetopt at misroute "
            f"{cross_txt}; "
            f"MoE tok/W at {DISPATCH_MS[-1]:g}ms dispatch = {slope:.2f}x "
            f"of 0ms; best K per chip: "
            + ", ".join(f"{g}={k}" for g, k in best_k.items()))


def harness_run():
    """benchmarks.run entry point (rows, derived); falls back to a cheap
    numpy subsample when jax is missing (the numpy-only perf job) so the
    harness never hard-fails on environment."""
    try:
        import jax  # noqa: F401
        engine = "jax"
    except ImportError:                                # pragma: no cover
        return [], "skipped: jax not installed (numpy-only environment)"
    rows, derived, timings = run(engine=engine)
    write_bench_json(timings, GRID_BENCH_JSON.with_name(
        "BENCH_fleet_grid_full.json"))
    return rows, derived


# keep the generic rows dump away from every committed perf baseline
harness_run.dump_name = "fleet_grid"


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=DEFAULT_N_REQUESTS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("jax", "numpy"), default="jax")
    ap.add_argument("--width", type=int, default=DEFAULT_WIDTH,
                    help="scenarios per batched drain call")
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--time", metavar="PATH", nargs="?", default=None,
                    const=str(GRID_BENCH_JSON))
    args = ap.parse_args(argv)
    rows, derived, timings = run(n_requests=args.n_requests, seed=args.seed,
                                 engine=args.engine, width=args.width)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"meta": dict(n_requests=args.n_requests,
                                    seed=args.seed, engine=args.engine,
                                    width=args.width), "rows": rows}, fh,
                      indent=1)
    if args.time:
        write_bench_json(timings, args.time)

    print(f"=== Table E: Azure sensitivity grid ({len(rows)} cells) ===")
    hdr = (f"{'topology':17s} {'gen':6s} {'misr':>5s} {'disp':>5s}"
           f" {'b_short':>7s} {'gamma':>5s} {'K':>2s} {'analytic':>8s}"
           f" {'simul':>7s} {'all-in':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['topology']:17s} {r['generation']:6s}"
              f" {r['misroute_rate']:5.2f} {r['dispatch_ms']:5.1f}"
              f" {r['b_short']:7d} {r['gamma']:5.2f} {r['k_pools']:2d}"
              f" {r['analytical']:8.2f} {r['simulated']:7.2f}"
              f" {r['all_in']:7.2f}")
    for t in timings:
        print(f"[time] {t['table']:18s} {t['wall_s']:8.2f}s"
              f"  ({t['sim_s_per_wall_s']:.0f} sim-s/wall-s)")
    print(derived)

    # acceptance: the full grid must fit inside the committed --quick
    # fleet_sim bench wall budget (the surface is only useful if it can
    # be re-measured as casually as the headline tables)
    fails = []
    incomplete = [r for r in rows if r["completed"] != n_expected(args)]
    if incomplete:
        fails.append(f"{len(incomplete)} cells dropped requests "
                     f"(first: {incomplete[0]})")
    if BENCH_JSON.exists():
        budget = [t["wall_s"] for t in
                  json.loads(BENCH_JSON.read_text())["timings"]
                  if t["table"] == "total"][-1]
        wall = [t["wall_s"] for t in timings if t["table"] == "total"][-1]
        verdict = "within" if wall <= budget else "OVER"
        print(f"grid wall-clock {wall:.1f}s vs --quick bench budget "
              f"{budget:.1f}s: {verdict}")
        if wall > budget:
            fails.append(f"grid {wall:.1f}s exceeds the --quick bench "
                         f"budget {budget:.1f}s")
    if fails:
        sys.exit("ACCEPTANCE FAIL: " + "; ".join(fails))


def n_expected(args) -> int:
    return args.n_requests


if __name__ == "__main__":
    main()
