"""Analytical vs simulated fleet tok/W (the measured side of Tables 3/4).

Runs the event-driven fleet simulator (serving.fleetsim) for every
(workload x topology) cell on the calibrated H100 Llama-70B profile and
puts the measured steady-state tok/W next to the closed-form core.fleet
prediction it was provisioned from.  `simulated` is the decode-only
measurement (like-for-like with Eq. 4); `all_in` additionally meters the
prefill compute and idle power the analytical model ignores — the gap is
the honest price of serving, TokenPowerBench-style.

Standalone:  PYTHONPATH=src python benchmarks/fleet_sim_bench.py
             [--n-requests N] [--quick]
Harness:     PYTHONPATH=src python -m benchmarks.run --only fleet_sim
"""
import sys

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import AGENT, AZURE, LMSYS
from repro.serving import simulate_topology

# per-workload split boundary (paper: Azure 4K, LMSYS 1.5K, Agent 8K)
B_SHORT = {"azure-conv": 4096, "lmsys-chat": 1536, "agent-heavy": 8192}
TOPOLOGIES = ("homo", "two_pool", "fleetopt")


def run(n_requests: int = 10_000, seed: int = 0):
    rows = []
    for wl in (AZURE, LMSYS, AGENT):
        for kind in TOPOLOGIES:
            cell = simulate_topology(
                kind, wl, H100_LLAMA70B, LLAMA31_70B,
                b_short=B_SHORT[wl.name], n_requests=n_requests, seed=seed)
            f = cell.report["fleet"]
            rows.append(dict(cell.row(),
                             occupancy={r: s["occupancy"]
                                        for r, s in cell.report.items()
                                        if r != "fleet"},
                             prefill_energy_frac=f["prefill_energy_frac"],
                             tokens_per_s=f["tokens_per_s"]))
    az = {r["topology"]: r["simulated"] for r in rows
          if r["workload"] == "azure-conv"}
    ratio = az["fleetopt"] / az["homo"] if az["homo"] else float("nan")
    derived = (f"simulated fleetopt/homo on Azure = {ratio:.2f}x "
               f"(paper analytical ~2.5x; acceptance >= 2x)")
    return rows, derived


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=10_000)
    ap.add_argument("--quick", action="store_true",
                    help="1k-request smoke run (CI)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    n = 1000 if args.quick else args.n_requests
    rows, derived = run(n_requests=n, seed=args.seed)
    hdr = (f"{'workload':12s} {'topology':9s} {'analytic':>8s} {'simulated':>9s}"
           f" {'delta%':>7s} {'all-in':>7s} {'ttft_p99':>9s} {'migr':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['workload']:12s} {r['topology']:9s} {r['analytical']:8.2f} "
              f"{r['simulated']:9.2f} {r['delta_pct']:7.1f} {r['all_in']:7.2f} "
              f"{r['ttft_p99_s']:9.2f} {r['migrations']:5d}")
    print(derived)
    az = {r["topology"]: r["simulated"] for r in rows
          if r["workload"] == "azure-conv"}
    if az["fleetopt"] < 2.0 * az["homo"]:
        sys.exit("ACCEPTANCE FAIL: simulated fleetopt < 2x homo on Azure")


if __name__ == "__main__":
    main()
