"""Analytical vs simulated fleet tok/W (Tables 3/4) + the SLO-constrained
sizing table (the measured side of the paper's own P99 TTFT constraint).

Table A (unconstrained) runs the event-driven fleet simulator
(serving.fleetsim) for every (workload x topology) cell on the calibrated
H100 Llama-70B profile and puts the measured steady-state tok/W next to
the closed-form core.fleet prediction it was provisioned from.
`simulated` is the decode-only measurement (like-for-like with Eq. 4);
`all_in` additionally meters the prefill compute and idle power the
analytical model ignores — the gap is the honest price of serving,
TokenPowerBench-style.

Table B (SLO-constrained) is the bugfix headline: PR 1 showed the fleets
Table A is quoted for *violate* the paper's P99 TTFT <= 500 ms SLO when
actually run.  `core.slo.size_to_slo` re-provisions each topology until
the measured TTFT p99 complies; every Table B cell reports the
SLO-feasible tok/W (the new headline metric next to Eq. 4's unconstrained
number) and its measured TTFT p99 — all <= 0.5 s by construction.  The
sweep covers H100/H200/B200 x homo/fleetopt/multipool(K=3) on Azure, so
the §4.2 generation-gain claim (B200/H100 ~ 1.7x) is re-measured under
the latency constraint.

Table C (disaggregation, §10.3) serves prefill/decode disaggregation
through FleetSim: homo vs fleetopt vs disagg vs disagg+fleetopt on
Azure/H100, analytical (whole-fleet and decode-only) vs measured vs
SLO-constrained, with the KV-handoff energy the interconnect really
charges.  Gates: every disagg cell's measured TTFT p99 <= 500 ms after
size_to_slo; if disagg+fleetopt's measured all-in tok/W falls short of
plain fleetopt's, the bench prints the shortfall and the KV-handoff cost
that (partially) explains it instead of failing.

Table D (model heterogeneity, §5.1/§3.2 — DESIGN.md §9) is the headline
the paper can't give: how much of the semantic-routing and MoE
active-parameter gains survives real queueing, misroutes and the TTFT
SLO.  On H100 it serves homo-70B vs fleetopt-70B vs semantic 8B/70B
(zero misroute, plus the FleetOpt-headroom variant at a 5% classifier
error with its escalation traffic) vs Qwen3-235B-A22B as a `moe_pool` at
dispatch_ms in {0, 2, 10} and as the large model of `moe_semantic` —
analytical vs measured vs SLO-constrained (with the post-compliance trim
phase).  Azure in --quick; Azure + Agent in the full run.  Gate: every
Table D cell is SLO-compliant after size_to_slo.

`--json PATH` dumps {"meta", "rows"} for CI's perf-regression diff
(benchmarks/perf_diff.py --fleet against the committed
benchmarks/results/fleet_sim.json, which is regenerated with
`--quick --json benchmarks/results/fleet_sim.json`).

`--time [PATH]` additionally records per-table and total wall-clock (plus
simulated-seconds-per-wall-second throughput) as
{table, config, wall_s, sim_s_per_wall_s} rows — the repo's perf
trajectory.  Default PATH is benchmarks/results/BENCH_fleet_sim.json (the
committed baseline `perf_diff.py --wall-budget` gates against); CI passes
an explicit scratch path so the baseline is never clobbered in place.

Standalone:  PYTHONPATH=src python benchmarks/fleet_sim_bench.py
             [--n-requests N] [--slo-requests N] [--quick] [--json PATH]
             [--time [PATH]]
Harness:     PYTHONPATH=src python -m benchmarks.run --only fleet_sim
"""
import json
import pathlib
import platform
import sys
import time

from repro.core import ladder_windows, size_to_slo
from repro.core.hardware import H100
from repro.core.modelspec import LLAMA31_70B, QWEN3_235B_A22B
from repro.core.moe import moe_profile
from repro.core.power import H100_POWER
from repro.core.profiles import (B200_LLAMA70B_FLEET, H100_LLAMA70B,
                                 H200_LLAMA70B)
from repro.core.workloads import AGENT, AZURE, LMSYS
from repro.serving import FleetSim, simulate_topology

BENCH_JSON = pathlib.Path(__file__).resolve().parent / "results" \
    / "BENCH_fleet_sim.json"

# per-workload split boundary (paper: Azure 4K, LMSYS 1.5K, Agent 8K)
B_SHORT = {"azure-conv": 4096, "lmsys-chat": 1536, "agent-heavy": 8192}
TOPOLOGIES = ("homo", "two_pool", "fleetopt")
GENERATIONS = (("H100", H100_LLAMA70B), ("H200", H200_LLAMA70B),
               ("B200", B200_LLAMA70B_FLEET))
SLO_TOPOLOGIES = ("homo", "fleetopt", "multipool")
DISAGG_TOPOLOGIES = ("disagg", "disagg_fleetopt")
K_POOLS = 3
# Table D: MoE expert-dispatch sweep and the semantic classifier error
# whose misrouted-giant-prompt tail still fits the 1% p99 TTFT budget
# (at 0.1 on Azure the misroutes alone are ~1.1% of traffic and the SLO
# is service-time unattainable — DESIGN.md §9)
MOE_DISPATCH_MS = (0.0, 2.0, 10.0)
D_MISROUTE = 0.05


def disagg_vs_fleetopt(rows):
    """(disagg rows, unconstrained Azure rows) keyed by topology — the one
    place the Table C comparison cells are looked up (run() derives the
    acceptance ratio from them, main() prints the verdict)."""
    dis = {r["topology"]: r for r in rows if r["table"] == "disagg"}
    az_a = {r["topology"]: r for r in rows
            if r["table"] == "unconstrained"
            and r.get("workload") == "azure-conv"}
    return dis, az_a


def _table_d_cells(wl):
    """(kind, profile, model, kwargs) per Table D cell for one workload."""
    bs = B_SHORT[wl.name]
    moe = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    cells = [("homo", H100_LLAMA70B, LLAMA31_70B, {}),
             ("fleetopt", H100_LLAMA70B, LLAMA31_70B, dict(b_short=bs)),
             ("semantic", H100_LLAMA70B, LLAMA31_70B, dict(b_short=bs)),
             ("semantic_fleetopt", H100_LLAMA70B, LLAMA31_70B,
              dict(b_short=bs, misroute_rate=D_MISROUTE))]
    cells += [("moe_pool", moe, QWEN3_235B_A22B, dict(dispatch_ms=d))
              for d in MOE_DISPATCH_MS]
    cells.append(("moe_semantic", moe, QWEN3_235B_A22B,
                  dict(b_short=bs, misroute_rate=D_MISROUTE,
                       dispatch_ms=2.0)))
    return cells


def table_d(workloads, *, n_requests: int, slo_requests: int, seed: int,
            engine: str = "numpy"):
    """Model-heterogeneous cells: measured + SLO-constrained, per workload."""
    rows = []
    for wl in workloads:
        for kind, prof, mdl, kw in _table_d_cells(wl):
            cell = simulate_topology(kind, wl, prof, mdl,
                                     n_requests=n_requests, seed=seed,
                                     engine=engine, **kw)
            res = size_to_slo(kind, wl, prof, mdl,
                              n_requests=slo_requests, seed=seed,
                              engine=engine, **kw)
            f = cell.report["fleet"]
            rows.append(dict(
                table="model_hetero", workload=wl.name, topology=kind,
                model=mdl.name,
                dispatch_ms=float(kw.get("dispatch_ms", 0.0)),
                misroute_rate=float(kw.get("misroute_rate", 0.0)),
                analytical=round(cell.analytical_tok_per_watt, 2),
                simulated=round(cell.sim_decode_tok_per_watt, 2),
                delta_pct=round(cell.delta_pct, 1),
                all_in=round(cell.sim_tok_per_watt, 2),
                ttft_p99_s=f.get("ttft_p99_s", 0.0),
                escalations=f["escalations"], migrations=f["migrations"],
                dispatch_energy_frac=f["moe_dispatch_energy_frac"],
                slo_feasible=round(res.slo_tok_per_watt, 2),
                slo_measured_all_in=round(res.measured_tok_per_watt, 2),
                slo_ttft_p99_s=round(res.ttft_p99_s, 3),
                slo_added=res.instances_added,
                slo_trimmed=res.instances_trimmed,
                slo_compliant=res.compliant))
    return rows


# per-kind bench arguments (kind *behaviour* lives in
# core.topospec.TopologySpec.from_kind; this is just argument selection)
_SLO_CELL_KW = {"multipool": lambda: dict(windows=ladder_windows(K_POOLS))}


def _slo_cell(kind: str, profile, *, n_requests: int, seed: int,
              engine: str = "numpy"):
    kw = _SLO_CELL_KW.get(
        kind, lambda: dict(b_short=B_SHORT[AZURE.name]))()
    return size_to_slo(kind, AZURE, profile, LLAMA31_70B,
                       n_requests=n_requests, seed=seed, engine=engine, **kw)


class _TableTimer:
    """Per-table wall-clock + simulated-seconds throughput recorder —
    the bench's perf-trajectory rows ({table, config, wall_s,
    sim_s_per_wall_s})."""

    def __init__(self, config: dict):
        self.config = config
        self.rows = []
        self._t0 = time.perf_counter()
        self._wall0 = self._t0
        self._sim0 = FleetSim.sim_seconds_total
        self._simstart = self._sim0

    def lap(self, table: str) -> None:
        now, sim = time.perf_counter(), FleetSim.sim_seconds_total
        wall = now - self._t0
        self.rows.append(dict(
            table=table, config=self.config, wall_s=round(wall, 3),
            sim_s_per_wall_s=round((sim - self._sim0) / wall, 1)
            if wall > 0 else 0.0))
        self._t0, self._sim0 = now, sim

    def total(self) -> None:
        wall = time.perf_counter() - self._wall0
        sim = FleetSim.sim_seconds_total - self._simstart
        self.rows.append(dict(
            table="total", config=self.config, wall_s=round(wall, 3),
            sim_s_per_wall_s=round(sim / wall, 1) if wall > 0 else 0.0))


def run(n_requests: int = 10_000, slo_requests: int = 3000, seed: int = 0,
        quick: bool = False, engine: str = "numpy"):
    timer = _TableTimer(dict(quick=quick, n_requests=n_requests,
                             slo_requests=slo_requests, seed=seed))
    rows = []
    for wl in (AZURE, LMSYS, AGENT):
        for kind in TOPOLOGIES:
            cell = simulate_topology(
                kind, wl, H100_LLAMA70B, LLAMA31_70B,
                b_short=B_SHORT[wl.name], n_requests=n_requests, seed=seed,
                engine=engine)
            f = cell.report["fleet"]
            rows.append(dict(cell.row(), table="unconstrained",
                             occupancy={r: s["occupancy"]
                                        for r, s in cell.report.items()
                                        if r != "fleet"},
                             prefill_energy_frac=f["prefill_energy_frac"],
                             tokens_per_s=f["tokens_per_s"]))
    timer.lap("unconstrained")
    slo = {}
    for gen, prof in GENERATIONS:
        for kind in SLO_TOPOLOGIES:
            res = _slo_cell(kind, prof, n_requests=slo_requests,
                            seed=seed, engine=engine)
            slo[(gen, kind)] = res
            rows.append(dict(res.row(), table="slo", generation=gen))
    timer.lap("slo")
    # Table C: disaggregation on Azure/H100 (homo/fleetopt cells reuse
    # Table A measured + Table B SLO numbers; only the disagg kinds add
    # simulation + SLO-loop work)
    for kind in DISAGG_TOPOLOGIES:
        cell = simulate_topology(
            kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
            b_short=B_SHORT[AZURE.name], n_requests=n_requests, seed=seed,
            engine=engine)
        res = size_to_slo(kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
                          b_short=B_SHORT[AZURE.name],
                          n_requests=slo_requests, seed=seed, engine=engine)
        f = cell.report["fleet"]
        rows.append(dict(
            table="disagg", workload=AZURE.name, topology=kind,
            analytical=round(cell.analytical_tok_per_watt, 2),
            analytical_fleet=round(cell.analytical_fleet_tok_per_watt, 2),
            simulated=round(cell.sim_decode_tok_per_watt, 2),
            delta_pct=round(cell.delta_pct, 1),
            all_in=round(cell.sim_tok_per_watt, 2),
            ttft_p99_s=f.get("ttft_p99_s", 0.0),
            handoffs=f["handoffs"], migrations=f["migrations"],
            kv_handoff_joules=f["kv_handoff_joules"],
            kv_handoff_energy_frac=f["kv_handoff_energy_frac"],
            slo_feasible=round(res.slo_tok_per_watt, 2),
            slo_measured_all_in=round(res.measured_tok_per_watt, 2),
            slo_ttft_p99_s=round(res.ttft_p99_s, 3),
            slo_added=res.instances_added,
            slo_compliant=res.compliant))
    timer.lap("disagg")
    # Table D: model heterogeneity (Azure always; Agent in the full run)
    rows += table_d((AZURE,) if quick else (AZURE, AGENT),
                    n_requests=n_requests, slo_requests=slo_requests,
                    seed=seed, engine=engine)
    timer.lap("model_hetero")
    az = {r["topology"]: r["simulated"] for r in rows
          if r.get("workload") == "azure-conv"
          and r["table"] == "unconstrained"}
    ratio = az["fleetopt"] / az["homo"] if az["homo"] else float("nan")
    slo_ratio = (slo[("H100", "fleetopt")].slo_tok_per_watt
                 / slo[("H100", "homo")].slo_tok_per_watt)
    gen_gain = {k: (slo[("B200", k)].slo_tok_per_watt
                    / slo[("H100", k)].slo_tok_per_watt)
                for k in SLO_TOPOLOGIES}
    dis, az_a = disagg_vs_fleetopt(rows)
    dfo, fo = dis["disagg_fleetopt"]["all_in"], az_a["fleetopt"]["all_in"]
    dh = {(r["workload"], r["topology"], r["dispatch_ms"]): r for r in rows
          if r["table"] == "model_hetero"}
    d_homo = dh[("azure-conv", "homo", 0.0)]
    moe_adv = {d: dh[("azure-conv", "moe_pool", d)]["simulated"]
               / d_homo["simulated"] for d in MOE_DISPATCH_MS}
    sem_adv = dh[("azure-conv", "semantic", 0.0)]["simulated"] \
        / d_homo["simulated"]
    derived = (f"simulated fleetopt/homo on Azure = {ratio:.2f}x "
               f"(acceptance >= 2x); SLO-constrained = {slo_ratio:.2f}x; "
               f"B200/H100 gain under SLO: "
               + ", ".join(f"{k} {v:.2f}x" for k, v in gen_gain.items())
               + f"; disagg+fleetopt/fleetopt all-in = {dfo / fo:.2f}x"
               + f"; measured semantic/homo = {sem_adv:.2f}x"
               + "; measured MoE/homo at dispatch "
               + ", ".join(f"{d:g}ms {v:.2f}x" for d, v in moe_adv.items()))
    timer.total()
    return rows, derived, timer.rows


def write_bench_json(timings, path=BENCH_JSON) -> None:
    """Persist the perf-trajectory rows ({table, config, wall_s,
    sim_s_per_wall_s}) with enough host metadata to judge whether a
    wall-clock delta is a code change or a runner-class change."""
    pathlib.Path(path).parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as fh:
        json.dump({"meta": dict(python=platform.python_version(),
                                machine=platform.machine(),
                                system=platform.system()),
                   "timings": timings}, fh, indent=1)


def harness_run():
    """benchmarks.run entry point: (rows, derived) like every suite, with
    the timing rows persisted as a side effect — the full-run perf
    trajectory.  Written next to (never over) the committed quick-config
    baseline BENCH_fleet_sim.json, which only a deliberate
    `--quick --time` refresh may move: the CI wall-budget gate compares
    quick against quick."""
    rows, derived, timings = run()
    write_bench_json(timings, BENCH_JSON.with_name("BENCH_fleet_sim_full"
                                                   ".json"))
    return rows, derived


# redirect benchmarks.run's generic rows dump away from the committed
# --quick CI baseline results/fleet_sim.json (full-config rows are not
# comparable cell-for-cell with the quick gate's)
harness_run.dump_name = "fleet_sim_full"


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-requests", type=int, default=10_000)
    ap.add_argument("--slo-requests", type=int, default=3000)
    ap.add_argument("--quick", action="store_true",
                    help="1k-request (1.5k SLO) smoke run (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy",
                    help="pool drive loop: the numpy oracle (default) or "
                         "the compiled serving.jax_engine drains — same "
                         "cells, same tolerances (CI diffs jax against "
                         "the committed numpy baseline)")
    ap.add_argument("--trace", metavar="PATH", nargs="?", default=None,
                    const="-",
                    help="record a FleetScope lifecycle trace of every "
                         "sim in the run (FleetSim.default_telemetry); "
                         "optional PATH dumps it as Perfetto-viewable "
                         "Chrome trace-event JSON.  Rows are unchanged "
                         "— the CI wall-budget gate runs with this on "
                         "to price the tracing overhead")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump {'meta', 'rows'} JSON (the CI perf-"
                         "regression baseline/current format)")
    ap.add_argument("--time", metavar="PATH", nargs="?", default=None,
                    const=str(BENCH_JSON),
                    help="record per-table + total wall-clock to PATH "
                         f"(default {BENCH_JSON}; gated in CI by "
                         "perf_diff.py --wall-budget)")
    args = ap.parse_args(argv)
    n = 1000 if args.quick else args.n_requests
    n_slo = 1500 if args.quick else args.slo_requests
    recorder = None
    if args.trace:
        from repro.serving import TraceRecorder, to_perfetto
        recorder = TraceRecorder(level="lifecycle")
        FleetSim.default_telemetry = recorder
    rows, derived, timings = run(n_requests=n, slo_requests=n_slo,
                                 seed=args.seed, quick=args.quick,
                                 engine=args.engine)
    if recorder is not None:
        FleetSim.default_telemetry = None
        counts = {k: v for k, v in recorder.counts().items() if v}
        print(f"=== trace: {len(recorder.events)} events over "
              f"{len(recorder.pool_names)} pools {counts} ===")
        if args.trace != "-":
            with open(args.trace, "w") as fh:
                json.dump(to_perfetto(recorder), fh)
            print(f"perfetto trace -> {args.trace}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"meta": dict(n_requests=n, slo_requests=n_slo,
                                    seed=args.seed, quick=args.quick),
                       "rows": rows}, fh, indent=1)
    if args.time:
        write_bench_json(timings, args.time)
        print("=== wall-clock (s) ===")
        for t in timings:
            print(f"{t['table']:14s} {t['wall_s']:8.2f}"
                  f"  ({t['sim_s_per_wall_s']:.0f} sim-s/wall-s)")

    print("=== Table A: unconstrained (H100) ===")
    hdr = (f"{'workload':12s} {'topology':9s} {'analytic':>8s} {'simulated':>9s}"
           f" {'delta%':>7s} {'all-in':>7s} {'ttft_p99':>9s} {'migr':>5s}")
    print(hdr)
    print("-" * len(hdr))
    uncon = [r for r in rows if r["table"] == "unconstrained"]
    for r in uncon:
        print(f"{r['workload']:12s} {r['topology']:9s} {r['analytical']:8.2f} "
              f"{r['simulated']:9.2f} {r['delta_pct']:7.1f} {r['all_in']:7.2f} "
              f"{r['ttft_p99_s']:9.2f} {r['migrations']:5d}")

    print("\n=== Table B: SLO-constrained (Azure, P99 TTFT <= 500 ms) ===")
    hdr = (f"{'gen':5s} {'topology':9s} {'Eq.4':>7s} {'SLO-ok':>7s}"
           f" {'cost%':>6s} {'measured':>8s} {'ttft_p99':>9s} {'inst':>5s}"
           f" {'+add':>5s} {'rds':>4s}")
    print(hdr)
    print("-" * len(hdr))
    slo_rows = [r for r in rows if r["table"] == "slo"]
    for r in slo_rows:
        print(f"{r['generation']:5s} {r['topology']:9s}"
              f" {r['unconstrained']:7.2f} {r['slo_feasible']:7.2f}"
              f" {r['cost_pct']:6.1f} {r['measured']:8.2f}"
              f" {r['ttft_p99_s']:9.3f} {r['instances']:5d}"
              f" {r['added']:5d} {r['rounds']:4d}"
              + ("" if r["compliant"] else "  NON-COMPLIANT"))

    print("\n=== Table C: prefill/decode disaggregation (Azure, H100) ===")
    dis, az_a = disagg_vs_fleetopt(rows)
    slo_b = {r["topology"]: r for r in slo_rows
             if r["generation"] == "H100"}
    dis_rows = list(dis.values())
    hdr = (f"{'topology':16s} {'an.fleet':>8s} {'an.dec':>7s} {'simul':>7s}"
           f" {'all-in':>7s} {'SLO-ok':>7s} {'ttft(SLO)':>10s}"
           f" {'kvJ':>8s} {'hoffs':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for kind in ("homo", "fleetopt"):
        a, b = az_a[kind], slo_b[kind]
        print(f"{kind:16s} {a['analytical']:8.2f} {a['analytical']:7.2f}"
              f" {a['simulated']:7.2f} {a['all_in']:7.2f}"
              f" {b['slo_feasible']:7.2f} {b['ttft_p99_s']:10.3f}"
              f" {'-':>8s} {'-':>6s}")
    for kind in ("disagg", "disagg_fleetopt"):
        r = dis[kind]
        print(f"{kind:16s} {r['analytical_fleet']:8.2f}"
              f" {r['analytical']:7.2f} {r['simulated']:7.2f}"
              f" {r['all_in']:7.2f} {r['slo_feasible']:7.2f}"
              f" {r['slo_ttft_p99_s']:10.3f}"
              f" {r['kv_handoff_joules']:8.1f} {r['handoffs']:6d}"
              + ("" if r["slo_compliant"] else "  NON-COMPLIANT"))
    print("\n=== Table D: model heterogeneity (H100, semantic + MoE) ===")
    hdr = (f"{'workload':12s} {'topology':17s} {'model':16s} {'disp':>5s}"
           f" {'misr':>5s} {'analytic':>8s} {'simul':>7s} {'all-in':>7s}"
           f" {'SLO-ok':>7s} {'ttft(SLO)':>10s} {'esc':>5s} {'trim':>5s}")
    print(hdr)
    print("-" * len(hdr))
    het_rows = [r for r in rows if r["table"] == "model_hetero"]
    for r in het_rows:
        print(f"{r['workload']:12s} {r['topology']:17s}"
              f" {r['model'][:16]:16s} {r['dispatch_ms']:5.0f}"
              f" {r['misroute_rate']:5.2f} {r['analytical']:8.2f}"
              f" {r['simulated']:7.2f} {r['all_in']:7.2f}"
              f" {r['slo_feasible']:7.2f} {r['slo_ttft_p99_s']:10.3f}"
              f" {r['escalations']:5d} {r['slo_trimmed']:5d}"
              + ("" if r["slo_compliant"] else "  NON-COMPLIANT"))

    dfo, fo = dis["disagg_fleetopt"]["all_in"], az_a["fleetopt"]["all_in"]
    if dfo >= fo:
        print(f"measured: disagg+fleetopt all-in tok/W beats interleaved "
              f"fleetopt ({dfo:.2f} vs {fo:.2f}, +{100 * (dfo / fo - 1):.1f}%)"
              f" — prefill interference removed from the decode pools")
    else:
        r = dis["disagg_fleetopt"]
        print(f"measured: disagg+fleetopt all-in tok/W falls short of "
              f"interleaved fleetopt ({dfo:.2f} vs {fo:.2f}, "
              f"{100 * (dfo / fo - 1):.1f}%) — the dedicated prefill fleet "
              f"burns saturated watts the interleave absorbed; KV handoff "
              f"adds {r['kv_handoff_joules']:.1f} J "
              f"({100 * r['kv_handoff_energy_frac']:.3f}% of fleet energy)")
    print(derived)

    # acceptance gates -----------------------------------------------------
    fails = []
    az = {r["topology"]: r["simulated"] for r in uncon
          if r["workload"] == "azure-conv"}
    if az["fleetopt"] < 2.0 * az["homo"]:
        fails.append("simulated fleetopt < 2x homo on Azure")
    bad = [f"{r['generation']}/{r['topology']}" for r in slo_rows
           if not r["compliant"] or r["ttft_p99_s"] > 0.5]
    if bad:
        fails.append(f"SLO cells non-compliant: {bad}")
    slo_az = {(r["generation"], r["topology"]): r["slo_feasible"]
              for r in slo_rows}
    if slo_az[("H100", "fleetopt")] < 2.0 * slo_az[("H100", "homo")]:
        fails.append("SLO-constrained fleetopt < 2x homo on Azure (H100)")
    bad_dis = [r["topology"] for r in dis_rows
               if not r["slo_compliant"] or r["slo_ttft_p99_s"] > 0.5]
    if bad_dis:
        fails.append(f"disagg cells violate the TTFT SLO after"
                     f" size_to_slo: {bad_dis}")
    bad_het = [f"{r['workload']}/{r['topology']}@d{r['dispatch_ms']:g}"
               for r in het_rows
               if not r["slo_compliant"] or r["slo_ttft_p99_s"] > 0.5]
    if bad_het:
        fails.append(f"Table D cells violate the TTFT SLO after"
                     f" size_to_slo: {bad_het}")
    if fails:
        sys.exit("ACCEPTANCE FAIL: " + "; ".join(fails))


if __name__ == "__main__":
    main()
