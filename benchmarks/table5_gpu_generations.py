"""Table 5: GPU generation comparison (70B @ 8K) + tok/$M."""
from repro.core.profiles import GENERATION_PROFILES
from repro.core.tokenomics import tok_per_dollar_m

PAPER = {  # gpu -> (n_max@8K, tok/W, tok/$M)
    "H100-SXM5": (22, 7.41, 0.30), "H200-SXM": (44, 15.58, 0.49),
    "B200-SXM": (58, 20.93, 0.73), "GB200-NVL": (65, 18.49, 0.63),
}
# NOTE: the paper's Table 5 n_max uses the *replicated-KV* ComputedProfile
# (22 @ 8K) while its tok/W column matches the calibrated profiles'
# throughput at saturation; we report our calibrated profiles and flag the
# divergence (DESIGN.md §4).


def run():
    rows = []
    for name, prof in GENERATION_PROFILES.items():
        tpw = prof.tok_per_watt_at_window(8192)
        row = dict(gpu=name, tdp_w=prof.chip.tdp_w,
                   p_idle_w=prof.power_model.p_idle_w,
                   w_ms=round(prof.roofline.w_ms, 2),
                   n_max_8k=prof.n_max(8192),
                   tok_per_watt=round(tpw, 2),
                   tok_per_dollar_m=round(tok_per_dollar_m(prof, 8192), 2))
        if name in PAPER:
            row["tok_per_watt_paper"] = PAPER[name][1]
        rows.append(row)
    tpw = {r["gpu"]: r["tok_per_watt"] for r in rows}
    order_ok = (tpw["B200-SXM"] > tpw["H200-SXM"] > tpw["H100-SXM5"]
                and tpw["GB200-NVL"] < tpw["B200-SXM"])
    return rows, f"paper_ordering_reproduced={order_ok} (incl. GB200 dip)"
