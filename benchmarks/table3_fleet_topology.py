"""Table 3: fleet tok/W for Homo / Pool / FleetOpt on H100 & B200,
Azure + LMSYS workloads, plus the §4.2 gain decomposition."""
from repro.core import (AZURE, LMSYS, B200_LLAMA70B_FLEET, H100_LLAMA70B,
                        FleetOpt, Homogeneous, TwoPool, gain_decomposition)
from repro.core.modelspec import LLAMA31_70B

PAPER = {  # (workload, gpu, topo) -> (instances, kW, tok/W)
    ("azure", "H100", "homo"): (141, 58.3, 5.58),
    ("azure", "H100", "pool"): (68, 32.0, 9.16),
    ("azure", "H100", "fleetopt"): (40, 23.1, 14.08),
    ("azure", "B200", "homo"): (47, 33.4, 9.74),
    ("azure", "B200", "pool"): (25, 19.1, 15.39),
    ("azure", "B200", "fleetopt"): (17, 13.7, 23.71),
    ("lmsys", "H100", "homo"): (69, 28.5, 4.77),
    ("lmsys", "H100", "pool"): (38, 16.4, 7.91),
    ("lmsys", "H100", "fleetopt"): (29, 12.9, 10.30),
    ("lmsys", "B200", "homo"): (24, 17.0, 7.98),
    ("lmsys", "B200", "pool"): (16, 11.7, 11.12),
    ("lmsys", "B200", "fleetopt"): (12, 9.0, 14.82),
}


def run():
    rows = []
    tpw_azure = {}
    for wname, wl, bs in (("azure", AZURE, 4096), ("lmsys", LMSYS, 1536)):
        for gname, prof in (("H100", H100_LLAMA70B),
                            ("B200", B200_LLAMA70B_FLEET)):
            reps = {
                "homo": Homogeneous().provision(wl, prof, LLAMA31_70B),
                "pool": TwoPool(b_short=bs).provision(wl, prof, LLAMA31_70B),
                "fleetopt": FleetOpt(b_short=bs, gamma=2.0).provision(
                    wl, prof, LLAMA31_70B)}
            if wname == "azure":
                tpw_azure[gname] = {t: r.tok_per_watt
                                    for t, r in reps.items()}
            for topo, rep in reps.items():
                pi, pk, pt = PAPER[(wname, gname, topo)]
                rows.append(dict(
                    workload=wname, gpu=gname, topology=topo,
                    instances=rep.instances, instances_paper=pi,
                    kw=round(rep.power_kw, 1), kw_paper=pk,
                    tok_per_watt=round(rep.tok_per_watt, 2),
                    tok_per_watt_paper=pt,
                    delta_pct=round(100 * (rep.tok_per_watt / pt - 1), 0)))
    g = gain_decomposition(tpw_azure)
    return rows, (f"combined={g['combined']:.2f}x (paper 4.25) "
                  f"topo_h100={g['topo_h100']:.2f} (2.52) "
                  f"gen_homo={g['gen_homo']:.2f} (1.75)")
