"""Searched vs hand-built topologies (the TopologySpec IR payoff).

`core.topo_search.optimize_topology` searches the declarative topology
space (window ladder depth K, per-rung chip and model, overflow headroom
gamma, prefill/decode disaggregation) for the fleet with the highest
measured-SLO-compliant tok/W.  This bench puts the searched fleet next
to every hand-built §4 topology — homo / two_pool / fleetopt /
multipool(K=3) — on Azure, LMSYS and Agent (Azure only in --quick),
ALL evaluated through the SAME `core.slo.size_to_slo_spec` evaluator
against the SAME frozen arrival trace (common random numbers: the
comparison is topology vs topology, never noise vs noise).

Acceptance gate: on every workload the searched fleet's SLO-compliant
tok/W >= the best hand-built topology's (within 1e-6 — the search is
seeded at multipool K=3, so it can only tie or beat the incumbent).

Rows carry `spec_hash` — the stable TopologySpec hash — which
benchmarks/perf_diff.py folds into the regression-diff cell key, so a
searched topology that *changes shape* shows up as a new cell (and a
missing old one) instead of a silent metric swap.

Standalone:  PYTHONPATH=src python benchmarks/topology_search_bench.py
             [--quick] [--json PATH] [--seed N] [--engine numpy|jax]
Harness:     PYTHONPATH=src python -m benchmarks.run --only topology_search
"""
import json
import sys

from repro.core import ladder_windows
from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.routing import LONG_WINDOW
from repro.core.slo import SLOSpec, size_to_slo_spec
from repro.core.topo_search import optimize_topology
from repro.core.topospec import TopologySpec
from repro.core.workloads import AGENT, AZURE, LMSYS

# per-workload split boundary (same as fleet_sim_bench)
B_SHORT = {"azure-conv": 4096, "lmsys-chat": 1536, "agent-heavy": 8192}
HAND_BUILT = ("homo", "two_pool", "fleetopt", "multipool")
K_POOLS = 3

# per-kind hand-built spec arguments (kind behaviour itself lives in
# TopologySpec.from_kind — this is just bench argument selection)
_HAND_KW = {"multipool": lambda wl: dict(windows=ladder_windows(K_POOLS))}


def _hand_spec(kind: str, wl) -> TopologySpec:
    kw = _HAND_KW.get(kind, lambda wl: dict(b_short=B_SHORT[wl.name]))(wl)
    return TopologySpec.from_kind(kind, H100_LLAMA70B, LLAMA31_70B, **kw)


def run(slo_requests: int = 3000, seed: int = 0, budget: int = 24,
        quick: bool = False, engine: str = "numpy"):
    from repro.serving.request import sample_trace

    slo = SLOSpec()
    rows = []
    for wl in (AZURE,) if quick else (AZURE, LMSYS, AGENT):
        # ONE frozen trace per workload, shared by every hand-built spec
        # AND the search (every spec's max_window is LONG_WINDOW)
        trace = sample_trace(wl, slo_requests, seed=seed,
                             max_total=LONG_WINDOW)
        best_hand, best_hand_kind = float("-inf"), None
        for kind in HAND_BUILT:
            spec = _hand_spec(kind, wl)
            res = size_to_slo_spec(
                spec, wl, slo=slo, n_requests=slo_requests, seed=seed,
                trim=False, engine=engine, trace=trace)
            score = res.slo_tok_per_watt if res.compliant else 0.0
            if res.compliant and score > best_hand:
                best_hand, best_hand_kind = score, kind
            rows.append(dict(
                table="topology_search", workload=wl.name, topology=kind,
                label=spec.label, spec_hash=spec.spec_hash,
                slo_feasible=round(score, 2),
                measured=round(res.measured_decode_tok_per_watt, 2),
                ttft_p99_s=round(res.ttft_p99_s, 3),
                instances=res.plan.instances, compliant=res.compliant))
        sr = optimize_topology(
            wl, H100_LLAMA70B, LLAMA31_70B, slo=slo,
            small_model=LLAMA31_8B, n_requests=slo_requests, seed=seed,
            budget=budget, trim=False, engine=engine)
        rows.append(dict(
            table="topology_search", workload=wl.name, topology="searched",
            label=sr.best_spec.label, spec_hash=sr.best_spec.spec_hash,
            # same convention as the hand-built rows: a non-compliant
            # fleet's SLO-feasible tok/W is 0, not -inf (keeps the JSON
            # dump strict and the diff cells finite)
            slo_feasible=round(sr.best_score, 2)
            if sr.best_result.compliant else 0.0,
            measured=round(sr.best_result.measured_decode_tok_per_watt, 2),
            ttft_p99_s=round(sr.best_result.ttft_p99_s, 3),
            instances=sr.best_result.plan.instances,
            compliant=sr.best_result.compliant,
            evaluations=sr.evaluations, restarts=sr.restarts,
            best_hand_built=best_hand_kind,
            gain_vs_hand_pct=round(
                100.0 * (sr.best_score / best_hand - 1.0), 1)
            if best_hand > 0 else None))
    searched = {r["workload"]: r for r in rows if r["topology"] == "searched"}
    derived = "; ".join(
        f"{w}: searched {r['slo_feasible']:.2f} tok/W ({r['label']})"
        + (f" vs best hand-built {r['best_hand_built']}"
           f" ({r['gain_vs_hand_pct']:+g}%)"
           if r["best_hand_built"] is not None
           else " (no hand-built topology is SLO-compliant)")
        for w, r in searched.items())
    return rows, derived


def harness_run():
    return run()


# the harness runs the full config; the committed --quick CI baseline
# results/topology_search.json must never be overwritten by it
harness_run.dump_name = "topology_search_full"


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slo-requests", type=int, default=3000)
    ap.add_argument("--budget", type=int, default=24,
                    help="max novel spec evaluations per workload")
    ap.add_argument("--quick", action="store_true",
                    help="Azure-only, 1.5k-request, small-budget smoke (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("numpy", "jax"), default="numpy")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="dump {'meta', 'rows'} JSON (perf_diff format)")
    args = ap.parse_args(argv)
    n = 1500 if args.quick else args.slo_requests
    budget = 10 if args.quick else args.budget
    rows, derived = run(slo_requests=n, seed=args.seed, budget=budget,
                        quick=args.quick, engine=args.engine)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"meta": dict(slo_requests=n, budget=budget,
                                    seed=args.seed, quick=args.quick),
                       "rows": rows}, fh, indent=1)

    hdr = (f"{'workload':12s} {'topology':10s} {'spec':30s} {'SLO-ok':>7s}"
           f" {'measured':>8s} {'ttft_p99':>9s} {'inst':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['workload']:12s} {r['topology']:10s} {r['label'][:30]:30s}"
              f" {r['slo_feasible']:7.2f} {r['measured']:8.2f}"
              f" {r['ttft_p99_s']:9.3f} {r['instances']:5d}"
              + ("" if r["compliant"] else "  NON-COMPLIANT"))
    print(derived)

    # acceptance gate: searched >= best hand-built on every workload.
    # A workload where NOTHING complies (agent-heavy at the full config:
    # the 8K+ prompt prefill alone busts the 500 ms TTFT p99 — the SLO
    # is service-time unattainable, cf. DESIGN.md §9) is a reported
    # finding, not a search failure; the gate only fires when the SLO is
    # attainable and the search missed it.
    fails = []
    for wl_name, sr in {r["workload"]: r for r in rows
                        if r["topology"] == "searched"}.items():
        hand = [r["slo_feasible"] for r in rows
                if r["workload"] == wl_name and r["topology"] != "searched"
                and r["compliant"]]
        if not hand and not sr["compliant"]:
            print(f"note: {wl_name}: no topology (hand-built or searched)"
                  f" meets the SLO — service-time unattainable")
        elif not sr["compliant"]:
            fails.append(f"{wl_name}: searched fleet is not SLO-compliant"
                         f" but hand-built {max(hand):.2f} tok/W is")
        elif hand and sr["slo_feasible"] < max(hand) - 1e-6:
            fails.append(f"{wl_name}: searched {sr['slo_feasible']:.2f} <"
                         f" best hand-built {max(hand):.2f}")
    if fails:
        sys.exit("ACCEPTANCE FAIL: " + "; ".join(fails))


if __name__ == "__main__":
    main()
