"""Appendix A Table 7: power model parameters (+ the x0 = log2(W/H0)
roofline consistency check)."""
import math

from repro.core.power import POWER_MODELS
from repro.core.profiles import GENERATION_PROFILES

PAPER = {  # gpu -> (tdp, p_idle, p_nom, k, x0)
    "H100-SXM5": (700, 300, 600, 1.0, 4.2),
    "H200-SXM": (700, 300, 600, 1.0, 5.5),
    "B200-SXM": (1000, 430, 860, 1.0, 6.8),
    "GB200-NVL": (1200, 516, 1032, 1.0, 6.8),
}


def run():
    rows = []
    for name, pm in POWER_MODELS.items():
        row = dict(gpu=name, p_idle_w=pm.p_idle_w, p_nom_w=pm.p_nom_w,
                   k=pm.k, x0=pm.x0, quality=pm.quality)
        if name in PAPER:
            row["x0_paper"] = PAPER[name][4]
        prof = GENERATION_PROFILES.get(name)
        if prof:
            # Appendix A footnote: x0 = log2(W / H0)
            row["x0_from_roofline"] = round(
                math.log2(prof.roofline.w_ms / prof.roofline.h0_ms), 2)
        rows.append(row)
    return rows, ("B200 x0: Table-1-consistent 4.45 used; Appendix-A lists "
                  "6.8 (paper-internal inconsistency, see EXPERIMENTS.md)")
