"""§Perf summary: optimized vs baseline bounding roofline term per pair."""
import json
import pathlib

import numpy as np

HERE = pathlib.Path(__file__).resolve().parent
BASE = HERE / "results" / "dryrun_baseline"
NEW = HERE / "results" / "dryrun"


def rows_for(mesh: str = "pod16x16"):
    rows = []
    for f in sorted(NEW.glob(f"*_{mesh}.json")):
        b = BASE / f.name
        if not b.exists():
            continue
        rb, rn = json.loads(b.read_text()), json.loads(f.read_text())
        if rb.get("status") != "ok" or rn.get("status") != "ok":
            continue
        tb = max(rb["roofline"][k]
                 for k in ("compute_s", "memory_s", "collective_s"))
        tn = max(rn["roofline"][k]
                 for k in ("compute_s", "memory_s", "collective_s"))
        rows.append(dict(pair=f.name.replace(f"_{mesh}.json", ""),
                         baseline_ms=round(tb * 1e3, 2),
                         optimized_ms=round(tn * 1e3, 2),
                         ratio=round(tn / tb, 3),
                         dominant_after=rn["roofline"]["dominant"]))
    return rows


def run():
    rows = rows_for()
    if not rows:
        return [], "baseline snapshot missing"
    g = float(np.exp(np.mean([np.log(r["ratio"]) for r in rows])))
    best = min(rows, key=lambda r: r["ratio"])
    return rows, (f"geomean bounding-term ratio {g:.2f} over {len(rows)} "
                  f"pairs; best {best['pair']} at {best['ratio']}")
