"""Table 1: n_max and tok/W vs context window (the 1/W law)."""
from repro.core import B200_LLAMA70B, H100_LLAMA70B, context_sweep

PAPER = {
    "H100-SXM5": [(2048, 512, 598, 35.0), (4096, 256, 593, 17.6),
                  (8192, 128, 583, 8.97), (16384, 64, 557, 4.69),
                  (32768, 32, 507, 2.58), (65536, 16, 435, 1.50),
                  (131072, 8, 369, 0.88)],
    "B200-SXM": [(2048, 1343, 859, 61.4), (4096, 671, 857, 30.8),
                 (8192, 335, 852, 15.5), (16384, 167, 838, 7.87),
                 (32768, 83, 805, 4.09), (65536, 41, 735, 2.24),
                 (131072, 20, 630, 1.30)],
}


def run():
    rows = []
    worst = 0.0
    for gpu, prof in (("H100-SXM5", H100_LLAMA70B),
                      ("B200-SXM", B200_LLAMA70B)):
        sweep = context_sweep(prof)
        for r, (ctx, nm, psat, tpw) in zip(sweep, PAPER[gpu]):
            delta = r.tok_per_watt / tpw - 1
            worst = max(worst, abs(delta))
            rows.append(dict(gpu=gpu, context=ctx, n_max=r.n_max,
                             n_max_paper=nm,
                             p_sat_w=round(r.p_sat_w, 0),
                             tok_per_watt=round(r.tok_per_watt, 2),
                             tok_per_watt_paper=tpw,
                             delta_pct=round(100 * delta, 1)))
    return rows, f"worst_cell_delta={100 * worst:.1f}%"
