"""Table 6: topology & GPU recommendation by workload archetype — derived
by evaluating every (topology x GPU) combination per archetype and ranking
by fleet tok/W (the paper's stated ranking criterion)."""
from repro.core import (AGENT, AZURE, LMSYS, B200_LLAMA70B_FLEET,
                        H100_LLAMA70B, H200_LLAMA70B, FleetOpt, Homogeneous,
                        TwoPool)
from repro.core.modelspec import LLAMA31_70B

GPUS = {"H100": H100_LLAMA70B, "H200": H200_LLAMA70B,
        "B200": B200_LLAMA70B_FLEET}
PAPER_BEST = {"azure-conv": ("fleetopt", "B200"),
              "lmsys-chat": ("fleetopt", "B200"),
              "agent-heavy": (None, "B200")}   # paper: long-dominant -> homo


def run():
    rows = []
    for wl, bs in ((AZURE, 4096), (LMSYS, 1536), (AGENT, 8192)):
        best = (None, None, -1.0)
        for gname, prof in GPUS.items():
            for tname, topo in (("homo", Homogeneous()),
                                ("pool", TwoPool(b_short=bs)),
                                ("fleetopt", FleetOpt(b_short=bs,
                                                      gamma=2.0))):
                rep = topo.provision(wl, prof, LLAMA31_70B)
                if rep.tok_per_watt > best[2]:
                    best = (tname, gname, rep.tok_per_watt)
        frac8k = wl.frac_total_leq(8192)
        archetype = ("short-dominant" if frac8k > 0.8 else
                     "mixed" if frac8k > 0.5 else "long-dominant")
        rows.append(dict(workload=wl.name, frac_leq_8k=round(frac8k, 2),
                         archetype=archetype, best_topology=best[0],
                         best_gpu=best[1],
                         best_tok_per_watt=round(best[2], 2)))
    ok = all(r["best_gpu"] == "B200" for r in rows)
    return rows, f"b200_best_everywhere={ok} (paper Table 6 agrees)"
