"""§5.2 quantization sweep, §3.2 MoE dispatch sensitivity, and the
beyond-paper per-architecture 1/W-law curves (all 10 assigned archs +
TPU v5e profile)."""
import dataclasses

from repro.configs import get_config, list_archs
from repro.core import computed_profile, context_sweep, fit_one_over_w
from repro.core.hardware import H100, TPU_V5E
from repro.core.modelspec import LLAMA31_70B, QWEN3_235B_A22B
from repro.core.moe import dispatch_sensitivity
from repro.core.power import H100_POWER, TPU_V5E_POWER


def quantization():
    rows = []
    for label, b in (("fp16", 2.0), ("fp8", 1.0), ("int4", 0.5)):
        m = dataclasses.replace(LLAMA31_70B, dtype_bytes=b)
        prof = computed_profile(m, H100, H100_POWER, tp=8)
        rows.append(dict(quant=label, w_ms=round(prof.roofline.w_ms, 2),
                         n_max_8k=prof.n_max(8192),
                         tok_per_watt_8k=round(
                             prof.tok_per_watt_at_window(8192), 2)))
    # beyond-paper: int8 *KV cache* (weights fp16): kappa/2 -> n_max x2.
    # On the 1/W curve that is worth one full context-doubling — i.e. a
    # software change worth roughly a hardware generation at long context.
    base = computed_profile(LLAMA31_70B, H100, H100_POWER, tp=8)
    kv8 = computed_profile(LLAMA31_70B, H100, H100_POWER, tp=8,
                           kv_overhead=0.67)  # 1.34 * (1/2)
    for w in (8192, 65536):
        rows.append(dict(quant="int8-kv", window=w,
                         n_max=kv8.n_max(w), n_max_fp16=base.n_max(w),
                         tok_per_watt=round(kv8.tok_per_watt_at_window(w), 2),
                         tok_per_watt_fp16=round(
                             base.tok_per_watt_at_window(w), 2)))
    d = rows[1]["tok_per_watt_8k"] / rows[0]["tok_per_watt_8k"]
    kvgain = rows[-1]["tok_per_watt"] / rows[-1]["tok_per_watt_fp16"]
    return rows, (f"fp8_gain={d:.2f}x (paper: ~2x); int8-KV at 64K: "
                  f"{kvgain:.2f}x (~ one GPU generation, for free)")


def moe_dispatch():
    pts = dispatch_sensitivity(QWEN3_235B_A22B, LLAMA31_70B, H100,
                               H100_POWER)
    rows = [dict(dispatch_ms=p.dispatch_ms,
                 tok_per_watt=round(p.tok_per_watt, 2),
                 advantage=round(p.advantage_vs_dense, 2)) for p in pts]
    return rows, (f"advantage {rows[0]['advantage']}x -> "
                  f"{rows[-1]['advantage']}x at 20ms dispatch")


def per_arch_law():
    """Beyond-paper: the 1/W law for every assigned architecture, on the
    paper's H100 and on this framework's TPU v5e target."""
    rows = []
    for arch in list_archs():
        spec = get_config(arch).analytical_spec()
        for chip, pm, tp in ((H100, H100_POWER, 8),
                             (TPU_V5E, TPU_V5E_POWER, 16)):
            prof = computed_profile(spec, chip, pm, tp=tp)
            if spec.n_kv_heads == 0:
                rows.append(dict(arch=arch, chip=chip.name, law="exempt",
                                 slope=0.0,
                                 note="attention-free: no KV ceiling"))
                continue
            fit = fit_one_over_w(prof,
                                 contexts=(2048, 4096, 8192, 16384, 32768))
            rows.append(dict(arch=arch, chip=chip.name,
                             slope=round(fit.slope, 2),
                             tpw_4k=round(
                                 prof.tok_per_watt_at_window(4096), 2),
                             tpw_32k=round(
                                 prof.tok_per_watt_at_window(32768), 2),
                             law="holds" if fit.slope < -0.8 else "weakened"))
    return rows, "1/W law: holds for attention archs, weakened for hybrid, exempt for SSM"
