"""Perf-iteration helper: diff two dry-run result JSONs (before/after a
change) on the three roofline terms.

  PYTHONPATH=src python -m benchmarks.perf_diff \
      benchmarks/results/dryrun_baseline/yi-6b_decode_32k_pod16x16.json \
      benchmarks/results/dryrun/yi-6b_decode_32k_pod16x16.json
"""
import json
import sys


def diff(a_path: str, b_path: str) -> dict:
    a = json.loads(open(a_path).read())
    b = json.loads(open(b_path).read())
    out = {"pair": f"{a['arch']} x {a['shape']} ({a['mesh']})"}
    for k in ("compute_s", "memory_s", "collective_s"):
        va, vb = a["roofline"][k], b["roofline"][k]
        out[k] = dict(before_ms=round(va * 1e3, 3),
                      after_ms=round(vb * 1e3, 3),
                      delta_pct=round(100 * (vb / va - 1), 1) if va else None)
    out["dominant"] = {"before": a["roofline"]["dominant"],
                       "after": b["roofline"]["dominant"]}
    pa = a["bytes_per_device"]["peak_estimate"] / 2 ** 30
    pb = b["bytes_per_device"]["peak_estimate"] / 2 ** 30
    out["gib_per_device"] = dict(before=round(pa, 2), after=round(pb, 2),
                                 delta_pct=round(100 * (pb / pa - 1), 1))
    return out


if __name__ == "__main__":
    print(json.dumps(diff(sys.argv[1], sys.argv[2]), indent=2))
