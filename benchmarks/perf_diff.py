"""Perf-iteration helpers.

Two modes:

1. Dry-run roofline diff (legacy): diff two dry-run result JSONs
   (before/after a change) on the three roofline terms.

     PYTHONPATH=src python -m benchmarks.perf_diff \
         benchmarks/results/dryrun_baseline/yi-6b_decode_32k_pod16x16.json \
         benchmarks/results/dryrun/yi-6b_decode_32k_pod16x16.json

2. Fleet tok/W regression gate (CI): diff a fresh
   `fleet_sim_bench.py --quick --json` dump against the committed
   baseline, cell by cell, and exit non-zero when any tok/W cell drifts
   beyond the tolerance.  Runs are seeded and deterministic, so any
   drift is a real code-behaviour change: a drop is a perf regression; a
   rise means the baseline is stale and must be regenerated (with
   `--quick --json benchmarks/results/fleet_sim.json`) so the gate keeps
   teeth.

     PYTHONPATH=src python -m benchmarks.perf_diff --fleet \
         benchmarks/results/fleet_sim.json current.json [--tolerance 10]

   Accepts both the bench's {"meta", "rows"} dump and the bare row list
   `benchmarks/run.py` writes.  A cell is keyed by
   (table, generation, workload, topology, provisioning, dispatch_ms,
   misroute_rate) — `provisioning` splits Table F's static vs autoscaled
   rows, `dispatch_ms`/`misroute_rate` disambiguate the
   model-heterogeneous Table D sweep cells; each is empty for rows that
   don't carry the field (keys are computed identically from both files,
   so adding a key field never perturbs existing baselines) — plus the
   row's `spec_hash` when it carries one (searched-fleet rows from
   topology_search_bench.py: the stable TopologySpec hash keeps two
   different searched topologies from colliding in one cell); its metric
   is the row's primary tok/W field (`simulated` for measured tables,
   `slo_feasible` for SLO tables; both when a row carries both).

   When `$GITHUB_STEP_SUMMARY` is set (GitHub Actions), the per-cell
   diff table is additionally appended there as job-summary markdown,
   worst delta first, so a red perf job shows its damage without
   digging through logs.

3. Wall-clock budget gate (CI, alongside --fleet): diff the bench's
   timing dump (`fleet_sim_bench.py --time`, rows of
   {table, config, wall_s, sim_s_per_wall_s}) against the committed
   benchmarks/results/BENCH_fleet_sim.json and fail when the current
   *total* wall-clock exceeds `--wall-budget` times the baseline —
   a PR that slows the --quick bench by more than the budget factor
   fails even if every tok/W cell is unchanged.  The default 1.5x
   headroom absorbs runner-class variance between the machine that
   recorded the baseline and the CI runner; the perf job uploads its
   `bench_wall_current.json` as an artifact precisely so the committed
   baseline can be refreshed *from the CI runner class* (download the
   artifact from a green run and commit it as BENCH_fleet_sim.json).
   Regenerate deliberately — never to paper over a slowdown.

     PYTHONPATH=src python -m benchmarks.perf_diff --fleet \
         benchmarks/results/fleet_sim.json current.json \
         --wall-budget 1.5 \
         --bench-baseline benchmarks/results/BENCH_fleet_sim.json \
         --bench-current bench_current.json
"""
import argparse
import json
import os
import sys

# tok/W metrics gated per row: measured (simulated) and SLO-constrained
# (slo_feasible) are diffed independently when a row carries both (disagg
# rows do); tok_per_watt is the fallback for plain FleetReport-style rows
_METRIC_FIELDS = ("simulated", "slo_feasible", "tok_per_watt")


def diff(a_path: str, b_path: str) -> dict:
    a = json.loads(open(a_path).read())
    b = json.loads(open(b_path).read())
    out = {"pair": f"{a['arch']} x {a['shape']} ({a['mesh']})"}
    for k in ("compute_s", "memory_s", "collective_s"):
        va, vb = a["roofline"][k], b["roofline"][k]
        out[k] = dict(before_ms=round(va * 1e3, 3),
                      after_ms=round(vb * 1e3, 3),
                      delta_pct=round(100 * (vb / va - 1), 1) if va else None)
    out["dominant"] = {"before": a["roofline"]["dominant"],
                       "after": b["roofline"]["dominant"]}
    pa = a["bytes_per_device"]["peak_estimate"] / 2 ** 30
    pb = b["bytes_per_device"]["peak_estimate"] / 2 ** 30
    out["gib_per_device"] = dict(before=round(pa, 2), after=round(pb, 2),
                                 delta_pct=round(100 * (pb / pa - 1), 1))
    return out


def _fleet_cells(path: str) -> dict:
    data = json.loads(open(path).read())
    rows = data["rows"] if isinstance(data, dict) else data
    cells = {}
    for r in rows:
        if not isinstance(r, dict) or "topology" not in r:
            continue
        key = "/".join(str(r.get(k, "")) for k in
                       ("table", "generation", "workload", "topology",
                        "provisioning", "dispatch_ms", "misroute_rate"))
        # searched-fleet rows (benchmarks/topology_search_bench.py) carry
        # a TopologySpec hash: two different searched topologies must
        # never collapse into one diff cell
        if r.get("spec_hash"):
            key += "/" + str(r["spec_hash"])
        present = [f for f in _METRIC_FIELDS[:2] if f in r]
        if not present and _METRIC_FIELDS[2] in r:
            present = [_METRIC_FIELDS[2]]
        for f in present:
            cells[f"{key}:{f}"] = float(r[f])
    return cells


def fleet_diff(base_path: str, cur_path: str,
               tolerance_pct: float = 10.0) -> dict:
    base, cur = _fleet_cells(base_path), _fleet_cells(cur_path)
    cells, out_of_tol = [], []
    for key in sorted(base):
        if key not in cur:
            continue
        b, c = base[key], cur[key]
        delta = 100.0 * (c / b - 1.0) if b else (0.0 if not c else 1e9)
        cell = dict(cell=key, baseline=b, current=round(c, 3),
                    delta_pct=round(delta, 2))
        cells.append(cell)
        if abs(delta) > tolerance_pct:
            out_of_tol.append(cell)
    missing = sorted(set(base) - set(cur))
    new = sorted(set(cur) - set(base))
    return dict(tolerance_pct=tolerance_pct, cells=cells,
                out_of_tolerance=out_of_tol, missing_in_current=missing,
                new_in_current=new,
                ok=not (out_of_tol or missing))


def _bench_rows(path: str) -> list:
    with open(path) as fh:
        data = json.load(fh)
    return data["timings"] if isinstance(data, dict) else data


def _total_wall(rows: list) -> float:
    totals = [r["wall_s"] for r in rows if r.get("table") == "total"]
    if not totals:       # no explicit total row: sum the tables
        totals = [sum(r["wall_s"] for r in rows)]
    return float(totals[-1])


def wall_budget_diff(base_path: str, cur_path: str,
                     budget: float, floor_s: float = 2.0) -> dict:
    """Gate on current/baseline total wall-clock ratio, with an absolute
    grace floor: runs whose *current* total is under `floor_s` pass
    regardless of ratio — at sub-second totals the ratio is dominated by
    process start-up and filesystem jitter, not by the simulator, and a
    2x blip on a 0.4 s table is noise, not a regression."""
    b_rows, c_rows = _bench_rows(base_path), _bench_rows(cur_path)
    # wall seconds are only comparable under the same bench config (a
    # full-run dump vs the quick baseline would silently disable — or
    # permanently trip — the gate); every timing row carries it
    b_cfg = next((r.get("config") for r in b_rows), None)
    c_cfg = next((r.get("config") for r in c_rows), None)
    if b_cfg != c_cfg:
        return dict(budget=budget, config_mismatch=True,
                    baseline_config=b_cfg, current_config=c_cfg,
                    ok=False)
    b_by = {r["table"]: r["wall_s"] for r in b_rows}
    c_by = {r["table"]: r["wall_s"] for r in c_rows}
    tables = [dict(table=t, baseline_s=b_by[t],
                   current_s=c_by.get(t),
                   ratio=round(c_by[t] / b_by[t], 3)
                   if c_by.get(t) and b_by[t] else None)
              for t in b_by]
    b_tot, c_tot = _total_wall(b_rows), _total_wall(c_rows)
    ratio = c_tot / b_tot if b_tot else float("inf")
    under_floor = c_tot < floor_s
    return dict(budget=budget, floor_s=floor_s, baseline_total_s=b_tot,
                current_total_s=round(c_tot, 3),
                ratio=round(ratio, 3), tables=tables,
                under_floor=under_floor,
                ok=ratio <= budget or under_floor)


def summary_markdown(rep: dict, wall: dict = None,
                     title: str = "tok/W regression gate") -> str:
    """GitHub job-summary markdown for a `fleet_diff` report: per-cell
    table sorted worst delta first (regressions top the page), then
    missing/new cells and the wall-clock budget verdict.  Pure function
    of the report dicts so the emitter is unit-testable without a runner
    environment."""
    ok = rep["ok"] and (wall is None or wall.get("ok", True))
    lines = [f"## {title}: {'✅ ok' if ok else '❌ FAIL'}",
             "",
             f"tolerance ±{rep['tolerance_pct']:g}% · "
             f"{len(rep['cells'])} cells compared",
             "",
             "| cell | baseline | current | Δ% |",
             "| --- | ---: | ---: | ---: |"]
    for c in sorted(rep["cells"], key=lambda c: c["delta_pct"]):
        flag = " ⚠️" if abs(c["delta_pct"]) > rep["tolerance_pct"] else ""
        lines.append(f"| `{c['cell']}` | {c['baseline']:g} |"
                     f" {c['current']:g} | {c['delta_pct']:+.2f}%{flag} |")
    if rep["missing_in_current"]:
        lines += ["", "**Missing from current run:**"]
        lines += [f"- `{k}`" for k in rep["missing_in_current"]]
    if rep["new_in_current"]:
        lines += ["", "**New cells (not in baseline):**"]
        lines += [f"- `{k}`" for k in rep["new_in_current"]]
    if wall is not None:
        lines += ["", "### wall-clock budget"]
        if wall.get("config_mismatch"):
            lines.append(f"❌ config mismatch: baseline"
                         f" `{wall['baseline_config']}` vs current"
                         f" `{wall['current_config']}`")
        else:
            lines.append(
                f"{'✅' if wall['ok'] else '❌'} total "
                f"{wall['current_total_s']:.1f}s vs baseline "
                f"{wall['baseline_total_s']:.1f}s "
                f"({wall['ratio']:.2f}x, budget {wall['budget']:g}x)")
    return "\n".join(lines) + "\n"


def _emit_step_summary(rep: dict, wall: dict = None,
                       title: str = "tok/W regression gate") -> None:
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as fh:
        fh.write(summary_markdown(rep, wall, title=title) + "\n")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fleet", action="store_true",
                    help="fleet tok/W regression mode")
    ap.add_argument("--tolerance", type=float, default=10.0,
                    help="max abs tok/W drift per cell, percent")
    ap.add_argument("--wall-budget", type=float, default=None,
                    metavar="RATIO",
                    help="max current/baseline total wall-clock ratio "
                         "(needs --bench-baseline/--bench-current)")
    ap.add_argument("--bench-baseline", default=None,
                    help="committed BENCH_fleet_sim.json timing baseline")
    ap.add_argument("--bench-current", default=None,
                    help="freshly recorded timing dump (--time)")
    ap.add_argument("--wall-floor", type=float, default=2.0,
                    metavar="SECONDS",
                    help="absolute grace floor: a current total under this"
                         " many seconds passes the wall budget regardless"
                         " of ratio (start-up jitter dominates tiny runs)")
    ap.add_argument("--summary-title", default="tok/W regression gate",
                    help="heading for the $GITHUB_STEP_SUMMARY markdown "
                         "(distinguishes multiple perf_diff steps in one "
                         "job summary)")
    ap.add_argument("baseline")
    ap.add_argument("current")
    args = ap.parse_args(argv)
    if not args.fleet:
        print(json.dumps(diff(args.baseline, args.current), indent=2))
        return
    rep = fleet_diff(args.baseline, args.current,
                     tolerance_pct=args.tolerance)
    print(json.dumps(rep, indent=2))
    wall_fail = None
    wrep = None
    if args.wall_budget is not None:
        if not (args.bench_baseline and args.bench_current):
            sys.exit("--wall-budget needs --bench-baseline and"
                     " --bench-current")
        wrep = wall_budget_diff(args.bench_baseline, args.bench_current,
                                args.wall_budget,
                                floor_s=args.wall_floor)
        print(json.dumps(wrep, indent=2))
        if wrep.get("config_mismatch"):
            wall_fail = (f"WALL-BUDGET CONFIG MISMATCH: baseline recorded"
                         f" under {wrep['baseline_config']} but current"
                         f" under {wrep['current_config']} — wall seconds"
                         f" are not comparable across bench configs")
        elif not wrep["ok"]:
            wall_fail = (f"WALL-CLOCK REGRESSION: --quick bench "
                         f"{wrep['current_total_s']:.1f}s vs baseline "
                         f"{wrep['baseline_total_s']:.1f}s "
                         f"({wrep['ratio']:.2f}x > budget "
                         f"{args.wall_budget:g}x); regenerate the "
                         f"baseline only for a deliberate slowdown")
    _emit_step_summary(rep, wrep, title=args.summary_title)
    if not rep["ok"] or wall_fail:
        regressed = [c for c in rep["out_of_tolerance"]
                     if c["delta_pct"] < 0]
        improved = [c for c in rep["out_of_tolerance"]
                    if c["delta_pct"] >= 0]
        msgs = []
        if regressed:
            msgs.append("tok/W REGRESSION: "
                        + ", ".join(f"{c['cell']} {c['delta_pct']:+.1f}%"
                                    for c in regressed))
        if improved:
            msgs.append("tok/W improved beyond tolerance (regenerate the "
                        "baseline with `fleet_sim_bench.py --quick --json "
                        "benchmarks/results/fleet_sim.json`): "
                        + ", ".join(f"{c['cell']} {c['delta_pct']:+.1f}%"
                                    for c in improved))
        if rep["missing_in_current"]:
            msgs.append("cells missing from current run: "
                        + ", ".join(rep["missing_in_current"]))
        if wall_fail:
            msgs.append(wall_fail)
        sys.exit("; ".join(msgs))


if __name__ == "__main__":
    main()
