"""Perf-iteration helpers.

Two modes:

1. Dry-run roofline diff (legacy): diff two dry-run result JSONs
   (before/after a change) on the three roofline terms.

     PYTHONPATH=src python -m benchmarks.perf_diff \
         benchmarks/results/dryrun_baseline/yi-6b_decode_32k_pod16x16.json \
         benchmarks/results/dryrun/yi-6b_decode_32k_pod16x16.json

2. Fleet tok/W regression gate (CI): diff a fresh
   `fleet_sim_bench.py --quick --json` dump against the committed
   baseline, cell by cell, and exit non-zero when any tok/W cell drifts
   beyond the tolerance.  Runs are seeded and deterministic, so any
   drift is a real code-behaviour change: a drop is a perf regression; a
   rise means the baseline is stale and must be regenerated (with
   `--quick --json benchmarks/results/fleet_sim.json`) so the gate keeps
   teeth.

     PYTHONPATH=src python -m benchmarks.perf_diff --fleet \
         benchmarks/results/fleet_sim.json current.json [--tolerance 10]

   Accepts both the bench's {"meta", "rows"} dump and the bare row list
   `benchmarks/run.py` writes.  A cell is keyed by
   (table, generation, workload, topology, dispatch_ms, misroute_rate) —
   the last two disambiguate the model-heterogeneous Table D sweep cells
   and are empty for every other row; its metric is the row's primary
   tok/W field (`simulated` for measured tables, `slo_feasible` for SLO
   tables; both when a row carries both).
"""
import argparse
import json
import sys

# tok/W metrics gated per row: measured (simulated) and SLO-constrained
# (slo_feasible) are diffed independently when a row carries both (disagg
# rows do); tok_per_watt is the fallback for plain FleetReport-style rows
_METRIC_FIELDS = ("simulated", "slo_feasible", "tok_per_watt")


def diff(a_path: str, b_path: str) -> dict:
    a = json.loads(open(a_path).read())
    b = json.loads(open(b_path).read())
    out = {"pair": f"{a['arch']} x {a['shape']} ({a['mesh']})"}
    for k in ("compute_s", "memory_s", "collective_s"):
        va, vb = a["roofline"][k], b["roofline"][k]
        out[k] = dict(before_ms=round(va * 1e3, 3),
                      after_ms=round(vb * 1e3, 3),
                      delta_pct=round(100 * (vb / va - 1), 1) if va else None)
    out["dominant"] = {"before": a["roofline"]["dominant"],
                       "after": b["roofline"]["dominant"]}
    pa = a["bytes_per_device"]["peak_estimate"] / 2 ** 30
    pb = b["bytes_per_device"]["peak_estimate"] / 2 ** 30
    out["gib_per_device"] = dict(before=round(pa, 2), after=round(pb, 2),
                                 delta_pct=round(100 * (pb / pa - 1), 1))
    return out


def _fleet_cells(path: str) -> dict:
    data = json.loads(open(path).read())
    rows = data["rows"] if isinstance(data, dict) else data
    cells = {}
    for r in rows:
        if not isinstance(r, dict) or "topology" not in r:
            continue
        key = "/".join(str(r.get(k, "")) for k in
                       ("table", "generation", "workload", "topology",
                        "dispatch_ms", "misroute_rate"))
        present = [f for f in _METRIC_FIELDS[:2] if f in r]
        if not present and _METRIC_FIELDS[2] in r:
            present = [_METRIC_FIELDS[2]]
        for f in present:
            cells[f"{key}:{f}"] = float(r[f])
    return cells


def fleet_diff(base_path: str, cur_path: str,
               tolerance_pct: float = 10.0) -> dict:
    base, cur = _fleet_cells(base_path), _fleet_cells(cur_path)
    cells, out_of_tol = [], []
    for key in sorted(base):
        if key not in cur:
            continue
        b, c = base[key], cur[key]
        delta = 100.0 * (c / b - 1.0) if b else (0.0 if not c else 1e9)
        cell = dict(cell=key, baseline=b, current=round(c, 3),
                    delta_pct=round(delta, 2))
        cells.append(cell)
        if abs(delta) > tolerance_pct:
            out_of_tol.append(cell)
    missing = sorted(set(base) - set(cur))
    new = sorted(set(cur) - set(base))
    return dict(tolerance_pct=tolerance_pct, cells=cells,
                out_of_tolerance=out_of_tol, missing_in_current=missing,
                new_in_current=new,
                ok=not (out_of_tol or missing))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fleet", action="store_true",
                    help="fleet tok/W regression mode")
    ap.add_argument("--tolerance", type=float, default=10.0,
                    help="max abs tok/W drift per cell, percent")
    ap.add_argument("baseline")
    ap.add_argument("current")
    args = ap.parse_args(argv)
    if not args.fleet:
        print(json.dumps(diff(args.baseline, args.current), indent=2))
        return
    rep = fleet_diff(args.baseline, args.current,
                     tolerance_pct=args.tolerance)
    print(json.dumps(rep, indent=2))
    if not rep["ok"]:
        regressed = [c for c in rep["out_of_tolerance"]
                     if c["delta_pct"] < 0]
        improved = [c for c in rep["out_of_tolerance"]
                    if c["delta_pct"] >= 0]
        msgs = []
        if regressed:
            msgs.append("tok/W REGRESSION: "
                        + ", ".join(f"{c['cell']} {c['delta_pct']:+.1f}%"
                                    for c in regressed))
        if improved:
            msgs.append("tok/W improved beyond tolerance (regenerate the "
                        "baseline with `fleet_sim_bench.py --quick --json "
                        "benchmarks/results/fleet_sim.json`): "
                        + ", ".join(f"{c['cell']} {c['delta_pct']:+.1f}%"
                                    for c in improved))
        if rep["missing_in_current"]:
            msgs.append("cells missing from current run: "
                        + ", ".join(rep["missing_in_current"]))
        sys.exit("; ".join(msgs))


if __name__ == "__main__":
    main()
