"""Kernel micro-benchmarks (CPU: jnp reference path timing + interpret-mode
validation cost; real-TPU numbers require hardware — see EXPERIMENTS.md)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.attention import flash_attention


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run():
    rng = jax.random.PRNGKey(0)
    rows = []
    # decode attention: B=8 sequences, 4K cache, GQA 8/2
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (8, 8, 64))
    k = jax.random.normal(ks[1], (8, 4096, 2, 64))
    v = jax.random.normal(ks[2], (8, 4096, 2, 64))
    lengths = jnp.full((8,), 4096)
    f_ref = jax.jit(lambda *a: ops.decode_attention(*a, force="ref"))
    rows.append(dict(name="decode_attention_ref_b8_t4096",
                     us_per_call=_time(f_ref, q, k, v, lengths),
                     derived="kv_bytes=%d" % (k.nbytes + v.nbytes)))
    # prefill flash attention 1x1024
    q2 = jax.random.normal(ks[0], (1, 1024, 8, 64))
    k2 = jax.random.normal(ks[1], (1, 1024, 2, 64))
    f_fa = jax.jit(lambda a, b, c: flash_attention(a, b, c, q_chunk=256,
                                                   kv_chunk=256))
    rows.append(dict(name="flash_attention_1x1024",
                     us_per_call=_time(f_fa, q2, k2, k2),
                     derived="flops=%.2e" % (4 * 1024 * 1024 * 8 * 64)))
    # ssm scans
    xt = jax.random.normal(ks[0], (2, 512, 4, 64))
    Bm = jax.random.normal(ks[1], (2, 512, 64))
    lA = -jnp.abs(jax.random.normal(ks[2], (2, 512, 4)))
    f_ssd = jax.jit(lambda *a: ops.ssd_scan(*a, force="ref"))
    rows.append(dict(name="ssd_scan_ref_2x512",
                     us_per_call=_time(f_ssd, xt, Bm, Bm, lA),
                     derived="state=(4,64,64)"))
    r = jax.random.normal(ks[0], (2, 256, 4, 64))
    w = jnp.exp(-jnp.exp(-6 + 0.1 * jax.random.normal(ks[1],
                                                      (2, 256, 4, 64))))
    u = jnp.ones((4, 64)) * 0.5
    f_wkv = jax.jit(lambda *a: ops.wkv_scan(*a, force="ref"))
    rows.append(dict(name="wkv6_ref_2x256",
                     us_per_call=_time(f_wkv, r, r, r, w, u),
                     derived="state=(4,64,64)"))
    return rows, "CPU reference-path timings (TPU kernels validated in interpret mode)"
