"""Pallas TPU chunked WKV6 kernel (RWKV6 data-dependent-decay recurrence).

Same sequential-chunk-grid structure as mamba_scan, with per-channel decay.
The intra-chunk pairwise decay exp(cw_ex[t] - cw[s]) is computed as an
explicit (Lc, Lc, hd) difference tensor *before* exponentiation — exact and
overflow-safe for any w in (0, 1] (the factored qd/kd form overflows f32
once cumulative in-chunk decay exceeds ~e^88; see tests/kernels sweeps).
grid = (batch, heads, chunks); state (hd_k, hd_v) lives in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, fin_ref, st_ref,
                *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    r = r_ref[0, :, 0].astype(jnp.float32)         # (Lc, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)               # (hd,)
    state = st_ref[...]                            # (hd_k, hd_v)

    Lc = r.shape[0]
    cw = jnp.cumsum(lw, axis=0)                    # inclusive (Lc, hd)
    cw_ex = cw - lw                                # exclusive

    y_inter = jnp.dot(r * jnp.exp(cw_ex), state)   # (Lc, hd_v)

    # exact pairwise decay: (t, s, d) tensor, exponent <= 0 for s < t
    diff = cw_ex[:, None, :] - cw[None, :, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1))
    att = jnp.einsum("td,sd,tsd->ts", r, k,
                     jnp.where(tri[:, :, None], jnp.exp(diff), 0.0))
    y_intra = jnp.dot(att, v)

    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    y_ref[0, :, 0] = (y_inter + y_intra + bonus).astype(y_ref.dtype)

    kdec = k * jnp.exp(cw[-1][None, :] - cw)       # exponent <= 0
    st_new = state * jnp.exp(cw[-1])[:, None] + jnp.dot(kdec.T, v)
    st_ref[...] = st_new

    @pl.when(ci == n_chunks - 1)
    def _finish():
        fin_ref[0, 0] = st_new.astype(fin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = True):
    """r,k,v,w: (B,S,H,hd); u: (H,hd).
    Returns (out (B,S,H,hd), final_state (B,H,hd,hd))."""
    B, S, H, hd = r.shape
    Lc = min(chunk, S)
    n_chunks = -(-S // Lc)
    pad = n_chunks * Lc - S

    def padt(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=fill) if pad else a

    r_, k_, v_ = padt(r), padt(k), padt(v)
    lw = jnp.log(jnp.maximum(padt(w, fill=1.0), 1e-30))

    kernel = functools.partial(_wkv_kernel, n_chunks=n_chunks)
    spec = pl.BlockSpec((1, Lc, 1, hd), lambda b, h, c: (b, c, h, 0))
    y, fin = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, c: (h, 0))],
        out_specs=[spec,
                   pl.BlockSpec((1, 1, hd, hd),
                                lambda b, h, c: (b, h, 0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_chunks * Lc, H, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r_, k_, v_, lw, u)
    return y[:, :S], fin
