"""Pallas TPU chunked-SSD scan (Mamba2) — recurrent-state hot path.

TPU adaptation of the GPU SSD algorithm: instead of warp-level scans, the
chunk dimension is the innermost *sequential* grid axis with the (hd, ds)
state carried in VMEM scratch; intra-chunk work is two MXU matmuls
((Lc x Lc) decay-masked attention-like product and the state outer-product
update).  Chunk length and head dim are chosen so tiles are (8,128)-aligned.
grid = (batch, heads, chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _mamba_kernel(xt_ref, b_ref, c_ref, la_ref, y_ref, fin_ref, st_ref,
                  *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    xt = xt_ref[0, :, 0].astype(jnp.float32)       # (Lc, hd)
    bm = b_ref[0].astype(jnp.float32)              # (Lc, ds)
    cm = c_ref[0].astype(jnp.float32)              # (Lc, ds)
    la = la_ref[0, :, 0].astype(jnp.float32)       # (Lc,)
    state = st_ref[...]                            # (hd, ds)

    cs = jnp.cumsum(la)                            # inclusive
    Lc = xt.shape[0]
    diff = cs[:, None] - cs[None, :]               # (q, t)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Lc, Lc), 1))
    G = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    att = jnp.dot(cm, bm.T) * G                    # (q, t)
    y_intra = jnp.dot(att, xt)                     # (q, hd)
    y_inter = jnp.exp(cs)[:, None] * jnp.dot(cm, state.T)
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    dec = jnp.exp(cs[-1] - cs)[:, None]            # (t, 1)
    st_new = state * jnp.exp(cs[-1]) + jnp.dot((dec * xt).T, bm)
    st_ref[...] = st_new

    @pl.when(ci == n_chunks - 1)
    def _finish():
        fin_ref[0, 0] = st_new.astype(fin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(xt: jax.Array, Bm: jax.Array, Cm: jax.Array, lA: jax.Array,
               *, chunk: int = DEFAULT_CHUNK, interpret: bool = True):
    """Chunked SSD scan.

    xt: (B,S,nh,hd) dt-scaled inputs; Bm/Cm: (B,S,ds); lA: (B,S,nh).
    Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds)).
    """
    B, S, nh, hd = xt.shape
    ds = Bm.shape[-1]
    Lc = min(chunk, S)
    n_chunks = -(-S // Lc)
    pad = n_chunks * Lc - S
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        lA = jnp.pad(lA, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_mamba_kernel, n_chunks=n_chunks)
    y, fin = pl.pallas_call(
        kernel,
        grid=(B, nh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, Lc, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Lc, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Lc, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Lc, 1), lambda b, h, c: (b, c, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, Lc, 1, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_chunks * Lc, nh, hd), xt.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xt, Bm, Cm, lA)
    return y[:, :S], fin
