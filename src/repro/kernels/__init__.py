"""TPU Pallas kernels for the paper's compute hot spots.

flash_decode — blocked GQA decode attention (the H(L)*n KV-scan term of the
               paper's decode roofline, §2.2);
mamba_scan   — chunked SSD scan (Mamba2 prefill/train path);
wkv6         — chunked RWKV6 data-dependent-decay recurrence.

Each kernel ships with ops.py (backend dispatch) and ref.py (naive
sequential pure-jnp oracle); see tests/kernels for shape/dtype sweeps.
"""
from . import ops, ref
from .flash_decode import flash_decode
from .flash_decode_int8 import flash_decode_int8, quantize_kv
from .mamba_scan import mamba_scan
from .wkv6 import wkv6

__all__ = ["ops", "ref", "flash_decode", "flash_decode_int8", "quantize_kv",
           "mamba_scan", "wkv6"]
