"""Pure-jnp oracles for every Pallas kernel.

These are deliberately *naive* (direct softmax, sequential one-step scans):
slow, obviously-correct references.  The chunked jnp implementations in
repro.models.{attention,ssm} and the Pallas kernels are both validated
against these in tests/kernels/.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    """Decode GQA attention, direct softmax.

    q: (B, H, D) one query per sequence; k, v: (B, T, K, D);
    lengths: (B,) valid cache entries.  Returns (B, H, D).
    """
    B, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qh = q.reshape(B, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    valid = jnp.arange(T)[None] < lengths[:, None]            # (B, T)
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D)


def mamba_scan_ref(xt: jax.Array, Bm: jax.Array, Cm: jax.Array,
                   lA: jax.Array, init_state=None):
    """Sequential SSD scan (one step per token).

    xt: (B,S,nh,hd) dt-scaled inputs; Bm/Cm: (B,S,ds); lA: (B,S,nh) log-decay.
    Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds)).
    """
    B, S, nh, hd = xt.shape
    ds = Bm.shape[-1]
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, nh, hd, ds), jnp.float32))

    def step(state, inp):
        x_t, b_t, c_t, la_t = inp
        state = state * jnp.exp(la_t)[:, :, None, None] \
            + jnp.einsum("bnp,bs->bnps", x_t, b_t)
        y_t = jnp.einsum("bnps,bs->bnp", state, c_t)
        return state, y_t

    xs = (xt.transpose(1, 0, 2, 3), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), lA.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), state


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, init_state=None):
    """Sequential RWKV6 recurrence.

    r,k,v,w: (B,S,H,hd); u: (H,hd).  Returns (out (B,S,H,hd),
    final_state (B,H,hd,hd)).
    """
    B, S, H, hd = r.shape
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp
        out = jnp.einsum("bhd,bhde->bhe", r_t, state) \
            + jnp.einsum("bhd,bhd->bh", r_t, u[None] * k_t)[..., None] * v_t
        state = state * w_t[..., None] \
            + jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        return state, out

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), state
