"""Backend-dispatching wrappers around the Pallas kernels.

On TPU backends the compiled Pallas kernels run (interpret=False); on CPU
(this container) the default is the pure-jnp reference so jit/grad/vmap all
work at full speed, with `force="interpret"` available to execute the actual
kernel bodies for validation (tests/kernels does exactly that).

  force=None         backend-based dispatch
  force="pallas"     compiled kernel (TPU only)
  force="interpret"  Pallas interpret mode (CPU-executable kernel body)
  force="ref"        pure-jnp oracle
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from . import flash_decode as _fd
from . import mamba_scan as _ms
from . import ref as _ref
from . import wkv6 as _wk


def _mode(force: Optional[str]) -> str:
    force = force or os.environ.get("REPRO_FORCE_KERNEL") or None
    if force:
        return force
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def decode_attention(q, k, v, lengths, *, block_t: int = 256,
                     force: Optional[str] = None):
    """(B,H,D) x (B,T,K,D) -> (B,H,D); the tau = W + H(L)n KV-scan."""
    m = _mode(force)
    if m == "ref":
        return _ref.flash_decode_ref(q, k, v, lengths)
    return _fd.flash_decode(q, k, v, lengths, block_t=block_t,
                            interpret=(m != "pallas"))


def ssd_scan(xt, Bm, Cm, lA, *, chunk: int = 128,
             force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.mamba_scan_ref(xt, Bm, Cm, lA)
    return _ms.mamba_scan(xt, Bm, Cm, lA, chunk=chunk,
                          interpret=(m != "pallas"))


def wkv_scan(r, k, v, w, u, *, chunk: int = 64,
             force: Optional[str] = None):
    m = _mode(force)
    if m == "ref":
        return _ref.wkv6_ref(r, k, v, w, u)
    return _wk.wkv6(r, k, v, w, u, chunk=chunk,
                    interpret=(m != "pallas"))
