"""Pallas TPU flash-decode over an int8-quantized KV cache.

The analytical stack shows int8 KV halves kappa -> doubles n_max -> ~1.7x
tok/W at 64K (one hardware generation, §5.2-beyond).  This kernel is what
makes that real on TPU: K/V live in HBM as int8 with per-(token, head)
f32 scales; dequantization happens inside the VMEM tile right before the
MXU dot, so the HBM stream is genuinely half of bf16 — an XLA-level
dequant would materialise the bf16 copy and erase the win (same lesson as
§Perf iteration A2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def quantize_kv(k: jax.Array, v: jax.Array):
    """Symmetric per-(token, head) int8 quantization.

    k, v: (B, T, K, D) float -> (k_q, v_q int8, k_s, v_s f32 (B, T, K))."""
    def one(x):
        s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
        s = jnp.maximum(s, 1e-8)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                     -127, 127).astype(jnp.int8)
        return q, s
    kq, ks = one(k)
    vq, vs = one(v)
    return kq, vq, ks, vs


def _kernel(len_ref, q_ref, kq_ref, vq_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_t: int, n_blocks: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)
    # dequantize inside the tile: int8 stream from HBM, f32 math in VMEM
    k = kq_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
    v = vq_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    length = len_ref[0]

    s = jnp.dot(q, k.T) / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    t_idx = t * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(t_idx < length, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_new = acc_prev * corr + jnp.dot(p, v)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(t == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def flash_decode_int8(q, kq, vq, ks, vs, lengths, *, block_t: int = 256,
                      interpret: bool = True):
    """q: (B,H,D); kq/vq: int8 (B,T,K,D); ks/vs: f32 (B,T,K);
    lengths: (B,).  Returns (B,H,D)."""
    B, H, D = q.shape
    T, K = kq.shape[1], kq.shape[2]
    G = H // K
    block_t = min(block_t, T)
    n_blocks = -(-T // block_t)
    pad = n_blocks * block_t - T
    if pad:
        kq = jnp.pad(kq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vq = jnp.pad(vq, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0)))
    qh = q.reshape(B, K, G, D)
    kernel = functools.partial(_kernel, block_t=block_t, n_blocks=n_blocks)
    kv_spec = pl.BlockSpec((1, block_t, 1, D), lambda b, h, t: (b, t, h, 0))
    sc_spec = pl.BlockSpec((1, block_t, 1), lambda b, h, t: (b, t, h))
    out = pl.pallas_call(
        kernel,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            kv_spec, kv_spec, sc_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qh, kq, vq, ks, vs)
    return out.reshape(B, H, D)
