"""Pallas TPU flash-decode kernel — the paper's H(L)*n KV-scan term.

Decode attention is memory-bound: per iteration every sequence streams its
whole KV cache (kappa * L bytes) HBM -> VMEM once.  This kernel expresses
that stream explicitly: grid = (batch, kv_head, kv_blocks) with the KV-block
dimension innermost/sequential, carrying online-softmax state (m, l, acc) in
VMEM scratch.  Block shapes are (BLOCK_T, 128)-aligned for the VPU/MXU;
the G = H/K query heads of a GQA group ride along in one tile so each KV
block is read exactly once per group (not per head) — the TPU-native
adaptation of TP-sharded GQA decode (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 256
NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, block_t: int,
                         n_blocks: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)           # (Tb, D)
    v = v_ref[0, :, 0].astype(jnp.float32)           # (Tb, D)
    length = len_ref[0]

    s = jnp.dot(q, k.T) / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    t_idx = t * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(t_idx < length, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_new = acc_prev * corr + jnp.dot(p, v)

    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(t == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: jax.Array, *, block_t: int = DEFAULT_BLOCK_T,
                 interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k, v: (B, T, K, D); lengths: (B,) -> (B, H, D).

    interpret=True executes the kernel body in Python on CPU (this
    container); on a real TPU pass interpret=False.
    """
    B, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block_t = min(block_t, T)
    n_blocks = -(-T // block_t)
    pad_t = n_blocks * block_t - T
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
    qh = q.reshape(B, K, G, D)

    kernel = functools.partial(_flash_decode_kernel, block_t=block_t,
                               n_blocks=n_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(B, K, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_t, 1, D), lambda b, h, t: (b, t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # m
            pltpu.VMEM((G, 1), jnp.float32),     # l
            pltpu.VMEM((G, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(lengths, qh, k, v)
    return out.reshape(B, H, D)
