"""GQA attention block: full-sequence (chunked/flash), decode, cross-attn.

The full-sequence path is a chunked online-softmax ("flash") attention
written in pure jnp with lax.scan — it is simultaneously (a) the memory-safe
prefill/train path (never materialises S x T score matrices), (b) the
reference oracle for the Pallas flash kernels, and (c) what the multi-pod
dry-run lowers (Pallas TPU kernels cannot compile on the CPU backend).

Sliding-window attention (SWA, h2o-danube3 and the -swa long-context
variants) is the same computation with a banded mask; its decode path uses a
ring-buffer KV cache of `window` slots, which is what makes `long_500k`
memory-feasible for dense models.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, dtype_of, rms_norm

NEG_INF = -1e30


def init_attention(rng, cfg, *, cross: bool = False) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 5)
    dt = dtype_of(cfg)
    p = {
        "norm": jnp.ones((d,), jnp.float32),
        "wq": dense_init(ks[0], (d, H * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, K * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, K * hd), dtype=dt),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cross:
        p["q_norm"] = jnp.ones((d,), jnp.float32)
    return p


def _qkv(params, cfg, x, *, rope_positions=None):
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if rope_positions is not None:
        q = apply_rope(q, rope_positions, cfg.rope_theta)
        k = apply_rope(k, rope_positions, cfg.rope_theta)
    return q, k, v


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, q_offset=0,
                    q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    """Chunked online-softmax GQA attention.

    q: (B, S, H, D); k, v: (B, T, K, D) with H = K * G.  Returns (B, S, H, D).
    `window` > 0 restricts keys to (q_pos - window, q_pos].
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))

    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    nq, nk = -(-S // qc), -(-T // kc)
    pad_q, pad_k = nq * qc - S, nk * kc - T

    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    # (nq, B, qc, K, G, D) / (nk, B, kc, K, D)
    qs = qp.reshape(B, nq, qc, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, nk, kc, K, D).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nk, kc, K, D).transpose(1, 0, 2, 3, 4)

    q_pos_base = jnp.asarray(q_offset)

    def per_q_chunk(qi, q_blk):
        q_pos = q_pos_base + qi * qc + jnp.arange(qc)          # (qc,)

        def per_kv_chunk(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            k_pos = kj * kc + jnp.arange(kc)                   # (kc,)
            s = jnp.einsum("bqkgd,btkd->bkgqt",
                           q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            mask &= (k_pos < T)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qc), jnp.float32)
        a0 = jnp.zeros((B, K, G, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            per_kv_chunk, (m0, l0, a0),
            (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,K,G,qc,D)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, D)

    # checkpoint per q-chunk: the inner online-softmax scan otherwise saves
    # (B,K,G,qc,D) f32 residuals for every kv block — tens of GiB/layer on
    # command-r train_4k (§Perf bonus iteration D2)
    outs = jax.lax.map(jax.checkpoint(lambda args: per_q_chunk(*args)),
                       (jnp.arange(nq), qs))                   # (nq,B,qc,H,D)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * qc, H, D)
    return out[:, :S].astype(q.dtype)


def attention_full(params, cfg, x, *, positions=None, mode: str = "train",
                   ) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence attention (train / prefill). Returns (y, cache|None)."""
    B, S, _ = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    pos = positions if positions is not None else jnp.arange(S)
    q, k, v = _qkv(params, cfg, h, rope_positions=pos)
    out = flash_attention(q, k, v, causal=True, window=cfg.swa_window)
    y = out.reshape(B, S, -1) @ params["wo"]
    cache = None
    if mode == "prefill":
        if cfg.swa_window:
            # ring-buffer layout: position p lives in slot p % W, matching
            # attention_decode's write pattern past the wrap point
            W = min(cfg.swa_window, S)
            kw, vw = k[:, S - W:], v[:, S - W:]
            if S > W:
                kw = jnp.roll(kw, S % W, axis=1)
                vw = jnp.roll(vw, S % W, axis=1)
            cache = {"k": kw, "v": vw}
        else:
            cache = {"k": k, "v": v}
    return x + y, cache


def attention_decode(params, cfg, x, cache: dict, pos: jax.Array,
                     ) -> Tuple[jax.Array, dict]:
    """One-token decode against a KV cache.

    cache["k"]/["v"]: (B, T, K, D).  For SWA, T == window and the cache is a
    ring buffer indexed pos % window; otherwise slots are absolute and
    positions >= pos are masked out.
    """
    B, S1, _ = x.shape  # S1 == 1
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))  # per-slot pos
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q, k_new, v_new = _qkv(params, cfg, h, rope_positions=pos[:, None])
    T = cache["k"].shape[1]
    slot = (pos % T) if cfg.swa_window else pos
    bidx = jnp.arange(B)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])

    K, hd = cfg.n_kv_heads, cfg.hd
    G = cfg.n_heads // K
    qh = q.reshape(B, 1, K, G, hd)
    # bf16 operands + f32 accumulation (MXU-native); casting the cache to
    # f32 would materialise a 2x-size copy of the whole KV — §Perf iter 2
    s = jnp.einsum("bqkgd,btkd->bkgqt", qh, k,
                   preferred_element_type=jnp.float32) / jnp.sqrt(float(hd))
    t_idx = jnp.arange(T)
    if cfg.swa_window:
        # ring buffer: once pos >= T the buffer holds exactly the last T
        # positions; before that, mask unwritten slots.
        valid = t_idx[None] <= jnp.minimum(pos, T - 1)[:, None]
    else:
        valid = t_idx[None] <= pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    y = out.reshape(B, 1, -1).astype(x.dtype) @ params["wo"]
    return x + y, {"k": k, "v": v}


def cross_attention_full(params, cfg, x, enc_kv: dict) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (no mask)."""
    B, S, _ = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (h @ params["wq"]).reshape(B, S, H, hd)
    out = flash_attention(q, enc_kv["k"], enc_kv["v"], causal=False)
    y = out.reshape(B, S, -1) @ params["wo"]
    return x + y


def encode_cross_kv(params, cfg, enc_out: jax.Array) -> dict:
    """Precompute cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ params["wk"]).reshape(B, T, K, hd)
    v = (enc_out @ params["wv"]).reshape(B, T, K, hd)
    return {"k": k, "v": v}
