"""JAX version compatibility shims for the ambient-mesh API.

The ambient ("abstract") mesh API moved between JAX releases:

  * 0.5.x+ — `jax.sharding.get_abstract_mesh()` / `jax.sharding.set_mesh()`
    (earlier spelled `use_mesh`), and `jax.make_mesh` grew an `axis_types`
    kwarg.
  * 0.4.x — none of those exist; the ambient mesh is the thread-resources
    physical mesh installed by `with mesh:`.

Everything in models/ and launch/ that needs the ambient mesh goes through
this module so the rest of the codebase is version-agnostic.  Callers treat
the return value of `get_abstract_mesh()` uniformly: it is either None or a
mesh-like object with `.empty`, `.axis_names` and `.shape`.
"""
from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax


def get_abstract_mesh() -> Any:
    """The ambient mesh, or None when none is installed.

    On 0.5.x+ this is `jax.sharding.get_abstract_mesh()` (an AbstractMesh,
    possibly empty); on 0.4.x it is the thread-resources physical mesh set
    by `with mesh:` (a Mesh, possibly empty).  Both expose `.empty`,
    `.axis_names` and `.shape`, which is all our call sites use.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    from jax._src import mesh as _mesh_lib
    return _mesh_lib.thread_resources.env.physical_mesh


def set_mesh(mesh) -> contextlib.AbstractContextManager:
    """Context manager installing `mesh` as the ambient mesh.

    0.5.x+: `jax.sharding.set_mesh` (or `use_mesh` on the releases that
    spelled it that way).  0.4.x: `with mesh:` installs the physical mesh,
    which `with_sharding_constraint` resolves against.
    """
    for name in ("set_mesh", "use_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    return mesh  # Mesh is itself a context manager on 0.4.x


def cost_analysis(compiled) -> dict:
    """Flat cost dict from a compiled executable.

    jaxlib 0.4.x returns a list of per-device dicts (one entry on
    single-controller runs); 0.5.x+ returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs):
    """`jax.make_mesh` with Auto axis_types where the release supports it."""
    if hasattr(jax.sharding, "AxisType"):
        kwargs.setdefault(
            "axis_types",
            (jax.sharding.AxisType.Auto,) * len(axis_names))
    else:
        kwargs.pop("axis_types", None)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
