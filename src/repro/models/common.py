"""Shared numerics for the model zoo: norms, RoPE, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .compat import get_abstract_mesh


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def dense_init(rng, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(rng, shape, dtype=jnp.float32)
            * scale).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                              # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# Set by the launch layer for pure-data-parallel mappings (small models:
# batch sharded over the model axis as well, weights replicated).
BATCH_AXES_OVERRIDE = None


def batch_axes() -> tuple:
    """Data-parallel axes of the ambient mesh (empty tuple if no mesh)."""
    m = get_abstract_mesh()
    if m is None or m.empty:
        return ()
    if BATCH_AXES_OVERRIDE is not None:
        return tuple(a for a in BATCH_AXES_OVERRIDE if a in m.axis_names)
    return tuple(a for a in ("pod", "data") if a in m.axis_names)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; no-op without one.

    Axis names absent from the mesh are dropped.  The canonical use is
    pinning the residual stream to batch sharding (constrain(x, BATCH)) so
    GSPMD doesn't trade batch parallelism for feature sharding on the big
    f32 loss/activation tensors (see EXPERIMENTS.md §Perf, iteration 0).
    """
    m = get_abstract_mesh()
    if m is None or m.empty:
        return x
    names = set(m.axis_names)

    def clean(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(s for s in a if s in names)
            return kept or None
        return a if a in names else None

    expanded = []
    used = set()
    for a in spec:
        e = tuple(batch_axes()) or None if a == "BATCH" else clean(a)
        # an axis may appear in at most one positional dim (pure-DP maps
        # `model` into BATCH, which then owns it exclusively)
        if isinstance(e, tuple):
            e = tuple(s for s in e if s not in used) or None
        elif e in used:
            e = None
        for s in (e if isinstance(e, tuple) else (e,) if e else ()):
            used.add(s)
        expanded.append(e)
    expanded += [None] * (x.ndim - len(expanded))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*expanded))
