"""Unified decoder-stack model: init / forward / prefill / decode_step.

The repeating block unit is scanned with `lax.scan` (stacked parameters,
leading axis = n_repeat) so the HLO stays one-unit-sized regardless of depth
— essential for AOT-compiling 64-layer multi-pod configs on this CPU-only
container.  Shared blocks (Zamba2's shared attention) live outside the scan
xs and are closed over as scan constants.

Modality carve-out (see DESIGN.md §5): whisper's conv/mel frontend and
llava's vision tower are stubs — batches carry precomputed `frames` /
`patches` embeddings; the transformer backbones that consume them are fully
implemented (including the whisper encoder stack + cross-attention).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (attention_decode, attention_full,
                        cross_attention_full, encode_cross_kv, flash_attention,
                        init_attention)
from .common import constrain, dense_init, dtype_of, rms_norm
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .spec import ArchConfig
from .ssm import (init_mamba2, init_rwkv6, mamba2_decode, mamba2_full,
                  rwkv6_decode, rwkv6_full)

Params = Dict[str, Any]
Cache = Dict[str, Any]

# Scan unroll factor for the layer stack.  1 = rolled while-loop (fast
# compiles; production default).  The dry-run's cost-correction pass sets
# this to True (full unroll) so XLA's HloCostAnalysis sees every repeat —
# it counts a while-loop body exactly once regardless of trip count.
SCAN_UNROLL: Any = 1

# Megatron-style sequence parallelism for the residual stream: shard the
# sequence dim over `model` between blocks so the per-layer remat residual
# shrinks by the TP factor (command-r train_4k: 301 GiB/dev of saved
# activations otherwise — §Perf bonus iteration D3).  The launch layer
# enables it for large-model training.
SEQ_SHARD_RESIDUAL: bool = False

_INIT = {"attn": init_attention, "cross_attn": lambda r, c: init_attention(r, c, cross=True),
         "mlp": init_mlp, "moe": init_moe, "mamba2": init_mamba2,
         "rwkv6": init_rwkv6}


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def init_params(rng, cfg: ArchConfig) -> Params:
    dt = dtype_of(cfg)
    n_keys = 4 + len(cfg.unit) * (cfg.n_repeat + 1) \
        + (cfg.encoder.n_layers * 2 + 1 if cfg.encoder else 0)
    keys = iter(jax.random.split(rng, n_keys))
    params: Params = {
        "embed": dense_init(next(keys), (cfg.vocab, cfg.d_model), scale=0.02,
                            dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(next(keys), (cfg.d_model, cfg.vocab),
                                       dtype=dt)
    unit, shared = {}, {}
    for i, b in enumerate(cfg.unit):
        name = f"b{i}_{b.kind}"
        if b.shared:
            shared[name] = _INIT[b.kind](next(keys), cfg)
        else:
            ks = jnp.stack(jax.random.split(next(keys), cfg.n_repeat))
            unit[name] = jax.vmap(lambda k: _INIT[b.kind](k, cfg))(ks)
    params["unit"] = unit
    if shared:
        params["shared"] = shared
    if cfg.encoder is not None:
        enc_unit = {}
        for i, kind in enumerate(("attn", "mlp")):
            ks = jnp.stack(jax.random.split(next(keys), cfg.encoder.n_layers))
            enc_unit[f"b{i}_{kind}"] = jax.vmap(
                lambda k: _INIT[kind](k, cfg))(ks)
        params["encoder"] = {"unit": enc_unit,
                             "final_norm": jnp.ones((cfg.d_model,),
                                                    jnp.float32)}
    return params


# ----------------------------------------------------------------------
# Encoder (whisper backbone; bidirectional)
# ----------------------------------------------------------------------

def _encoder_apply(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d) stub frontend output -> encoder hidden states."""

    def body(x, layer_params):
        p_attn = layer_params["b0_attn"]
        h = rms_norm(x, p_attn["norm"], cfg.norm_eps)
        B, S, _ = h.shape
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q = (h @ p_attn["wq"]).reshape(B, S, H, hd)
        k = (h @ p_attn["wk"]).reshape(B, S, K, hd)
        v = (h @ p_attn["wv"]).reshape(B, S, K, hd)
        out = flash_attention(q, k, v, causal=False)
        x = x + out.reshape(B, S, -1) @ p_attn["wo"]
        x = apply_mlp(layer_params["b1_mlp"], cfg, x)
        return x, None

    x, _ = jax.lax.scan(body, frames, params["encoder"]["unit"],
                        unroll=SCAN_UNROLL)
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# ----------------------------------------------------------------------
# Decoder unit application
# ----------------------------------------------------------------------

def _block_param(params, b, i, unit_params):
    name = f"b{i}_{b.kind}"
    return params.get("shared", {}).get(name) if b.shared \
        else unit_params[name]


def _unit_full(params, cfg: ArchConfig, x, *, mode: str,
               enc_out: Optional[jax.Array],
               remat: bool = False) -> Tuple[jax.Array, Any, Any]:
    """Scan the unit over n_repeat in full-sequence mode."""

    def body(carry, unit_params):
        x, aux = carry
        # keep the residual stream batch-sharded (+ sequence-sharded over
        # the TP axis when sequence parallelism is on)
        x = constrain(x, "BATCH", "model" if SEQ_SHARD_RESIDUAL else None)
        caches = {}
        for i, b in enumerate(cfg.unit):
            p = _block_param(params, b, i, unit_params)
            name = f"b{i}_{b.kind}"
            if b.kind == "attn":
                x, c = attention_full(p, cfg, x, mode=mode)
                if c is not None:
                    caches[name] = c
            elif b.kind == "cross_attn":
                x = cross_attention_full(p, cfg, x, encode_cross_kv(
                    p, cfg, enc_out))
                if mode == "prefill":
                    caches[name] = encode_cross_kv(p, cfg, enc_out)
            elif b.kind == "mlp":
                x = apply_mlp(p, cfg, x)
            elif b.kind == "moe":
                x, a = apply_moe(p, cfg, x, return_aux=True)
                aux = aux + a
            elif b.kind == "mamba2":
                x, c = mamba2_full(p, cfg, x, mode=mode)
                if c is not None:
                    caches[name] = c
            elif b.kind == "rwkv6":
                x, c = rwkv6_full(p, cfg, x, mode=mode)
                if c is not None:
                    caches[name] = c
        return (x, aux), caches

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    params["unit"], unroll=SCAN_UNROLL)
    return x, aux, caches


def _unit_decode(params, cfg: ArchConfig, x, cache: Cache, pos,
                 ) -> Tuple[jax.Array, Cache]:
    def body(x, inp):
        unit_params, cache_slice = inp
        new_slice = {}
        for i, b in enumerate(cfg.unit):
            p = _block_param(params, b, i, unit_params)
            name = f"b{i}_{b.kind}"
            if b.kind == "attn":
                x, new_slice[name] = attention_decode(p, cfg, x,
                                                      cache_slice[name], pos)
            elif b.kind == "cross_attn":
                x = cross_attention_full(p, cfg, x, cache_slice[name])
                new_slice[name] = cache_slice[name]
            elif b.kind == "mlp":
                x = apply_mlp(p, cfg, x)
            elif b.kind == "moe":
                x = apply_moe(p, cfg, x)
            elif b.kind == "mamba2":
                x, new_slice[name] = mamba2_decode(p, cfg, x,
                                                   cache_slice[name], pos)
            elif b.kind == "rwkv6":
                x, new_slice[name] = rwkv6_decode(p, cfg, x,
                                                  cache_slice[name], pos)
        return x, new_slice

    x, new_cache = jax.lax.scan(body, x, (params["unit"], cache),
                                unroll=SCAN_UNROLL)
    return x, new_cache


# ----------------------------------------------------------------------
# Public API
# ----------------------------------------------------------------------

def _embed_inputs(params, cfg: ArchConfig, batch: Dict[str, jax.Array]
                  ) -> jax.Array:
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.n_patches and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return constrain(x, "BATCH")


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            *, mode: str = "train", remat: bool = False):
    """Full-sequence pass.

    mode="train":   returns (logits, aux_loss)
    mode="prefill": returns (last_logits, cache, aux_loss)
    """
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encoder_apply(params, cfg, batch["frames"])
    x = _embed_inputs(params, cfg, batch)
    x, aux, caches = _unit_full(params, cfg, x, mode=mode, enc_out=enc_out,
                                remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if mode == "prefill":
        logits = x[:, -1:] @ head
        return constrain(logits, "BATCH", None, "model"), caches, aux
    return constrain(x @ head, "BATCH", None, "model"), aux


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array,
                cache: Cache, pos) -> Tuple[jax.Array, Cache]:
    """One decode iteration: tokens (B, 1), cache from prefill/init_cache.

    `pos` is the absolute position of the new token (scalar int32).
    This is the paper's tau(n, L) iteration: weight streaming (every matmul
    touches all — or active, for MoE — weights) + the KV scan over `pos`
    cached tokens.
    """
    x = _embed_inputs(params, cfg, {"tokens": tokens})
    x, new_cache = _unit_decode(params, cfg, x, cache, pos)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(x @ head, "BATCH", None, "model"), new_cache


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               *, enc_frames: int = 0, dtype=None) -> Cache:
    """Zero-initialised decode cache (the dry-run serve_step input).

    Attention caches hold `max_seq` slots (or the SWA window if smaller);
    SSM blocks hold O(1) state — the geometry behind the 1/W-law exemption
    of attention-free architectures (DESIGN.md §5).
    """
    dt = dtype or dtype_of(cfg)
    R, K, hd = cfg.n_repeat, cfg.n_kv_heads, cfg.hd
    cache: Cache = {}
    for i, b in enumerate(cfg.unit):
        name = f"b{i}_{b.kind}"
        if b.kind == "attn":
            slots = min(cfg.swa_window, max_seq) if cfg.swa_window else max_seq
            cache[name] = {
                "k": jnp.zeros((R, batch, slots, K, hd), dt),
                "v": jnp.zeros((R, batch, slots, K, hd), dt)}
        elif b.kind == "cross_attn":
            cache[name] = {
                "k": jnp.zeros((R, batch, enc_frames, K, hd), dt),
                "v": jnp.zeros((R, batch, enc_frames, K, hd), dt)}
        elif b.kind == "mamba2":
            cache[name] = {
                "conv": jnp.zeros((R, batch, cfg.d_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_state),
                                  jnp.float32),
                "ssm": jnp.zeros((R, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)}
        elif b.kind == "rwkv6":
            cache[name] = {
                "wkv": jnp.zeros((R, batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                                  cfg.rwkv_head_dim), jnp.float32),
                "shift_tm": jnp.zeros((R, batch, cfg.d_model), dt),
                "shift_cm": jnp.zeros((R, batch, cfg.d_model), dt)}
    return cache


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            *, aux_weight: float = 0.01, remat: bool = False) -> jax.Array:
    """Next-token cross-entropy (+ MoE load-balance aux).

    Vocab-parallel formulation: the target logit is extracted with a fused
    iota==target masked reduction instead of take_along_axis, so with the
    vocab dim sharded on `model` every cross-shard exchange is (B, S)-sized.
    (log_softmax + take_along_axis made GSPMD all-gather the full f32
    logits — 12.3 GiB/chip/step on granite-moe train_4k, §Perf iter 2.)
    """
    logits, aux = forward(params, cfg, batch, mode="train", remat=remat)
    # text tokens predict their successor; modality prefixes are unlabeled
    txt = logits[:, -batch["tokens"].shape[1]:]
    B, S, V = txt.shape
    # ignore-label pad keeps S chunkable (last position has no successor)
    targets = jnp.concatenate(
        [batch["labels"][:, 1:], jnp.full((B, 1), -1, jnp.int32)], axis=1)
    # Sequence-chunked CE: the unchunked f32 softmax pipeline materialised
    # ~20 GiB/chip of (B, S, V_shard) buffers (+ a 4 GiB s32 iota) on
    # command-r train_4k; 512-token chunks cap it at ~0.5 GiB (§Perf bonus
    # iteration D1).
    cs = min(512, S)
    while S % cs:
        cs //= 2
    zc = jnp.moveaxis(txt.reshape(B, S // cs, cs, V), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, S // cs, cs), 1, 0)

    def chunk(carry, inp):
        z, t = inp
        zf = z.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(zf, axis=-1, keepdims=True))
        zs = zf - m
        lse = jnp.log(jnp.sum(jnp.exp(zs), axis=-1))      # (B, cs)
        vidx = jax.lax.broadcasted_iota(jnp.int32, zs.shape, 2)
        tl = jnp.sum(jnp.where(vidx == t[..., None], zs, 0.0), axis=-1)
        valid = t >= 0
        nll_sum, cnt = carry
        return (nll_sum + jnp.sum(jnp.where(valid, lse - tl, 0.0)),
                cnt + jnp.sum(valid)), None

    (nll_sum, cnt), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (zc, tc))
    return nll_sum / jnp.maximum(cnt, 1) + aux_weight * aux
