"""Architecture specification for the unified model zoo.

A model is a repeating `unit` of blocks scanned `n_repeat` times (plus
embeddings, final norm, LM head, and optionally an encoder stack for
enc-dec models).  Mixed architectures (Zamba2's Mamba-with-shared-attention)
express their interleave inside the unit; blocks marked `shared=True` reuse
one parameter set across all repeats (Zamba2's shared attention block).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.modelspec import ModelSpec


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: str              # attn | cross_attn | mlp | moe | mamba2 | rwkv6
    shared: bool = False   # share parameters across unit repeats


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Encoder stack for enc-dec (Whisper): bidirectional attn + mlp."""

    n_layers: int
    n_frames: int          # stub frontend emits this many frame embeddings


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str         # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    unit: Tuple[BlockSpec, ...]
    n_repeat: int
    head_dim: int = 0      # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0      # per-expert hidden dim
    capacity_factor: float = 1.25
    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2        # d_inner = expand * d_model
    # RWKV6
    rwkv_head_dim: int = 64
    # Attention details
    swa_window: int = 0    # 0 = full causal attention
    rope_theta: float = 5e5
    attn_bias: bool = False
    mlp_act: str = "swiglu"   # swiglu | gelu
    # Modality
    encoder: Optional[EncoderSpec] = None
    n_patches: int = 0     # VLM: image patch embeddings prepended
    # Misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""       # citation bracket from the assignment

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_layers(self) -> int:
        return len(self.unit) * self.n_repeat

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def attn_block_count(self) -> int:
        per_unit = sum(1 for b in self.unit if b.kind == "attn")
        return per_unit * self.n_repeat

    # --- parameter accounting (used by analytical profiles & FSDP plan) --
    def param_count(self) -> float:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        total = float(v * d)                      # embed
        if not self.tie_embeddings:
            total += v * d                        # lm head
        total += d                                # final norm
        shared_done = set()
        for i, b in enumerate(self.unit):
            mult = 1 if b.shared else self.n_repeat
            if b.shared:
                if (b.kind, i) in shared_done:
                    continue
                shared_done.add((b.kind, i))
            total += self._block_params(b) * mult
        if self.encoder is not None:
            # encoder layer = bidirectional attn + mlp
            attn_p = d * (self.n_heads * self.hd) * 2 \
                + d * (self.n_kv_heads * self.hd) * 2 + 2 * d
            mlp_p = 2 * d * ff + d if self.mlp_act == "gelu" \
                else 3 * d * ff + d
            total += self.encoder.n_layers * (attn_p + mlp_p)
        return total

    def _block_params(self, b: BlockSpec) -> float:
        d, ff = self.d_model, self.d_ff
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        if b.kind in ("attn", "cross_attn"):
            return d * H * hd + 2 * d * K * hd + H * hd * d + d
        if b.kind == "mlp":
            n_mat = 3 if self.mlp_act == "swiglu" else 2
            return n_mat * d * ff + d
        if b.kind == "moe":
            fe = self.moe_d_ff or ff
            return d * self.n_experts + self.n_experts * 3 * d * fe + d
        if b.kind == "mamba2":
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            in_p = d * (2 * di + 2 * ds + nh)
            conv = self.d_conv * (di + 2 * ds)
            extra = 2 * nh + nh + di  # A_log, dt_bias, D, norm-ish
            return in_p + conv + extra + di * d + d
        if b.kind == "rwkv6":
            # time-mix (5 proj + decay lora) + channel-mix
            tm = (5 * d * d + 2 * d * 64 + 6 * d
                  + self.rwkv_heads * self.rwkv_head_dim)
            cm = 2 * d * ff + d * d + 2 * d
            return tm + cm + 2 * d
        raise ValueError(b.kind)

    def moe_active_params(self) -> Optional[float]:
        if not any(b.kind == "moe" for b in self.unit):
            return None
        dense = self.param_count()
        fe = self.moe_d_ff or self.d_ff
        per_moe_total = self.n_experts * 3 * self.d_model * fe
        per_moe_active = self.top_k * 3 * self.d_model * fe
        n_moe = sum(1 for b in self.unit if b.kind == "moe") * self.n_repeat
        return dense - n_moe * (per_moe_total - per_moe_active)

    # --- bridge into the analytical 1/W-law stack ------------------------
    def analytical_spec(self, dtype_bytes: float = 2.0) -> ModelSpec:
        attn_frac = (self.attn_block_count / self.n_layers
                     if self.n_layers else 0.0)
        n_kv = self.n_kv_heads if self.attn_block_count > 0 else 0
        state_bytes = 0.0
        if any(b.kind == "mamba2" for b in self.unit):
            state_bytes = (self.ssm_heads * self.ssm_head_dim * self.ssm_state
                           * 4.0)
        if any(b.kind == "rwkv6" for b in self.unit):
            state_bytes = (self.rwkv_heads * self.rwkv_head_dim ** 2 * 4.0)
        return ModelSpec(
            name=self.name, n_params=self.param_count(),
            n_layers=max(self.attn_block_count, 1),
            n_kv_heads=n_kv, head_dim=self.hd, dtype_bytes=dtype_bytes,
            n_active_params=self.moe_active_params(),
            state_bytes_per_layer=state_bytes,
            attn_layer_fraction=1.0)  # n_layers above == attn layers already

    def reduced(self, *, n_repeat: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims (CPU-runnable)."""
        scale = d_model / self.d_model
        return dataclasses.replace(
            self, name=self.name + "-smoke", d_model=d_model,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            head_dim=d_model // max(2, min(4, self.n_heads)),
            d_ff=max(64, int(self.d_ff * scale) // 16 * 16),
            moe_d_ff=max(32, int((self.moe_d_ff or 64) * scale) // 16 * 16)
            if self.n_experts else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # drop-free capacity (C >= T) so smoke tests are deterministic
            capacity_factor=float(min(self.n_experts, 4)
                                  / min(self.top_k, 2))
            if self.n_experts else 1.25,
            vocab=vocab, n_repeat=n_repeat,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            rwkv_head_dim=32,
            swa_window=min(self.swa_window, 64) if self.swa_window else 0,
            encoder=EncoderSpec(n_layers=2, n_frames=16)
            if self.encoder else None,
            n_patches=8 if self.n_patches else 0,
            dtype="float32")
