"""Dense MLP block (SwiGLU / GELU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, rms_norm, silu


def init_mlp(rng, cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 3)
    p = {"norm": jnp.ones((d,), jnp.float32),
         "w_up": dense_init(ks[0], (d, ff), dtype=dt),
         "w_down": dense_init(ks[1], (ff, d), dtype=dt)}
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, ff), dtype=dt)
    return p


def apply_mlp(params, cfg, x) -> jax.Array:
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    up = h @ params["w_up"]
    if cfg.mlp_act == "swiglu":
        up = silu(h @ params["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return x + up @ params["w_down"]
