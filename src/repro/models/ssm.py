"""Recurrent blocks: Mamba2 (chunked SSD) and RWKV6 (Finch) time/channel mix.

Both are O(1)-state decoders — the architectures for which the paper's 1/W
law *weakens* (no per-token KV growth; see DESIGN.md §5).  Prefill uses
chunked scans (matmul-heavy intra-chunk + state carry across chunks), which
is also the algorithmic shape of the Pallas kernels in repro.kernels; the
functions here are their pure-jnp oracles.

Conventions:
  Mamba2:  S_t = exp(A dt_t) S_{t-1} + dt_t x_t (x) B_t ;  y_t = C_t . S_t + D x_t
  RWKV6:   out_t = r_t (S_{t-1} + diag(u) k_t^T v_t) ;
           S_t = diag(w_t) S_{t-1} + k_t^T v_t,  w_t data-dependent.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, dtype_of, rms_norm, silu

# ======================================================================
# Mamba2
# ======================================================================


def init_mamba2(rng, cfg) -> dict:
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 4)
    conv_ch = di + 2 * ds
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * ds + nh), dtype=dt),
        "conv_w": dense_init(ks[1], (cfg.d_conv, conv_ch), scale=0.5,
                             dtype=jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_y": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[2], (di, d), dtype=dt),
    }


def _causal_conv_full(x, w, b):
    """Depthwise causal conv, x: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, j:j + x.shape[1]] * w[j] for j in range(K))
    return out + b


def _mamba_inner(cfg, params, h, conv_state=None, ssm_state=None,
                 single_step=False):
    """Shared projection/conv/split for full & decode paths."""
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = h @ params["w_in"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)
    if single_step:
        # xbc: (B,1,C); conv_state: (B, K-1, C)
        seq = jnp.concatenate([conv_state, xbc.astype(jnp.float32)], axis=1)
        w = params["conv_w"]
        conv = (seq * w[:, None] if False else
                jnp.einsum("bkc,kc->bc", seq, w))[:, None] + params["conv_b"]
        new_conv_state = seq[:, 1:]
    else:
        conv = _causal_conv_full(xbc.astype(jnp.float32), params["conv_w"],
                                 params["conv_b"])
        new_conv_state = xbc.astype(jnp.float32)[:, -(cfg.d_conv - 1):]
    xbc = silu(conv)
    xs, Bm, Cm = jnp.split(xbc, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    B_, S_ = xs.shape[0], xs.shape[1]
    xh = xs.reshape(B_, S_, nh, cfg.ssm_head_dim)
    return z, xh, Bm, Cm, dt, A, new_conv_state


def mamba2_chunk_scan(xh, Bm, Cm, dt, A, D, *, chunk: int = 128,
                      init_state: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  xh:(B,S,nh,hd) Bm/Cm:(B,S,ds) dt:(B,S,nh).

    Returns (y (B,S,nh,hd), final_state (B,nh,hd,ds)).
    """
    B, S, nh, hd = xh.shape
    ds = Bm.shape[-1]
    Lc = min(chunk, S)
    nch = -(-S // Lc)
    pad = nch * Lc - S

    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xh_, Bm_, Cm_, dt_ = map(padt, (xh, Bm, Cm, dt))
    dt_ = dt_.at[:, S:].set(0.0) if pad else dt_
    lA = dt_ * A                                  # (B, S', nh) log-decay <= 0
    xt = xh_ * dt_[..., None]                     # x-tilde

    # (nc, B, Lc, ...)
    def chunked(a):
        return a.reshape(B, nch, Lc, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1))

    xs_c, B_c, C_c, lA_c, xt_c = map(chunked, (xh_, Bm_, Cm_, lA, xt))

    s0 = (init_state if init_state is not None
          else jnp.zeros((B, nh, hd, ds), jnp.float32))

    def per_chunk(state, inp):
        xs_k, B_k, C_k, lA_k, xt_k = inp
        cs = jnp.cumsum(lA_k, axis=1)             # (B, Lc, nh) inclusive
        # intra-chunk: weight(tau->q) = exp(cs_q - cs_tau), q >= tau.
        # Mask BEFORE exp: upper-triangle diffs are large-positive and a
        # where() after exp still back-propagates inf * 0 = NaN.
        diff = cs[:, :, None, :] - cs[:, None, :, :]        # (B, q, t, nh)
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))[None, :, :, None]
        G = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
        att = jnp.einsum("bqs,bts->bqt", C_k, B_k)          # (B, q, t)
        y_intra = jnp.einsum("bqt,bqtn,btnp->bqnp", att, G, xt_k)
        # inter-chunk: y_q += exp(cs_q) * C_q . state
        y_inter = jnp.einsum("bqs,bnps,bqn->bqnp", C_k, state,
                             jnp.exp(cs))
        # state update
        dec = jnp.exp(cs[:, -1:, :] - cs)                    # (B, t, nh)
        s_new = state * jnp.exp(cs[:, -1])[:, :, None, None] \
            + jnp.einsum("btn,btnp,bts->bnps", dec, xt_k, B_k)
        return s_new, y_intra + y_inter

    state, ys = jax.lax.scan(per_chunk, s0, (xs_c, B_c, C_c, lA_c, xt_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nch * Lc, nh, hd)[:, :S]
    y = y + xh * D[None, None, :, None]
    return y, state


def mamba2_full(params, cfg, x, *, mode: str = "train",
                ) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    z, xh, Bm, Cm, dt, A, conv_state = _mamba_inner(cfg, params, h)
    y, state = mamba2_chunk_scan(xh, Bm, Cm, dt, A, params["D"])
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(y * silu(z.astype(jnp.float32)), params["norm_y"],
                 cfg.norm_eps)
    out = y.astype(x.dtype) @ params["w_out"]
    cache = None
    if mode == "prefill":
        cache = {"conv": conv_state, "ssm": state}
    return x + out, cache


def mamba2_decode(params, cfg, x, cache: dict, pos=None,
                  ) -> Tuple[jax.Array, dict]:
    B, S1, d = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    z, xh, Bm, Cm, dt, A, new_conv = _mamba_inner(
        cfg, params, h, conv_state=cache["conv"], single_step=True)
    # single-step SSM update
    dA = jnp.exp(dt[:, 0] * A)                                # (B, nh)
    xt = xh[:, 0] * dt[:, 0, :, None]                          # (B, nh, hd)
    s_new = cache["ssm"] * dA[..., None, None] \
        + jnp.einsum("bnp,bs->bnps", xt, Bm[:, 0])
    y = jnp.einsum("bnps,bs->bnp", s_new, Cm[:, 0]) \
        + xh[:, 0] * params["D"][None, :, None]
    y = y.reshape(B, 1, cfg.d_inner)
    y = rms_norm(y * silu(z.astype(jnp.float32)), params["norm_y"],
                 cfg.norm_eps)
    out = y.astype(x.dtype) @ params["w_out"]
    return x + out, {"conv": new_conv, "ssm": s_new}


# ======================================================================
# RWKV6
# ======================================================================

_LORA = 64


def init_rwkv6(rng, cfg) -> dict:
    d, H, hd, ff = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 10)
    return {
        "norm_tm": jnp.ones((d,), jnp.float32),
        "norm_cm": jnp.ones((d,), jnp.float32),
        "maa": 0.5 * jnp.ones((5, d), jnp.float32),   # r,k,v,g,w mixing
        "w0": -6.0 * jnp.ones((H, hd), jnp.float32),
        "wA": dense_init(ks[0], (d, _LORA), scale=0.01, dtype=jnp.float32),
        "wB": dense_init(ks[1], (_LORA, H * hd), scale=0.01,
                         dtype=jnp.float32),
        "u": 0.5 * jnp.ones((H, hd), jnp.float32),
        "Wr": dense_init(ks[2], (d, d), dtype=dt),
        "Wk": dense_init(ks[3], (d, d), dtype=dt),
        "Wv": dense_init(ks[4], (d, d), dtype=dt),
        "Wg": dense_init(ks[5], (d, d), dtype=dt),
        "Wo": dense_init(ks[6], (d, d), dtype=dt),
        "ln_x": jnp.ones((d,), jnp.float32),
        "maa_cm": 0.5 * jnp.ones((2, d), jnp.float32),
        "Wk_cm": dense_init(ks[7], (d, ff), dtype=dt),
        "Wv_cm": dense_init(ks[8], (ff, d), dtype=dt),
        "Wr_cm": dense_init(ks[9], (d, d), dtype=dt),
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1}, with `prev` filling slot 0 (decode state)."""
    first = prev[:, None] if prev is not None \
        else jnp.zeros_like(x[:, :1])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def wkv6_chunk_scan(r, k, v, w, u, *, chunk: int = 64,
                    init_state: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6 recurrence.

    r,k,v,w: (B,S,H,hd); w in (0,1) is the per-channel data-dependent decay.
    Returns (out (B,S,H,hd), final_state (B,H,hd,hd) [k-dim, v-dim]).
    """
    B, S, H, hd = r.shape
    Lc = min(chunk, S)
    nch = -(-S // Lc)
    pad = nch * Lc - S

    def padt(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=fill)

    r_, k_, v_ = padt(r), padt(k), padt(v)
    w_ = padt(w, fill=1.0)
    lw = jnp.log(jnp.maximum(w_, 1e-12))                       # (B,S',H,hd)

    def chunked(a):
        return a.reshape(B, nch, Lc, H, hd).transpose(1, 0, 2, 3, 4)

    r_c, k_c, v_c, lw_c = map(chunked, (r_, k_, v_, lw))
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))

    def per_chunk(state, inp):
        rk, kk, vk, lwk = inp
        cw = jnp.cumsum(lwk, axis=1)                # inclusive (B,Lc,H,hd)
        cw_ex = cw - lwk                            # exclusive: sum_{s<t}
        # inter: out_t += (r_t * exp(cw_ex_t)) . state
        y_inter = jnp.einsum("bthd,bhde->bthe", rk * jnp.exp(cw_ex), state)
        # intra past tokens: A[t,tau] = sum_d r_t exp(cw_ex_t - cw_tau) k_tau
        qd = rk * jnp.exp(cw_ex)                    # (B,t,H,hd)
        kd = kk * jnp.exp(-cw)                      # (B,tau,H,hd)
        att = jnp.einsum("bthd,bshd->bhts", qd, kd)
        tri = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)   # strictly past
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhts,bshe->bthe", att, vk)
        # current-token bonus
        bonus = jnp.einsum("bthd,bthd->bth", rk, u[None, None] * kk)
        y_bonus = bonus[..., None] * vk
        # state update: S = diag(exp(cw_L)) S + sum_tau diag(exp(cw_L-cw_tau)) k v
        decay_all = jnp.exp(cw[:, -1])              # (B,H,hd)
        kdec = kk * jnp.exp(cw[:, -1][:, None] - cw)
        s_new = state * decay_all[..., None] \
            + jnp.einsum("bshd,bshe->bhde", kdec, vk)
        return s_new, y_inter + y_intra + y_bonus

    state, ys = jax.lax.scan(per_chunk, s0, (r_c, k_c, v_c, lw_c))
    out = ys.transpose(1, 0, 2, 3, 4).reshape(B, nch * Lc, H, hd)[:, :S]
    return out, state


def _rwkv_decay(params, xw, H, hd):
    lora = jnp.tanh(xw.astype(jnp.float32) @ params["wA"]) @ params["wB"]
    w = jnp.exp(-jnp.exp(params["w0"].reshape(-1)
                         + lora))                  # (B,S,H*hd) in (0,1)
    return w.reshape(*xw.shape[:-1], H, hd)


def rwkv6_full(params, cfg, x, *, mode: str = "train",
               ) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    # ---- time mix ----
    h = rms_norm(x, params["norm_tm"], cfg.norm_eps)
    hx = _shift(h)
    # mixing coefficients in the residual dtype: f32 maa promoted all five
    # (B,S,d) mixed copies (and the d x d matmuls consuming them) to f32 —
    # 40 % of this block's HBM bytes at train_4k (§Perf iteration 3a)
    maa = params["maa"].astype(h.dtype)
    # One batched dot for r/k/v/g: five separate d x d matmuls each paid an
    # activation-shaped collective in their backward pass (dx = dy @ W^T
    # partial-sums over the TP axis); batching them makes it one
    # (§Perf iteration 3c).
    W_tm = jnp.stack([params["Wr"], params["Wk"], params["Wv"],
                      params["Wg"]])                       # (4, d, d)
    delta = hx - h
    mixed4 = h[:, :, None, :] + delta[:, :, None, :] * maa[None, None, :4]
    proj = jnp.einsum("bsid,idf->bsif", mixed4, W_tm)
    r = proj[:, :, 0].reshape(B, S, H, hd).astype(jnp.float32)
    k = proj[:, :, 1].reshape(B, S, H, hd).astype(jnp.float32)
    v = proj[:, :, 2].reshape(B, S, H, hd).astype(jnp.float32)
    g = proj[:, :, 3]
    xw = h + delta * maa[4]
    w = _rwkv_decay(params, xw, H, hd)
    out, state = wkv6_chunk_scan(r, k, v, w, params["u"])
    out = rms_norm(out.reshape(B, S, d), params["ln_x"], cfg.norm_eps)
    y = (out * silu(g.astype(jnp.float32))).astype(x.dtype) @ params["Wo"]
    x = x + y
    # ---- channel mix ----
    h2 = rms_norm(x, params["norm_cm"], cfg.norm_eps)
    hx2 = _shift(h2)
    maa_cm = params["maa_cm"].astype(h2.dtype)
    xk2 = h2 + (hx2 - h2) * maa_cm[0]
    xr2 = h2 + (hx2 - h2) * maa_cm[1]
    kcm = jnp.square(jax.nn.relu(xk2 @ params["Wk_cm"]))
    out2 = jax.nn.sigmoid(xr2 @ params["Wr_cm"]) * (kcm @ params["Wv_cm"])
    x = x + out2.astype(x.dtype)
    cache = None
    if mode == "prefill":
        cache = {"wkv": state, "shift_tm": h[:, -1], "shift_cm": h2[:, -1]}
    return x, cache


def rwkv6_decode(params, cfg, x, cache: dict, pos=None,
                 ) -> Tuple[jax.Array, dict]:
    B, S1, d = x.shape
    H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    h = rms_norm(x, params["norm_tm"], cfg.norm_eps)
    hx = _shift(h, prev=cache["shift_tm"])
    maa = params["maa"].astype(h.dtype)
    mixed = [h + (hx - h) * maa[i] for i in range(5)]
    xr, xk, xv, xg, xw = mixed
    r = (xr @ params["Wr"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xk @ params["Wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xv @ params["Wv"]).reshape(B, H, hd).astype(jnp.float32)
    g = xg @ params["Wg"]
    w = _rwkv_decay(params, xw, H, hd)[:, 0]        # (B,H,hd)
    S_prev = cache["wkv"]
    out = jnp.einsum("bhd,bhde->bhe", r, S_prev) \
        + jnp.einsum("bhd,bhd->bh", r, params["u"][None] * k)[..., None] \
        * v
    s_new = S_prev * w[..., None] + jnp.einsum("bhd,bhe->bhde", k, v)
    out = rms_norm(out.reshape(B, 1, d), params["ln_x"], cfg.norm_eps)
    y = (out * silu(g.astype(jnp.float32))).astype(x.dtype) @ params["Wo"]
    x = x + y
    h2 = rms_norm(x, params["norm_cm"], cfg.norm_eps)
    hx2 = _shift(h2, prev=cache["shift_cm"])
    maa_cm = params["maa_cm"].astype(h2.dtype)
    xk2 = h2 + (hx2 - h2) * maa_cm[0]
    xr2 = h2 + (hx2 - h2) * maa_cm[1]
    kcm = jnp.square(jax.nn.relu(xk2 @ params["Wk_cm"]))
    out2 = jax.nn.sigmoid(xr2 @ params["Wr_cm"]) * (kcm @ params["Wv_cm"])
    x = x + out2.astype(x.dtype)
    return x, {"wkv": s_new, "shift_tm": h[:, 0], "shift_cm": h2[:, 0]}
