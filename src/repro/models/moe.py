"""Top-k MoE block with sort-based capacity dispatch.

Dispatch is the sort/scatter scheme (MaxText-style) rather than the dense
one-hot einsum: tokens are repeated k times, sorted by expert id, scattered
into a fixed (E, C, d) buffer, processed with batched expert einsums, and
combined back with router gates.  This keeps compiled FLOPs equal to
top_k/E of the dense-all-experts cost (capacity factor aside), which is what
makes the paper's active-parameter weight-streaming analysis (§3.2) visible
in the dry-run roofline instead of being washed out by 4x padded compute.

Sharding: the (E, C, d) buffer is expert-sharded on the `model` mesh axis
when E % model == 0 (granite: 32 experts / 16); otherwise experts are
replicated and each expert's ffn dim is TP-sharded (grok: 8 experts).
XLA inserts the all-to-all at the scatter/gather boundary.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import constrain, dense_init, dtype_of, rms_norm, silu


def _f0(a):
    """float0 cotangent for integer arguments."""
    return np.zeros(a.shape, jax.dtypes.float0)


# --- gather-only autodiff primitives ----------------------------------
# The VJP of a gather is a scatter-add, which the SPMD partitioner lowers
# to a masked all-reduce of the full feature buffer.  All our index maps
# are (partial) permutations, so each backward pass can be expressed as a
# gather by the inverse map instead (§Perf iteration 2d).

@jax.custom_vjp
def _permute(x, perm, inv_perm):
    """y[i] = x[perm[i]] with a gather-based VJP (inv_perm = perm^-1)."""
    return x[perm]


def _permute_fwd(x, perm, inv_perm):
    return x[perm], (inv_perm,)


def _permute_bwd(res, g):
    (inv_perm,) = res
    return g[inv_perm], _f0(inv_perm), _f0(inv_perm)


_permute.defvjp(_permute_fwd, _permute_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _slot_gather(hf_pad, slot_token, token_slot, k):
    """buf[slot] = hf_pad[slot_token[slot]] (sentinel row -> zeros).

    VJP: each token feeds at most k slots; token_slot lists them (flat
    assignment-major, sentinel E*C for dropped), so d_hf = sum_k of a
    gather — no feature scatter."""
    return hf_pad[slot_token]


def _slot_gather_fwd(hf_pad, slot_token, token_slot, k):
    return hf_pad[slot_token], (slot_token, token_slot, hf_pad.shape[0])


def _slot_gather_bwd(k, res, g):
    slot_token, token_slot, n_rows = res
    g_pad = jnp.concatenate([g, jnp.zeros((1, g.shape[1]), g.dtype)], 0)
    per_choice = g_pad[token_slot]                    # (Tg*k, d)
    d_hf = per_choice.reshape(-1, k, g.shape[1]).sum(1)
    d_hf = jnp.concatenate(
        [d_hf, jnp.zeros((n_rows - d_hf.shape[0], g.shape[1]), g.dtype)], 0)
    return d_hf, _f0(slot_token), _f0(token_slot)


_slot_gather.defvjp(_slot_gather_fwd, _slot_gather_bwd)


@jax.custom_vjp
def _pick(out_flat, dest, keep, slot_s):
    """picked[s] = keep[s] ? out_flat[dest[s]] : 0, gather-based VJP via
    the inverse slot->sorted-position map slot_s (sentinel -> zero)."""
    return jnp.where(keep[:, None], out_flat[dest], 0)


def _pick_fwd(out_flat, dest, keep, slot_s):
    return _pick(out_flat, dest, keep, slot_s), (dest, keep, slot_s)


def _pick_bwd(res, g):
    dest, keep, slot_s = res
    gm = jnp.where(keep[:, None], g, 0)
    gm_pad = jnp.concatenate([gm, jnp.zeros((1, g.shape[1]), g.dtype)], 0)
    d_out = gm_pad[slot_s]                            # (E*C, d)
    return d_out, _f0(dest), _f0(keep), _f0(slot_s)


_pick.defvjp(_pick_fwd, _pick_bwd)


def init_moe(rng, cfg) -> dict:
    d, E = cfg.d_model, cfg.n_experts
    fe = cfg.moe_d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 4)
    return {"norm": jnp.ones((d,), jnp.float32),
            "router": dense_init(ks[0], (d, E), scale=0.02, dtype=jnp.float32),
            "w_gate": dense_init(ks[1], (E, d, fe), dtype=dt),
            "w_up": dense_init(ks[2], (E, d, fe), dtype=dt),
            "w_down": dense_init(ks[3], (E, fe, d), dtype=dt)}


def router_topk(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Softmax-then-topk router (granite/grok convention): gates renormed."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(logits: jax.Array, idx: jax.Array, n_experts: int
                      ) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.reshape(-1, n_experts).mean(0)
    one_hot = jax.nn.one_hot(idx.reshape(-1), n_experts).mean(0)
    return n_experts * jnp.sum(me * one_hot)


def _n_dispatch_groups(T: int) -> int:
    """Group-local dispatch: one routing group per data shard so the
    argsort/scatter stays local and inter-group traffic is a single
    all-to-all on the (G, E, C, d) buffer (GSPMD cannot shard a *global*
    sort — it replicates it, an ~80 GiB/device disaster at train_4k)."""
    from .common import batch_axes
    from .compat import get_abstract_mesh
    m = get_abstract_mesh()
    g = 1
    if m is not None and not m.empty:
        for a in batch_axes():   # includes `model` under pure-DP mappings
            g *= m.shape[a]
    while T % g:
        g //= 2
    return max(g, 1)


def _dispatch_group(hf, gates, idx, E: int, k: int, C: int):
    """Sort-based dispatch of one group: hf (Tg, d) -> (E, C, d) + combine
    metadata.

    Scatter-free feature movement: all data-dependent *feature* transfers
    are gathers (pass-through partitioning in GSPMD); the only scatter is
    of int32 slot->token indices (Tg*k * 4 bytes).  Feature scatters made
    the SPMD partitioner emit masked (u32+f32) all-reduces of the full
    (Tg*k, d) buffer — 9.3 GiB/chip *per layer* on granite-moe train_4k
    (§Perf iteration 2b).
    """
    Tg, d = hf.shape
    Tk = Tg * k
    flat_e = idx.reshape(-1)                                    # (Tk,)
    order = jnp.argsort(flat_e)
    inv_order = jnp.argsort(order)
    sorted_e = flat_e[order]
    token_of = order // k
    counts = jnp.bincount(sorted_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(Tk) - starts[sorted_e]
    keep = pos_in_e < C
    dest = sorted_e * C + jnp.where(keep, pos_in_e, 0)
    safe_dest = jnp.where(keep, dest, E * C)     # dropped: off the end
    # int32 index maps (4-byte scatters; the *feature* movement below is
    # gather-only in both fwd and bwd):
    slot_token = jnp.full((E * C,), Tg, jnp.int32).at[safe_dest].set(
        token_of.astype(jnp.int32), mode="drop")
    token_slot = jnp.where(keep[inv_order], dest[inv_order],
                           E * C).astype(jnp.int32)            # (Tk,)
    slot_s = jnp.full((E * C,), Tk, jnp.int32).at[safe_dest].set(
        jnp.arange(Tk, dtype=jnp.int32), mode="drop")
    hf_pad = jnp.concatenate([hf, jnp.zeros((1, d), hf.dtype)], axis=0)
    # NOTE (§Perf iteration 2d, REFUTED): replacing the implicit backward
    # scatter-adds of these gathers with explicit inverse-map gathers
    # (_slot_gather/_pick/_permute custom VJPs above) made the collective
    # term 33 % WORSE — GSPMD lowers cross-shard gathers to the same
    # masked all-reduce as scatters, so 2 bwd gathers > 1 bwd scatter.
    # The custom-vjp primitives are kept for the TPU path where a Pallas
    # ragged all-to-all would make them local.
    buf = hf_pad[slot_token]
    return buf.reshape(E, C, d), (dest, keep, slot_s, order, inv_order)


def _combine_group(out_e, meta, gates, k: int):
    dest, keep, slot_s, order, inv_order = meta
    Tg = gates.shape[0]
    d = out_e.shape[-1]
    picked = jnp.where(keep[:, None], out_e.reshape(-1, d)[dest], 0)
    unsorted = picked[inv_order]
    return jnp.einsum("tkd,tk->td", unsorted.reshape(Tg, k, d)
                      .astype(jnp.float32), gates)


def apply_moe(params, cfg, x, *, return_aux: bool = False):
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = _n_dispatch_groups(T)
    Tg = T // G
    if S == 1:
        # decode: per-expert load is bounded by Tg, so C = Tg never drops a
        # token (a dropped token at decode would corrupt the stream).
        C = Tg
    else:
        C = max(int(Tg * k / E * cfg.capacity_factor), 1)

    h = rms_norm(x, params["norm"], cfg.norm_eps)
    hf = h.reshape(G, Tg, d)
    hf = constrain(hf, "BATCH")
    logits = hf.astype(jnp.float32) @ params["router"]          # (G, Tg, E)
    gates, idx = router_topk(logits, k)

    buf, meta = jax.vmap(
        lambda hh, gg, ii: _dispatch_group(hh, gg, ii, E, k, C))(
            hf, gates, idx)                                     # (G, E, C, d)
    # data->expert boundary: the resharding below is the all-to-all
    ep = "model" if (E % _model_axis_size() == 0) else None
    buf = constrain(buf, "BATCH", ep)
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    out_e = jnp.einsum("gecf,efd->gecd", silu(gate) * up, params["w_down"])
    out_e = constrain(out_e, "BATCH", ep)

    y = jax.vmap(lambda oo, m0, m1, m2, m3, m4, gg:
                 _combine_group(oo, (m0, m1, m2, m3, m4), gg, k))(
        out_e, *meta, gates)                                    # (G, Tg, d)
    out = x + y.reshape(B, S, d).astype(x.dtype)
    if return_aux:
        return out, load_balance_loss(logits, idx, E)
    return out


def _model_axis_size() -> int:
    from .compat import get_abstract_mesh
    m = get_abstract_mesh()
    if m is None or m.empty or "model" not in m.axis_names:
        return 1
    return m.shape["model"]
