"""AdamW in pure JAX (no optax dependency) + cosine LR schedule."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 20
    total_steps: int = 1000

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def schedule(self, step) -> jax.Array:
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState]:
        # global-norm clip
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        b1t = 1 - self.b1 ** step.astype(jnp.float32)
        b2t = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            upd = (m / b1t) / (jnp.sqrt(v / b2t) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
