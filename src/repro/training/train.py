"""Training loop substrate: train_step + TrainState."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.spec import ArchConfig

from .optimizer import AdamW, AdamWState


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: AdamWState


def make_train_step(cfg: ArchConfig, opt: AdamW):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Pure function of its inputs — safe to jit/pjit."""

    def train_step(params, opt_state, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch))(params)
        new_params, new_opt = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm,
                                     "lr": opt.schedule(new_opt.step)}

    return train_step


def train_loop(cfg: ArchConfig, *, steps: int, batch_iter, opt: AdamW,
               rng=None, log_every: int = 10, callback=None):
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    history = []
    for i in range(steps):
        batch = next(batch_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            if callback:
                callback(i, m)
    return params, opt_state, history
