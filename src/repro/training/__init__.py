from .checkpoint import load_checkpoint, save_checkpoint
from .optimizer import AdamW, AdamWState
from .train import TrainState, make_train_step, train_loop

__all__ = ["AdamW", "AdamWState", "TrainState", "make_train_step",
           "train_loop", "save_checkpoint", "load_checkpoint"]
