"""Minimal checkpointing: pytree <-> .npz with path-flattened keys."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, params: Any, step: int = 0) -> None:
    flat = _flatten(params)
    flat["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_checkpoint(path: str, template: Any):
    """Restore into the structure of `template` (shape/dtype preserved)."""
    data = np.load(path)
    step = int(data["__step__"])
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_elems, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_elems)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
