"""Synthetic LM data pipeline.

Deterministic, seeded, infinite token streams with a Zipfian unigram
distribution plus short-range Markov structure (so a ~100M model actually
has something learnable — loss decreases measurably within a few hundred
steps, unlike uniform noise).  Supplies the modality-stub tensors
(patches/frames) for VLM/audio backbones.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.spec import ArchConfig


class SyntheticLM:
    """Zipf unigram + first-order Markov synthetic corpus."""

    def __init__(self, vocab: int, seed: int = 0, alpha: float = 1.1,
                 markov_order_mix: float = 0.7):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.unigram = ranks ** (-alpha)
        self.unigram /= self.unigram.sum()
        # sparse deterministic successor table: token t prefers (a t + c) % V
        self.succ = (31 * np.arange(vocab) + 17) % vocab
        self.mix = markov_order_mix

    def sample_tokens(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int64)
        out[:, 0] = self.rng.choice(self.vocab, size=batch, p=self.unigram)
        for t in range(1, seq):
            follow = self.rng.random(batch) < self.mix
            out[:, t] = np.where(
                follow, self.succ[out[:, t - 1]],
                self.rng.choice(self.vocab, size=batch, p=self.unigram))
        return out


def batch_iterator(cfg: ArchConfig, *, batch: int, seq: int,
                   seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    src = SyntheticLM(cfg.vocab, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        toks = src.sample_tokens(batch, seq)
        out = {"tokens": toks, "labels": toks}
        if cfg.n_patches:
            out["patches"] = rng.standard_normal(
                (batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.encoder is not None:
            out["frames"] = rng.standard_normal(
                (batch, cfg.encoder.n_frames, cfg.d_model)) \
                .astype(np.float32) * 0.02
        yield out
