from .pipeline import SyntheticLM, batch_iterator

__all__ = ["SyntheticLM", "batch_iterator"]
