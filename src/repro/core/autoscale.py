"""Autoscaling policy knobs (declarative config only).

The *mechanics* — planning per-instance online windows from a routed
trace and rebuilding the pool engines — live in `serving.autoscale`;
this module holds only the frozen policy dataclass so the topology IR
(`core.topospec.TopologySpec.autoscale`) can carry the knob without the
core layer importing serving.

The controller this configures is deliberately the boring production
one: reactive rate tracking.  Each pool watches its own per-epoch
arrival rate (the RPS signal every serving autoscaler exports), targets
`target_utilization` of the per-instance service rate the *peak* sizing
plan established, reacts one control epoch behind the signal, pays
`scaleup_lag_s` of control-plane actuation plus a weight-load time
derived from the model's byte size before new capacity serves, and only
sheds capacity after the demand signal has been low for
`scaledown_delay_s` (hysteresis).  No oracle knowledge of the diurnal
envelope enters the loop — the measured whole-day tok/W therefore pays
every reaction lag and every warm spare the real policy would.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Per-pool reactive autoscaling configuration.

    `weight_load_Bps` is the bandwidth new capacity streams model bytes
    at before it can serve (PCIe gen5 x16 host -> HBM ~ 60 GB/s); the
    load *duration* is derived per pool from its `ModelProfileRegistry`
    binding's weight bytes, so a 70B pool pays a longer cold start than
    an 8B one.  `min_frac` floors the pool at a fraction of its peak
    instance count (>= 1 instance always stays online).
    `spare_instances` is N+1-style redundancy: held on top of the
    rate-derived target so a small pool (where one instance is a big
    fraction of capacity) is not quantized straight to the critical
    point — its idle draw is exactly the warm-spare power the fleet
    report charges."""

    control_interval_s: float = 60.0
    target_utilization: float = 0.85
    scaleup_lag_s: float = 30.0
    scaledown_delay_s: float = 300.0
    min_frac: float = 0.1
    weight_load_Bps: float = 60e9
    spare_instances: int = 1

    def __post_init__(self):
        if self.control_interval_s <= 0:
            raise ValueError("control_interval_s must be positive")
        if not 0.0 < self.target_utilization <= 1.0:
            raise ValueError("target_utilization must be in (0, 1]")
        if self.scaleup_lag_s < 0 or self.scaledown_delay_s < 0:
            raise ValueError("lag/delay must be non-negative")
        if not 0.0 <= self.min_frac <= 1.0:
            raise ValueError("min_frac must be in [0, 1]")
        if self.weight_load_Bps <= 0:
            raise ValueError("weight_load_Bps must be positive")
        if self.spare_instances < 0:
            raise ValueError("spare_instances must be non-negative")

    def canon(self) -> tuple:
        """Canonical tuple for `TopologySpec.spec_hash` embedding."""
        return ("autoscale", self.control_interval_s,
                self.target_utilization, self.scaleup_lag_s,
                self.scaledown_delay_s, self.min_frac, self.weight_load_Bps,
                self.spare_instances)
