"""SLO-constrained fleet sizing: the simulator as provisioning authority.

The closed-form sizing in `core.fleet` is *optimistic*: its prefill
piggyback model (effective PREFILL_MFU) ignores queueing, so fleets it
provisions can violate the paper's P99 TTFT <= 500 ms constraint when
actually run through `serving.fleetsim` — Table 3's tok/W numbers were
quoted for fleets that don't meet their own SLO.  This module closes the
predict-vs-measure loop (the TokenPowerBench-style validation posture):

  1. provision a topology analytically (`serving.fleetsim.build_topology`);
  2. *measure* its TTFT p99 by running the fleet end-to-end in FleetSim;
  3. while the measurement violates the SLO, recalibrate the violating
     pools — lower their effective prefill MFU (which raises the
     closed-form prefill instance bound) and force at least one extra
     instance — and re-provision;
  4. report the SLO-feasible fleet next to the unconstrained Eq. 4 one:
     the tok/W delta is the measured price of latency compliance.

Capacity is monotone non-decreasing across rounds and the SLO target is
never loosened — the loop only ever *adds* instances, so it terminates
(each violating pool grows every round) and the resulting tok/W cost is
monotone in the number of rounds.  See DESIGN.md §5/§6.

The loop works for every router topology FleetSim can serve: homo,
two_pool, fleetopt, K >= 3 multipool ladders and the prefill/decode
disaggregated kinds (paper §10.3).  For disaggregated fleets the prefill
and decode fleets re-provision *independently*: TTFT violations grow the
prefill pools (they drain the prompt), TPOT violations (when
`SLOSpec.tpot_p99_ms` is set) grow the decode pools.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from .fleet import PREFILL_MFU, FleetReport, PoolOverride
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .workloads import Workload

# per-round backoff clamps: the capacity step is driven by the *fleet*
# TTFT overshoot (a violating pool's own p99 can be service-time-bound —
# a giant prompt's prefill takes seconds no matter how many instances
# exist — so stepping by per-pool overshoot over-provisions wildly);
# bounded to [1.15, 1.5] per round — geometric convergence with at most
# ~50% capacity overshoot past the compliance frontier — and the
# effective prefill MFU never drops below 2% of peak
_MIN_STEP = 1.15
_MAX_STEP = 1.5
_MIN_MFU = 0.02


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Latency service-level objective (paper §4: P99 TTFT <= 500 ms).

    `tpot_p99_ms` optionally constrains the P99 time-per-output-token the
    meters already report (None = TTFT-only, the paper's constraint).  In
    a disaggregated fleet the two constraints pull on different pools:
    prefill instances drive TTFT, decode instances drive TPOT.
    """

    ttft_p99_s: float = 0.5
    tpot_p99_ms: Optional[float] = None


@dataclasses.dataclass
class SLORound:
    """One provision -> simulate -> adjust iteration."""

    round: int
    instances: Dict[str, int]            # role -> provisioned instances
    ttft_p99_s: float                    # measured, fleet-wide
    per_pool_ttft_p99_s: Dict[str, float]
    violators: Dict[str, int]            # role -> attributed SLO violations
    budget: int                          # fleet-wide violator allowance
    analytical_tok_per_watt: float       # of this round's (adjusted) plan
    measured_tok_per_watt: float         # all-in, steady-state window
    measured_decode_tok_per_watt: float
    tpot_p99_ms: float = 0.0             # measured, fleet-wide


@dataclasses.dataclass
class SLOSizingResult:
    """SLO-feasible fleet + the audit trail that produced it."""

    kind: str
    workload: str
    slo: SLOSpec
    policy: object                       # serving.RouterPolicy
    plan: FleetReport                    # final, SLO-adjusted sizing
    unconstrained: FleetReport           # round-0 Eq. 4 sizing
    report: Dict[str, dict]              # final FleetSim report
    overrides: Dict[str, PoolOverride]   # accumulated recalibrations
    rounds: List[SLORound]
    compliant: bool

    @property
    def ttft_p99_s(self) -> float:
        return float(self.report["fleet"].get("ttft_p99_s", 0.0))

    @property
    def slo_tok_per_watt(self) -> float:
        """The headline metric: analytical tok/W of the SLO-feasible fleet
        (Eq. 4 evaluated on the sizing that actually meets its SLO)."""
        return self.plan.tok_per_watt

    @property
    def measured_tok_per_watt(self) -> float:
        return float(self.report["fleet"]["tok_per_watt"])

    @property
    def measured_decode_tok_per_watt(self) -> float:
        return float(self.report["fleet"]["decode_tok_per_watt"])

    @property
    def compliance_cost_pct(self) -> float:
        """tok/W given up to meet the SLO, vs the unconstrained Eq. 4
        fleet (positive = compliance costs efficiency)."""
        u = self.unconstrained.tok_per_watt
        return 100.0 * (1.0 - self.slo_tok_per_watt / u) if u else 0.0

    @property
    def instances_added(self) -> int:
        return self.plan.instances - self.unconstrained.instances

    @property
    def calibrated_prefill_mfu(self) -> Dict[str, float]:
        """Effective per-pool prefill MFU the loop converged to (roles not
        listed kept the closed-form PREFILL_MFU)."""
        return {role: o.prefill_mfu for role, o in self.overrides.items()
                if o.prefill_mfu is not None}

    def row(self) -> dict:
        return dict(topology=self.kind, workload=self.workload,
                    unconstrained=round(self.unconstrained.tok_per_watt, 2),
                    slo_feasible=round(self.slo_tok_per_watt, 2),
                    cost_pct=round(self.compliance_cost_pct, 1),
                    measured=round(self.measured_decode_tok_per_watt, 2),
                    ttft_p99_s=round(self.ttft_p99_s, 3),
                    tpot_p99_ms=round(float(
                        self.report["fleet"].get("tpot_p99_ms", 0.0)), 3),
                    instances=self.plan.instances,
                    added=self.instances_added,
                    rounds=len(self.rounds),
                    compliant=self.compliant)


def size_to_slo(kind: str, workload: Workload, profile: BaseProfile,
                model: ModelSpec, *, b_short: int = 4096,
                gamma: float = 2.0,
                windows: Optional[Sequence[int]] = None,
                slo: SLOSpec = SLOSpec(),
                n_requests: int = 3000, seed: int = 0,
                max_rounds: int = 8, prefill_chunk: int = 512,
                long_window: Optional[int] = None) -> SLOSizingResult:
    """Iteratively re-provision `kind` until the *measured* TTFT p99 meets
    the SLO (or `max_rounds` is exhausted — `compliant` reports which).

    Each round replays the identical request trace (same seed), so rounds
    differ only in fleet capacity.  Violating pools are identified by
    violator-count attribution: a pool is grown when it holds more
    requests with TTFT > SLO than its completion-weighted share of the
    fleet-wide p99 budget (floor(1% x completions)), falling back to the
    largest remaining contributor; pools whose violator count stops
    dropping despite growth are saturated (service-time-bound) and
    excluded.  Each grown pool is recalibrated via `PoolOverride`:
    effective prefill MFU backed off by the *fleet* TTFT overshoot and
    the instance floor stepped up by the same factor (at least one
    instance per round, for guaranteed progress).
    """
    # serving imports are lazy: core stays importable without the serving
    # layer, and the serving layer itself imports core.fleet
    from repro.serving.fleetsim import (FleetSim, build_topology,
                                        topology_roles, trace_requests)
    from repro.core.routing import LONG_WINDOW

    if long_window is None:
        long_window = int(max(windows)) if (kind == "multipool" and windows) \
            else LONG_WINDOW
    overrides: Dict[str, PoolOverride] = {}
    rounds: List[SLORound] = []
    unconstrained: Optional[FleetReport] = None
    base_mfu: Dict[str, float] = {}
    policy = plan = report = sim = None
    compliant = False
    prev_violators: Dict[str, int] = {}
    grown_last: set = set()
    saturated: set = set()
    for round_i in range(max_rounds):
        policy, plan = build_topology(
            kind, workload, profile, model, b_short=b_short, gamma=gamma,
            long_window=long_window, windows=windows,
            pool_overrides=overrides or None)
        if unconstrained is None:
            # round 0 has no overrides: this plan IS the pure Eq. 4 sizing
            # (later rounds re-provision fresh PoolSizing objects, so it
            # is never mutated again)
            unconstrained = plan
            # MFU backoff starts from each pool's *sized* MFU, not the
            # global closed-form constant (a disagg prefill pool may have
            # been provisioned at its own dedicated-prefill MFU)
            base_mfu = {role: pool.sized_prefill_mfu
                        for role, pool in zip(
                            topology_roles(kind, plan),
                            sorted(plan.pools, key=lambda p: p.window))}
        sim = FleetSim(policy, plan, model=model,
                       prefill_chunk=prefill_chunk, rng_seed=seed)
        reqs = trace_requests(workload, n_requests, seed=seed,
                              max_total=long_window)
        report = sim.run(reqs)
        fleet_p99 = float(report["fleet"].get("ttft_p99_s", 0.0))
        fleet_tpot = float(report["fleet"].get("tpot_p99_ms", 0.0))
        per_pool = {role: float(lat.get("ttft_p99_s", 0.0))
                    for role, lat in sim.latency_by_role().items()}
        # violation attribution: the fleet p99 <= SLO iff at most
        # floor(1% of observations) exceed the SLO — count each pool's
        # contribution to that fleet-wide violator budget.  A TTFT
        # violation is attributed to the pool that drained the request's
        # prefill (in a disagg fleet that is the prefill pool: decode
        # capacity cannot buy TTFT there); a TPOT violation (when the SLO
        # constrains TPOT) to the pool that decoded the request.
        violators = {role: 0 for role in sim.order}
        observations = {role: 0 for role in sim.order}
        for role in sim.order:
            for r in sim.groups[role].completed:
                ttft_role = r.prefill_role \
                    if r.prefill_role in violators else role
                observations[ttft_role] += 1
                if r.first_token_time - r.arrival_time > slo.ttft_p99_s:
                    violators[ttft_role] += 1
                if slo.tpot_p99_ms is not None and r.n_generated > 1:
                    observations[role] += 1
                    tpot_ms = 1e3 * (r.finish_time - r.first_token_time) \
                        / (r.n_generated - 1)
                    if tpot_ms > slo.tpot_p99_ms:
                        violators[role] += 1
        n_obs = max(sum(observations.values()), 1)
        budget = int(0.01 * n_obs)
        rounds.append(SLORound(
            round=round_i,
            instances={role: len(sim.groups[role].engines)
                       for role in sim.order},
            ttft_p99_s=fleet_p99, tpot_p99_ms=fleet_tpot,
            per_pool_ttft_p99_s=per_pool,
            violators=violators, budget=budget,
            analytical_tok_per_watt=plan.tok_per_watt,
            measured_tok_per_watt=float(report["fleet"]["tok_per_watt"]),
            measured_decode_tok_per_watt=float(
                report["fleet"]["decode_tok_per_watt"])))
        if fleet_p99 <= slo.ttft_p99_s and (
                slo.tpot_p99_ms is None or fleet_tpot <= slo.tpot_p99_ms):
            compliant = True
            break
        # a pool that was grown last round but whose violator count did
        # not drop is service-time-bound (e.g. a giant prompt's prefill
        # takes seconds regardless of capacity): stop pouring instances in
        saturated |= {role for role in grown_last
                      if violators.get(role, 0)
                      >= prev_violators.get(role, 0)}
        # grow pools holding more than their observation-weighted share of
        # the fleet violator budget; fall back to the biggest contributor
        violating = [
            role for role in sim.order
            if violators[role] > budget * (observations[role] / n_obs)
            and role not in saturated]
        if not violating:
            violating = [r for r in sorted(violators, key=violators.get,
                                           reverse=True)
                         if violators[r] > 0 and r not in saturated][:1]
        if not violating:            # every contributor is saturated:
            break                    # capacity cannot buy this SLO
        overshoot = fleet_p99 / slo.ttft_p99_s
        if slo.tpot_p99_ms:
            overshoot = max(overshoot, fleet_tpot / slo.tpot_p99_ms)
        step = min(max(overshoot, _MIN_STEP), _MAX_STEP)
        roles = topology_roles(kind, plan)
        for role in violating:
            if role not in roles:    # defensive: role vanished from plan
                continue
            start_mfu = base_mfu.get(role, PREFILL_MFU)
            o = overrides.setdefault(
                role, PoolOverride(prefill_mfu=start_mfu))
            o.prefill_mfu = max((o.prefill_mfu or start_mfu) / step,
                                _MIN_MFU)
            # the MFU backoff only bites once the prefill bound binds, so
            # also ratchet the instance floor by the same step (at least
            # one new instance, for guaranteed progress); floor and bound
            # take a max in recalibrate(), they never compound
            cur = len(sim.groups[role].engines)
            o.min_instances = max(o.min_instances, cur
                                  + max(int(math.ceil(cur * (step - 1.0))),
                                        1))
        prev_violators = violators
        grown_last = set(violating)
    return SLOSizingResult(
        kind=kind, workload=workload.name, slo=slo, policy=policy,
        plan=plan, unconstrained=unconstrained, report=report,
        overrides=overrides, rounds=rounds, compliant=compliant)
