"""SLO-constrained fleet sizing: the simulator as provisioning authority.

The closed-form sizing in `core.fleet` is *optimistic*: its prefill
piggyback model (effective PREFILL_MFU) ignores queueing, so fleets it
provisions can violate the paper's P99 TTFT <= 500 ms constraint when
actually run through `serving.fleetsim` — Table 3's tok/W numbers were
quoted for fleets that don't meet their own SLO.  This module closes the
predict-vs-measure loop (the TokenPowerBench-style validation posture):

  1. provision a topology analytically (`core.topospec.TopologySpec.build`);
  2. *measure* its TTFT p99 by running the fleet end-to-end in FleetSim;
  3. while the measurement violates the SLO, recalibrate the violating
     pools — lower their effective prefill MFU (which raises the
     closed-form prefill instance bound) and force at least one extra
     instance — and re-provision;
  4. report the SLO-feasible fleet next to the unconstrained Eq. 4 one:
     the tok/W delta is the measured price of latency compliance.

Capacity is monotone non-decreasing across rounds and the SLO target is
never loosened — the loop only ever *adds* instances, so it terminates
(each violating pool grows every round) and the resulting tok/W cost is
monotone in the number of rounds.  See DESIGN.md §5/§6.

Measurement cost structure (DESIGN.md §10): every round replays one
**frozen** arrival trace (common random numbers — sampled once, so rounds
differ only in capacity and round-to-round variance is structurally
zero), `measure()` is memoized on the override signature (an exact repeat
of a configuration — e.g. a trim-bisection probe landing on an
already-measured count — costs nothing), and between rounds only pools
whose provisioning actually changed are re-simulated: unchanged pools
replay their prior round's `PoolSummary` snapshot through
`FleetSim.run(reuse=...)` (cross-pool flow only points forward, so an
unchanged topological prefix is exact, not approximate).
`SLOSizingResult.sim_stats` records the audit: full-fleet simulations
vs measure calls vs pools replayed.

The loop works for every router topology FleetSim can serve: homo,
two_pool, fleetopt, K >= 3 multipool ladders and the prefill/decode
disaggregated kinds (paper §10.3).  For disaggregated fleets the prefill
and decode fleets re-provision *independently*: TTFT violations grow the
prefill pools (they drain the prompt), TPOT violations (when
`SLOSpec.tpot_p99_ms` is set) grow the decode pools.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from .fleet import PREFILL_MFU, FleetReport, PoolOverride
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .topospec import TopologySpec, plan_roles
from .workloads import Workload

# per-round backoff clamps: the capacity step is driven by the *fleet*
# TTFT overshoot (a violating pool's own p99 can be service-time-bound —
# a giant prompt's prefill takes seconds no matter how many instances
# exist — so stepping by per-pool overshoot over-provisions wildly);
# bounded to [1.15, 1.5] per round — geometric convergence with at most
# ~50% capacity overshoot past the compliance frontier — and the
# effective prefill MFU never drops below 2% of peak
_MIN_STEP = 1.15
_MAX_STEP = 1.5
_MIN_MFU = 0.02


def _max_hol() -> float:
    """Measured HOL-inflation calibration ceiling: never push the knob
    past the analytically calibrated plain-two-pool value — beyond it
    the queueing signal is double-counted with the instance ratchet,
    which grows capacity through min_instances in the same round.
    (Imported lazily: core.routing itself builds on core.fleet.)"""
    from .routing import HOL_INFLATION
    return HOL_INFLATION


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Latency service-level objective (paper §4: P99 TTFT <= 500 ms).

    `tpot_p99_ms` optionally constrains the P99 time-per-output-token and
    `e2e_p99_s` the P99 end-to-end request latency the meters already
    report (None = TTFT-only, the paper's constraint).  The constraints
    pull on different pools: TTFT violations grow the pool that drained
    the request's prefill, TPOT and e2e violations grow the pool that
    decoded it (in a disaggregated fleet those are different fleets).
    """

    ttft_p99_s: float = 0.5
    tpot_p99_ms: Optional[float] = None
    e2e_p99_s: Optional[float] = None


@dataclasses.dataclass
class SLORound:
    """One provision -> simulate -> adjust iteration."""

    round: int
    instances: Dict[str, int]            # role -> provisioned instances
    ttft_p99_s: float                    # measured, fleet-wide
    per_pool_ttft_p99_s: Dict[str, float]
    violators: Dict[str, int]            # role -> attributed SLO violations
    budget: int                          # fleet-wide violator allowance
    analytical_tok_per_watt: float       # of this round's (adjusted) plan
    measured_tok_per_watt: float         # all-in, steady-state window
    measured_decode_tok_per_watt: float
    tpot_p99_ms: float = 0.0             # measured, fleet-wide
    e2e_p99_s: float = 0.0               # measured, fleet-wide


@dataclasses.dataclass
class SLOSizingResult:
    """SLO-feasible fleet + the audit trail that produced it."""

    kind: str
    workload: str
    slo: SLOSpec
    policy: object                       # serving.RouterPolicy
    plan: FleetReport                    # final, SLO-adjusted sizing
    unconstrained: FleetReport           # round-0 Eq. 4 sizing
    report: Dict[str, dict]              # final FleetSim report
    overrides: Dict[str, PoolOverride]   # accumulated recalibrations
    rounds: List[SLORound]
    compliant: bool
    # trim phase (DESIGN.md §5): per-role instances shaved back off the
    # geometric step's overshoot after compliance, and the number of
    # measured bisection trials it took.  The trials are not SLORounds —
    # `rounds` stays the monotone grow-only audit trail.
    trimmed: Dict[str, int] = dataclasses.field(default_factory=dict)
    trim_rounds: int = 0
    # measurement-cost audit (DESIGN.md §10): how many measure() calls the
    # sizing took, how many were full-fleet simulations vs memo hits, and
    # how many per-pool simulations the warm-start replay avoided
    sim_stats: Dict[str, int] = dataclasses.field(default_factory=dict)
    # measured HOL calibration: per-role occupancy-inflation factor the
    # loop fed back into the closed-form sizing (PoolOverride.hol_inflation)
    measured_hol: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-role violation forensics from the final measured fleet
    # (`explain()` rows: which pool busted the SLO, when, how badly) —
    # FleetScope's attribution view of the same per-request columns the
    # sizing loop reduces over
    explanation: List[dict] = dataclasses.field(default_factory=list)

    @property
    def ttft_p99_s(self) -> float:
        return float(self.report["fleet"].get("ttft_p99_s", 0.0))

    @property
    def slo_tok_per_watt(self) -> float:
        """The headline metric: analytical tok/W of the SLO-feasible fleet
        (Eq. 4 evaluated on the sizing that actually meets its SLO)."""
        return self.plan.tok_per_watt

    @property
    def measured_tok_per_watt(self) -> float:
        return float(self.report["fleet"]["tok_per_watt"])

    @property
    def measured_decode_tok_per_watt(self) -> float:
        return float(self.report["fleet"]["decode_tok_per_watt"])

    @property
    def compliance_cost_pct(self) -> float:
        """tok/W given up to meet the SLO, vs the unconstrained Eq. 4
        fleet (positive = compliance costs efficiency)."""
        u = self.unconstrained.tok_per_watt
        return 100.0 * (1.0 - self.slo_tok_per_watt / u) if u else 0.0

    @property
    def instances_added(self) -> int:
        return self.plan.instances - self.unconstrained.instances

    @property
    def instances_trimmed(self) -> int:
        return sum(self.trimmed.values())

    @property
    def calibrated_prefill_mfu(self) -> Dict[str, float]:
        """Effective per-pool prefill MFU the loop converged to (roles not
        listed kept the closed-form PREFILL_MFU)."""
        return {role: o.prefill_mfu for role, o in self.overrides.items()
                if o.prefill_mfu is not None}

    def row(self) -> dict:
        return dict(topology=self.kind, workload=self.workload,
                    unconstrained=round(self.unconstrained.tok_per_watt, 2),
                    slo_feasible=round(self.slo_tok_per_watt, 2),
                    cost_pct=round(self.compliance_cost_pct, 1),
                    measured=round(self.measured_decode_tok_per_watt, 2),
                    ttft_p99_s=round(self.ttft_p99_s, 3),
                    tpot_p99_ms=round(float(
                        self.report["fleet"].get("tpot_p99_ms", 0.0)), 3),
                    instances=self.plan.instances,
                    added=self.instances_added,
                    trimmed=self.instances_trimmed,
                    rounds=len(self.rounds),
                    compliant=self.compliant)


def explain(sim, slo: SLOSpec, *, n_bins: int = 12) -> List[dict]:
    """Per-role SLO violation forensics over a drained `FleetSim`.

    Mirrors the sizing loop's attribution (a TTFT violation belongs to
    the pool that drained the request's prefill — `ttft_role` on the
    cached summaries) but answers the *observability* question the loop
    never had to: which pool violated, **when**, and how badly.  Returns
    one row per role, worst offender first:

      role, n_obs, n_late, late_frac  — attribution counts
      worst_ttft_s                    — the single worst TTFT (NaN if the
                                        role observed nothing)
      first_violation_s,
      last_violation_s                — arrival-time span of the late
                                        requests (NaN when none)
      peak_window_s, peak_window_late — the [lo, hi) arrival-time bin (of
                                        `n_bins` over the run) holding
                                        the most violations, and its
                                        count — "the 14:00 peak did it"
    """
    n_roles = len(sim.order)
    arrivals = [[] for _ in range(n_roles)]
    ttfts = [[] for _ in range(n_roles)]
    for role in sim.order:
        s = sim.summaries[role]
        for k in range(n_roles):
            m = s.ttft_role == k
            if m.any():
                arrivals[k].append(s.arrival[m])
                ttfts[k].append((s.first_token - s.arrival)[m])
    t_hi = max((float(a.max()) for lst in arrivals for a in lst),
               default=1.0)
    edges = np.linspace(0.0, max(t_hi, 1e-9), n_bins + 1)
    out = []
    for k, role in enumerate(sim.order):
        a = np.concatenate(arrivals[k]) if arrivals[k] else np.empty(0)
        t = np.concatenate(ttfts[k]) if ttfts[k] else np.empty(0)
        late = t > slo.ttft_p99_s
        n_obs, n_late = len(t), int(late.sum())
        row = dict(role=role, n_obs=n_obs, n_late=n_late,
                   late_frac=round(n_late / n_obs, 4) if n_obs else 0.0,
                   worst_ttft_s=round(float(t.max()), 4) if n_obs
                   else float("nan"),
                   first_violation_s=float("nan"),
                   last_violation_s=float("nan"),
                   peak_window_s=(float("nan"), float("nan")),
                   peak_window_late=0)
        if n_late:
            la = a[late]
            row["first_violation_s"] = round(float(la.min()), 3)
            row["last_violation_s"] = round(float(la.max()), 3)
            hist, _ = np.histogram(la, bins=edges)
            b = int(np.argmax(hist))
            row["peak_window_s"] = (round(float(edges[b]), 3),
                                    round(float(edges[b + 1]), 3))
            row["peak_window_late"] = int(hist[b])
        out.append(row)
    out.sort(key=lambda r: (-r["n_late"], r["role"]))
    return out


class _FleetMeasurer:
    """Incremental provision-and-measure harness for the SLO loop.

    Three cost levers on top of the SoA fleet simulator:

      frozen trace  — the arrival trace is sampled exactly once (common
                      random numbers): rounds differ only in capacity,
                      and the trim bisection compares like with like.
      memoization   — `measure()` results are keyed by the override
                      signature (every per-role knob, by value), so an
                      exact configuration is never simulated twice.
      warm start    — consecutive measurements share the per-pool
                      `PoolSummary` snapshots: pools whose provisioning
                      (instance count — the only override-movable input
                      the simulator sees) is unchanged over an unchanged
                      topological prefix are replayed from their prior
                      steady state via `FleetSim.run(reuse=...)` instead
                      of re-simulated.

    `stats` carries the audit counts `size_to_slo` exposes as
    `SLOSizingResult.sim_stats`.

    The measurer is keyed on a `TopologySpec` (the IR is the single
    provisioning authority — `spec.build` replaces the old kind-string
    `build_topology` plumbing), and the frozen trace can be *injected*
    (`trace=`): the topology search (`core.topo_search`) sizes many
    candidate specs against one shared trace, so candidate scores differ
    only in topology, never in arrival noise.
    """

    def __init__(self, spec: TopologySpec, workload: Workload, *,
                 n_requests: int, seed: int, prefill_chunk: int,
                 engine: str = "numpy", trace=None):
        # serving imports are lazy: core stays importable without the
        # serving layer, and the serving layer itself imports core.fleet
        from repro.serving import fleetsim as _fs
        from repro.serving.request import sample_trace
        self._fs = _fs
        self.spec, self.workload = spec, workload
        self.n_requests, self.seed = n_requests, seed
        self.prefill_chunk = prefill_chunk
        self.engine = engine
        # common random numbers: ONE frozen trace for every round/trial
        self._trace = trace if trace is not None else sample_trace(
            workload, n_requests, seed=seed, max_total=spec.max_window)
        self._memo: Dict[tuple, tuple] = {}
        self._prev: Optional[tuple] = None   # (roles, sigs, summaries)
        self.stats = dict(measure_calls=0, memo_hits=0, full_fleet_sims=0,
                          pool_sims=0, pools_reused=0)

    def _requests(self):
        # fresh mutable Request objects over the frozen trace, built by
        # the one shared construction path (serving.fleetsim) so the SLO
        # loop can never diverge from simulate_topology's conventions
        return self._fs.trace_requests(self.workload, self.n_requests,
                                       trace=self._trace)

    @staticmethod
    def _sig(overrides: Dict[str, PoolOverride]) -> tuple:
        return tuple(sorted(
            (role, (o.prefill_mfu, o.hol_inflation, o.min_instances,
                    o.extra_instances, o.max_instances))
            for role, o in overrides.items()))

    def measure(self, overrides: Dict[str, PoolOverride]):
        """Provision with `overrides`, measure end-to-end; returns
        (policy, plan, sim, report)."""
        self.stats["measure_calls"] += 1
        key = self._sig(overrides)
        if key in self._memo:
            self.stats["memo_hits"] += 1
            return self._memo[key]
        policy, plan, registry = self.spec.build(
            self.workload, pool_overrides=overrides or None)
        sim = self._fs.FleetSim(policy, plan, registry=registry,
                                prefill_chunk=self.prefill_chunk,
                                rng_seed=self.seed, engine=self.engine)
        roles = plan_roles(plan)
        # the only sim-relevant quantity a PoolOverride can move is the
        # instance count (the recalibrated MFU/HOL change the *bounds*,
        # not the engines) — so an unchanged count over an unchanged
        # topological prefix means an identical pool simulation
        sigs = [max(p.instances, 1)
                for p in sorted(plan.pools, key=lambda p: p.window)]
        reuse = {}
        if self._prev is not None and self._prev[0] == roles:
            for role, new_sig, old_sig in zip(roles, sigs, self._prev[1]):
                if new_sig != old_sig:
                    break
                reuse[role] = self._prev[2][role]
        report = sim.run(self._requests(), reuse=reuse or None)
        self.stats["pool_sims"] += len(sim.fresh_roles)
        self.stats["pools_reused"] += len(roles) - len(sim.fresh_roles)
        if not reuse:
            self.stats["full_fleet_sims"] += 1
        self._prev = (roles, sigs, dict(sim.summaries))
        out = (policy, plan, sim, report)
        self._memo[key] = out
        return out


def size_to_slo_spec(spec: TopologySpec, workload: Workload, *,
                     slo: SLOSpec = SLOSpec(),
                     n_requests: int = 3000, seed: int = 0,
                     max_rounds: int = 8, prefill_chunk: int = 512,
                     trim: bool = True,
                     engine: str = "numpy",
                     trace=None) -> SLOSizingResult:
    """Iteratively re-provision `spec` until the *measured* TTFT p99 meets
    the SLO (or `max_rounds` is exhausted — `compliant` reports which).

    Each round replays the identical request trace (same seed), so rounds
    differ only in fleet capacity.  Violating pools are identified by
    violator-count attribution: a pool is grown when it holds more
    requests with TTFT > SLO than its completion-weighted share of the
    fleet-wide p99 budget (floor(1% x completions)), falling back to the
    largest remaining contributor; pools whose violator count stops
    dropping despite growth are saturated (service-time-bound) and
    excluded.  Each grown pool is recalibrated via `PoolOverride`:
    effective prefill MFU backed off by the *fleet* TTFT overshoot and
    the instance floor stepped up by the same factor (at least one
    instance per round, for guaranteed progress).

    Works for every `TopologySpec` FleetSim can serve — hand-built specs
    and every `TopologySpec.from_kind` compilation alike (the legacy
    kind-string front end is `size_to_slo`).  Pass `trace=` to share one
    frozen arrival trace across many candidate specs (the topology
    search's common-random-numbers discipline); by default the measurer
    samples its own trace capped at `spec.max_window`.

    After compliance, a **trim phase** (`trim=True`) bisects each grown
    pool's instance count back down toward its round-0 sizing, keeping
    only capacity the measured SLO actually needs — the geometric step
    converges from above with up to ~1.5x overshoot, and the bisection
    claws that back (`SLOSizingResult.trimmed`).  Every trial re-measures
    the full fleet, so the final report is always measured-compliant;
    trials never enter `rounds` (which stays the monotone grow-only audit
    trail).
    """
    measurer = _FleetMeasurer(
        spec, workload, n_requests=n_requests, seed=seed,
        prefill_chunk=prefill_chunk, engine=engine, trace=trace)
    measure = measurer.measure
    kind = spec.kind

    def meets(report: Dict[str, dict]) -> bool:
        f = report["fleet"]
        return (float(f.get("ttft_p99_s", 0.0)) <= slo.ttft_p99_s
                and (slo.tpot_p99_ms is None
                     or float(f.get("tpot_p99_ms", 0.0)) <= slo.tpot_p99_ms)
                and (slo.e2e_p99_s is None
                     or float(f.get("e2e_p99_s", 0.0)) <= slo.e2e_p99_s))

    overrides: Dict[str, PoolOverride] = {}
    rounds: List[SLORound] = []
    measured_hol: Dict[str, float] = {}
    unconstrained: Optional[FleetReport] = None
    base_mfu: Dict[str, float] = {}
    policy = plan = report = sim = None
    compliant = False
    prev_violators: Dict[str, int] = {}
    grown_last: set = set()
    saturated: set = set()
    for round_i in range(max_rounds):
        policy, plan, sim, report = measure(overrides)
        if unconstrained is None:
            # round 0 has no overrides: this plan IS the pure Eq. 4 sizing
            # (later rounds re-provision fresh PoolSizing objects, so it
            # is never mutated again)
            unconstrained = plan
            # MFU backoff starts from each pool's *sized* MFU, not the
            # global closed-form constant (a disagg prefill pool may have
            # been provisioned at its own dedicated-prefill MFU)
            base_mfu = {pool.role: pool.sized_prefill_mfu
                        for pool in plan.pools}
        fleet_p99 = float(report["fleet"].get("ttft_p99_s", 0.0))
        fleet_tpot = float(report["fleet"].get("tpot_p99_ms", 0.0))
        fleet_e2e = float(report["fleet"].get("e2e_p99_s", 0.0))
        per_pool = {role: float(lat.get("ttft_p99_s", 0.0))
                    for role, lat in sim.latency_by_role().items()}
        # violation attribution: the fleet p99 <= SLO iff at most
        # floor(1% of observations) exceed the SLO — count each pool's
        # contribution to that fleet-wide violator budget.  A TTFT
        # violation is attributed to the pool that drained the request's
        # prefill (in a disagg fleet that is the prefill pool: decode
        # capacity cannot buy TTFT there); a TPOT or e2e violation (when
        # the SLO constrains them) to the pool that decoded the request.
        # Counted by array reduction over the cached pool summaries — the
        # summaries carry per-completed-request metric columns, so reused
        # (warm-started) pools attribute without any Request objects.
        n_roles = len(sim.order)
        viol = np.zeros(n_roles, np.int64)
        obs = np.zeros(n_roles, np.int64)
        for k, role in enumerate(sim.order):
            s = sim.summaries[role]
            obs += np.bincount(s.ttft_role, minlength=n_roles)
            late = (s.first_token - s.arrival) > slo.ttft_p99_s
            viol += np.bincount(s.ttft_role[late], minlength=n_roles)
            if slo.tpot_p99_ms is not None:
                m = s.n_generated > 1
                obs[k] += int(m.sum())
                tpot_ms = 1e3 * (s.finish[m] - s.first_token[m]) \
                    / (s.n_generated[m] - 1)
                viol[k] += int((tpot_ms > slo.tpot_p99_ms).sum())
            if slo.e2e_p99_s is not None:
                m = s.finish >= 0
                obs[k] += int(m.sum())
                viol[k] += int(((s.finish[m] - s.arrival[m])
                                > slo.e2e_p99_s).sum())
        violators = {role: int(viol[k]) for k, role in enumerate(sim.order)}
        observations = {role: int(obs[k])
                        for k, role in enumerate(sim.order)}
        n_obs = max(sum(observations.values()), 1)
        budget = int(0.01 * n_obs)
        rounds.append(SLORound(
            round=round_i,
            instances={role: sim.groups[role].instances
                       for role in sim.order},
            ttft_p99_s=fleet_p99, tpot_p99_ms=fleet_tpot,
            e2e_p99_s=fleet_e2e,
            per_pool_ttft_p99_s=per_pool,
            violators=violators, budget=budget,
            analytical_tok_per_watt=plan.tok_per_watt,
            measured_tok_per_watt=float(report["fleet"]["tok_per_watt"]),
            measured_decode_tok_per_watt=float(
                report["fleet"]["decode_tok_per_watt"])))
        if meets(report):
            compliant = True
            break
        # a pool that was grown last round but whose violator count did
        # not drop is service-time-bound (e.g. a giant prompt's prefill
        # takes seconds regardless of capacity): stop pouring instances in
        saturated |= {role for role in grown_last
                      if violators.get(role, 0)
                      >= prev_violators.get(role, 0)}
        # grow pools holding more than their observation-weighted share of
        # the fleet violator budget; fall back to the biggest contributor
        violating = [
            role for role in sim.order
            if violators[role] > budget * (observations[role] / n_obs)
            and role not in saturated]
        if not violating:
            violating = [r for r in sorted(violators, key=violators.get,
                                           reverse=True)
                         if violators[r] > 0 and r not in saturated][:1]
        if not violating:            # every contributor is saturated:
            break                    # capacity cannot buy this SLO
        overshoot = fleet_p99 / slo.ttft_p99_s
        if slo.tpot_p99_ms:
            overshoot = max(overshoot, fleet_tpot / slo.tpot_p99_ms)
        if slo.e2e_p99_s:
            overshoot = max(overshoot, fleet_e2e / slo.e2e_p99_s)
        step = min(max(overshoot, _MIN_STEP), _MAX_STEP)
        roles = plan_roles(plan)
        pools_by_role = {p.role: p for p in plan.pools}
        for role in violating:
            if role not in roles:    # defensive: role vanished from plan
                continue
            start_mfu = base_mfu.get(role, PREFILL_MFU)
            o = overrides.setdefault(
                role, PoolOverride(prefill_mfu=start_mfu))
            o.prefill_mfu = max((o.prefill_mfu or start_mfu) / step,
                                _MIN_MFU)
            # hol_inflation recalibration (ROADMAP gap): the simulator
            # measures each pool's head-of-line queueing directly — the
            # steady-state-windowed mean occupied-slot population
            # (m_slot_seconds / window span, ramp-in and drain excluded
            # like every m_* meter counter) vs the closed form's
            # Little's-law in-flight population at the hol = 1 baseline.
            # Feeding the measured inflation back through PoolOverride
            # raises the closed-form decode/prefill bounds for congested
            # pools instead of leaving the knob at the analytical
            # default (capped at the calibrated two-pool ceiling;
            # decode-phase pools only — a prefill-phase pool's occupancy
            # is chunk-queue depth, not a decode population).
            pool = pools_by_role[role]
            s = sim.summaries[role]
            if pool.phase != "prefill" and pool.n_inflight > 0:
                n_meas = s.m_slot_seconds / s.measure_span
                hol1 = pool.n_inflight / pool.hol_inflation
                hol_meas = n_meas / hol1 if hol1 > 0 else 1.0
                measured_hol[role] = round(hol_meas, 3)
                if hol_meas > 1.0:
                    o.hol_inflation = max(o.hol_inflation or 1.0,
                                          min(hol_meas, _max_hol()))
            # the MFU backoff only bites once the prefill bound binds, so
            # also ratchet the instance floor by the same step (at least
            # one new instance, for guaranteed progress); floor and bound
            # take a max in recalibrate(), they never compound
            cur = sim.groups[role].instances
            o.min_instances = max(o.min_instances, cur
                                  + max(int(math.ceil(cur * (step - 1.0))),
                                        1))
        prev_violators = violators
        grown_last = set(violating)
    # --- trim phase: bisect the geometric step's capacity overshoot back
    # down (ROADMAP open item).  Every candidate is measured end-to-end,
    # so a kept cap is a *verified* compliance fact; pools are trimmed
    # most-grown-first and each pool's accepted cap stays in force while
    # the next is bisected.
    trimmed: Dict[str, int] = {}
    trim_rounds = 0
    if trim and compliant and overrides and len(rounds) > 1:
        counts = dict(rounds[-1].instances)
        floors = rounds[0].instances
        grown = sorted((r for r in counts
                        if counts[r] > floors.get(r, counts[r])),
                       key=lambda r: counts[r] - floors[r], reverse=True)
        for role in grown:
            lo, best = floors[role], counts[role]
            o = overrides[role]   # grown roles always carry an override
            while lo < best:
                mid = (lo + best) // 2
                o.max_instances = mid
                trial = measure(overrides)
                trim_rounds += 1
                if meets(trial[3]):
                    best = mid
                    policy, plan, sim, report = trial
                else:
                    lo = mid + 1
            o.max_instances = best if best < counts[role] else 0
            if best < counts[role]:
                trimmed[role] = counts[role] - best
                counts[role] = best
    return SLOSizingResult(
        kind=kind, workload=workload.name, slo=slo, policy=policy,
        plan=plan, unconstrained=unconstrained, report=report,
        overrides=overrides, rounds=rounds, compliant=compliant,
        trimmed=trimmed, trim_rounds=trim_rounds,
        sim_stats=dict(measurer.stats), measured_hol=measured_hol,
        explanation=explain(sim, slo) if sim is not None else [])


def size_to_slo(kind: str, workload: Workload, profile: BaseProfile,
                model: ModelSpec, *, b_short: int = 4096,
                gamma: float = 2.0,
                windows: Optional[Sequence[int]] = None,
                slo: SLOSpec = SLOSpec(),
                n_requests: int = 3000, seed: int = 0,
                max_rounds: int = 8, prefill_chunk: int = 512,
                small_model: Optional[ModelSpec] = None,
                small_profile: Optional[BaseProfile] = None,
                misroute_rate: float = 0.0,
                dispatch_ms: float = 0.0,
                trim: bool = True,
                long_window: Optional[int] = None,
                engine: str = "numpy") -> SLOSizingResult:
    """Legacy kind-string front end for `size_to_slo_spec`: compile the
    kind to its `TopologySpec` (`TopologySpec.from_kind` is the single
    kind-dispatch site in the codebase) and size that.  The frozen-trace
    cap is `spec.max_window`, which subsumes the old multipool
    `max(windows)` special case; pass `long_window` to stretch the
    terminal serve window of the non-multipool kinds."""
    from .routing import LONG_WINDOW

    spec = TopologySpec.from_kind(
        kind, profile, model, b_short=b_short, gamma=gamma,
        long_window=int(long_window) if long_window else LONG_WINDOW,
        windows=windows, small_model=small_model,
        small_profile=small_profile, misroute_rate=misroute_rate,
        dispatch_ms=dispatch_ms, misroute_seed=seed)
    return size_to_slo_spec(
        spec, workload, slo=slo, n_requests=n_requests, seed=seed,
        max_rounds=max_rounds, prefill_chunk=prefill_chunk, trim=trim,
        engine=engine)
