"""KV-cache accounting (paper Eq. 3).

Standalone helpers shared by the analytical layer (profiles) and the serving
scheduler (admission control).  kappa conventions follow DESIGN.md §4.
"""
from __future__ import annotations

import math


def n_max(kv_token_capacity: float, window: float) -> int:
    """Eq. 3: floor(V_KV / (kappa * W)) expressed in token capacity."""
    return max(int(math.floor(kv_token_capacity / float(window))), 1)


def kv_bytes_per_token(*, n_layers: int, n_kv_heads: int, head_dim: int,
                       dtype_bytes: float = 2.0, tp: int = 1,
                       kv_sharded: bool = True, overhead: float = 1.0,
                       attn_layer_fraction: float = 1.0) -> float:
    """kappa for a GQA transformer; 0 for attention-free models."""
    if n_kv_heads == 0:
        return 0.0
    heads = max(math.ceil(n_kv_heads / tp), 1) if kv_sharded else n_kv_heads
    return (2.0 * heads * head_dim * dtype_bytes * n_layers
            * attn_layer_fraction * overhead)


def halving_check(capacities: list[float], windows: list[float]) -> bool:
    """The discrete skeleton of the 1/W law: n_max halves per doubling."""
    ns = [n_max(c, w) for c, w in zip(capacities, windows)]
    return all(a == 2 * b or abs(a - 2 * b) <= 1 for a, b in zip(ns, ns[1:]))
