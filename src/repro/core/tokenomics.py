"""Token/Watt definition and decomposition (paper §2.2, Eqs. 2 & 4)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from .profiles import BaseProfile


def single_gpu_tok_per_watt(profile: BaseProfile, n_active: float,
                            mean_context: float) -> float:
    """Eq. 2: (n / tau(n, Lbar)) / P(n)."""
    return profile.tok_per_watt(n_active, mean_context)


@dataclasses.dataclass(frozen=True)
class ContextPoint:
    """One Table-1 row."""

    context: int
    n_max: int
    p_sat_w: float
    tok_per_s: float
    tok_per_watt: float


def context_sweep(profile: BaseProfile,
                  contexts: Sequence[int] = (2048, 4096, 8192, 16384, 32768,
                                             65536, 131072),
                  ) -> List[ContextPoint]:
    """Table 1: n_max / P_sat / tok/W vs serving context window.

    Table-1 convention: operate at full n_max with mean context = the window.
    """
    rows = []
    for w in contexts:
        n = profile.n_max(w)
        rows.append(ContextPoint(
            context=w, n_max=n,
            p_sat_w=profile.power_w(n),
            tok_per_s=profile.tokens_per_s(n, w),
            tok_per_watt=profile.tok_per_watt(n, w)))
    return rows


def fleet_tok_per_watt(arrival_rates: Sequence[float],
                       mean_outputs: Sequence[float],
                       instances: Sequence[int],
                       powers_w: Sequence[float]) -> float:
    """Eq. 4: sum_i lambda_i Lbar_out,i / sum_i n_i P(n_act,i)."""
    num = sum(l * o for l, o in zip(arrival_rates, mean_outputs))
    den = sum(n * p for n, p in zip(instances, powers_w))
    return num / den if den else 0.0


def tok_per_dollar_m(profile: BaseProfile, window: int,
                     mean_context: Optional[float] = None) -> float:
    """Table 5 'tok/$M': million output tokens per rented instance-hour $."""
    n = profile.n_max(window)
    tok_s = profile.tokens_per_s(n, mean_context or window)
    return tok_s * 3600.0 / profile.chip.rental_usd_hr / 1e6
