"""Beyond-paper: K >= 3 context-window pools (paper §10.3 future work).

"The multiplicative gain structure suggests that finer-grained topologies
could compound further efficiency improvements, but this is not analyzed
here."  — we analyze it.  A K-pool topology partitions traffic by
predicted total into K geometric windows; each pool gets FleetOpt-style
overflow headroom (route at w/gamma, serve at w).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .fleet import FleetReport, PoolSizing, size_fleet
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .routing import _subset_stats
from .workloads import Workload


@dataclasses.dataclass
class MultiPool:
    """Pools at `windows` (ascending); requests go to the smallest window
    whose admission boundary (window / gamma) covers their predicted
    total."""

    windows: Sequence[int]
    gamma: float = 2.0

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        ws = [int(w) for w in self.windows]
        if not ws or any(a >= b for a, b in zip(ws, ws[1:])):
            raise ValueError(
                f"MultiPool windows must be strictly ascending, got {ws}")
        if self.gamma < 1.0:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")
        names = [f"pool-{w // 1024}K" for w in ws]
        if len(set(names)) != len(names):
            raise ValueError(f"windows {ws} collide at 1K naming"
                             f" granularity: {names}")
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        predicted = p + workload.mean_output
        pools: List[PoolSizing] = []
        assigned = np.zeros(p.shape, bool)
        for i, w in enumerate(ws):
            boundary = w / self.gamma if i < len(ws) - 1 else w
            mask = ~assigned & (predicted <= boundary)
            if i == len(ws) - 1:             # largest pool takes the rest
                mask = ~assigned
            assigned |= mask
            s = _subset_stats(p, o, mask)
            pools.append(PoolSizing(
                name=names[i], window=int(w), profile=profile,
                arrival_rate=lam * s["frac"],
                mean_output=s["mean_output"],
                mean_context=s["mean_context"],
                mean_prompt=s["mean_prompt"]))
        return size_fleet(pools, streamed_params=model.streamed_params,
                          label=f"MultiPool{list(self.windows)}")


def ladder_windows(k: int, *, max_window: int = 65536,
                   min_window: int = 2048) -> List[int]:
    """Geometric window ladder ending at max_window.  The min_window clamp
    can collapse the bottom rungs into duplicates (e.g. two 2K pools at
    k >= 4 under a 64K ceiling) — those are deduped, so the effective pool
    count may be smaller than `k`."""
    windows = [max(max_window // (4 ** (k - 1 - i)), min_window)
               for i in range(k)]
    return sorted(dict.fromkeys(windows))


def sweep_pool_counts(workload: Workload, profile: BaseProfile,
                      model: ModelSpec, *, max_window: int = 65536,
                      ) -> List[Tuple[int, float]]:
    """Fleet tok/W vs *effective* number of pools (deduped geometric window
    ladder).  Requested k whose clamped ladder collapses onto an already
    reported pool count are skipped — no dead duplicate-window pools."""
    out = []
    seen = set()
    for k in (1, 2, 3, 4, 5):
        windows = ladder_windows(k, max_window=max_window)
        if len(windows) in seen:
            continue
        seen.add(len(windows))
        rep = MultiPool(windows=windows).provision(workload, profile, model)
        out.append((len(windows), rep.tok_per_watt))
    return out
