"""Beyond-paper: K >= 3 context-window pools (paper §10.3 future work).

"The multiplicative gain structure suggests that finer-grained topologies
could compound further efficiency improvements, but this is not analyzed
here."  — we analyze it.  A K-pool topology partitions traffic by
predicted total into K geometric windows; each pool gets FleetOpt-style
overflow headroom (route at w/gamma, serve at w).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .fleet import FleetReport, PoolSizing, size_fleet
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .routing import _subset_stats
from .workloads import Workload


@dataclasses.dataclass
class MultiPool:
    """Pools at `windows` (ascending); requests go to the smallest window
    whose admission boundary (window / gamma) covers their predicted
    total."""

    windows: Sequence[int]
    gamma: float = 2.0

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        predicted = p + workload.mean_output
        pools: List[PoolSizing] = []
        assigned = np.zeros(p.shape, bool)
        for i, w in enumerate(self.windows):
            boundary = w / self.gamma if i < len(self.windows) - 1 else w
            mask = ~assigned & (predicted <= boundary)
            if i == len(self.windows) - 1:   # largest pool takes the rest
                mask = ~assigned
            assigned |= mask
            s = _subset_stats(p, o, mask)
            pools.append(PoolSizing(
                name=f"pool-{w // 1024}K", window=int(w), profile=profile,
                arrival_rate=lam * s["frac"],
                mean_output=s["mean_output"],
                mean_context=s["mean_context"],
                mean_prompt=s["mean_prompt"]))
        return size_fleet(pools, streamed_params=model.streamed_params,
                          label=f"MultiPool{list(self.windows)}")


def sweep_pool_counts(workload: Workload, profile: BaseProfile,
                      model: ModelSpec, *, max_window: int = 65536,
                      ) -> List[Tuple[int, float]]:
    """Fleet tok/W vs number of pools (geometric window ladder)."""
    out = []
    for k in (1, 2, 3, 4, 5):
        # geometric ladder ending at max_window
        windows = [max_window // (4 ** (k - 1 - i)) for i in range(k)]
        windows = [max(w, 2048) for w in windows]
        rep = MultiPool(windows=windows).provision(workload, profile, model)
        out.append((k, rep.tok_per_watt))
    return out
