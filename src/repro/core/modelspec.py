"""Analytical model descriptions (the paper's Table 2 / Table 5 inputs).

`ModelSpec` is the *analytical* view of a model: just enough geometry to
compute weight-streaming bytes and KV bytes/token. The full executable
architectures live in `repro.models`; `repro.configs.<arch>.analytical_spec()`
bridges each of them into this form so the 1/W-law stack applies to every
assigned architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    n_params: float                 # total parameters
    n_layers: int
    n_kv_heads: int                 # GQA KV heads (0 for attention-free)
    head_dim: int
    dtype_bytes: float = 2.0        # fp16/bf16 by default; 1.0 for fp8
    n_active_params: Optional[float] = None   # MoE: active params / token
    # Attention-free / hybrid geometry: recurrent state bytes per sequence
    # per layer (replaces KV growth; O(1) in context length).
    state_bytes_per_layer: float = 0.0
    attn_layer_fraction: float = 1.0  # hybrid: fraction of layers with KV

    @property
    def is_moe(self) -> bool:
        return (self.n_active_params is not None
                and self.n_active_params < self.n_params)

    @property
    def streamed_params(self) -> float:
        """Parameters touched per decode iteration (§3.2 MoE override)."""
        return self.n_active_params if self.is_moe else self.n_params

    def weight_bytes(self, *, active_only: bool = True) -> float:
        p = self.streamed_params if active_only else self.n_params
        return p * self.dtype_bytes

    def kv_bytes_per_token(self, *, tp: int = 1, kv_sharded: bool = True,
                           overhead: float = 1.0) -> float:
        """kappa: KV bytes per token per GPU.

        kv_sharded=True  -> TP-sharded GQA storage (paper Table 1 / fleet
                            results): each GPU stores n_kv/TP heads (>=1).
        kv_sharded=False -> full replication per GPU (paper Table 2
                            ComputedProfile behaviour).
        """
        import math
        if self.n_kv_heads == 0:
            return 0.0  # attention-free: no per-token KV growth
        if kv_sharded:
            # Each GPU stores ceil(n_kv / TP) heads, floor 1 (a head cannot
            # be split; TP > n_kv replicates single heads across ranks).
            heads = float(max(math.ceil(self.n_kv_heads / tp), 1))
        else:
            heads = float(self.n_kv_heads)
        per_layer = 2.0 * heads * self.head_dim * self.dtype_bytes
        return per_layer * self.n_layers * self.attn_layer_fraction * overhead


# --- The paper's own models (Table 2 / §4) ------------------------------
LLAMA31_8B = ModelSpec("Llama-3.1-8B", n_params=8.03e9, n_layers=32,
                       n_kv_heads=8, head_dim=128)
LLAMA31_70B = ModelSpec("Llama-3.1-70B", n_params=70.6e9, n_layers=80,
                        n_kv_heads=8, head_dim=128)
LLAMA31_405B = ModelSpec("Llama-3.1-405B", n_params=405e9, n_layers=126,
                         n_kv_heads=8, head_dim=128)
QWEN3_235B_A22B = ModelSpec("Qwen3-235B-A22B", n_params=235e9, n_layers=94,
                            n_kv_heads=4, head_dim=128, n_active_params=22e9)
DEEPSEEK_V3 = ModelSpec("DeepSeek-V3", n_params=671e9, n_layers=61,
                        n_kv_heads=1, head_dim=576,  # MLA compressed KV
                        dtype_bytes=1.0, n_active_params=37e9)

PAPER_MODELS = {m.name: m for m in
                (LLAMA31_8B, LLAMA31_70B, LLAMA31_405B, QWEN3_235B_A22B,
                 DEEPSEEK_V3)}
