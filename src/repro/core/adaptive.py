"""Beyond-paper: adaptive topology control (paper §10.3: "an online
controller that monitors the live request-length distribution and adjusts
pool boundaries dynamically could maintain near-optimal tok/W under
distribution shift").

`AdaptiveController` keeps an exponentially-weighted reservoir of observed
(prompt, output) pairs and periodically re-optimizes (B_short, gamma)
under the same SLO-constrained grid the offline optimizer uses.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .modelspec import ModelSpec
from .profiles import BaseProfile
from .routing import FleetOpt, optimize_gamma
from .workloads import Workload


@dataclasses.dataclass(frozen=True)
class _ReservoirWorkload(Workload):
    """Workload view backed by observed samples instead of the parametric
    mixture."""
    samples: Optional[np.ndarray] = None        # (n, 2) prompt, output

    @property
    def _sample(self):  # type: ignore[override]
        return self.samples[:, 0].astype(float), \
            self.samples[:, 1].astype(float)


def _observed(samples: np.ndarray, arrival_rate: float) -> Workload:
    wl = _ReservoirWorkload(
        name="observed", prompt_mix=((1.0, 0.0, 1.0),),
        output_mu=0.0, output_sigma=1.0, arrival_rate=arrival_rate,
        samples=samples)
    return wl


class AdaptiveController:
    def __init__(self, profile: BaseProfile, model: ModelSpec, *,
                 arrival_rate: float = 1000.0, capacity: int = 20000,
                 b_short_grid: Tuple[int, ...] = (1536, 4096, 8192, 16384),
                 reoptimize_every: int = 5000, seed: int = 0):
        self.profile, self.model = profile, model
        self.arrival_rate = arrival_rate
        self.capacity = capacity
        self.grid = b_short_grid
        self.every = reoptimize_every
        self.rng = np.random.default_rng(seed)
        self.buf = np.zeros((0, 2), np.int64)
        self.seen = 0
        self.b_short, self.gamma = 4096, 2.0
        self.history: List[dict] = []

    def observe(self, prompt_len: int, output_len: int) -> None:
        row = np.array([[prompt_len, output_len]])
        if len(self.buf) < self.capacity:
            self.buf = np.concatenate([self.buf, row])
        else:   # reservoir sampling
            j = int(self.rng.integers(0, self.seen + 1))
            if j < self.capacity:
                self.buf[j] = row
        self.seen += 1
        if self.seen % self.every == 0 and len(self.buf) > 1000:
            self.reoptimize()

    def reoptimize(self) -> Tuple[int, float]:
        wl = _observed(self.buf, self.arrival_rate)
        best = (self.b_short, self.gamma, -1.0)
        for b in self.grid:
            g, rep = optimize_gamma(wl, self.profile, self.model, b)
            if rep.tok_per_watt > best[2]:
                best = (b, g, rep.tok_per_watt)
        self.b_short, self.gamma = best[0], best[1]
        self.history.append(dict(seen=self.seen, b_short=self.b_short,
                                 gamma=self.gamma,
                                 tok_per_watt=round(best[2], 2)))
        return self.b_short, self.gamma

    def route(self, prompt_len: int, expected_output: float) -> str:
        return ("short" if prompt_len + expected_output <= self.b_short
                else "long")
