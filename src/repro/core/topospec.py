"""Declarative topology IR: `TopologySpec` — the single source of truth.

Until this module, the repo's topologies were *string kinds* (``homo`` /
``fleetopt`` / ``multipool`` / ``disagg*`` / ``semantic*`` / ``moe*``)
threaded through parallel ``if kind == ...`` ladders in
`serving.fleetsim` (pool wiring, eviction policy, role lists),
`serving.router` (admission ladders, the semantic flip), `core.slo`
(violator attribution) and the benches — so the topology itself could
not be an optimization variable: there was nothing to search over.

`TopologySpec` replaces every one of those dispatch sites with data: an
ordered list of `PoolSpec` (role, window, profile, model, phase,
admission boundary, overflow / escalation / KV-handoff edges) plus
routing metadata.  Every layer derives what it needs from the spec:

  provision()   — the analytical `core.fleet` sizing (FleetReport whose
                  pools carry their router role), replacing the
                  per-kind Homogeneous / TwoPool / FleetOpt / MultiPool /
                  Semantic / Disaggregated provisioners bit-for-bit;
  policy()      — the `serving.router.RouterPolicy` with an *explicit*
                  admission ladder, metric kind and misroute flip pair;
  registry()    — the `serving.models.ModelProfileRegistry` binding each
                  role to the model/profile its pool serves;
  build()       — (policy, plan, registry), the `build_topology` tuple;
  roles / max_window / spec_hash — the derived facts the SLO loop, the
                  trace synthesiser and the perf baseline key off.

All legacy kind strings compile through `TopologySpec.from_kind(...)` —
the ONLY place kind-string dispatch is allowed to exist — and are pinned
bit-exact against the committed quick-bench baseline
(tests/core/test_topospec.py, tests/serving/test_spec_parity.py).

Provision accounting modes (`accounting=`): the four closed-form traffic
models the legacy provisioners implemented.  ``subset`` partitions the
trace greedily over the admission ladder (Homo / TwoPool / MultiPool /
MoE-pool); ``fleetopt`` prices output-length mispredictions as migrated
load (wasted short-pool decode backed out of tokens/s); ``semantic``
adds the misroute + escalation channels of §5.1; ``disagg`` provisions a
(prefill, decode) pool pair per window slice.  The math is a verbatim
transcription of the legacy provisioners — float op-order preserved, so
`math.ceil` instance counts can never flip (DESIGN.md §12).

On top of the IR, `core.topo_search.optimize_topology` searches the spec
space (window ladder depth K, per-pool chip and model, overflow headroom
gamma, disagg on/off) for the max measured-SLO-compliant tok/W fleet.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .autoscale import AutoscalePolicy
from .fleet import PREFILL_MFU, FleetReport, PoolSizing
from .modelspec import LLAMA31_8B, ModelSpec
from .moe import with_dispatch_floor
from .profiles import BaseProfile, computed_profile
from .routing import (ESCALATION_DETECT_TOKENS, HOL_INFLATION, LONG_WINDOW,
                      _subset_stats)
from .workloads import Workload

# kinds whose [small, large] rungs serve different models and whose
# classifier can misroute (the SemanticRouter layer).  Lives here — the
# IR owns the kind vocabulary — and is re-exported by serving.router for
# backward compatibility.
SEMANTIC_KINDS = ("semantic", "semantic_fleetopt", "moe_semantic")

# every legacy kind `from_kind` compiles (DESIGN.md §12 table)
KINDS = ("homo", "two_pool", "fleetopt", "multipool", "moe_pool",
         "semantic", "semantic_fleetopt", "moe_semantic",
         "disagg", "disagg_fleetopt")

_METRICS = ("predicted_total", "prompt_plus_p99")
_ACCOUNTINGS = ("subset", "fleetopt", "semantic", "disagg")
_PHASES = ("decode", "prefill")


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One pool of the topology: identity, geometry, and outbound edges.

    `admit` is the pool's rung on the admission ladder (requests whose
    routing metric is <= admit and exceeds every earlier rung land
    here); None means the pool is not admission-reachable and must be
    fed by an inbound edge (a disagg decode pool, fed by its prefill
    partner's `handoff_to`).  `window` is the *serve* window — admit <
    window is FleetOpt-style overflow headroom.  Edges name other pools'
    roles and always point forward in the spec order (the topological
    drain order of serving.fleetsim)."""

    role: str
    window: int
    profile: BaseProfile
    model_key: str = "default"
    phase: str = "decode"
    admit: Optional[float] = None
    hol_inflation: float = 1.0
    evict_on_overflow: bool = False
    overflow_to: Optional[str] = None
    escalate_to: Optional[str] = None
    handoff_to: Optional[str] = None
    # FleetReport pool name; defaults to the role
    name: Optional[str] = None
    # MoE expert-dispatch floor attribution (serving.models.ModelBinding)
    dispatch_ms: float = 0.0
    # physical MFU a prefill-phase pool's engines run at
    prefill_engine_mfu: Optional[float] = None

    @property
    def pool_name(self) -> str:
        return self.name if self.name is not None else self.role


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Ordered pools + routing metadata; validated at construction."""

    kind: str
    pools: Tuple[PoolSpec, ...]
    models: Dict[str, ModelSpec]
    metric: str = "predicted_total"
    accounting: str = "subset"
    # semantic misroute channel: classifier error rate, the deterministic
    # per-rid draw seed, detection latency, and the (small, large) role
    # pair whose decisions flip
    misroute_rate: float = 0.0
    detect_tokens: int = ESCALATION_DETECT_TOKENS
    misroute_seed: int = 0
    flip: Optional[Tuple[str, str]] = None
    # routing metadata carried onto the RouterPolicy (labels / sweeps)
    b_short: int = 4096
    gamma: float = 2.0
    label: str = ""
    # opt-in autoscaling policy (core.autoscale) for non-stationary
    # traffic runs.  `provision()` / the SLO loop ALWAYS size for peak
    # regardless — the knob only parameterises a FleetSim that was
    # explicitly asked to autoscale (prepare_spec(..., autoscale=True)),
    # so steady-state provisioning, sizing and committed baselines are
    # untouched by its presence.
    autoscale: Optional["AutoscalePolicy"] = None

    # --- construction-time validation -----------------------------------
    def __post_init__(self):
        if isinstance(self.pools, list):
            object.__setattr__(self, "pools", tuple(self.pools))
        if not self.pools:
            raise ValueError("TopologySpec needs at least one PoolSpec")
        roles = [sp.role for sp in self.pools]
        if len(set(roles)) != len(roles):
            dupes = sorted({r for r in roles if roles.count(r) > 1})
            raise ValueError(f"duplicate pool roles {dupes}: every"
                             f" PoolSpec.role must be unique")
        names = [sp.pool_name for sp in self.pools]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate pool names {dupes}: every pool"
                             f" needs a distinct FleetReport name")
        if self.metric not in _METRICS:
            raise ValueError(f"unknown routing metric {self.metric!r}"
                             f" (expected one of {_METRICS})")
        if self.accounting not in _ACCOUNTINGS:
            raise ValueError(f"unknown accounting mode {self.accounting!r}"
                             f" (expected one of {_ACCOUNTINGS})")
        idx = {sp.role: i for i, sp in enumerate(self.pools)}
        for sp in self.pools:
            if sp.phase not in _PHASES:
                raise ValueError(f"pool {sp.role!r}: unknown phase"
                                 f" {sp.phase!r} (expected one of {_PHASES})")
            if sp.window <= 0:
                raise ValueError(f"pool {sp.role!r}: window must be a"
                                 f" positive token count, got {sp.window}")
            if sp.hol_inflation < 1.0:
                raise ValueError(f"pool {sp.role!r}: hol_inflation must be"
                                 f" >= 1, got {sp.hol_inflation}")
            if sp.dispatch_ms < 0.0:
                raise ValueError(f"pool {sp.role!r}: dispatch_ms must be"
                                 f" >= 0, got {sp.dispatch_ms}")
            if sp.model_key not in self.models:
                raise ValueError(
                    f"pool {sp.role!r}: model_key {sp.model_key!r} is not in"
                    f" spec.models (have {sorted(self.models)})")
            for edge in ("overflow_to", "escalate_to", "handoff_to"):
                dest = getattr(sp, edge)
                if dest is None:
                    continue
                if dest not in idx:
                    raise ValueError(
                        f"pool {sp.role!r}: {edge} target {dest!r} is not a"
                        f" pool of this spec (roles: {sorted(idx)}) —"
                        f" dangling edge")
                if idx[dest] <= idx[sp.role]:
                    raise ValueError(
                        f"pool {sp.role!r}: {edge} -> {dest!r} points"
                        f" backward; cross-pool edges must point forward in"
                        f" the pool order (the topological drain order)")
            if sp.evict_on_overflow and sp.overflow_to is None:
                raise ValueError(
                    f"pool {sp.role!r} evicts on overflow but has no"
                    f" overflow_to destination for its evictions")
            if sp.phase == "prefill" and sp.handoff_to is None:
                raise ValueError(
                    f"prefill-phase pool {sp.role!r} needs a handoff_to"
                    f" decode partner — its drained prefills have nowhere"
                    f" to go")
            if sp.handoff_to is not None:
                dest = self.pools[idx[sp.handoff_to]]
                if sp.phase != "prefill" or dest.phase == "prefill":
                    raise ValueError(
                        f"handoff {sp.role!r} (phase={sp.phase!r}) ->"
                        f" {dest.role!r} (phase={dest.phase!r}) is not"
                        f" phase-consistent: a KV handoff flows a prefill"
                        f" pool into a decode pool")
                if dest.window != sp.window:
                    raise ValueError(
                        f"handoff {sp.role!r} -> {dest.role!r} crosses"
                        f" window slices ({sp.window} != {dest.window}):"
                        f" a prefill pool hands off to the decode pool of"
                        f" its own slice")
        admitting = self.admitting
        if not admitting:
            raise ValueError("no pool carries an admission boundary"
                             " (admit=...): requests cannot enter the fleet")
        admits = [sp.admit for sp in admitting]
        if any(a is not None and not math.isinf(a) and a <= 0
               for a in admits):
            raise ValueError(f"admission boundaries must be positive,"
                             f" got {admits}")
        if any(a >= b for a, b in zip(admits, admits[1:])):
            raise ValueError(
                f"admission boundaries must be strictly ascending in pool"
                f" order, got {[(sp.role, sp.admit) for sp in admitting]}")
        if not math.isinf(admits[-1]):
            raise ValueError(
                f"the last admitting pool ({admitting[-1].role!r}) must"
                f" admit everything (admit=math.inf), got {admits[-1]}")
        aws = [sp.window for sp in admitting]
        if any(a >= b for a, b in zip(aws, aws[1:])):
            raise ValueError(
                f"admitting pool windows must be strictly ascending"
                f" (generalized multipool ladder), got"
                f" {[(sp.role, sp.window) for sp in admitting]}")
        for sp in admitting:
            if not math.isinf(sp.admit) and sp.admit > sp.window:
                raise ValueError(
                    f"pool {sp.role!r}: admission boundary {sp.admit} exceeds"
                    f" its serve window {sp.window} — admitted requests"
                    f" could never fit")
        for sp in self.pools:
            if sp.admit is None and not any(
                    dest == sp.role for other in self.pools
                    for dest in (other.overflow_to, other.escalate_to,
                                 other.handoff_to)):
                raise ValueError(
                    f"pool {sp.role!r} has no admission boundary and no"
                    f" inbound edge — it can never receive traffic")
        if not 0.0 <= self.misroute_rate < 1.0:
            raise ValueError(f"misroute_rate must be in [0, 1), got"
                             f" {self.misroute_rate}")
        if self.misroute_rate and self.flip is None:
            raise ValueError("misroute_rate > 0 needs a flip=(small_role,"
                             " large_role) pair to flip between")
        if self.flip is not None:
            small, large = self.flip
            for r in (small, large):
                if r not in idx:
                    raise ValueError(f"flip role {r!r} is not a pool of"
                                     f" this spec (roles: {sorted(idx)})")
            if self.pools[idx[small]].escalate_to != large:
                raise ValueError(
                    f"flip small role {small!r} must escalate_to the large"
                    f" role {large!r} (misrouted true-large requests are"
                    f" detected and re-served there)")

    # --- derived facts ---------------------------------------------------
    @property
    def roles(self) -> List[str]:
        return [sp.role for sp in self.pools]

    @property
    def admitting(self) -> List[PoolSpec]:
        """Pools on the admission ladder, in rung order."""
        return [sp for sp in self.pools if sp.admit is not None]

    @property
    def max_window(self) -> int:
        """Trace clipping bound: the largest serve window in the fleet
        (subsumes the legacy `long_window` / max(multipool windows)
        special-casing)."""
        return max(sp.window for sp in self.pools)

    def pool(self, role: str) -> PoolSpec:
        for sp in self.pools:
            if sp.role == role:
                return sp
        raise KeyError(role)

    @property
    def spec_hash(self) -> str:
        """Stable short hash of everything that determines provisioning
        and serving behaviour — the perf-baseline key for searched fleets
        (benchmarks/perf_diff.py), and the search memo key."""
        def _prof(pr: BaseProfile) -> tuple:
            return (pr.name, pr.chip.name, pr.tp,
                    round(pr.kv_token_capacity, 3),
                    round(pr.roofline.w_ms, 6))
        canon = (
            self.kind, self.metric, self.accounting,
            round(self.misroute_rate, 9), self.detect_tokens,
            self.misroute_seed, self.flip,
            tuple(sorted((k, m.name) for k, m in self.models.items())),
            tuple((sp.role, sp.pool_name, sp.window, sp.phase,
                   None if sp.admit is None else round(float(sp.admit), 6),
                   sp.model_key, _prof(sp.profile),
                   round(sp.hol_inflation, 6), sp.evict_on_overflow,
                   sp.overflow_to, sp.escalate_to, sp.handoff_to,
                   round(sp.dispatch_ms, 6), sp.prefill_engine_mfu)
                  for sp in self.pools),
        )
        # appended ONLY when set: every pre-existing spec's hash — and
        # with it every committed topology_search.json cell key — is
        # unchanged by the autoscale knob's existence
        if self.autoscale is not None:
            canon = canon + (self.autoscale.canon(),)
        return hashlib.sha1(repr(canon).encode()).hexdigest()[:12]

    # --- provisioning ----------------------------------------------------
    def provision(self, workload: Workload) -> FleetReport:
        """Closed-form `core.fleet` sizing of this spec — the analytical
        twin of the fleet `serving.fleetsim` instantiates.  Every pool of
        the returned report carries its router role (`PoolSizing.role`),
        the single place roles enter the system."""
        fn = {"subset": self._provision_subset,
              "fleetopt": self._provision_fleetopt,
              "semantic": self._provision_semantic,
              "disagg": self._provision_disagg}[self.accounting]
        return fn(workload)

    def _streamed(self, sp: PoolSpec) -> float:
        return self.models[sp.model_key].streamed_params

    def _metric_values(self, workload: Workload) -> np.ndarray:
        p, o = workload.prompts, workload.outputs
        if self.metric == "prompt_plus_p99":
            # conservative two_pool admission: no overflow handling, so a
            # request may only go short if prompt + p99(output) fits
            return p + float(np.quantile(o, 0.99))
        return p + workload.mean_output

    def _provision_subset(self, workload: Workload) -> FleetReport:
        """Greedy ladder partition (Homo / TwoPool / MultiPool / MoE)."""
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        vals = self._metric_values(workload)
        admitting = self.admitting
        pools: List[PoolSizing] = []
        assigned = np.zeros(p.shape, bool)
        for i, sp in enumerate(admitting):
            if i == len(admitting) - 1:     # largest pool takes the rest
                mask = ~assigned
            else:
                mask = ~assigned & (vals <= sp.admit)
            assigned |= mask
            s = _subset_stats(p, o, mask)
            ps = PoolSizing(
                name=sp.pool_name, window=sp.window, profile=sp.profile,
                arrival_rate=lam * s["frac"],
                mean_output=s["mean_output"],
                mean_context=s["mean_context"],
                mean_prompt=s["mean_prompt"],
                hol_inflation=sp.hol_inflation, role=sp.role)
            ps.size(streamed_params=self._streamed(sp))
            pools.append(ps)
        return FleetReport(pools=[q for q in pools if q.arrival_rate > 0],
                           label=self.label)

    def _provision_fleetopt(self, workload: Workload) -> FleetReport:
        """FleetOpt overflow accounting: requests routed short by
        predicted total whose *actual* total outgrows the short serve
        window burn their short-pool decode (backed out of tokens/s) and
        migrate — re-prefilled and fully served in the long pool."""
        short_sp, long_sp = self.admitting
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        routed_short = (p + workload.mean_output) <= short_sp.admit
        mispredict = routed_short & ((p + o) > short_sp.window)
        legit = routed_short & ~mispredict
        lam_mis = lam * float(mispredict.mean())
        s = _subset_stats(p, o, legit)
        l = _subset_stats(p, o, ~routed_short)
        long_lam = lam * l["frac"] + lam_mis
        m = _subset_stats(p, o, mispredict)
        if long_lam > 0:
            wl_frac = lam * l["frac"] / long_lam
            l_mean_out = wl_frac * l["mean_output"] \
                + (1 - wl_frac) * m["mean_output"]
            l_mean_ctx = wl_frac * l["mean_context"] \
                + (1 - wl_frac) * m["mean_context"]
            l_mean_prompt = wl_frac * l["mean_prompt"] \
                + (1 - wl_frac) * m["mean_prompt"]
        else:
            l_mean_out = l_mean_ctx = l_mean_prompt = 0.0
        pools = [
            PoolSizing(name=short_sp.pool_name, window=short_sp.window,
                       profile=short_sp.profile,
                       arrival_rate=lam * s["frac"] + lam_mis,
                       mean_output=s["mean_output"],
                       mean_context=s["mean_context"],
                       mean_prompt=s["mean_prompt"],
                       hol_inflation=short_sp.hol_inflation,
                       role=short_sp.role),
            PoolSizing(name=long_sp.pool_name, window=long_sp.window,
                       profile=long_sp.profile, arrival_rate=long_lam,
                       mean_output=l_mean_out, mean_context=l_mean_ctx,
                       mean_prompt=l_mean_prompt,
                       hol_inflation=long_sp.hol_inflation,
                       role=long_sp.role),
        ]
        pools[0].size(streamed_params=self._streamed(short_sp))
        pools[1].size(streamed_params=self._streamed(long_sp))
        rep = FleetReport(pools=[q for q in pools if q.arrival_rate > 0],
                          label=self.label)
        # wasted short-pool decode work of migrated requests is real load
        # but produces no counted output tokens:
        if lam_mis > 0 and rep.pools:
            rep.pools[0].tokens_per_s -= lam_mis * s["mean_output"]
        return rep

    def _provision_semantic(self, workload: Workload) -> FleetReport:
        """§5.1 semantic accounting: FleetOpt-style length overflows plus
        the classifier misroute + escalation channels (core.routing
        .Semantic, transcribed)."""
        small_sp, large_sp = self.admitting
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        r = self.misroute_rate
        short_window = small_sp.window
        routed_small = (p + workload.mean_output) <= small_sp.admit
        overflow = routed_small & ((p + o) > short_window)
        legit = routed_small & ~overflow
        s = _subset_stats(p, o, legit)
        v = _subset_stats(p, o, overflow)
        l = _subset_stats(p, o, ~routed_small)
        # an overflower decodes only until its KV hits the serve window
        ovf_waste = float(np.maximum(
            short_window - p[overflow], 0.0).mean()) \
            if overflow.any() else 0.0
        lam_legit = lam * (1.0 - r) * s["frac"]
        lam_ovf = lam * (1.0 - r) * v["frac"]
        lam_esc = lam * r * l["frac"]
        lam_small = lam_legit + lam_ovf + lam_esc
        if lam_small > 0:
            w_legit, w_ovf, w_esc = (lam_legit / lam_small,
                                     lam_ovf / lam_small,
                                     lam_esc / lam_small)
            s_out = (w_legit * s["mean_output"] + w_ovf * ovf_waste
                     + w_esc * self.detect_tokens)
            s_prompt = (w_legit * s["mean_prompt"] + w_ovf * v["mean_prompt"]
                        + w_esc * l["mean_prompt"])
            s_ctx = (w_legit * s["mean_context"]
                     + w_ovf * (v["mean_prompt"] + ovf_waste / 2.0)
                     + w_esc * (l["mean_prompt"] + self.detect_tokens / 2.0))
        else:
            s_out = s_prompt = s_ctx = 0.0
        lam_mis_s = lam * r * s["frac"] + lam * r * v["frac"]
        lam_large = lam * (1.0 - r) * l["frac"] + lam_mis_s \
            + lam_ovf + lam_esc
        if lam_large > 0:
            comps = (  # (rate, output, context, prompt)
                (lam * (1.0 - r) * l["frac"] + lam_esc,
                 l["mean_output"], l["mean_context"], l["mean_prompt"]),
                (lam * r * s["frac"],
                 s["mean_output"], s["mean_context"], s["mean_prompt"]),
                (lam * r * v["frac"] + lam_ovf,
                 v["mean_output"], v["mean_context"], v["mean_prompt"]),
            )
            l_out = sum(c[0] * c[1] for c in comps) / lam_large
            l_ctx = sum(c[0] * c[2] for c in comps) / lam_large
            l_prompt = sum(c[0] * c[3] for c in comps) / lam_large
        else:
            l_out = l_ctx = l_prompt = 0.0
        pools = [
            PoolSizing(name=small_sp.pool_name, window=short_window,
                       profile=small_sp.profile, arrival_rate=lam_small,
                       mean_output=s_out, mean_context=s_ctx,
                       mean_prompt=s_prompt, role=small_sp.role),
            PoolSizing(name=large_sp.pool_name, window=large_sp.window,
                       profile=large_sp.profile, arrival_rate=lam_large,
                       mean_output=l_out, mean_context=l_ctx,
                       mean_prompt=l_prompt, role=large_sp.role),
        ]
        # sizing uses each pool's own streamed params — the point of the
        # topology (DESIGN.md §9)
        pools[0].size(streamed_params=self._streamed(small_sp))
        pools[1].size(streamed_params=self._streamed(large_sp))
        # wasted small-pool decode (overflow migrations + escalated
        # misroutes) is provisioned load that produces no counted output
        if pools[0].instances and (lam_ovf > 0 or lam_esc > 0):
            pools[0].tokens_per_s -= (lam_ovf * ovf_waste
                                      + lam_esc * self.detect_tokens)
        return FleetReport(pools=[q for q in pools if q.arrival_rate > 0],
                           label=self.label)

    def _provision_disagg(self, workload: Workload) -> FleetReport:
        """Prefill/decode disaggregation: one (compute-bound prefill,
        interference-free decode) pool pair per admitting window slice;
        slices that route no traffic provision no pools."""
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        predicted = p + workload.mean_output
        admitting = self.admitting
        pools: List[PoolSizing] = []
        assigned = np.zeros(p.shape, bool)
        for i, sp in enumerate(admitting):
            if i == len(admitting) - 1:
                mask = ~assigned
            else:
                mask = ~assigned & (predicted <= sp.admit)
            assigned |= mask
            if mask.sum() == 0:
                continue
            dec_sp = self.pool(sp.handoff_to)
            frac = float(mask.mean())
            mean_prompt = float(p[mask].mean())
            mean_out = float(o[mask].mean())
            mean_ctx = float((p[mask] + o[mask] / 2).mean())
            lam_i = lam * frac
            pf = PoolSizing(
                name=sp.pool_name, window=sp.window, profile=sp.profile,
                arrival_rate=lam_i,
                mean_output=0.0,     # output-only accounting (paper §10.1)
                mean_context=mean_prompt, mean_prompt=mean_prompt,
                phase="prefill", prefill_engine_mfu=sp.prefill_engine_mfu,
                role=sp.role)
            pf.size(streamed_params=self._streamed(sp),
                    prefill_mfu=sp.prefill_engine_mfu)
            dec = PoolSizing(
                name=dec_sp.pool_name, window=dec_sp.window,
                profile=dec_sp.profile, arrival_rate=lam_i,
                mean_output=mean_out, mean_context=mean_ctx,
                mean_prompt=0.0,     # prefill load removed from this pool
                role=dec_sp.role)
            dec.size(streamed_params=self._streamed(dec_sp))
            pools.extend([pf, dec])
        return FleetReport(pools=pools, label=self.label)

    # --- serving-layer compilation (lazy serving imports: core stays
    # importable without the serving layer, which itself builds on core) --
    def registry(self):
        """`serving.models.ModelProfileRegistry` binding each role to the
        model/profile its pool serves.  The default binding is the
        terminal pool's; only roles that differ are bound explicitly, so
        homogeneous specs keep `registry.heterogeneous == False`."""
        from repro.serving.models import ModelBinding, ModelProfileRegistry
        term = self.pools[-1]
        reg = ModelProfileRegistry(default=ModelBinding(
            self.models[term.model_key], term.profile,
            dispatch_ms=term.dispatch_ms))
        for sp in self.pools:
            if (sp.model_key != term.model_key
                    or sp.profile is not term.profile
                    or sp.dispatch_ms != term.dispatch_ms):
                reg.bind(sp.role, ModelBinding(
                    self.models[sp.model_key], sp.profile,
                    dispatch_ms=sp.dispatch_ms))
        return reg

    def policy(self, workload: Workload, plan: FleetReport):
        """Explicit-ladder `RouterPolicy` over the pools that survived
        provisioning (a rung that routes no traffic provisions no pool
        and drops off the ladder; the last survivor admits everything)."""
        from repro.serving.router import RouterPolicy
        surviving = {q.role for q in plan.pools}
        rungs = [sp for sp in self.admitting if sp.role in surviving]
        if not rungs:
            raise ValueError(
                f"{self.kind}: no admitting pool survived provisioning —"
                f" the workload routed no traffic anywhere")
        ladder = [(sp.role, float(sp.admit)) for sp in rungs[:-1]]
        ladder.append((rungs[-1].role, math.inf))
        p99 = int(np.quantile(workload.outputs, 0.99)) \
            if self.metric == "prompt_plus_p99" else 1024
        return RouterPolicy(
            kind=self.kind, b_short=self.b_short, gamma=self.gamma,
            p99_output=p99, ladder=ladder, metric_kind=self.metric,
            flip=self.flip, misroute_rate=self.misroute_rate,
            detect_tokens=self.detect_tokens,
            misroute_seed=self.misroute_seed, spec=self)

    def build(self, workload: Workload, *, pool_overrides=None):
        """(policy, plan, registry) — the `build_topology` contract,
        derived entirely from the spec."""
        from .fleet import apply_overrides
        plan = self.provision(workload)
        registry = self.registry()
        policy = self.policy(workload, plan)
        if pool_overrides:
            roles = plan_roles(plan)
            apply_overrides(plan, pool_overrides, roles=roles,
                            streamed_params=registry.streamed_params_by_role(
                                roles))
        return policy, plan, registry

    # --- legacy kind compilation ----------------------------------------
    @classmethod
    def from_kind(cls, kind: str, profile: BaseProfile, model: ModelSpec, *,
                  b_short: int = 4096, gamma: float = 2.0,
                  long_window: int = LONG_WINDOW,
                  windows: Optional[Sequence[int]] = None,
                  small_model: Optional[ModelSpec] = None,
                  small_profile: Optional[BaseProfile] = None,
                  misroute_rate: float = 0.0,
                  dispatch_ms: float = 0.0,
                  misroute_seed: int = 0) -> "TopologySpec":
        """Compile a legacy kind string to the IR — the only place kind
        dispatch exists.  Pinned bit-exact against the committed
        quick-bench baseline; see DESIGN.md §12 for the full table.

        The serving-twin conventions the legacy `build_topology` encoded
        are preserved: `fleetopt` routes *and* serves at
        W = int(gamma * b_short) (admission boundary == short serve
        window — the analytical twin of the router's
        `predicted <= gamma * b_short` rung, identical for every
        integral gamma * b_short), the disagg kinds likewise, `semantic`
        serves its small pool at int(g * b_short) with admission at
        b_short, and `multipool` admits each rung at window / gamma.
        """
        if misroute_rate and kind not in SEMANTIC_KINDS:
            raise ValueError(f"misroute_rate only applies to semantic kinds,"
                             f" not {kind!r}")
        if dispatch_ms and kind not in ("moe_pool", "moe_semantic"):
            raise ValueError(f"dispatch_ms only applies to MoE kinds,"
                             f" not {kind!r}")
        models = {"default": model}
        if kind == "homo" or kind == "moe_pool":
            prof = with_dispatch_floor(profile, dispatch_ms) \
                if kind == "moe_pool" else profile
            pools = (PoolSpec(
                role="homo" if kind == "homo" else "moe",
                name=f"homo-{long_window // 1024}K", window=long_window,
                profile=prof, admit=math.inf, dispatch_ms=dispatch_ms),)
            return cls(kind=kind, pools=pools, models=models,
                       b_short=b_short, gamma=gamma,
                       label=f"Homo {long_window // 1024}K")
        if kind == "two_pool":
            pools = (
                PoolSpec(role="short", name=f"short-{b_short // 1024}K",
                         window=b_short, profile=profile,
                         admit=float(b_short), overflow_to="long"),
                PoolSpec(role="long", name=f"long-{long_window // 1024}K",
                         window=long_window, profile=profile,
                         admit=math.inf, hol_inflation=HOL_INFLATION),
            )
            return cls(kind=kind, pools=pools, models=models,
                       metric="prompt_plus_p99", b_short=b_short,
                       gamma=gamma, label=f"Pool {b_short // 1024}K")
        if kind == "fleetopt":
            w_short = int(gamma * b_short)
            pools = (
                PoolSpec(role="short",
                         name=f"fleetopt-short-{w_short // 1024}K",
                         window=w_short, profile=profile,
                         admit=float(w_short), evict_on_overflow=True,
                         overflow_to="long"),
                PoolSpec(role="long",
                         name=f"fleetopt-long-{long_window // 1024}K",
                         window=long_window, profile=profile,
                         admit=math.inf),
            )
            return cls(kind=kind, pools=pools, models=models,
                       accounting="fleetopt", b_short=b_short, gamma=gamma,
                       label=f"FleetOpt {w_short // 1024}K/g=1")
        if kind == "multipool":
            if not windows:
                raise ValueError(
                    "kind='multipool' needs an ascending `windows` ladder"
                    " (e.g. core.multipool.ladder_windows)")
            ws = [int(w) for w in windows]
            if any(a >= b for a, b in zip(ws, ws[1:])):
                raise ValueError(f"MultiPool windows must be strictly"
                                 f" ascending, got {ws}")
            if gamma < 1.0:
                raise ValueError(f"gamma must be >= 1, got {gamma}")
            names = [f"pool-{w // 1024}K" for w in ws]
            if len(set(names)) != len(names):
                raise ValueError(f"windows {ws} collide at 1K naming"
                                 f" granularity: {names}")
            pools = tuple(PoolSpec(
                role=names[i], window=w, profile=profile,
                admit=(w / gamma if i < len(ws) - 1 else math.inf),
                evict_on_overflow=i < len(ws) - 1,
                overflow_to=names[i + 1] if i < len(ws) - 1 else None)
                for i, w in enumerate(ws))
            return cls(kind=kind, pools=pools, models=models,
                       b_short=b_short, gamma=gamma,
                       label=f"MultiPool{ws}")
        if kind in SEMANTIC_KINDS:
            if not 0.0 <= misroute_rate < 1.0:
                raise ValueError(f"misroute_rate must be in [0, 1), got"
                                 f" {misroute_rate}")
            g = 1.0 if kind == "semantic" else gamma
            if g < 1.0:
                raise ValueError(f"gamma must be >= 1, got {g}")
            if small_model is None:
                small_model = LLAMA31_8B
            if small_profile is None:
                # the paper's §5.1 small pool: the 8B-class model at TP1
                # on the same accelerator generation as the large pool
                small_profile = computed_profile(
                    small_model, profile.chip, profile.power_model, tp=1)
            large_profile = with_dispatch_floor(profile, dispatch_ms) \
                if kind == "moe_semantic" else profile
            w_short = int(g * b_short)
            pools = (
                PoolSpec(role="small",
                         name=f"semantic-small-{w_short // 1024}K",
                         window=w_short, profile=small_profile,
                         model_key="small", admit=float(b_short),
                         evict_on_overflow=True, overflow_to="large",
                         escalate_to="large"),
                PoolSpec(role="large",
                         name=f"semantic-large-{long_window // 1024}K",
                         window=long_window, profile=large_profile,
                         admit=math.inf, dispatch_ms=dispatch_ms),
            )
            return cls(kind=kind, pools=pools,
                       models={"default": model, "small": small_model},
                       accounting="semantic", misroute_rate=misroute_rate,
                       detect_tokens=ESCALATION_DETECT_TOKENS,
                       misroute_seed=misroute_seed,
                       flip=("small", "large"), b_short=b_short, gamma=g,
                       label=f"Semantic {b_short // 1024}K/g={g:g}"
                             + (f"/mr={misroute_rate:g}"
                                if misroute_rate else ""))
        if kind in ("disagg", "disagg_fleetopt"):
            split = kind == "disagg_fleetopt"
            w_short = int(gamma * b_short)
            slices = [(w_short, float(w_short)), (long_window, math.inf)] \
                if split else [(long_window, math.inf)]
            pools = []
            for i, (w, admit) in enumerate(slices):
                pf_role = f"prefill-{w // 1024}K"
                dec_role = f"decode-{w // 1024}K"
                nxt = f"prefill-{slices[i + 1][0] // 1024}K" \
                    if i < len(slices) - 1 else None
                pools.append(PoolSpec(
                    role=pf_role, window=w, profile=profile,
                    phase="prefill", admit=admit, handoff_to=dec_role,
                    prefill_engine_mfu=PREFILL_MFU))
                pools.append(PoolSpec(
                    role=dec_role, window=w, profile=profile,
                    evict_on_overflow=nxt is not None, overflow_to=nxt))
            return cls(kind=kind, pools=tuple(pools), models=models,
                       accounting="disagg", b_short=b_short, gamma=gamma,
                       label=f"Disagg{'+FleetOpt' if split else ''}")
        raise ValueError(kind)


def plan_roles(plan: FleetReport) -> List[str]:
    """Router role per plan pool, ascending-window order (ties keep the
    provisioning order — prefill before its paired decode — because
    Python's sort is stable).  Replaces the deleted
    `serving.fleetsim.topology_roles` kind table: roles now travel *on*
    the pools, stamped by `TopologySpec.provision`."""
    pools = sorted(plan.pools, key=lambda p: p.window)
    roles = [p.role for p in pools]
    if not all(roles):
        missing = [p.name for p in pools if not p.role]
        raise ValueError(
            f"plan pools {missing} carry no router role — provision fleets"
            f" through core.topospec.TopologySpec (build_topology does)")
    return roles
