"""MoE architecture lever (paper §3.2).

Active-parameter weight streaming: in a dense model every weight is touched
every decode iteration; in a MoE only the activated experts stream, so
W = active_param_bytes / mem_bw — an *upper bound* on efficiency because
expert all-to-all dispatch adds latency.  `dispatch_sensitivity` reproduces
the paper's "at 10 ms dispatch the 5.1x shrinks to ~1.5x" analysis.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from .hardware import ChipSpec
from .modelspec import ModelSpec
from .power import PowerModel
from .profiles import BaseProfile, computed_profile
from .roofline import DecodeRoofline


def with_dispatch_floor(profile: BaseProfile,
                        dispatch_ms: float) -> BaseProfile:
    """`profile` with an expert all-to-all dispatch cost added to the
    per-iteration latency floor: tau(n, L) = (W + dispatch) + H(L) n.

    The floor is paid every decode iteration regardless of batch — exactly
    the mechanism that collapses the paper's 5.1x MoE upper bound toward
    ~1.5x at 10 ms dispatch.  Shared by `moe_profile` and the serving
    layer's `moe_pool` / `moe_semantic` topology kinds, so the analytical
    prediction and the simulated fleet price dispatch identically."""
    if dispatch_ms < 0.0:
        raise ValueError(f"dispatch_ms must be >= 0, got {dispatch_ms}")
    if dispatch_ms == 0.0:
        return profile
    rl = profile.roofline
    return dataclasses.replace(
        profile, roofline=DecodeRoofline(w_ms=rl.w_ms + dispatch_ms,
                                         h0_ms=rl.h0_ms,
                                         l_calib=rl.l_calib))


def moe_profile(model: ModelSpec, chip: ChipSpec,
                power_model: Optional[PowerModel] = None, *, tp: int = 8,
                dispatch_ms: float = 0.0, **kw) -> BaseProfile:
    """ComputedProfile with the active-parameter W override + optional
    dispatch overhead added to the per-iteration latency floor."""
    return with_dispatch_floor(
        computed_profile(model, chip, power_model, tp=tp, **kw), dispatch_ms)


@dataclasses.dataclass(frozen=True)
class DispatchPoint:
    dispatch_ms: float
    tok_per_watt: float
    advantage_vs_dense: float


def dispatch_sensitivity(moe: ModelSpec, dense: ModelSpec, chip: ChipSpec,
                         power_model: Optional[PowerModel] = None, *,
                         window: int = 8192, tp: int = 8,
                         concurrency: float = 8.0,
                         dispatch_grid_ms: tuple = (0.0, 1.0, 2.0, 5.0, 10.0,
                                                    20.0),
                         ) -> List[DispatchPoint]:
    """tok/W advantage of the MoE over the dense baseline vs dispatch cost.

    Evaluated at fixed moderate `concurrency` — the weight-stream-bound
    regime where §3.2's mechanism lives.  (At full n_max both models are
    KV-scan-bound and the active-parameter advantage collapses; the paper's
    Table-2 convention is internally inconsistent — see EXPERIMENTS.md
    §Claims.)
    """
    dense_prof = computed_profile(dense, chip, power_model, tp=tp)
    dense_tpw = dense_prof.tok_per_watt(concurrency, window)
    out = []
    for d in dispatch_grid_ms:
        prof = moe_profile(moe, chip, power_model, tp=tp, dispatch_ms=d)
        tpw = prof.tok_per_watt(concurrency, window)
        out.append(DispatchPoint(dispatch_ms=d, tok_per_watt=tpw,
                                 advantage_vs_dense=tpw / dense_tpw))
    return out
