"""repro.core — the paper's analytical contribution.

Public API surface (see DESIGN.md §2):
  hardware   — ChipSpec constants (H100/H200/B200/GB200 + TPU v5e)
  power      — logistic P(b) model (Eq. 1, Table 7)
  roofline   — decode latency tau = W + H(L) n (§2.2)
  kvcache    — kappa / n_max helpers (Eq. 3)
  modelspec  — analytical model geometry (Table 2 models)
  profiles   — GpuProfile protocol, ManualProfile, computed_profile
  tokenomics — Eq. 2 / Eq. 4 + Table-1 context sweep
  workloads  — Azure / LMSYS / agent trace reconstructions
  fleet      — Little's-law fleet sizing (+ PoolOverride recalibration)
  routing    — Homo / TwoPool / FleetOpt / Semantic topologies
  multipool  — K >= 3 geometric window ladders (§10.3)
  topospec   — declarative topology IR (TopologySpec / PoolSpec)
  topo_search — tok/W-maximizing topology search over the IR
  slo        — SLO-constrained sizing loop (measured TTFT p99 authority)
  timeline   — FleetScope time-series grid + Chrome trace-event builders
  law        — 1/W-law fits + gain decomposition
  moe        — active-parameter streaming + dispatch sensitivity
  analyzer   — fleet_tpw_analysis (Appendix B API)
"""
from . import (adaptive, analyzer, autoscale, carbon, disagg, fleet,
               hardware, kvcache, law, modelspec, moe, multipool, power,
               profiles, roofline, routing, slo, speculative, timeline,
               tokenomics, topo_search, topospec, workloads)
from .adaptive import AdaptiveController
from .autoscale import AutoscalePolicy
from .carbon import GRIDS, EnergyBill, GridProfile, bill
from .disagg import Disaggregated
from .fleet import PoolOverride
from .multipool import MultiPool, ladder_windows, sweep_pool_counts
from .slo import (SLOSizingResult, SLOSpec, explain as explain_slo,
                  size_to_slo, size_to_slo_spec)
from .timeline import (EVENT_NAMES, LIFECYCLE_KINDS, PHASES,
                       TIMELINE_SCHEMA_VERSION, TRACE_SCHEMA_VERSION,
                       MetricsTimeline, bin_intervals, chrome_trace_doc)
from .topo_search import TopologySearchResult, ladder_spec, optimize_topology
from .topospec import SEMANTIC_KINDS, PoolSpec, TopologySpec, plan_roles
from .speculative import speculative_tok_per_watt
from .analyzer import FleetAnalysis, fleet_tpw_analysis
from .hardware import B200, GB200, H100, H200, TPU_V5E, ChipSpec
from .law import fit_one_over_w, gain_decomposition
from .modelspec import ModelSpec
from .moe import dispatch_sensitivity, moe_profile, with_dispatch_floor
from .power import PowerModel
from .profiles import (B200_LLAMA70B, B200_LLAMA70B_FLEET, GB200_LLAMA70B,
                       H100_LLAMA70B, H200_LLAMA70B, V5E_LLAMA70B, BaseProfile,
                       GpuProfile, ManualProfile, computed_profile)
from .roofline import DecodeRoofline
from .routing import FleetOpt, Homogeneous, Semantic, TwoPool, optimize_gamma
from .tokenomics import context_sweep, fleet_tok_per_watt, single_gpu_tok_per_watt
from .workloads import (AGENT, AZURE, AZURE_DIURNAL, LMSYS, WORKLOADS,
                        DiurnalProfile, Workload)

__all__ = [n for n in dir() if not n.startswith("_")]
