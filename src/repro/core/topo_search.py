"""Topology search over the `TopologySpec` IR (DESIGN.md §12).

The point of compiling topologies to data (`core.topospec`) is that the
topology becomes an *optimization variable*: this module searches the
spec space for the fleet with the highest **measured-SLO-compliant**
tok/W.  The objective is `SLOSizingResult.slo_tok_per_watt` — Eq. 4
evaluated on a sizing that `core.slo.size_to_slo_spec` has verified
against the FleetSim-measured TTFT p99 — so a candidate only scores at
all if it actually meets the latency SLO (non-compliant candidates
score -inf and can never win).

Genome (one candidate fleet):

  windows      — ascending serve-window ladder; the terminal window is
                 FIXED at `LONG_WINDOW` so every candidate serves the
                 whole trace and all candidates share ONE frozen arrival
                 trace (common random numbers: scores differ only in
                 topology, never in arrival noise).
  gamma        — overflow headroom: rung i admits at window/gamma and
                 serves at window (multipool semantics; gamma = 1 is
                 plain partitioning).
  disagg       — serve each window slice as a (prefill, decode) pool
                 pair instead of a unified decode pool.
  chips        — per-rung accelerator profile (a key into the `chips`
                 candidate dict).
  small_first  — bind the shortest rung to the small model (§5.1
                 model-heterogeneity with a perfect length classifier;
                 only meaningful when `small_model` is given).

Search algorithm — coordinate descent with evolutionary restarts:

  1. seed at the best hand-built topology (multipool K=3: windows
     [4096, 16384, 65536], gamma=2) — the searched fleet therefore
     scores >= the incumbent *by construction*;
  2. sweep the incumbent's neighbourhood one axis at a time (window
     step up/down the grid, add/drop a rung, gamma step, disagg
     toggle, per-rung chip swap, small-model toggle) and move to the
     first improving neighbour (first-improvement descent: determinstic
     and budget-frugal);
  3. on a full sweep with no improvement (a local optimum), apply
     `np.random.default_rng(seed + restart)`-drawn random mutations to
     the incumbent and descend again (evolutionary restart);
  4. stop when the evaluation budget is exhausted or `max_restarts`
     consecutive restarts fail to improve the incumbent.

Every evaluation is memoized on `TopologySpec.spec_hash`, so revisiting
a genome (common after restarts) costs nothing and only *novel* specs
consume budget.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .fleet import PREFILL_MFU
from .modelspec import ModelSpec
from .profiles import BaseProfile, computed_profile
from .routing import LONG_WINDOW
from .slo import SLOSizingResult, SLOSpec, size_to_slo_spec
from .topospec import PoolSpec, TopologySpec
from .workloads import Workload

# the non-terminal window grid (the terminal rung is pinned at
# LONG_WINDOW so every candidate shares one frozen trace)
_WINDOW_GRID = (2048, 4096, 8192, 16384, 32768)
_GAMMA_GRID = (1.0, 1.5, 2.0, 3.0, 4.0)
_MAX_RUNGS = 5          # terminal + up to 4 short rungs
_EPS = 1e-9             # improvement threshold (ties never move)


@dataclasses.dataclass(frozen=True)
class _Genome:
    """Hashable candidate encoding; `ladder_spec` compiles it to the IR."""

    windows: Tuple[int, ...]     # ascending; windows[-1] == LONG_WINDOW
    gamma: float
    disagg: bool
    chips: Tuple[str, ...]       # per-rung chip key, len == len(windows)
    small_first: bool


def ladder_spec(windows: Sequence[int], profiles: Sequence[BaseProfile],
                model: ModelSpec, *, gamma: float = 2.0,
                disagg: bool = False,
                small_model: Optional[ModelSpec] = None,
                small_profile: Optional[BaseProfile] = None,
                kind: str = "searched", label: str = "") -> TopologySpec:
    """Build a generalized K-rung ladder `TopologySpec` by hand.

    `windows` are ascending serve windows; rung i admits at
    window/gamma (the terminal rung admits everything) and overflows
    into rung i+1, exactly the multipool semantics — so
    `ladder_spec([4096, 16384, 65536], [p]*3, m)` provisions the same
    fleet as `TopologySpec.from_kind("multipool", ...)`.  `profiles`
    gives each rung its accelerator (one entry per rung).  With
    `disagg=True` every rung becomes a (prefill, decode) pool pair with
    a KV handoff inside the slice.  With `small_model` (+ its
    `small_profile`) the shortest rung serves the small model — §5.1
    model-heterogeneous routing under a perfect length classifier.
    """
    ws = [int(w) for w in windows]
    if any(a >= b for a, b in zip(ws, ws[1:])):
        raise ValueError(f"ladder windows must be strictly ascending,"
                         f" got {ws}")
    if gamma < 1.0:
        raise ValueError(f"gamma must be >= 1, got {gamma}")
    if len(profiles) != len(ws):
        raise ValueError(f"need one profile per rung: {len(ws)} windows"
                         f" vs {len(profiles)} profiles")
    if small_model is not None and small_profile is None:
        raise ValueError("small_model needs its small_profile (the small"
                         " rung's accelerator, sized for that model)")
    models: Dict[str, ModelSpec] = {"default": model}
    if small_model is not None:
        models["small"] = small_model
    k = len(ws)
    pools: List[PoolSpec] = []
    for i, w in enumerate(ws):
        terminal = i == k - 1
        admit = math.inf if terminal else w / gamma
        prof = profiles[i]
        model_key = "default"
        if small_model is not None and i == 0 and not terminal:
            model_key, prof = "small", small_profile
        if disagg:
            pf_role, dec_role = f"prefill-{w // 1024}K", f"decode-{w // 1024}K"
            nxt = None if terminal else f"prefill-{ws[i + 1] // 1024}K"
            pools.append(PoolSpec(
                role=pf_role, window=w, profile=prof, model_key=model_key,
                phase="prefill", admit=admit, handoff_to=dec_role,
                prefill_engine_mfu=PREFILL_MFU))
            pools.append(PoolSpec(
                role=dec_role, window=w, profile=prof, model_key=model_key,
                evict_on_overflow=nxt is not None, overflow_to=nxt))
        else:
            pools.append(PoolSpec(
                role=f"pool-{w // 1024}K", window=w, profile=prof,
                model_key=model_key, admit=admit,
                evict_on_overflow=not terminal,
                overflow_to=None if terminal else f"pool-{ws[i + 1] // 1024}K"))
    return TopologySpec(
        kind=kind, pools=tuple(pools), models=models,
        accounting="disagg" if disagg else "subset",
        b_short=ws[0], gamma=gamma,
        label=label or (f"Searched{[w // 1024 for w in ws]}K/g={gamma:g}"
                        + ("/disagg" if disagg else "")))


@dataclasses.dataclass
class TopologySearchResult:
    """Search outcome + the full evaluation audit trail."""

    workload: str
    best_spec: TopologySpec
    best_result: SLOSizingResult
    best_score: float                  # SLO-compliant analytical tok/W
    history: List[dict]                # one entry per novel evaluation
    evaluations: int                   # novel (budget-consuming) evals
    restarts: int

    def row(self) -> dict:
        return dict(workload=self.workload,
                    label=self.best_spec.label,
                    spec_hash=self.best_spec.spec_hash,
                    # a non-compliant best (SLO unattainable on this
                    # workload) reports 0, not -inf, like the bench rows
                    slo_feasible=round(self.best_score, 2)
                    if math.isfinite(self.best_score) else 0.0,
                    measured=round(
                        self.best_result.measured_decode_tok_per_watt, 2),
                    ttft_p99_s=round(self.best_result.ttft_p99_s, 3),
                    instances=self.best_result.plan.instances,
                    compliant=self.best_result.compliant,
                    evaluations=self.evaluations,
                    restarts=self.restarts)


def _neighbors(g: _Genome, chip_keys: Sequence[str],
               allow_small: bool) -> List[_Genome]:
    """The coordinate-descent neighbourhood, one axis moved at a time,
    in a fixed deterministic order."""
    out: List[_Genome] = []
    short = list(g.windows[:-1])
    # window step: move each short rung one notch up/down the grid
    for i, w in enumerate(short):
        gi = _WINDOW_GRID.index(w)
        for gj in (gi - 1, gi + 1):
            if not 0 <= gj < len(_WINDOW_GRID):
                continue
            cand = sorted(short[:i] + [_WINDOW_GRID[gj]] + short[i + 1:])
            if len(set(cand)) == len(cand):
                out.append(dataclasses.replace(
                    g, windows=tuple(cand) + (LONG_WINDOW,)))
    # add a rung (chip inherited from the rung it splits off of)
    if len(g.windows) < _MAX_RUNGS:
        for w in _WINDOW_GRID:
            if w in short:
                continue
            cand = sorted(short + [w])
            j = cand.index(w)
            chips = g.chips[:j] + (g.chips[min(j, len(g.chips) - 1)],) \
                + g.chips[j:]
            out.append(dataclasses.replace(
                g, windows=tuple(cand) + (LONG_WINDOW,), chips=chips))
    # drop a rung
    if len(g.windows) > 1:
        for i in range(len(short)):
            out.append(dataclasses.replace(
                g, windows=tuple(short[:i] + short[i + 1:]) + (LONG_WINDOW,),
                chips=g.chips[:i] + g.chips[i + 1:],
                small_first=g.small_first and len(short) > 1))
    # gamma step
    gi = _GAMMA_GRID.index(g.gamma)
    for gj in (gi - 1, gi + 1):
        if 0 <= gj < len(_GAMMA_GRID):
            out.append(dataclasses.replace(g, gamma=_GAMMA_GRID[gj]))
    # disagg toggle (the disagg ladder is model-homogeneous)
    out.append(dataclasses.replace(g, disagg=not g.disagg,
                                   small_first=False))
    # per-rung chip swap
    for i, cur in enumerate(g.chips):
        for key in chip_keys:
            if key != cur:
                out.append(dataclasses.replace(
                    g, chips=g.chips[:i] + (key,) + g.chips[i + 1:]))
    # small-model toggle on the shortest rung
    if allow_small and not g.disagg and len(g.windows) >= 2:
        out.append(dataclasses.replace(g, small_first=not g.small_first))
    return out


def _mutate(g: _Genome, rng: np.random.Generator, chip_keys: Sequence[str],
            allow_small: bool, n_ops: int) -> _Genome:
    """Evolutionary restart: `n_ops` random single-axis jumps applied to
    the incumbent (drawn from the same move set as the descent, but
    landing anywhere on each axis's grid, not one notch away)."""
    for _ in range(n_ops):
        short = list(g.windows[:-1])
        ops = ["gamma", "chip"]
        if len(g.windows) < _MAX_RUNGS and len(short) < len(_WINDOW_GRID):
            ops.append("add")
        if short:
            ops += ["drop", "move"]
        if allow_small and not g.disagg and len(g.windows) >= 2:
            ops.append("small")
        ops.append("disagg")
        op = ops[int(rng.integers(len(ops)))]
        if op == "gamma":
            g = dataclasses.replace(
                g, gamma=_GAMMA_GRID[int(rng.integers(len(_GAMMA_GRID)))])
        elif op == "chip":
            i = int(rng.integers(len(g.chips)))
            key = chip_keys[int(rng.integers(len(chip_keys)))]
            g = dataclasses.replace(
                g, chips=g.chips[:i] + (key,) + g.chips[i + 1:])
        elif op == "add":
            free = [w for w in _WINDOW_GRID if w not in short]
            w = free[int(rng.integers(len(free)))]
            cand = sorted(short + [w])
            j = cand.index(w)
            chips = g.chips[:j] + (g.chips[min(j, len(g.chips) - 1)],) \
                + g.chips[j:]
            g = dataclasses.replace(
                g, windows=tuple(cand) + (LONG_WINDOW,), chips=chips)
        elif op == "drop":
            i = int(rng.integers(len(short)))
            g = dataclasses.replace(
                g, windows=tuple(short[:i] + short[i + 1:]) + (LONG_WINDOW,),
                chips=g.chips[:i] + g.chips[i + 1:],
                small_first=g.small_first and len(short) > 1)
        elif op == "move":
            i = int(rng.integers(len(short)))
            w = _WINDOW_GRID[int(rng.integers(len(_WINDOW_GRID)))]
            cand = sorted(short[:i] + [w] + short[i + 1:])
            if len(set(cand)) == len(cand):
                g = dataclasses.replace(
                    g, windows=tuple(cand) + (LONG_WINDOW,))
        elif op == "small":
            g = dataclasses.replace(g, small_first=not g.small_first)
        elif op == "disagg":
            g = dataclasses.replace(g, disagg=not g.disagg,
                                    small_first=False)
    return g


def optimize_topology(workload: Workload, profile: BaseProfile,
                      model: ModelSpec, *, slo: SLOSpec = SLOSpec(),
                      chips: Optional[Dict[str, BaseProfile]] = None,
                      small_model: Optional[ModelSpec] = None,
                      n_requests: int = 1500, seed: int = 0,
                      budget: int = 24, max_restarts: int = 3,
                      max_rounds: int = 6, prefill_chunk: int = 512,
                      trim: bool = False,
                      engine: str = "numpy") -> TopologySearchResult:
    """Search the `TopologySpec` space for the fleet with the highest
    measured-SLO-compliant tok/W on `workload` (module docstring: genome,
    moves, stopping rule).

    `chips` maps chip names to *large-model* profiles the per-rung chip
    axis may pick from (default: just `profile`); `small_model` enables
    the model axis (its per-chip profiles are derived at TP1, the §5.1
    convention).  `budget` caps the number of *novel* spec evaluations —
    each one is a full `size_to_slo_spec` sizing against the shared
    frozen trace; memo hits are free.  Deterministic for fixed inputs:
    the descent order is fixed and every random draw comes from
    `np.random.default_rng(seed + restart)`.
    """
    from repro.serving.request import sample_trace

    if chips is None:
        chips = {profile.chip.name: profile}
    chip_keys = tuple(sorted(chips))
    small_by_chip: Dict[str, BaseProfile] = {}
    if small_model is not None:
        small_by_chip = {
            key: computed_profile(small_model, pr.chip, pr.power_model, tp=1)
            for key, pr in chips.items()}
    default_key = profile.chip.name if profile.chip.name in chips \
        else chip_keys[0]

    # ONE frozen trace for every candidate (the terminal rung is pinned
    # at LONG_WINDOW, so max_window — the trace clip — is identical)
    trace = sample_trace(workload, n_requests, seed=seed,
                         max_total=LONG_WINDOW)

    def spec_of(g: _Genome) -> TopologySpec:
        profs = [chips[key] for key in g.chips]
        sm = small_model if (g.small_first and not g.disagg
                             and len(g.windows) >= 2) else None
        return ladder_spec(
            g.windows, profs, model, gamma=g.gamma, disagg=g.disagg,
            small_model=sm,
            small_profile=small_by_chip.get(g.chips[0]) if sm else None)

    memo: Dict[str, Tuple[float, SLOSizingResult, TopologySpec]] = {}
    history: List[dict] = []
    evals = itertools.count(1)
    n_evals = 0

    def evaluate(g: _Genome):
        nonlocal n_evals
        spec = spec_of(g)
        h = spec.spec_hash
        if h in memo:
            return memo[h]
        n_evals = next(evals)
        try:
            res = size_to_slo_spec(
                spec, workload, slo=slo, n_requests=n_requests, seed=seed,
                max_rounds=max_rounds, prefill_chunk=prefill_chunk,
                trim=trim, engine=engine, trace=trace)
            score = res.slo_tok_per_watt if res.compliant \
                else float("-inf")
            err = None
        except Exception as exc:  # a broken candidate loses, not the search
            res, score, err = None, float("-inf"), f"{type(exc).__name__}:"\
                f" {exc}"
        history.append(dict(
            eval=n_evals, spec_hash=h, label=spec.label,
            score=None if math.isinf(score) else round(score, 4),
            compliant=bool(res.compliant) if res is not None else False,
            error=err))
        memo[h] = (score, res, spec)
        return memo[h]

    # seed: the best hand-built topology (multipool K=3) — the search
    # result is >= the incumbent by construction
    g_best = _Genome(windows=(4096, 16384, LONG_WINDOW), gamma=2.0,
                     disagg=False, chips=(default_key,) * 3,
                     small_first=False)
    best_score, best_res, best_spec = evaluate(g_best)
    restarts = stall = 0
    while n_evals < budget and stall <= max_restarts:
        improved = False
        for g in _neighbors(g_best, chip_keys,
                            allow_small=small_model is not None):
            if n_evals >= budget:
                break
            score, res, spec = evaluate(g)
            if score > best_score + _EPS:
                g_best, best_score = g, score
                best_res, best_spec = res, spec
                improved = True
                break
        if improved:
            stall = 0
            continue
        if n_evals >= budget:
            break
        # local optimum: evolutionary restart from the incumbent
        restarts += 1
        stall += 1
        rng = np.random.default_rng(seed + restarts)
        g = _mutate(g_best, rng, chip_keys,
                    allow_small=small_model is not None,
                    n_ops=1 + restarts % 3)
        score, res, spec = evaluate(g)
        if score > best_score + _EPS:
            g_best, best_score = g, score
            best_res, best_spec = res, spec
            stall = 0
    if best_res is None:      # the seed itself failed — surface it loudly
        raise RuntimeError(
            f"topology search found no feasible fleet on {workload.name}:"
            f" {history}")
    return TopologySearchResult(
        workload=workload.name, best_spec=best_spec, best_result=best_res,
        best_score=best_score, history=history, evaluations=n_evals,
        restarts=restarts)
