"""Workload traces (paper §4/§7): context-length CDFs + arrival process.

The paper uses two production traces (Azure LLM Inference / LMSYS-Chat-1M)
plus an "agent-heavy" archetype.  The raw traces are not redistributable, so
each workload here is a *parametric* reconstruction — a 2-component lognormal
mixture for prompt length (chat tail + document tail; a single lognormal
cannot satisfy both the stated mean and the stated tail mass) and a lognormal
for output length — fitted to the statistics the paper states:

  Azure  — 89% of requests <= 4K total tokens; mean output ~325 tok
           (reverse-derived from Table 3: fleet tok/s / lambda).
  LMSYS  — short-dominant chat, split boundary B_short = 1.5K; mean output
           ~136 tok (same reverse derivation).
  Agent  — 74% <= 8K, p99 ~= 32K (paper §7).

`tests/core/test_workloads.py` asserts these paper-stated statistics hold.
All consumers (fleet sizing, router, benchmarks, the serving simulator) share
one fixed-seed Monte-Carlo sample so they see the identical distribution.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import numpy as np

_N_SAMPLE = 200_000
_SEED = 20260712


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    # prompt mixture: ((weight, mu, sigma), ...)
    prompt_mix: Tuple[Tuple[float, float, float], ...]
    output_mu: float
    output_sigma: float
    arrival_rate: float = 1000.0   # requests / s (paper: lambda = 1000)
    max_total: float = 131072.0

    # ------------------------------------------------------------------
    @functools.cached_property
    def _sample(self) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(_SEED)
        weights = np.array([w for w, _, _ in self.prompt_mix])
        comp = rng.choice(len(self.prompt_mix), size=_N_SAMPLE,
                          p=weights / weights.sum())
        mus = np.array([m for _, m, _ in self.prompt_mix])[comp]
        sigmas = np.array([s for _, _, s in self.prompt_mix])[comp]
        p = np.exp(rng.normal(mus, sigmas))
        o = rng.lognormal(self.output_mu, self.output_sigma, _N_SAMPLE)
        p = np.clip(p, 1, self.max_total - 1)
        o = np.clip(o, 1, self.max_total - p)
        return p, o

    @property
    def prompts(self) -> np.ndarray:
        return self._sample[0]

    @property
    def outputs(self) -> np.ndarray:
        return self._sample[1]

    @property
    def totals(self) -> np.ndarray:
        return self.prompts + self.outputs

    @property
    def mean_output(self) -> float:
        return float(self.outputs.mean())

    @property
    def mean_prompt(self) -> float:
        return float(self.prompts.mean())

    @property
    def mean_context(self) -> float:
        """Fleet-wide mean KV length during decode (prompt + output/2)."""
        return float((self.prompts + self.outputs / 2.0).mean())

    def frac_total_leq(self, bound: float) -> float:
        """P(prompt + output <= bound)."""
        return float((self.totals <= bound).mean())

    def quantile_total(self, q: float) -> float:
        return float(np.quantile(self.totals, q))

    # --- pool views (context-length routing) ---------------------------
    def split_by_total(self, boundary: float) -> Dict[str, dict]:
        """Statistics for short (total <= boundary) vs long sub-traffic."""
        mask = self.totals <= boundary
        out = {}
        for key, m in (("short", mask), ("long", ~mask)):
            if m.sum() == 0:
                out[key] = dict(frac=0.0, mean_context=0.0, mean_output=0.0,
                                mean_prompt=0.0, p99_total=0.0)
                continue
            p, o = self.prompts[m], self.outputs[m]
            out[key] = dict(
                frac=float(m.mean()),
                mean_context=float((p + o / 2.0).mean()),
                mean_output=float(o.mean()),
                mean_prompt=float(p.mean()),
                p99_total=float(np.quantile(p + o, 0.99)),
            )
        return out

    def sample_requests(self, n: int, seed: int = 0) -> np.ndarray:
        """(n, 2) int array of (prompt_len, output_len) for the simulator."""
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, _N_SAMPLE, size=n)
        return np.maximum(np.stack([self.prompts[idx], self.outputs[idx]],
                                   axis=1), 1.0).astype(np.int64)


# Fitted reconstructions (targets asserted in tests/core/test_workloads.py).
AZURE = Workload("azure-conv",
                 prompt_mix=((0.88, 5.90, 0.85), (0.12, 8.95, 0.70)),
                 output_mu=5.46, output_sigma=0.80)
LMSYS = Workload("lmsys-chat",
                 prompt_mix=((0.85, 4.90, 0.90), (0.15, 7.80, 0.80)),
                 output_mu=4.58, output_sigma=0.85)
AGENT = Workload("agent-heavy",
                 prompt_mix=((0.70, 7.00, 1.00), (0.30, 9.40, 0.60)),
                 output_mu=5.70, output_sigma=0.80)

WORKLOADS = {w.name: w for w in (AZURE, LMSYS, AGENT)}
