"""Workload traces (paper §4/§7): context-length CDFs + arrival process.

The paper uses two production traces (Azure LLM Inference / LMSYS-Chat-1M)
plus an "agent-heavy" archetype.  The raw traces are not redistributable, so
each workload here is a *parametric* reconstruction — a 2-component lognormal
mixture for prompt length (chat tail + document tail; a single lognormal
cannot satisfy both the stated mean and the stated tail mass) and a lognormal
for output length — fitted to the statistics the paper states:

  Azure  — 89% of requests <= 4K total tokens; mean output ~325 tok
           (reverse-derived from Table 3: fleet tok/s / lambda).
  LMSYS  — short-dominant chat, split boundary B_short = 1.5K; mean output
           ~136 tok (same reverse derivation).
  Agent  — 74% <= 8K, p99 ~= 32K (paper §7).

`tests/core/test_workloads.py` asserts these paper-stated statistics hold.
All consumers (fleet sizing, router, benchmarks, the serving simulator) share
one fixed-seed Monte-Carlo sample so they see the identical distribution.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import numpy as np

_N_SAMPLE = 200_000
_SEED = 20260712


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    # prompt mixture: ((weight, mu, sigma), ...)
    prompt_mix: Tuple[Tuple[float, float, float], ...]
    output_mu: float
    output_sigma: float
    arrival_rate: float = 1000.0   # requests / s (paper: lambda = 1000)
    max_total: float = 131072.0

    # ------------------------------------------------------------------
    @functools.cached_property
    def _sample(self) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(_SEED)
        weights = np.array([w for w, _, _ in self.prompt_mix])
        comp = rng.choice(len(self.prompt_mix), size=_N_SAMPLE,
                          p=weights / weights.sum())
        mus = np.array([m for _, m, _ in self.prompt_mix])[comp]
        sigmas = np.array([s for _, _, s in self.prompt_mix])[comp]
        p = np.exp(rng.normal(mus, sigmas))
        o = rng.lognormal(self.output_mu, self.output_sigma, _N_SAMPLE)
        p = np.clip(p, 1, self.max_total - 1)
        o = np.clip(o, 1, self.max_total - p)
        return p, o

    @property
    def prompts(self) -> np.ndarray:
        return self._sample[0]

    @property
    def outputs(self) -> np.ndarray:
        return self._sample[1]

    @property
    def totals(self) -> np.ndarray:
        return self.prompts + self.outputs

    @property
    def mean_output(self) -> float:
        return float(self.outputs.mean())

    @property
    def mean_prompt(self) -> float:
        return float(self.prompts.mean())

    @property
    def mean_context(self) -> float:
        """Fleet-wide mean KV length during decode (prompt + output/2)."""
        return float((self.prompts + self.outputs / 2.0).mean())

    def frac_total_leq(self, bound: float) -> float:
        """P(prompt + output <= bound)."""
        return float((self.totals <= bound).mean())

    def quantile_total(self, q: float) -> float:
        return float(np.quantile(self.totals, q))

    # --- pool views (context-length routing) ---------------------------
    def split_by_total(self, boundary: float) -> Dict[str, dict]:
        """Statistics for short (total <= boundary) vs long sub-traffic."""
        mask = self.totals <= boundary
        out = {}
        for key, m in (("short", mask), ("long", ~mask)):
            if m.sum() == 0:
                out[key] = dict(frac=0.0, mean_context=0.0, mean_output=0.0,
                                mean_prompt=0.0, p99_total=0.0)
                continue
            p, o = self.prompts[m], self.outputs[m]
            out[key] = dict(
                frac=float(m.mean()),
                mean_context=float((p + o / 2.0).mean()),
                mean_output=float(o.mean()),
                mean_prompt=float(p.mean()),
                p99_total=float(np.quantile(p + o, 0.99)),
            )
        return out

    def sample_requests(self, n: int, seed: int = 0) -> np.ndarray:
        """(n, 2) int array of (prompt_len, output_len) for the simulator."""
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, _N_SAMPLE, size=n)
        return np.maximum(np.stack([self.prompts[idx], self.outputs[idx]],
                                   axis=1), 1.0).astype(np.int64)


# ----------------------------------------------------------------------
# Diurnal arrival envelope (non-stationary traffic).
#
# Everything above measures steady-state Poisson arrivals at a flat
# `arrival_rate`; real fleets ride a ~5x day/night swing (the Azure LLM
# inference trace shows working-hours peaks at ~5x the overnight trough).
# `DiurnalProfile` is a periodic piecewise-linear rate envelope r(t) over
# hourly control points, normalised so the *peak* control point is 1.0 —
# `peak_rate` then has the same meaning as `Workload.arrival_rate` at the
# busiest instant, which is exactly the rate `provision()`/`size_to_slo`
# size for.  Arrivals are sampled *exactly* (no thinning rejection noise)
# by time-rescaling: unit-rate exponential gaps are cumsummed and mapped
# through the inverse of the cumulative rate L(t) = integral r, which is
# piecewise quadratic and invertible in closed form per segment.

# Hourly shape of the Azure-style envelope (fraction of peak, hour 0-23):
# overnight trough 0.20, working-hours plateau ~1.0 — a 5x swing.
AZURE_DIURNAL_SHAPE: Tuple[float, ...] = (
    0.30, 0.25, 0.22, 0.20, 0.20, 0.22, 0.30, 0.45,
    0.62, 0.80, 0.92, 1.00, 1.00, 0.97, 0.95, 0.92,
    0.88, 0.82, 0.75, 0.68, 0.58, 0.48, 0.40, 0.34,
)


@dataclasses.dataclass(frozen=True)
class DiurnalProfile:
    """Periodic day/night arrival-rate envelope r(t) (requests / s).

    `shape` holds one rate multiplier per equal segment of the period
    (hourly for the default 24-point Azure envelope); r(t) interpolates
    linearly between control points and wraps at `day_s`.  `peak_rate`
    scales the whole envelope so max(shape) * peak_rate is the busiest
    instantaneous rate.  Benchmarks compress the day (`day_s` of minutes,
    not hours) so a whole simulated day stays CI-sized; the *shape* —
    and therefore the idle/overprovision arithmetic relative to peak —
    is unchanged by compression.
    """
    name: str = "azure-diurnal"
    peak_rate: float = 1000.0
    day_s: float = 86400.0
    shape: Tuple[float, ...] = AZURE_DIURNAL_SHAPE

    def __post_init__(self):
        if len(self.shape) < 2:
            raise ValueError("DiurnalProfile.shape needs >= 2 control points")
        if min(self.shape) <= 0:
            raise ValueError("DiurnalProfile.shape must be strictly positive "
                             "(a zero-rate segment makes L(t) non-invertible)")
        if self.peak_rate <= 0 or self.day_s <= 0:
            raise ValueError("peak_rate and day_s must be positive")

    # -- envelope geometry --------------------------------------------
    @functools.cached_property
    def _grid(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(knot times, knot rates, cumulative L at knots) over one period
        with the wrap point appended (len == len(shape) + 1)."""
        k = len(self.shape)
        scale = self.peak_rate / max(self.shape)
        t = np.linspace(0.0, self.day_s, k + 1)
        r = np.array(list(self.shape) + [self.shape[0]]) * scale
        seg = self.day_s / k
        # trapezoid integral of the piecewise-linear rate per segment
        cum = np.concatenate([[0.0], np.cumsum((r[:-1] + r[1:]) * 0.5 * seg)])
        return t, r, cum

    @property
    def swing(self) -> float:
        """Peak-to-trough rate ratio of the envelope."""
        return float(max(self.shape) / min(self.shape))

    @property
    def mean_rate(self) -> float:
        """Whole-day average arrival rate (requests / s)."""
        _, _, cum = self._grid
        return float(cum[-1] / self.day_s)

    def rate_at(self, t) -> np.ndarray:
        """Instantaneous rate r(t) (vectorised; periodic in day_s)."""
        knots, r, _ = self._grid
        tm = np.asarray(t, dtype=np.float64) % self.day_s
        return np.interp(tm, knots, r)

    def cumulative(self, t) -> np.ndarray:
        """L(t) = integral_0^t r(s) ds (vectorised, t >= 0, multi-day)."""
        knots, r, cum = self._grid
        t = np.asarray(t, dtype=np.float64)
        days, tm = np.divmod(t, self.day_s)
        seg = self.day_s / len(self.shape)
        i = np.minimum((tm // seg).astype(np.int64), len(self.shape) - 1)
        dt = tm - knots[i]
        slope = (r[i + 1] - r[i]) / seg
        return days * cum[-1] + cum[i] + r[i] * dt + 0.5 * slope * dt * dt

    def _invert(self, u: np.ndarray) -> np.ndarray:
        """L^-1(u): arrival times from rescaled unit-rate event times."""
        knots, r, cum = self._grid
        days, rem = np.divmod(np.asarray(u, dtype=np.float64), cum[-1])
        seg = self.day_s / len(self.shape)
        i = np.minimum(np.searchsorted(cum, rem, side="right") - 1,
                       len(self.shape) - 1)
        y = rem - cum[i]
        slope = (r[i + 1] - r[i]) / seg
        # solve 0.5*slope*dt^2 + r_i*dt = y for dt (positive root); the
        # linear fallback covers flat segments (slope == 0)
        disc = np.sqrt(np.maximum(r[i] ** 2 + 2.0 * slope * y, 0.0))
        with np.errstate(divide="ignore", invalid="ignore"):
            dt = np.where(np.abs(slope) > 1e-12 * self.peak_rate / seg,
                          (disc - r[i]) / np.where(slope == 0.0, 1.0, slope),
                          y / r[i])
        return days * self.day_s + knots[i] + dt

    def sample_arrivals(self, t_end: float, *, seed: int = 0) -> np.ndarray:
        """Exact non-homogeneous Poisson arrival times on [0, t_end).

        Time-rescaling: cumulative unit-rate exponential gaps E_k are an
        ordinary Poisson process on the L axis; mapping through L^-1
        yields arrivals with intensity r(t).  Deterministic per seed.
        """
        rng = np.random.default_rng(seed + 13)
        target = float(self.cumulative(t_end))
        est = int(target + 6.0 * np.sqrt(target) + 64)
        u = np.cumsum(rng.exponential(1.0, size=est))
        while u[-1] < target:  # pragma: no cover - 6-sigma headroom
            u = np.concatenate(
                [u, u[-1] + np.cumsum(rng.exponential(1.0, size=est))])
        u = u[u < target]
        return self._invert(u)


AZURE_DIURNAL = DiurnalProfile()


# Fitted reconstructions (targets asserted in tests/core/test_workloads.py).
AZURE = Workload("azure-conv",
                 prompt_mix=((0.88, 5.90, 0.85), (0.12, 8.95, 0.70)),
                 output_mu=5.46, output_sigma=0.80)
LMSYS = Workload("lmsys-chat",
                 prompt_mix=((0.85, 4.90, 0.90), (0.15, 7.80, 0.80)),
                 output_mu=4.58, output_sigma=0.85)
AGENT = Workload("agent-heavy",
                 prompt_mix=((0.70, 7.00, 1.00), (0.30, 9.40, 0.60)),
                 output_mu=5.70, output_sigma=0.80)

WORKLOADS = {w.name: w for w in (AZURE, LMSYS, AGENT)}
