"""GpuProfile protocol + ManualProfile / ComputedProfile (paper Appendix B).

A profile bundles, for one (model, accelerator, TP) deployment:
  * the logistic power model P(b)             (Eq. 1)
  * the decode roofline tau(n, L) = W + H(L)n (§2.2)
  * the KV token capacity -> n_max(window)    (Eq. 3)

`ManualProfile` carries calibrated constants (the paper's HIGH-quality H100
profile, and the Table-1 B200 projection).  `ComputedProfile` derives the same
quantities from first principles (ChipSpec x ModelSpec), which is how every
assigned architecture in `repro.configs` gets its own 1/W-law curve.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from .hardware import B200, GB200, H100, H200, TPU_V5E, ChipSpec
from .modelspec import LLAMA31_70B, ModelSpec
from .power import (B200_POWER, GB200_POWER, H100_POWER, H200_POWER,
                    TPU_V5E_POWER, PowerModel)
from .roofline import DecodeRoofline


@runtime_checkable
class GpuProfile(Protocol):
    """What `fleet_tpw_analysis` (Appendix B) needs from a profile."""

    name: str
    chip: ChipSpec
    power_model: PowerModel
    roofline: DecodeRoofline
    tp: int

    def n_max(self, window: float) -> int: ...
    def power_w(self, n: float) -> float: ...
    def tokens_per_s(self, n: float, mean_context: float) -> float: ...


@dataclasses.dataclass(frozen=True)
class BaseProfile:
    name: str
    chip: ChipSpec
    power_model: PowerModel
    roofline: DecodeRoofline
    kv_token_capacity: float     # tokens of KV the cache budget holds (per GPU)
    tp: int = 8
    weights_exceed_vram: bool = False

    def n_max(self, window: float) -> int:
        """Eq. 3: concurrency ceiling at serving context window `window`."""
        n = int(math.floor(self.kv_token_capacity / float(window)))
        return max(n, 1)  # paper clamps to 1 (405B / DeepSeek rows)

    def power_w(self, n: float) -> float:
        return float(self.power_model.power_w(n))

    def tokens_per_s(self, n: float, mean_context: float) -> float:
        return float(self.roofline.tokens_per_s(n, mean_context))

    # --- Eq. 2 ----------------------------------------------------------
    def tok_per_watt(self, n: float, mean_context: float) -> float:
        return self.tokens_per_s(n, mean_context) / self.power_w(n)

    def tok_per_watt_at_window(self, window: float,
                               utilization: float = 1.0,
                               mean_context: Optional[float] = None) -> float:
        """Table-1 convention: n = n_max(window), mean context = window."""
        n = self.n_max(window) * utilization
        return self.tok_per_watt(n, window if mean_context is None else mean_context)


class ManualProfile(BaseProfile):
    """Profile with externally calibrated constants."""


def computed_profile(model: ModelSpec, chip: ChipSpec,
                     power_model: Optional[PowerModel] = None,
                     *, tp: int = 8, kv_sharded: bool = True,
                     vram_reserve_frac: float = 0.035,
                     kv_overhead: float = 1.34,
                     l_calib: float = 8192,
                     name: Optional[str] = None) -> BaseProfile:
    """ComputedProfile: first-principles profile for any (model, chip, TP).

    vram_reserve_frac — framework/activation reserve off the top of VRAM.
    kv_overhead       — PagedAttention block fragmentation + metadata
                        (calibrated 1.34 = 55 KB / 40.96 KB on the H100
                        Llama-70B reference point).
    """
    if power_model is None:
        power_model = PowerModel.from_tdp_fraction(chip)
    weight_bytes_per_gpu = model.weight_bytes(active_only=False) / tp
    budget = chip.vram_bytes * (1.0 - vram_reserve_frac) - weight_bytes_per_gpu
    kappa = model.kv_bytes_per_token(tp=tp, kv_sharded=kv_sharded,
                                     overhead=kv_overhead)
    exceeds = budget <= 0
    capacity = max(budget, 0.0) / kappa if kappa > 0 else np.inf
    if exceeds:
        capacity = 1.0  # clamp: paper reports n_max = 1 for over-VRAM models
    # Weight streaming uses *active* bytes (MoE §3.2 override; upper bound —
    # dispatch overhead excluded, see core.moe for the sensitivity analysis).
    roofline = DecodeRoofline.from_first_principles(
        weight_bytes_per_gpu=model.weight_bytes(active_only=True) / tp,
        kv_bytes_per_token_per_gpu=kappa if model.n_kv_heads else 1e-9,
        mem_bw_Bps=chip.mem_bw_Bps, l_calib=l_calib)
    return BaseProfile(name=name or f"{model.name}@{chip.name}(TP{tp})",
                       chip=chip, power_model=power_model, roofline=roofline,
                       kv_token_capacity=capacity, tp=tp,
                       weights_exceed_vram=exceeds)


# --- Calibrated headline profiles (paper §2.1 / Table 1) -----------------
# H100 + Llama-3.1-70B, TP=8, TP-sharded GQA KV.  Token capacity 2^20 comes
# from the paper's calibration point n_max = 128 @ 8K (128 * 8192).  W and H0
# reverse-derived from Table 1 (see DESIGN.md §4); reproduces every H100 cell
# to <2%.
H100_LLAMA70B = ManualProfile(
    name="Llama-3.1-70B@H100-SXM5(TP8,calibrated)",
    chip=H100, power_model=H100_POWER,
    roofline=DecodeRoofline(w_ms=6.72, h0_ms=0.139, l_calib=8192),
    kv_token_capacity=float(2 ** 20), tp=8)

# B200 projection: capacity ratio 2.6235x (Table 1 column 5), W = 2.95 ms,
# H0 reverse-derived 0.067 ms.  FAIR quality, +-20%.
B200_LLAMA70B = ManualProfile(
    name="Llama-3.1-70B@B200-SXM(TP8,projected)",
    chip=B200, power_model=B200_POWER,
    roofline=DecodeRoofline(w_ms=2.95, h0_ms=0.067, l_calib=8192),
    kv_token_capacity=float(2 ** 20) * 2.6235, tp=8)

# H200: same power envelope as H100, 1.41x bandwidth -> W = 4.76 ms,
# capacity scaled by usable-memory ratio (141-17.5)/(80*0.965-17.5) ~ 2.0.
H200_LLAMA70B = ManualProfile(
    name="Llama-3.1-70B@H200-SXM(TP8,projected)",
    chip=H200, power_model=H200_POWER,
    roofline=DecodeRoofline(w_ms=4.76, h0_ms=0.0985, l_calib=8192),
    kv_token_capacity=float(2 ** 20) * 2.0, tp=8)

GB200_LLAMA70B = ManualProfile(
    name="Llama-3.1-70B@GB200-NVL(TP8,projected)",
    chip=GB200, power_model=GB200_POWER,
    roofline=DecodeRoofline(w_ms=2.95, h0_ms=0.067, l_calib=8192),
    kv_token_capacity=float(2 ** 20) * 2.95, tp=8)

# Fleet-analysis B200 profile per the paper's stated §4.1 methodology:
# "B200 uses a profile scaled proportionally from H100 by the 2.62x KV-budget
# ratio".  W improves with bandwidth (2.95 ms, Table 1) but the per-token
# KV-scan coefficient H0 is NOT rescaled (only the *capacity* is), matching
# the paper's scaled-profile construction.  The first-principles profile
# B200_LLAMA70B above (H0 = 0.067) is what Table 1 reproduces; both are
# reported in EXPERIMENTS.md.
B200_LLAMA70B_FLEET = ManualProfile(
    name="Llama-3.1-70B@B200-SXM(TP8,fleet-scaled)",
    chip=B200, power_model=B200_POWER,
    roofline=DecodeRoofline(w_ms=2.95, h0_ms=0.139, l_calib=8192),
    kv_token_capacity=float(2 ** 20) * 2.6235, tp=8)

# Beyond-paper: the same 70B served on a TPU-v5e slice (16 chips, model axis).
V5E_LLAMA70B = computed_profile(LLAMA31_70B, TPU_V5E, TPU_V5E_POWER, tp=16,
                                name="Llama-3.1-70B@TPU-v5e(16-chip)")

GENERATION_PROFILES = {
    "H100-SXM5": H100_LLAMA70B,
    "H200-SXM": H200_LLAMA70B,
    "B200-SXM": B200_LLAMA70B,
    "GB200-NVL": GB200_LLAMA70B,
    "TPU-v5e": V5E_LLAMA70B,
}
