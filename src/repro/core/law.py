"""The 1/W law itself (paper §3.1) + gain decomposition (§4.2)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from .profiles import BaseProfile
from .tokenomics import context_sweep


@dataclasses.dataclass(frozen=True)
class LawFit:
    """log2(tok/W) regressed on log2(window): the law predicts slope -1."""

    slope: float
    r2: float
    halving_ratios: List[float]   # tok/W(2w)/tok/W(w) per doubling (~0.5)


def fit_one_over_w(profile: BaseProfile,
                   contexts: Sequence[int] = (2048, 4096, 8192, 16384, 32768,
                                              65536, 131072)) -> LawFit:
    rows = context_sweep(profile, contexts)
    x = np.log2([r.context for r in rows])
    y = np.log2([r.tok_per_watt for r in rows])
    slope, intercept = np.polyfit(x, y, 1)
    pred = slope * x + intercept
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    ratios = [float(2.0 ** (y[i + 1] - y[i])) for i in range(len(y) - 1)]
    return LawFit(slope=float(slope), r2=1.0 - ss_res / ss_tot,
                  halving_ratios=ratios)


def gain_decomposition(tpw: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """§4.2: topology / generation gains and their multiplicativity.

    tpw[gen][topo] -> fleet tok/W, gens = {"H100","B200"},
    topos = {"homo","fleetopt"}.
    """
    d_topo_h = tpw["H100"]["fleetopt"] / tpw["H100"]["homo"]
    d_topo_b = tpw["B200"]["fleetopt"] / tpw["B200"]["homo"]
    d_gen_homo = tpw["B200"]["homo"] / tpw["H100"]["homo"]
    d_gen_fo = tpw["B200"]["fleetopt"] / tpw["H100"]["fleetopt"]
    combined = tpw["B200"]["fleetopt"] / tpw["H100"]["homo"]
    return dict(topo_h100=d_topo_h, topo_b200=d_topo_b,
                gen_homo=d_gen_homo, gen_fleetopt=d_gen_fo,
                combined=combined,
                product_of_means=float(np.sqrt(d_topo_h * d_topo_b)
                                       * np.sqrt(d_gen_homo * d_gen_fo)),
                independence_error=abs(d_topo_h - d_topo_b)
                / max(d_topo_h, d_topo_b))
