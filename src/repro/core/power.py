"""Logistic GPU power model (paper Eq. 1, Appendix A Table 7).

P(b) = P_range / (1 + exp(-k (log2(b) - x0))) + P_idle

with b the number of concurrently in-flight sequences (vLLM max_num_seqs).
Works with python floats, numpy arrays and jax arrays (uses jnp only when
handed tracers, so the analytical layer stays autodiff-compatible for the
topology optimizer).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Union

import numpy as np

from .hardware import ChipSpec

ArrayLike = Union[float, int, np.ndarray, "jax.Array"]  # noqa: F821


def _xp(x):
    """numpy for concrete inputs, jax.numpy for traced inputs."""
    if type(x).__module__.startswith("jax"):
        import jax.numpy as jnp
        return jnp
    return np


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Eq. 1 logistic power curve for one accelerator."""

    name: str
    p_idle_w: float
    p_nom_w: float
    k: float = 1.0
    x0: float = 4.2
    quality: str = "FAIR"

    @property
    def p_range_w(self) -> float:
        return self.p_nom_w - self.p_idle_w

    def power_w(self, b: ArrayLike) -> ArrayLike:
        """Power draw at b in-flight sequences. b <= 0 -> idle power."""
        xp = _xp(b)
        b = xp.asarray(b, dtype=xp.float64 if xp is np else None)
        safe_b = xp.maximum(b, 1e-9)
        logistic = self.p_range_w / (1.0 + xp.exp(-self.k * (xp.log2(safe_b) - self.x0)))
        return xp.where(b <= 0, self.p_idle_w, self.p_idle_w + logistic)

    def saturation_b(self) -> float:
        """Half-saturation concurrency 2**x0 (paper: ~18 seqs on H100)."""
        return 2.0 ** self.x0

    @classmethod
    def from_tdp_fraction(cls, chip: ChipSpec, x0: float = 4.2, k: float = 1.0,
                          quality: str | None = None) -> "PowerModel":
        """FAIR-quality projection: P_idle = 0.43 TDP, P_nom = 0.86 TDP."""
        return cls(name=chip.name, p_idle_w=chip.p_idle_w, p_nom_w=chip.p_nom_w,
                   k=k, x0=x0, quality=quality or chip.quality)


# --- Appendix A, Table 7 ------------------------------------------------
# H100: fitted to ML.ENERGY v3.0 / G2G Fig. 2 (HIGH).  Others projected.
# NOTE (paper inconsistency): Appendix A lists x0=6.8 for B200/GB200, but the
# Table 1 B200 P_sat column is only consistent with x0 ~ 4.45; we follow the
# table (the actual results) and record the delta in EXPERIMENTS.md.
H100_POWER = PowerModel("H100-SXM5", p_idle_w=300.0, p_nom_w=600.0, k=1.0,
                        x0=4.2, quality="HIGH")
H200_POWER = PowerModel("H200-SXM", p_idle_w=300.0, p_nom_w=600.0, k=1.0,
                        x0=4.2, quality="FAIR")
B200_POWER = PowerModel("B200-SXM", p_idle_w=430.0, p_nom_w=860.0, k=1.0,
                        x0=4.45, quality="FAIR")
GB200_POWER = PowerModel("GB200-NVL", p_idle_w=516.0, p_nom_w=1032.0, k=1.0,
                         x0=4.45, quality="FAIR")
TPU_V5E_POWER = PowerModel("TPU-v5e", p_idle_w=0.43 * 215.0, p_nom_w=0.86 * 215.0,
                           k=1.0, x0=4.2, quality="FAIR")

POWER_MODELS = {m.name: m for m in
                (H100_POWER, H200_POWER, B200_POWER, GB200_POWER, TPU_V5E_POWER)}
