"""Chip specifications for the accelerators referenced by the paper.

H100 figures are HIGH quality (calibrated against ML.ENERGY v3.0 via
Liang et al.'s logistic fit); every other part is a FAIR-quality projection
per the paper's Appendix A. TPU v5e is this framework's actual deployment
target (beyond-paper extension) and uses the same TDP-fraction heuristic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# Paper §2.1: TDP fractions validated on H100 measurements.
IDLE_TDP_FRACTION = 0.43
NOM_TDP_FRACTION = 0.86


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Static hardware parameters for one accelerator generation."""

    name: str
    tdp_w: float
    vram_bytes: float
    mem_bw_Bps: float           # HBM bandwidth, bytes/s
    peak_bf16_flops: float      # dense bf16/fp16 FLOP/s
    ici_Bps: float              # per-link interconnect bandwidth, bytes/s
    rental_usd_hr: float        # paper Table 5 "$/hr" (per 8-chip instance)
    quality: str                # HIGH | FAIR (paper's provenance tag)

    @property
    def p_idle_w(self) -> float:
        return IDLE_TDP_FRACTION * self.tdp_w

    @property
    def p_nom_w(self) -> float:
        return NOM_TDP_FRACTION * self.tdp_w


GiB = 1024 ** 3

H100 = ChipSpec("H100-SXM5", tdp_w=700.0, vram_bytes=80 * GiB,
                mem_bw_Bps=3.35e12, peak_bf16_flops=989e12, ici_Bps=450e9,
                rental_usd_hr=32.2, quality="HIGH")
H200 = ChipSpec("H200-SXM", tdp_w=700.0, vram_bytes=141 * GiB,
                mem_bw_Bps=4.8e12, peak_bf16_flops=989e12, ici_Bps=450e9,
                rental_usd_hr=48.0, quality="FAIR")
B200 = ChipSpec("B200-SXM", tdp_w=1000.0, vram_bytes=180 * GiB,
                mem_bw_Bps=8.0e12, peak_bf16_flops=2250e12, ici_Bps=900e9,
                rental_usd_hr=64.0, quality="FAIR")
GB200 = ChipSpec("GB200-NVL", tdp_w=1200.0, vram_bytes=200 * GiB,
                 mem_bw_Bps=8.0e12, peak_bf16_flops=2250e12, ici_Bps=900e9,
                 rental_usd_hr=80.0, quality="FAIR")

# Beyond-paper: the TPU this framework actually targets.  Roofline constants
# per the deployment brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = ChipSpec("TPU-v5e", tdp_w=215.0, vram_bytes=16 * GiB,
                   mem_bw_Bps=819e9, peak_bf16_flops=197e12, ici_Bps=50e9,
                   rental_usd_hr=9.6, quality="FAIR")

CHIPS: Dict[str, ChipSpec] = {c.name: c for c in (H100, H200, B200, GB200, TPU_V5E)}

# TPU v5e roofline constants, exported for the launch/benchmark layers.
V5E_PEAK_FLOPS = TPU_V5E.peak_bf16_flops
V5E_HBM_BW = TPU_V5E.mem_bw_Bps
V5E_ICI_BW = TPU_V5E.ici_Bps
