"""Beyond-paper: prefill-decode disaggregation (paper §10.3 / Splitwise).

"Splitwise-style separation assigns prefill and decode to different GPU
pools.  Combined with context-length routing, this could remove prefill
energy from the output tok/W accounting and unlock further efficiency."

We build it — and serve it: prefill pools are compute-bound chunk
processors drawing near-saturated power; decode pools run pure token
generation with their concurrency ceiling n_max(window) and no prefill
interference.  The KV handoff crosses the interconnect once per request
(kappa * prompt bytes), costing transfer latency (TPOT, not TTFT — the
first token comes out of the prefill pool) and link + HBM energy, charged
to the EnergyMeter as non-output energy.  Composable with FleetOpt
windows (``split=True``); served end-to-end by `serving.fleetsim` via the
``disagg`` / ``disagg_fleetopt`` topology kinds.

Dedicated prefill runs the same calibrated compute-bound MFU as the
chunked-interleave charging model (fleet.PREFILL_MFU): separation removes
the decode-side interference, not the FLOP ceiling.  (Anything materially
lower makes the paper's P99 TTFT <= 500 ms SLO physically unreachable on
the Azure trace: at MFU 0.55 ~2% of prompts have a pure service-time
floor above 500 ms, more than the whole p99 violator budget.)
"""
from __future__ import annotations

import dataclasses
from typing import List

from .fleet import PREFILL_MFU, FleetReport, PoolSizing
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .workloads import Workload

# Per-instance interconnect bandwidth available to KV migration (NVLink /
# NVSwitch class links; Splitwise uses the same assumption for its
# "negligible transfer latency" claim — we charge it instead of waving it).
INTERCONNECT_BPS = 450e9
# Energy per migrated KV byte: HBM read (~4 pJ/bit) + link traversal
# (~1.3 pJ/bit, NVLink4) + HBM write (~4 pJ/bit) ~= 9.3 pJ/bit ~= 75 pJ/B.
HANDOFF_J_PER_BYTE = 75e-12


@dataclasses.dataclass
class DisaggPools:
    """One (prefill fleet, decode fleet) pair for a traffic slice."""

    window: int
    prefill_instances: int
    decode_instances: int
    prefill_power_w: float       # per instance
    decode_power_w: float
    tokens_per_s: float          # output tokens (decode side)

    @property
    def power_kw(self) -> float:
        return (self.prefill_instances * self.prefill_power_w
                + self.decode_instances * self.decode_power_w) / 1e3


@dataclasses.dataclass
class Disaggregated:
    """Prefill/decode-disaggregated topology, optionally two-pool routed."""

    b_short: int = 4096
    gamma: float = 2.0
    long_window: int = 65536
    prefill_mfu: float = PREFILL_MFU  # dedicated prefill: compute-bound,
                                      # same calibrated MFU as interleave
    split: bool = True           # False = one disaggregated pool at 64K
    interconnect_Bps: float = INTERCONNECT_BPS

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        if self.split:
            short = (p + workload.mean_output) <= self.b_short
            slices = [(int(self.gamma * self.b_short), short),
                      (self.long_window, ~short)]
        else:
            import numpy as np
            slices = [(self.long_window, np.ones_like(p, dtype=bool))]

        # Pools are appended prefill-before-decode per slice so the stable
        # window sort used by serving.fleetsim / core.fleet.apply_overrides
        # yields the handoff DAG order (prefill-w, decode-w, ascending w).
        pools: List[PoolSizing] = []
        for window, mask in slices:
            if mask.sum() == 0:
                continue
            frac = float(mask.mean())
            mean_prompt = float(p[mask].mean())
            mean_out = float(o[mask].mean())
            mean_ctx = float((p[mask] + o[mask] / 2).mean())
            lam_i = lam * frac
            # --- prefill fleet: compute-bound batch processors ----------
            pf = PoolSizing(
                name=f"prefill-{window // 1024}K", window=window,
                profile=profile, arrival_rate=lam_i,
                mean_output=0.0,     # output-only accounting (paper §10.1)
                mean_context=mean_prompt, mean_prompt=mean_prompt,
                phase="prefill", prefill_engine_mfu=self.prefill_mfu)
            pf.size(streamed_params=model.streamed_params,
                    prefill_mfu=self.prefill_mfu)
            # --- decode fleet: Little's law, no prefill interference ----
            dec = PoolSizing(
                name=f"decode-{window // 1024}K", window=window,
                profile=profile, arrival_rate=lam_i,
                mean_output=mean_out, mean_context=mean_ctx,
                mean_prompt=0.0)     # prefill load removed from this pool
            dec.size(streamed_params=model.streamed_params)
            pools.extend([pf, dec])
        return FleetReport(pools=pools,
                           label=f"Disagg{'+FleetOpt' if self.split else ''}")

    @staticmethod
    def kv_handoff_bytes_per_request(prompt_len: float, model: ModelSpec,
                                     profile: BaseProfile) -> float:
        """Whole-instance KV bytes one prefill->decode migration moves."""
        tp = profile.tp
        return model.kv_bytes_per_token(tp=tp) * tp * prompt_len

    @staticmethod
    def kv_handoff_bytes_per_s(workload: Workload, model: ModelSpec,
                               profile: BaseProfile) -> float:
        """Aggregate interconnect load of the prefill->decode migration
        (TP degree and KV sharding come from the profile actually serving
        the fleet, not a hardcoded TP=8)."""
        return workload.arrival_rate * Disaggregated.kv_handoff_bytes_per_request(
            workload.mean_prompt, model, profile)

    def kv_handoff_delay_s(self, prompt_len: float, model: ModelSpec,
                           profile: BaseProfile) -> float:
        """Per-request KV migration latency over the interconnect."""
        return self.kv_handoff_bytes_per_request(
            prompt_len, model, profile) / self.interconnect_Bps
