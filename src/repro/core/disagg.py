"""Beyond-paper: prefill-decode disaggregation (paper §10.3 / Splitwise).

"Splitwise-style separation assigns prefill and decode to different GPU
pools.  Combined with context-length routing, this could remove prefill
energy from the output tok/W accounting and unlock further efficiency."

We build it: prefill pools run at compute-bound MFU and high power
saturation; decode pools run pure token generation with their concurrency
ceiling n_max(window).  The KV handoff crosses the interconnect once per
request (kappa * prompt bytes).  Composable with FleetOpt windows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from .fleet import RHO_OP, FleetReport, PoolSizing
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .workloads import Workload


@dataclasses.dataclass
class DisaggPools:
    """One (prefill fleet, decode fleet) pair for a traffic slice."""

    window: int
    prefill_instances: int
    decode_instances: int
    prefill_power_w: float       # per instance
    decode_power_w: float
    tokens_per_s: float          # output tokens (decode side)

    @property
    def power_kw(self) -> float:
        return (self.prefill_instances * self.prefill_power_w
                + self.decode_instances * self.decode_power_w) / 1e3


@dataclasses.dataclass
class Disaggregated:
    """Prefill/decode-disaggregated topology, optionally two-pool routed."""

    b_short: int = 4096
    gamma: float = 2.0
    long_window: int = 65536
    prefill_mfu: float = 0.55    # dedicated prefill: no decode interleave,
                                 # but batch-formation bubbles cap MFU
    split: bool = True           # False = one disaggregated pool at 64K

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        slices = []
        if self.split:
            short = (p + workload.mean_output) <= self.b_short
            slices = [(int(self.gamma * self.b_short), short),
                      (self.long_window, ~short)]
        else:
            import numpy as np
            slices = [(self.long_window, np.ones_like(p, dtype=bool))]

        pools: List[PoolSizing] = []
        for window, mask in slices:
            if mask.sum() == 0:
                continue
            frac = float(mask.mean())
            mean_prompt = float(p[mask].mean())
            mean_out = float(o[mask].mean())
            mean_ctx = float((p[mask] + o[mask] / 2).mean())
            lam_i = lam * frac
            # --- decode fleet: Little's law, no prefill interference ----
            nmax = profile.n_max(window)
            tau_s = profile.roofline.tau_ms(nmax, mean_ctx) * 1e-3
            dec_inst = max(math.ceil(lam_i * mean_out * tau_s / nmax), 1)
            dec = PoolSizing(
                name=f"decode-{window // 1024}K", window=window,
                profile=profile, arrival_rate=lam_i,
                mean_output=mean_out, mean_context=mean_ctx,
                mean_prompt=0.0)   # prefill load removed from this pool
            dec.instances = dec_inst
            dec.n_active = min(lam_i * mean_out * tau_s / dec_inst,
                               RHO_OP * nmax)
            dec.power_w_per_instance = profile.power_w(dec.n_active)
            dec.tokens_per_s = lam_i * mean_out
            # --- prefill fleet: compute-bound batch processors ----------
            pf_tput = (profile.tp * profile.chip.peak_bf16_flops
                       * self.prefill_mfu / (2.0 * model.streamed_params))
            pf_inst = max(math.ceil(lam_i * mean_prompt / pf_tput), 1)
            pf = PoolSizing(
                name=f"prefill-{window // 1024}K", window=window,
                profile=profile, arrival_rate=lam_i,
                mean_output=0.0, mean_context=mean_prompt,
                mean_prompt=mean_prompt)
            pf.instances = pf_inst
            # prefill saturates compute: power at the saturated end
            pf.n_active = RHO_OP * max(nmax, 32)
            pf.power_w_per_instance = profile.power_model.p_nom_w \
                * 0.97  # compute-bound ~ saturated
            pf.tokens_per_s = 0.0   # output-only accounting (paper §10.1)
            pools.extend([dec, pf])
        return FleetReport(pools=pools,
                           label=f"Disagg{'+FleetOpt' if self.split else ''}")

    @staticmethod
    def kv_handoff_bytes_per_s(workload: Workload, model: ModelSpec,
                               tp: int = 8) -> float:
        """Interconnect cost of the prefill->decode KV migration."""
        kappa = model.kv_bytes_per_token(tp=tp) * tp   # whole-instance KV
        return workload.arrival_rate * workload.mean_prompt * kappa
