"""Fixed-grid time-series telemetry + Chrome trace-event primitives.

This module is the *format* half of FleetScope (the observability layer;
serving.telemetry is the recording half).  It knows nothing about
engines or meters: everything here operates on plain numpy arrays and
python scalars so `core` stays importable without the serving stack (and
without jax — the perf-regression CI job installs numpy only).

Two artifacts are defined:

* `MetricsTimeline` — per-pool series (watts, per-phase joules, tokens,
  occupancy, in-flight decode population, queue depth, online-instance
  count) sampled on a fixed sim-time grid, built by pro-rating charge
  intervals onto bins (`bin_intervals`).  tok/W(t), ramp lag, and the
  stacked energy decomposition in `benchmarks/fleet_trace_report.py`
  are all row-reads of this structure.
* Chrome trace-event JSON builders (`span_event` / `instant_event` /
  `counter_event` / `meta_event` / `chrome_trace_doc`) — the dialect
  Perfetto ingests: one "process" per pool, one "thread" per instance,
  counter tracks for power and occupancy.  Times are seconds in, the
  builders convert to the microsecond `ts` the format requires.

Both JSON shapes carry a schema version (pinned in
tests/core/test_bench_schema.py) so downstream consumers of the nightly
artifacts can detect drift.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# bump when the exported JSON shape changes incompatibly
TRACE_SCHEMA_VERSION = 1       # chrome_trace_doc / Perfetto export
TIMELINE_SCHEMA_VERSION = 1    # MetricsTimeline.to_json

# --- request-lifecycle event kinds --------------------------------------
# One int per lifecycle edge.  The *lifecycle* set is emitted by all
# three engines (the jitted JAX drain only materializes terminal events
# plus first-token times in its `_finalize` replay); the *detail* extras
# (ADMIT, PREFILL chunks) exist only on the numpy engines.
(EV_ARRIVE, EV_ROUTE, EV_ADMIT, EV_PREFILL, EV_FIRST_TOKEN, EV_HANDOFF,
 EV_ESCALATE, EV_OVERFLOW, EV_COMPLETE) = range(9)

EVENT_NAMES = ("arrive", "route", "admit", "prefill", "first_token",
               "handoff", "escalate", "overflow", "complete")

LIFECYCLE_KINDS = frozenset((EV_ARRIVE, EV_ROUTE, EV_FIRST_TOKEN,
                             EV_HANDOFF, EV_ESCALATE, EV_OVERFLOW,
                             EV_COMPLETE))

# energy phases as recorded by the meter hooks; decode charges carry the
# MoE dispatch share separately (dispatch rides *inside* decode energy,
# never additive — see serving.energy)
PHASES = ("decode", "prefill", "idle", "handoff")


def bin_intervals(start, dur, weight, edges: np.ndarray,
                  out: np.ndarray) -> None:
    """Pro-rate interval weights onto a fixed bin grid, in place.

    Each interval [start, start+dur) deposits `weight` into `out`,
    split across the bins it overlaps in proportion to overlap length;
    the part of an interval outside [edges[0], edges[-1]] is dropped.
    Zero-length intervals (point charges) land whole in their bin.
    The common case — interval inside one bin — is fully vectorized;
    only straddlers (rare: long idle skips, handoff walls) loop.
    """
    start = np.atleast_1d(np.asarray(start, np.float64))
    dur = np.atleast_1d(np.asarray(dur, np.float64))
    weight = np.atleast_1d(np.asarray(weight, np.float64))
    start, dur, weight = np.broadcast_arrays(start, dur, weight)
    end = start + dur
    t0, t1 = float(edges[0]), float(edges[-1])
    keep = (end > t0) & (start < t1) | ((dur == 0.0)
                                        & (start >= t0) & (start <= t1))
    if not keep.all():
        start, dur, end, weight = (a[keep] for a in
                                   (start, dur, end, weight))
    if not len(start):
        return
    n = len(edges) - 1
    lo = np.clip(np.searchsorted(edges, start, side="right") - 1, 0, n - 1)
    hi = np.clip(np.searchsorted(edges, end, side="left") - 1, 0, n - 1)
    inside = (lo == hi) & (start >= t0) & (end <= t1)
    np.add.at(out, lo[inside], weight[inside])
    for i in np.flatnonzero(~inside):
        s, e, w = start[i], end[i], weight[i]
        span = e - s
        if span <= 0.0:                       # point charge at a seam
            out[lo[i]] += w
            continue
        for b in range(int(lo[i]), int(hi[i]) + 1):
            ov = min(e, edges[b + 1]) - max(s, edges[b])
            if ov > 0.0:
                out[b] += w * (ov / span)


# series keys every pool dict carries (pinned in test_bench_schema)
SERIES_KEYS = ("watts", "joules", "decode_j", "prefill_j", "idle_j",
               "handoff_j", "dispatch_j", "tokens", "occupancy",
               "inflight", "queue_depth", "online")


def empty_series(n_bins: int) -> Dict[str, np.ndarray]:
    return {k: np.zeros(n_bins, np.float64) for k in SERIES_KEYS}


@dataclasses.dataclass
class MetricsTimeline:
    """Per-pool fleet series on a fixed sim-time grid [t0, t1]."""

    t0: float
    t1: float
    n_bins: int
    pools: Dict[str, Dict[str, np.ndarray]]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def edges(self) -> np.ndarray:
        return np.linspace(self.t0, self.t1, self.n_bins + 1)

    @property
    def centers(self) -> np.ndarray:
        e = self.edges
        return 0.5 * (e[:-1] + e[1:])

    @property
    def bin_s(self) -> float:
        return (self.t1 - self.t0) / self.n_bins

    def fleet(self, key: str) -> np.ndarray:
        """Sum a series across pools (fleet-wide curve)."""
        out = np.zeros(self.n_bins, np.float64)
        for series in self.pools.values():
            out += series[key]
        return out

    def tok_per_watt(self, pool: Optional[str] = None) -> np.ndarray:
        """tok/W(t): per-bin decode tokens over per-bin total energy.
        Bins with no energy are NaN (no data, not zero efficiency)."""
        if pool is None:
            tok, j = self.fleet("tokens"), self.fleet("joules")
        else:
            tok, j = self.pools[pool]["tokens"], self.pools[pool]["joules"]
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(j > 0.0, tok / np.maximum(j, 1e-300), np.nan)

    def to_json(self) -> dict:
        """JSON-safe dict (schema pinned in test_bench_schema)."""
        def col(a):
            return [None if not np.isfinite(v) else round(float(v), 6)
                    for v in a]
        return {
            "schema_version": TIMELINE_SCHEMA_VERSION,
            "t0": self.t0, "t1": self.t1, "n_bins": self.n_bins,
            "bin_s": self.bin_s,
            "meta": dict(self.meta),
            "pools": {
                name: {k: col(series[k]) for k in SERIES_KEYS}
                for name, series in self.pools.items()},
            "fleet": {
                "tokens": col(self.fleet("tokens")),
                "joules": col(self.fleet("joules")),
                "watts": col(self.fleet("watts")),
                "online": col(self.fleet("online")),
                "cum_tokens": col(np.cumsum(self.fleet("tokens"))),
                "cum_joules": col(np.cumsum(self.fleet("joules"))),
                "tok_per_watt": col(self.tok_per_watt()),
            },
        }


# --- Chrome trace-event builders ----------------------------------------
# https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
# (the subset Perfetto's JSON importer understands).  `ts`/`dur` are in
# microseconds; pids map to pools, tids to instances.

_US = 1e6


def span_event(name: str, pid: int, tid: int, t0_s: float, dur_s: float,
               cat: str = "request", args: Optional[dict] = None) -> dict:
    ev = {"name": name, "cat": cat, "ph": "X", "pid": pid, "tid": tid,
          "ts": t0_s * _US, "dur": max(dur_s, 0.0) * _US}
    if args:
        ev["args"] = args
    return ev


def instant_event(name: str, pid: int, tid: int, t_s: float,
                  cat: str = "request",
                  args: Optional[dict] = None) -> dict:
    ev = {"name": name, "cat": cat, "ph": "i", "s": "t", "pid": pid,
          "tid": tid, "ts": t_s * _US}
    if args:
        ev["args"] = args
    return ev


def counter_event(name: str, pid: int, t_s: float, values: dict) -> dict:
    return {"name": name, "cat": "counter", "ph": "C", "pid": pid,
            "tid": 0, "ts": t_s * _US,
            "args": {k: float(v) for k, v in values.items()}}


def meta_event(pid: int, tid: int = 0, process_name: Optional[str] = None,
               thread_name: Optional[str] = None) -> dict:
    if process_name is not None:
        return {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": process_name}}
    return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": thread_name or f"instance {tid}"}}


def chrome_trace_doc(events: List[dict],
                     meta: Optional[dict] = None) -> dict:
    """Wrap event dicts into the Perfetto-ingestable JSON document."""
    other = {"schema_version": TRACE_SCHEMA_VERSION}
    if meta:
        other.update(meta)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}
