"""Beyond-paper: speculative decoding inside the P(b) framework
(paper §10.3: "whether this improves or degrades tok/W depends on the
draft model's power footprint and the verification hit rate — an open
problem within the P(b) framework").

Model: a draft model proposes L tokens per round; the target model
verifies them in ONE forward pass over L positions (compute-heavier but
still one weight stream).  With acceptance rate a, expected tokens per
round E = (1 - a^(L+1)) / (1 - a).  Per-round target latency is the
decode iteration with an L-fold wider token batch (weight stream W
unchanged, KV-scan term H * n * L'ish — decode stays bandwidth-bound, so
verification is nearly free until compute binds), plus the draft's L
sequential steps.  Power: the draft instance draws its own P(b).
"""
from __future__ import annotations

import dataclasses
from typing import List

from .modelspec import ModelSpec
from .profiles import BaseProfile


@dataclasses.dataclass(frozen=True)
class SpecPoint:
    accept_rate: float
    speculation_len: int
    tokens_per_round: float
    tok_per_watt: float
    speedup_vs_plain: float


def speculative_tok_per_watt(target: BaseProfile, draft: BaseProfile,
                             *, window: int = 8192,
                             accept_rate: float = 0.7,
                             speculation_len: int = 4,
                             utilization: float = 0.85,
                             draft_power_overhead: float = 0.08,
                             ) -> SpecPoint:
    """Co-located draft (sharded across the same TP group, the production
    design — a single-GPU draft's own KV scan at fleet concurrency costs
    as much per token as the TP-sharded target's, killing speculation).
    """
    n = max(target.n_max(window) * utilization, 1.0)
    L = speculation_len
    a = accept_rate
    exp_tokens = (1 - a ** (L + 1)) / (1 - a) if a < 1 else L + 1
    # target verify round: weight stream once + KV scan once per position
    tau_t = (target.roofline.w_ms
             + target.roofline.h_ms(window) * n) * 1e-3
    # draft co-located on the target's TP group: its per-step W and H
    # shrink by the TP factor relative to a standalone single-chip draft
    tp_scale = target.tp / max(draft.tp, 1)
    tau_d = L * (draft.roofline.w_ms / tp_scale
                 + draft.roofline.h_ms(window) / tp_scale * n) * 1e-3
    round_s = tau_t + tau_d
    tok_s = n * exp_tokens / round_s
    power = target.power_w(n) * (1.0 + draft_power_overhead)
    tpw = tok_s / power
    plain = target.tok_per_watt(n, window)
    return SpecPoint(accept_rate=a, speculation_len=L,
                     tokens_per_round=exp_tokens, tok_per_watt=tpw,
                     speedup_vs_plain=tpw / plain)


def sweep(target: BaseProfile, draft: BaseProfile, *, window: int = 8192,
          ) -> List[SpecPoint]:
    out = []
    for a in (0.5, 0.7, 0.8, 0.9):
        for L in (2, 4, 8):
            out.append(speculative_tok_per_watt(
                target, draft, window=window, accept_rate=a,
                speculation_len=L))
    return out
