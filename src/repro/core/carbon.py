"""Beyond-paper: carbon- and cost-aware objectives (paper §10.3).

tok/W says nothing about *when* and *where* the joules are drawn.  This
module converts fleet reports into gCO2/Mtok and $/Mtok using PUE, grid
carbon intensity, electricity price and instance rental — "the per-GPU
power model provides a natural starting point for a joint energy-cost
objective" (paper §10.3), so we build exactly that.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .fleet import FleetReport


@dataclasses.dataclass(frozen=True)
class GridProfile:
    name: str
    carbon_g_per_kwh: float      # grid intensity
    price_usd_per_kwh: float
    pue: float = 1.2             # datacenter power usage effectiveness


# Representative 2026 grid mixes (documented assumptions, not measurements)
GRIDS: Dict[str, GridProfile] = {
    "us-west-hydro": GridProfile("us-west-hydro", 90.0, 0.055),
    "us-east-mixed": GridProfile("us-east-mixed", 360.0, 0.085),
    "eu-north": GridProfile("eu-north", 45.0, 0.070),
    "apac-coal-heavy": GridProfile("apac-coal-heavy", 620.0, 0.095),
}


@dataclasses.dataclass(frozen=True)
class EnergyBill:
    tok_per_watt: float
    g_co2_per_mtok: float
    usd_energy_per_mtok: float
    usd_rental_per_mtok: float

    @property
    def usd_total_per_mtok(self) -> float:
        return self.usd_energy_per_mtok + self.usd_rental_per_mtok


def bill(report: FleetReport, grid: GridProfile) -> EnergyBill:
    """Convert a fleet report into carbon/cost per million output tokens."""
    tok_s = report.tokens_per_s
    kw_it = report.power_kw * grid.pue
    mtok_per_hour = tok_s * 3600 / 1e6
    kwh_per_mtok = kw_it / max(mtok_per_hour, 1e-12)
    rental_hr = sum(p.instances * p.profile.chip.rental_usd_hr
                    for p in report.pools)
    return EnergyBill(
        tok_per_watt=report.tok_per_watt,
        g_co2_per_mtok=kwh_per_mtok * grid.carbon_g_per_kwh,
        usd_energy_per_mtok=kwh_per_mtok * grid.price_usd_per_kwh,
        usd_rental_per_mtok=rental_hr / max(mtok_per_hour, 1e-12))


def rank_topologies(reports: Dict[str, FleetReport], grid: GridProfile,
                    objective: str = "g_co2_per_mtok") -> list:
    """Rank topologies by tok/W, carbon or total cost — the orderings can
    differ (rental dominates cost; carbon tracks energy)."""
    rows = []
    for name, rep in reports.items():
        b = bill(rep, grid)
        rows.append(dict(topology=name, tok_per_watt=round(b.tok_per_watt, 2),
                         g_co2_per_mtok=round(b.g_co2_per_mtok, 1),
                         usd_total_per_mtok=round(b.usd_total_per_mtok, 2)))
    key = objective if objective != "tok_per_watt" else None
    return sorted(rows, key=lambda r: r[objective],
                  reverse=(objective == "tok_per_watt"))
