"""Fleet sizing & fleet-level tok/W (paper §4, Eq. 4).

Sizing model (documented; FleetOpt internals are unpublished, see DESIGN.md §4):

  decode bound  — Little's law on the decode phase: the steady-state
                  in-flight population is N = lambda_i * Lbar_out * tau(n_max,
                  Lbar_ctx); instances = ceil(N / n_max).
  prefill bound — P99 TTFT <= 500 ms forces enough aggregate prefill
                  throughput: tokens/s_prefill = tp * peak_flops * mfu /
                  (2 * streamed_params).  Chunked prefill piggybacks on
                  memory-bound decode iterations, captured by `prefill_mfu`.
  no-overflow penalty — plain two-pool routing (no FleetOpt overflow /
                  compression) suffers conservative admission and
                  head-of-line blocking of long prefills in the long pool;
                  modeled as a long-pool occupancy inflation factor
                  `hol_inflation` (calibrated against Table 3; = 1.0 for
                  Homo and FleetOpt).

Power per instance is evaluated at the operating concurrency
n_act = min(N / instances, rho_op * n_max), rho_op = 0.85 (§5.1 uses the same
utilization).  "Instance" = one TP group (the paper's per-"GPU" power rows
are per TP-8 instance; see EXPERIMENTS.md §Claims).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from .profiles import BaseProfile

RHO_OP = 0.85           # operating utilization for the power term
# Effective prefill MFU (chunked prefill piggybacks on memory-bound decode
# iterations, so the achievable fraction of peak is high).  Calibrated
# jointly with HOL_INFLATION against Table 3 (see EXPERIMENTS.md §Claims).
# NOTE: this closed-form value is optimistic about queueing — fleets sized
# with it can violate the P99 TTFT SLO when actually run.  core.slo closes
# the loop by recalibrating an *effective* per-pool prefill MFU against the
# measured FleetSim TTFT (see DESIGN.md §5).
PREFILL_MFU = 0.8
# Dedicated prefill-phase pools (core.disagg) run compute-saturated: power
# is drawn near the logistic's P_nom asymptote, not at the decode operating
# point (batch-formation gaps keep it a hair under full saturation).
PREFILL_SATURATION = 0.97


@dataclasses.dataclass
class PoolSizing:
    """One provisioned pool of identical instances."""

    name: str
    window: int
    profile: BaseProfile
    arrival_rate: float          # requests/s routed here
    mean_output: float           # tokens
    mean_context: float          # mean KV length during decode
    mean_prompt: float           # tokens (prefill load)
    hol_inflation: float = 1.0
    # "decode" (default) or "prefill" — a prefill-phase pool (core.disagg)
    # is a compute-bound chunk processor: it is sized by the prefill bound
    # alone and draws saturated power instead of the decode operating point.
    phase: str = "decode"
    # physical MFU the prefill-phase *engines* run at (serving.fleetsim);
    # immutable under SLO recalibration, which only moves the sizing MFU.
    prefill_engine_mfu: Optional[float] = None
    # router role this pool serves, stamped by the TopologySpec IR
    # (core.topospec) at provision time — the single source every layer
    # (FleetSim wiring, SLO attribution, override application) reads role
    # names from; "" means the pool was built outside the IR.
    role: str = ""
    # computed:
    instances: int = 0
    n_active: float = 0.0
    power_w_per_instance: float = 0.0
    tokens_per_s: float = 0.0
    decode_bound: int = 0
    prefill_bound: int = 0
    n_inflight: float = 0.0      # Little's-law decode population (size())
    sized_prefill_mfu: float = PREFILL_MFU   # MFU the bounds were sized at

    def size(self, *, streamed_params: float,
             prefill_mfu: Optional[float] = None) -> "PoolSizing":
        if prefill_mfu is None:
            prefill_mfu = PREFILL_MFU  # read at call time (calibratable)
        self.sized_prefill_mfu = prefill_mfu
        prof = self.profile
        nmax = prof.n_max(self.window)
        tau_s = prof.roofline.tau_ms(nmax, self.mean_context) * 1e-3
        n_inflight = self.arrival_rate * self.mean_output * tau_s \
            * self.hol_inflation
        self.n_inflight = n_inflight
        self.decode_bound = math.ceil(n_inflight / nmax) if n_inflight else 0
        self.prefill_bound = self._prefill_bound(streamed_params, prefill_mfu)
        self.instances = max(self.decode_bound, self.prefill_bound, 0)
        if self.arrival_rate > 0:
            self.instances = max(self.instances, 1)
        if self.instances:
            self._operating_point()
            self.tokens_per_s = self.arrival_rate * self.mean_output
        return self

    def _prefill_bound(self, streamed_params: float,
                       prefill_mfu: float) -> int:
        """Instances forced by aggregate prefill throughput (tokens/s)."""
        prof = self.profile
        prefill_tput = (prof.tp * prof.chip.peak_bf16_flops * prefill_mfu
                        / (2.0 * streamed_params))
        prefill_load = self.arrival_rate * self.mean_prompt * self.hol_inflation
        return math.ceil(prefill_load / prefill_tput) if prefill_load else 0

    def _operating_point(self) -> None:
        nmax = self.profile.n_max(self.window)
        if self.phase == "prefill":
            # compute-bound: the profile's own concurrency ceiling and the
            # near-saturated end of its logistic (Eq. 1 as b -> inf)
            self.n_active = RHO_OP * nmax
            self.power_w_per_instance = \
                self.profile.power_model.p_nom_w * PREFILL_SATURATION
            return
        self.n_active = min(self.n_inflight / self.instances, RHO_OP * nmax)
        self.power_w_per_instance = self.profile.power_w(self.n_active)

    def recalibrate(self, *, streamed_params: float,
                    prefill_mfu: Optional[float] = None,
                    hol_inflation: Optional[float] = None,
                    min_instances: int = 0,
                    extra_instances: int = 0,
                    max_instances: int = 0) -> "PoolSizing":
        """SLO-loop re-provisioning knob (core.slo / DESIGN.md §5): re-derive
        the instance count under a recalibrated effective prefill MFU,
        head-of-line inflation factor and/or an instance-count floor,
        preserving every provision-time adjustment (e.g. FleetOpt's
        migrated-token backout of `tokens_per_s`).  The grow levers never
        *shrink* a pool — SLO compliance only adds capacity; `max_instances`
        (> 0) is the trim phase's cap, applied last so a measured-compliant
        bisection can shave the geometric step's overshoot below what the
        recalibrated bounds would provision (the cap encodes a *measured*
        compliance fact that overrides the pessimistic closed form)."""
        if self.arrival_rate <= 0:
            return self
        if hol_inflation is not None:
            self.hol_inflation = max(hol_inflation, self.hol_inflation)
            prof = self.profile
            nmax = prof.n_max(self.window)
            tau_s = prof.roofline.tau_ms(nmax, self.mean_context) * 1e-3
            self.n_inflight = self.arrival_rate * self.mean_output * tau_s \
                * self.hol_inflation
            self.decode_bound = math.ceil(self.n_inflight / nmax) \
                if self.n_inflight else 0
        if prefill_mfu is not None:
            self.sized_prefill_mfu = prefill_mfu
        if prefill_mfu is not None or hol_inflation is not None:
            self.prefill_bound = self._prefill_bound(
                streamed_params, self.sized_prefill_mfu)
        self.instances = max(self.instances, self.decode_bound,
                             self.prefill_bound, int(min_instances), 1)
        self.instances += max(int(extra_instances), 0)
        if max_instances > 0:
            self.instances = min(self.instances, max(int(max_instances), 1))
        self._operating_point()
        return self


@dataclasses.dataclass
class FleetReport:
    """Eq. 4 fleet-level result."""

    pools: List[PoolSizing]
    label: str = ""

    @property
    def instances(self) -> int:
        return sum(p.instances for p in self.pools)

    @property
    def gpus(self) -> int:
        return sum(p.instances * p.profile.tp for p in self.pools)

    @property
    def power_kw(self) -> float:
        return sum(p.instances * p.power_w_per_instance
                   for p in self.pools) / 1e3

    @property
    def tokens_per_s(self) -> float:
        return sum(p.tokens_per_s for p in self.pools)

    @property
    def tok_per_watt(self) -> float:
        pw = self.power_kw * 1e3
        return self.tokens_per_s / pw if pw else 0.0

    def row(self) -> dict:
        return dict(label=self.label, instances=self.instances,
                    gpus=self.gpus, kw=round(self.power_kw, 1),
                    tok_per_watt=round(self.tok_per_watt, 2))


def size_fleet(pools: List[PoolSizing], *, streamed_params: float,
               prefill_mfu: Optional[float] = None,
               label: str = "") -> FleetReport:
    for p in pools:
        p.size(streamed_params=streamed_params, prefill_mfu=prefill_mfu)
    return FleetReport(pools=[p for p in pools if p.arrival_rate > 0],
                       label=label)


@dataclasses.dataclass
class PoolOverride:
    """Per-pool sizing recalibration layered on a provisioned FleetReport.

    The SLO loop (core.slo) accumulates one of these per router role across
    rounds: `prefill_mfu` lowers the effective prefill MFU (raising the
    prefill instance bound), `hol_inflation` raises the head-of-line
    occupancy factor (raising both bounds), `min_instances` ratchets the
    pool to at least that capacity (levers take a max, they never
    compound), and `extra_instances` forces additional capacity beyond
    every bound.  `max_instances` (> 0) caps the pool from above — the
    trim phase's lever, set only from a *measured*-compliant simulation
    (DESIGN.md §5).  Applied via `apply_overrides`.
    """

    prefill_mfu: Optional[float] = None
    hol_inflation: Optional[float] = None
    min_instances: int = 0
    extra_instances: int = 0
    max_instances: int = 0


def apply_overrides(report: FleetReport,
                    overrides: Dict[str, PoolOverride], *,
                    roles: List[str],
                    streamed_params) -> FleetReport:
    """Recalibrate `report`'s pools (ascending-window order, one role name
    per pool) in place with the given per-role overrides.  In a
    model-heterogeneous fleet each pool streams its *own* model's
    parameters, so `streamed_params` may be a {role: params} dict (a bare
    float applies to every pool — the homogeneous case)."""
    pools = sorted(report.pools, key=lambda p: p.window)
    assert len(roles) == len(pools), (roles, [p.name for p in pools])
    for role, pool in zip(roles, pools):
        o = overrides.get(role)
        sp = streamed_params.get(role) \
            if isinstance(streamed_params, dict) else streamed_params
        if o is not None:
            pool.recalibrate(streamed_params=sp,
                             prefill_mfu=o.prefill_mfu,
                             hol_inflation=o.hol_inflation,
                             min_instances=o.min_instances,
                             extra_instances=o.extra_instances,
                             max_instances=o.max_instances)
    return report
