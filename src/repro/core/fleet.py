"""Fleet sizing & fleet-level tok/W (paper §4, Eq. 4).

Sizing model (documented; FleetOpt internals are unpublished, see DESIGN.md §4):

  decode bound  — Little's law on the decode phase: the steady-state
                  in-flight population is N = lambda_i * Lbar_out * tau(n_max,
                  Lbar_ctx); instances = ceil(N / n_max).
  prefill bound — P99 TTFT <= 500 ms forces enough aggregate prefill
                  throughput: tokens/s_prefill = tp * peak_flops * mfu /
                  (2 * streamed_params).  Chunked prefill piggybacks on
                  memory-bound decode iterations, captured by `prefill_mfu`.
  no-overflow penalty — plain two-pool routing (no FleetOpt overflow /
                  compression) suffers conservative admission and
                  head-of-line blocking of long prefills in the long pool;
                  modeled as a long-pool occupancy inflation factor
                  `hol_inflation` (calibrated against Table 3; = 1.0 for
                  Homo and FleetOpt).

Power per instance is evaluated at the operating concurrency
n_act = min(N / instances, rho_op * n_max), rho_op = 0.85 (§5.1 uses the same
utilization).  "Instance" = one TP group (the paper's per-"GPU" power rows
are per TP-8 instance; see EXPERIMENTS.md §Claims).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from .profiles import BaseProfile

RHO_OP = 0.85           # operating utilization for the power term
# Effective prefill MFU (chunked prefill piggybacks on memory-bound decode
# iterations, so the achievable fraction of peak is high).  Calibrated
# jointly with HOL_INFLATION against Table 3 (see EXPERIMENTS.md §Claims).
PREFILL_MFU = 0.8


@dataclasses.dataclass
class PoolSizing:
    """One provisioned pool of identical instances."""

    name: str
    window: int
    profile: BaseProfile
    arrival_rate: float          # requests/s routed here
    mean_output: float           # tokens
    mean_context: float          # mean KV length during decode
    mean_prompt: float           # tokens (prefill load)
    hol_inflation: float = 1.0
    # computed:
    instances: int = 0
    n_active: float = 0.0
    power_w_per_instance: float = 0.0
    tokens_per_s: float = 0.0
    decode_bound: int = 0
    prefill_bound: int = 0

    def size(self, *, streamed_params: float,
             prefill_mfu: Optional[float] = None) -> "PoolSizing":
        if prefill_mfu is None:
            prefill_mfu = PREFILL_MFU  # read at call time (calibratable)
        prof = self.profile
        nmax = prof.n_max(self.window)
        tau_s = prof.roofline.tau_ms(nmax, self.mean_context) * 1e-3
        n_inflight = self.arrival_rate * self.mean_output * tau_s \
            * self.hol_inflation
        self.decode_bound = math.ceil(n_inflight / nmax) if n_inflight else 0
        # prefill capacity per instance (tokens/s)
        prefill_tput = (prof.tp * prof.chip.peak_bf16_flops * prefill_mfu
                        / (2.0 * streamed_params))
        prefill_load = self.arrival_rate * self.mean_prompt * self.hol_inflation
        self.prefill_bound = math.ceil(prefill_load / prefill_tput) \
            if prefill_load else 0
        self.instances = max(self.decode_bound, self.prefill_bound, 0)
        if self.arrival_rate > 0:
            self.instances = max(self.instances, 1)
        if self.instances:
            self.n_active = min(n_inflight / self.instances, RHO_OP * nmax)
            self.power_w_per_instance = prof.power_w(self.n_active)
            self.tokens_per_s = self.arrival_rate * self.mean_output
        return self


@dataclasses.dataclass
class FleetReport:
    """Eq. 4 fleet-level result."""

    pools: List[PoolSizing]
    label: str = ""

    @property
    def instances(self) -> int:
        return sum(p.instances for p in self.pools)

    @property
    def gpus(self) -> int:
        return sum(p.instances * p.profile.tp for p in self.pools)

    @property
    def power_kw(self) -> float:
        return sum(p.instances * p.power_w_per_instance
                   for p in self.pools) / 1e3

    @property
    def tokens_per_s(self) -> float:
        return sum(p.tokens_per_s for p in self.pools)

    @property
    def tok_per_watt(self) -> float:
        pw = self.power_kw * 1e3
        return self.tokens_per_s / pw if pw else 0.0

    def row(self) -> dict:
        return dict(label=self.label, instances=self.instances,
                    gpus=self.gpus, kw=round(self.power_kw, 1),
                    tok_per_watt=round(self.tok_per_watt, 2))


def size_fleet(pools: List[PoolSizing], *, streamed_params: float,
               prefill_mfu: Optional[float] = None,
               label: str = "") -> FleetReport:
    for p in pools:
        p.size(streamed_params=streamed_params, prefill_mfu=prefill_mfu)
    return FleetReport(pools=[p for p in pools if p.arrival_rate > 0],
                       label=label)
