"""Decode roofline model (paper §2.2): tau(n, L) = W + H(L) * n.

W  — weight-streaming time per decode iteration (all touched weight bytes
     divided by HBM bandwidth; for MoE, only *active* expert bytes).
H(L) — per-sequence KV-scan overhead, linear in the mean KV length L:
     H(L) = H0 * L / L_calib.

Throughput at concurrency n is n / tau(n, L) tokens/s per instance.
"""
from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

ArrayLike = Union[float, int, np.ndarray]


@dataclasses.dataclass(frozen=True)
class DecodeRoofline:
    """Calibrated decode-latency roofline for one (model, accelerator) pair."""

    w_ms: float            # weight-streaming ms / iteration
    h0_ms: float           # KV-scan ms / sequence at L = l_calib
    l_calib: float = 8192  # calibration context length (tokens)

    def h_ms(self, mean_context: ArrayLike) -> ArrayLike:
        return self.h0_ms * (np.asarray(mean_context, dtype=float) / self.l_calib)

    def tau_ms(self, n: ArrayLike, mean_context: ArrayLike) -> ArrayLike:
        """Per-iteration decode latency at n in-flight sequences (ms)."""
        return self.w_ms + self.h_ms(mean_context) * np.asarray(n, dtype=float)

    def tokens_per_s(self, n: ArrayLike, mean_context: ArrayLike) -> ArrayLike:
        n = np.asarray(n, dtype=float)
        return np.where(n > 0, n / (self.tau_ms(n, mean_context) * 1e-3), 0.0)

    @property
    def x0_from_ratio(self) -> float:
        """Appendix A: x0 = log2(W / H0) — half-saturation from the roofline."""
        return float(np.log2(self.w_ms / self.h0_ms))

    @staticmethod
    def from_first_principles(*, weight_bytes_per_gpu: float,
                              kv_bytes_per_token_per_gpu: float,
                              mem_bw_Bps: float,
                              l_calib: float = 8192,
                              weight_stream_efficiency: float = 0.777,
                              kv_scan_efficiency: float = 0.968) -> "DecodeRoofline":
        """Compute W and H0 from bytes and bandwidth.

        Efficiency factors are calibrated so the H100 Llama-3.1-70B profile
        reproduces the paper's measured W = 6.72 ms and Table-1 tok/W:
        17.5 GB / (0.777 * 3.35 TB/s) = 6.72 ms; 55 KB * 8192 / (0.968 * 3.35
        TB/s) = 0.139 ms.
        """
        w_ms = weight_bytes_per_gpu / (weight_stream_efficiency * mem_bw_Bps) * 1e3
        h0_ms = (kv_bytes_per_token_per_gpu * l_calib
                 / (kv_scan_efficiency * mem_bw_Bps) * 1e3)
        return DecodeRoofline(w_ms=w_ms, h0_ms=h0_ms, l_calib=l_calib)
