"""Routing topologies (paper §4–§5): Homo / Pool / FleetOpt / Semantic.

A topology turns (workload, profile(s)) into provisioned pools:

  Homogeneous   — one pool at the long window; every GPU pays the 1/W price
                  of the worst-case context.
  TwoPool       — static context-length split at B_short.  Without an
                  overflow mechanism admission must be conservative
                  (prompt + p99(output) must fit the short window) and the
                  long pool suffers head-of-line inflation (see fleet.py).
  FleetOpt      — two-pool with overflow parameter gamma: the short pool
                  serves window gamma * B_short, admission by predicted total
                  <= gamma * B_short, no HOL penalty (the overflow headroom /
                  compress-and-route mechanism absorbs mispredictions).
                  `optimize_gamma` grid-searches gamma for fleet tok/W.
  Semantic      — §5.1: small model for short requests, large for long.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .fleet import FleetReport, PoolSizing, size_fleet
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .workloads import Workload

LONG_WINDOW = 65536   # paper: homogeneous / long pool serve at 64K
HOL_INFLATION = 2.15  # calibrated vs Table 3 (plain Pool, long pool)


def _subset_stats(prompts: np.ndarray, outputs: np.ndarray,
                  mask: np.ndarray) -> dict:
    if mask.sum() == 0:
        return dict(frac=0.0, mean_context=0.0, mean_output=0.0,
                    mean_prompt=0.0)
    p, o = prompts[mask], outputs[mask]
    return dict(frac=float(mask.mean()),
                mean_context=float((p + o / 2.0).mean()),
                mean_output=float(o.mean()),
                mean_prompt=float(p.mean()))


@dataclasses.dataclass
class Homogeneous:
    window: int = LONG_WINDOW

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        pool = PoolSizing(
            name=f"homo-{self.window // 1024}K", window=self.window,
            profile=profile, arrival_rate=workload.arrival_rate,
            mean_output=workload.mean_output,
            mean_context=workload.mean_context,
            mean_prompt=workload.mean_prompt)
        return size_fleet([pool], streamed_params=model.streamed_params,
                          label=f"Homo {self.window // 1024}K")


@dataclasses.dataclass
class TwoPool:
    b_short: int
    long_window: int = LONG_WINDOW
    hol_inflation: float = HOL_INFLATION

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        # Conservative admission: no overflow handling, so a request may only
        # go short if prompt + p99(output) fits the short window.
        p99_out = float(np.quantile(o, 0.99))
        short_mask = p + p99_out <= self.b_short
        lam = workload.arrival_rate
        s = _subset_stats(p, o, short_mask)
        l = _subset_stats(p, o, ~short_mask)
        pools = [
            PoolSizing(name=f"short-{self.b_short // 1024}K",
                       window=self.b_short, profile=profile,
                       arrival_rate=lam * s["frac"],
                       mean_output=s["mean_output"],
                       mean_context=s["mean_context"],
                       mean_prompt=s["mean_prompt"]),
            PoolSizing(name=f"long-{self.long_window // 1024}K",
                       window=self.long_window, profile=profile,
                       arrival_rate=lam * l["frac"],
                       mean_output=l["mean_output"],
                       mean_context=l["mean_context"],
                       mean_prompt=l["mean_prompt"],
                       hol_inflation=self.hol_inflation),
        ]
        return size_fleet(pools, streamed_params=model.streamed_params,
                          label=f"Pool {self.b_short // 1024}K")


@dataclasses.dataclass
class FleetOpt:
    b_short: int
    gamma: float = 2.0
    long_window: int = LONG_WINDOW

    @property
    def short_window(self) -> int:
        return int(self.gamma * self.b_short)

    def mispredict_rate(self, workload: Workload) -> float:
        """Fraction of short-routed requests whose actual total overflows
        the gamma-window (these migrate and bust their TTFT/TPOT SLO)."""
        p, o = workload.prompts, workload.outputs
        routed_short = (p + workload.mean_output) <= self.b_short
        if routed_short.mean() == 0:
            return 0.0
        mis = routed_short & ((p + o) > self.short_window)
        return float(mis.sum() / routed_short.sum())

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        # Honest routing: the router only knows the prompt and E[output].
        # The gamma-window is the overflow headroom: requests predicted to
        # fit B_short are served at window gamma*B_short, so output-length
        # mispredictions up to (gamma-1)*B_short finish in place.
        routed_short = (p + workload.mean_output) <= self.b_short
        mispredict = routed_short & ((p + o) > self.short_window)
        legit = routed_short & ~mispredict
        lam_mis = lam * float(mispredict.mean())
        s = _subset_stats(p, o, legit)
        l = _subset_stats(p, o, ~routed_short)
        # Mispredicted requests burn a short-pool slot for the full window
        # then migrate: re-prefilled and fully served in the long pool.
        long_lam = lam * l["frac"] + lam_mis
        m = _subset_stats(p, o, mispredict)
        if long_lam > 0:
            wl_frac = lam * l["frac"] / long_lam
            l_mean_out = wl_frac * l["mean_output"] \
                + (1 - wl_frac) * m["mean_output"]
            l_mean_ctx = wl_frac * l["mean_context"] \
                + (1 - wl_frac) * m["mean_context"]
            l_mean_prompt = wl_frac * l["mean_prompt"] \
                + (1 - wl_frac) * m["mean_prompt"]
        else:
            l_mean_out = l_mean_ctx = l_mean_prompt = 0.0
        pools = [
            PoolSizing(name=f"fleetopt-short-{self.short_window // 1024}K",
                       window=self.short_window, profile=profile,
                       arrival_rate=lam * s["frac"] + lam_mis,
                       mean_output=s["mean_output"],
                       mean_context=s["mean_context"],
                       mean_prompt=s["mean_prompt"]),
            PoolSizing(name=f"fleetopt-long-{self.long_window // 1024}K",
                       window=self.long_window, profile=profile,
                       arrival_rate=long_lam,
                       mean_output=l_mean_out,
                       mean_context=l_mean_ctx,
                       mean_prompt=l_mean_prompt),
        ]
        rep = size_fleet(pools, streamed_params=model.streamed_params,
                         label=f"FleetOpt {self.b_short // 1024}K"
                               f"/g={self.gamma:g}")
        # wasted short-pool decode work of migrated requests is real load
        # but produces no counted output tokens:
        if lam_mis > 0 and rep.pools:
            rep.pools[0].tokens_per_s -= lam_mis * s["mean_output"]
        return rep


def optimize_gamma(workload: Workload, profile: BaseProfile, model: ModelSpec,
                   b_short: int,
                   gammas: Tuple[float, ...] = (1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
                                                8.0),
                   max_mispredict: float = 5e-5,
                   ) -> Tuple[float, FleetReport]:
    """gamma*: grid-optimal overflow parameter for fleet tok/W, subject to
    the SLO constraint that overflow migrations (which bust P99 TTFT) stay
    below `max_mispredict` of short-pool traffic (0.005%: the P99.99
    tail budget of the TTFT SLO).  Smaller gamma packs more
    sequences per instance (n_max ~ 1/window) but absorbs less of the
    output-length tail — the constraint is what pins gamma* = 2 on the
    Azure trace, matching the paper."""
    best: Tuple[float, Optional[FleetReport]] = (gammas[-1], None)
    for g in gammas:
        fo = FleetOpt(b_short=b_short, gamma=g)
        if fo.mispredict_rate(workload) > max_mispredict:
            continue
        rep = fo.provision(workload, profile, model)
        if best[1] is None or rep.tok_per_watt > best[1].tok_per_watt:
            best = (g, rep)
    if best[1] is None:   # no gamma satisfies the SLO: take the largest
        g = gammas[-1]
        best = (g, FleetOpt(b_short=b_short, gamma=g).provision(
            workload, profile, model))
    return best  # type: ignore[return-value]


@dataclasses.dataclass
class Semantic:
    """§5.1 semantic routing: small model short pool, large model long pool."""

    b_short: int
    small_profile: BaseProfile
    small_model: ModelSpec
    short_window: int = 8192
    long_window: int = LONG_WINDOW

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        short_mask = (p + o) <= self.b_short
        lam = workload.arrival_rate
        s = _subset_stats(p, o, short_mask)
        l = _subset_stats(p, o, ~short_mask)
        pools = [
            PoolSizing(name=f"semantic-small-{self.short_window // 1024}K",
                       window=self.short_window, profile=self.small_profile,
                       arrival_rate=lam * s["frac"],
                       mean_output=s["mean_output"],
                       mean_context=s["mean_context"],
                       mean_prompt=s["mean_prompt"]),
            PoolSizing(name=f"semantic-large-{self.long_window // 1024}K",
                       window=self.long_window, profile=profile,
                       arrival_rate=lam * l["frac"],
                       mean_output=l["mean_output"],
                       mean_context=l["mean_context"],
                       mean_prompt=l["mean_prompt"]),
        ]
        # NOTE: sizing uses each pool's own streamed params.
        pools[0].size(streamed_params=self.small_model.streamed_params)
        pools[1].size(streamed_params=model.streamed_params)
        return FleetReport(pools=[q for q in pools if q.arrival_rate > 0],
                           label=f"Semantic {self.b_short // 1024}K")
