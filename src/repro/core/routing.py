"""Routing topologies (paper §4–§5): Homo / Pool / FleetOpt / Semantic.

A topology turns (workload, profile(s)) into provisioned pools:

  Homogeneous   — one pool at the long window; every GPU pays the 1/W price
                  of the worst-case context.
  TwoPool       — static context-length split at B_short.  Without an
                  overflow mechanism admission must be conservative
                  (prompt + p99(output) must fit the short window) and the
                  long pool suffers head-of-line inflation (see fleet.py).
  FleetOpt      — two-pool with overflow parameter gamma: the short pool
                  serves window gamma * B_short, admission by predicted total
                  <= gamma * B_short, no HOL penalty (the overflow headroom /
                  compress-and-route mechanism absorbs mispredictions).
                  `optimize_gamma` grid-searches gamma for fleet tok/W.
  Semantic      — §5.1: small *model* for short requests, large for long —
                  the model-heterogeneous topology.  Honest routing
                  (predicted total vs B_short) with FleetOpt-style overflow
                  headroom (serve at gamma * B_short), a semantic-classifier
                  `misroute_rate`, and an escalation hop: a true-large
                  request misrouted into the small-model pool is detected
                  after `detect_tokens` of decode and re-served from scratch
                  by the large pool; its small-pool work counts as
                  non-output energy (subtracted from tokens_per_s, the
                  FleetOpt migrated-token convention).  Served end-to-end
                  by serving.fleetsim (`semantic` / `semantic_fleetopt` /
                  `moe_semantic` kinds).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .fleet import FleetReport, PoolSizing, size_fleet
from .modelspec import ModelSpec
from .profiles import BaseProfile
from .workloads import Workload

LONG_WINDOW = 65536   # paper: homogeneous / long pool serve at 64K
HOL_INFLATION = 2.15  # calibrated vs Table 3 (plain Pool, long pool)
# Decode tokens a semantic misroute generates in the small-model pool
# before the quality monitor catches it and escalates (shared by the
# analytical Semantic model and the serving-side SemanticRouter so the
# two layers price the same detection latency).
ESCALATION_DETECT_TOKENS = 32


def _subset_stats(prompts: np.ndarray, outputs: np.ndarray,
                  mask: np.ndarray) -> dict:
    if mask.sum() == 0:
        return dict(frac=0.0, mean_context=0.0, mean_output=0.0,
                    mean_prompt=0.0)
    p, o = prompts[mask], outputs[mask]
    return dict(frac=float(mask.mean()),
                mean_context=float((p + o / 2.0).mean()),
                mean_output=float(o.mean()),
                mean_prompt=float(p.mean()))


@dataclasses.dataclass
class Homogeneous:
    window: int = LONG_WINDOW

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        pool = PoolSizing(
            name=f"homo-{self.window // 1024}K", window=self.window,
            profile=profile, arrival_rate=workload.arrival_rate,
            mean_output=workload.mean_output,
            mean_context=workload.mean_context,
            mean_prompt=workload.mean_prompt)
        return size_fleet([pool], streamed_params=model.streamed_params,
                          label=f"Homo {self.window // 1024}K")


@dataclasses.dataclass
class TwoPool:
    b_short: int
    long_window: int = LONG_WINDOW
    hol_inflation: float = HOL_INFLATION

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        # Conservative admission: no overflow handling, so a request may only
        # go short if prompt + p99(output) fits the short window.
        p99_out = float(np.quantile(o, 0.99))
        short_mask = p + p99_out <= self.b_short
        lam = workload.arrival_rate
        s = _subset_stats(p, o, short_mask)
        l = _subset_stats(p, o, ~short_mask)
        pools = [
            PoolSizing(name=f"short-{self.b_short // 1024}K",
                       window=self.b_short, profile=profile,
                       arrival_rate=lam * s["frac"],
                       mean_output=s["mean_output"],
                       mean_context=s["mean_context"],
                       mean_prompt=s["mean_prompt"]),
            PoolSizing(name=f"long-{self.long_window // 1024}K",
                       window=self.long_window, profile=profile,
                       arrival_rate=lam * l["frac"],
                       mean_output=l["mean_output"],
                       mean_context=l["mean_context"],
                       mean_prompt=l["mean_prompt"],
                       hol_inflation=self.hol_inflation),
        ]
        return size_fleet(pools, streamed_params=model.streamed_params,
                          label=f"Pool {self.b_short // 1024}K")


@dataclasses.dataclass
class FleetOpt:
    b_short: int
    gamma: float = 2.0
    long_window: int = LONG_WINDOW

    @property
    def short_window(self) -> int:
        return int(self.gamma * self.b_short)

    def mispredict_rate(self, workload: Workload) -> float:
        """Fraction of short-routed requests whose actual total overflows
        the gamma-window (these migrate and bust their TTFT/TPOT SLO)."""
        p, o = workload.prompts, workload.outputs
        routed_short = (p + workload.mean_output) <= self.b_short
        if routed_short.mean() == 0:
            return 0.0
        mis = routed_short & ((p + o) > self.short_window)
        return float(mis.sum() / routed_short.sum())

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        # Honest routing: the router only knows the prompt and E[output].
        # The gamma-window is the overflow headroom: requests predicted to
        # fit B_short are served at window gamma*B_short, so output-length
        # mispredictions up to (gamma-1)*B_short finish in place.
        routed_short = (p + workload.mean_output) <= self.b_short
        mispredict = routed_short & ((p + o) > self.short_window)
        legit = routed_short & ~mispredict
        lam_mis = lam * float(mispredict.mean())
        s = _subset_stats(p, o, legit)
        l = _subset_stats(p, o, ~routed_short)
        # Mispredicted requests burn a short-pool slot for the full window
        # then migrate: re-prefilled and fully served in the long pool.
        long_lam = lam * l["frac"] + lam_mis
        m = _subset_stats(p, o, mispredict)
        if long_lam > 0:
            wl_frac = lam * l["frac"] / long_lam
            l_mean_out = wl_frac * l["mean_output"] \
                + (1 - wl_frac) * m["mean_output"]
            l_mean_ctx = wl_frac * l["mean_context"] \
                + (1 - wl_frac) * m["mean_context"]
            l_mean_prompt = wl_frac * l["mean_prompt"] \
                + (1 - wl_frac) * m["mean_prompt"]
        else:
            l_mean_out = l_mean_ctx = l_mean_prompt = 0.0
        pools = [
            PoolSizing(name=f"fleetopt-short-{self.short_window // 1024}K",
                       window=self.short_window, profile=profile,
                       arrival_rate=lam * s["frac"] + lam_mis,
                       mean_output=s["mean_output"],
                       mean_context=s["mean_context"],
                       mean_prompt=s["mean_prompt"]),
            PoolSizing(name=f"fleetopt-long-{self.long_window // 1024}K",
                       window=self.long_window, profile=profile,
                       arrival_rate=long_lam,
                       mean_output=l_mean_out,
                       mean_context=l_mean_ctx,
                       mean_prompt=l_mean_prompt),
        ]
        rep = size_fleet(pools, streamed_params=model.streamed_params,
                         label=f"FleetOpt {self.b_short // 1024}K"
                               f"/g={self.gamma:g}")
        # wasted short-pool decode work of migrated requests is real load
        # but produces no counted output tokens:
        if lam_mis > 0 and rep.pools:
            rep.pools[0].tokens_per_s -= lam_mis * s["mean_output"]
        return rep


def optimize_gamma(workload: Workload, profile: BaseProfile, model: ModelSpec,
                   b_short: int,
                   gammas: Tuple[float, ...] = (1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
                                                8.0),
                   max_mispredict: float = 5e-5,
                   ) -> Tuple[float, FleetReport]:
    """gamma*: grid-optimal overflow parameter for fleet tok/W, subject to
    the SLO constraint that overflow migrations (which bust P99 TTFT) stay
    below `max_mispredict` of short-pool traffic (0.005%: the P99.99
    tail budget of the TTFT SLO).  Smaller gamma packs more
    sequences per instance (n_max ~ 1/window) but absorbs less of the
    output-length tail — the constraint is what pins gamma* = 2 on the
    Azure trace, matching the paper."""
    best: Tuple[float, Optional[FleetReport]] = (gammas[-1], None)
    for g in gammas:
        fo = FleetOpt(b_short=b_short, gamma=g)
        if fo.mispredict_rate(workload) > max_mispredict:
            continue
        rep = fo.provision(workload, profile, model)
        if best[1] is None or rep.tok_per_watt > best[1].tok_per_watt:
            best = (g, rep)
    if best[1] is None:   # no gamma satisfies the SLO: take the largest
        g = gammas[-1]
        best = (g, FleetOpt(b_short=b_short, gamma=g).provision(
            workload, profile, model))
    return best  # type: ignore[return-value]


@dataclasses.dataclass
class Semantic:
    """§5.1 semantic routing: small-model short pool, large-model long pool.

    Honest routing (the classifier sees prompt + E[output], like FleetOpt),
    with two error channels priced explicitly:

      * length mispredictions — a correctly-classified short request whose
        actual total outgrows the small pool's serve window
        (gamma * b_short) migrates: re-prefilled and fully served by the
        large pool, its small-pool decode work wasted (gamma = 1 is the
        headroom-free `semantic` serving kind; gamma > 1 the
        `semantic_fleetopt` kind).
      * semantic misroutes — a fraction `misroute_rate` of the classifier's
        decisions flip.  A true-short request sent large is merely served
        inefficiently; a true-large request sent small burns its (large)
        prompt prefill plus `detect_tokens` of small-model decode before
        escalation re-serves it from scratch in the large pool.

    Wasted small-pool work follows the FleetOpt migrated-token convention:
    the load is provisioned for, the output tokens are subtracted.
    """

    b_short: int
    small_profile: BaseProfile
    small_model: ModelSpec
    gamma: float = 2.0             # small-pool overflow headroom
    long_window: int = LONG_WINDOW
    misroute_rate: float = 0.0
    detect_tokens: int = ESCALATION_DETECT_TOKENS

    @property
    def short_window(self) -> int:
        return int(self.gamma * self.b_short)

    def provision(self, workload: Workload, profile: BaseProfile,
                  model: ModelSpec) -> FleetReport:
        if not 0.0 <= self.misroute_rate < 1.0:
            raise ValueError(f"misroute_rate must be in [0, 1), got"
                             f" {self.misroute_rate}")
        if self.gamma < 1.0:
            raise ValueError(f"gamma must be >= 1, got {self.gamma}")
        p, o = workload.prompts, workload.outputs
        lam = workload.arrival_rate
        r = self.misroute_rate
        routed_small = (p + workload.mean_output) <= self.b_short
        overflow = routed_small & ((p + o) > self.short_window)
        legit = routed_small & ~overflow
        s = _subset_stats(p, o, legit)
        v = _subset_stats(p, o, overflow)
        l = _subset_stats(p, o, ~routed_small)
        # an overflower decodes only until its KV hits the serve window
        # (then evicts), so its wasted small-pool output is window - prompt,
        # not its full sampled output
        ovf_waste = float(np.maximum(
            self.short_window - p[overflow], 0.0).mean()) \
            if overflow.any() else 0.0
        # --- small-model pool: correctly-routed shorts (1 - r of them)
        # plus the misrouted true-larges (r of the large class), which
        # prefill their big prompts here and decode detect_tokens each
        # before escalating ------------------------------------------------
        lam_legit = lam * (1.0 - r) * s["frac"]
        lam_ovf = lam * (1.0 - r) * v["frac"]
        lam_esc = lam * r * l["frac"]
        lam_small = lam_legit + lam_ovf + lam_esc
        if lam_small > 0:
            w_legit, w_ovf, w_esc = (lam_legit / lam_small,
                                     lam_ovf / lam_small,
                                     lam_esc / lam_small)
            s_out = (w_legit * s["mean_output"] + w_ovf * ovf_waste
                     + w_esc * self.detect_tokens)
            s_prompt = (w_legit * s["mean_prompt"] + w_ovf * v["mean_prompt"]
                        + w_esc * l["mean_prompt"])
            s_ctx = (w_legit * s["mean_context"]
                     + w_ovf * (v["mean_prompt"] + ovf_waste / 2.0)
                     + w_esc * (l["mean_prompt"] + self.detect_tokens / 2.0))
        else:
            s_out = s_prompt = s_ctx = 0.0
        # --- large-model pool: correctly-routed larges, misrouted shorts,
        # and the re-served overflow + escalation traffic ------------------
        lam_mis_s = lam * r * s["frac"] + lam * r * v["frac"]
        lam_large = lam * (1.0 - r) * l["frac"] + lam_mis_s \
            + lam_ovf + lam_esc
        if lam_large > 0:
            comps = (  # (rate, output, context, prompt)
                (lam * (1.0 - r) * l["frac"] + lam_esc,
                 l["mean_output"], l["mean_context"], l["mean_prompt"]),
                (lam * r * s["frac"],
                 s["mean_output"], s["mean_context"], s["mean_prompt"]),
                (lam * r * v["frac"] + lam_ovf,
                 v["mean_output"], v["mean_context"], v["mean_prompt"]),
            )
            l_out = sum(c[0] * c[1] for c in comps) / lam_large
            l_ctx = sum(c[0] * c[2] for c in comps) / lam_large
            l_prompt = sum(c[0] * c[3] for c in comps) / lam_large
        else:
            l_out = l_ctx = l_prompt = 0.0
        pools = [
            PoolSizing(name=f"semantic-small-{self.short_window // 1024}K",
                       window=self.short_window, profile=self.small_profile,
                       arrival_rate=lam_small,
                       mean_output=s_out, mean_context=s_ctx,
                       mean_prompt=s_prompt),
            PoolSizing(name=f"semantic-large-{self.long_window // 1024}K",
                       window=self.long_window, profile=profile,
                       arrival_rate=lam_large,
                       mean_output=l_out, mean_context=l_ctx,
                       mean_prompt=l_prompt),
        ]
        # NOTE: sizing uses each pool's own streamed params — the point of
        # the topology (DESIGN.md §9).
        pools[0].size(streamed_params=self.small_model.streamed_params)
        pools[1].size(streamed_params=model.streamed_params)
        # wasted small-pool decode (overflow migrations + escalated
        # misroutes) is provisioned load that produces no counted output
        if pools[0].instances and (lam_ovf > 0 or lam_esc > 0):
            pools[0].tokens_per_s -= (lam_ovf * ovf_waste
                                      + lam_esc * self.detect_tokens)
        return FleetReport(pools=[q for q in pools if q.arrival_rate > 0],
                           label=f"Semantic {self.b_short // 1024}K"
                                 f"/g={self.gamma:g}"
                                 + (f"/mr={self.misroute_rate:g}"
                                    if self.misroute_rate else ""))
