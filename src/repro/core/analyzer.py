"""`fleet_tpw_analysis` — the paper's Appendix B planning API.

The paper states all fleet tok/W results are produced by this call from
inference-fleet-sim.  It accepts any object satisfying the GpuProfile
protocol (ManualProfile or ComputedProfile) so measured and projected
hardware compare on equal footing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from .fleet import FleetReport
from .modelspec import ModelSpec, PAPER_MODELS
from .profiles import BaseProfile
from .routing import FleetOpt, Homogeneous, Semantic, TwoPool, optimize_gamma
from .workloads import WORKLOADS, Workload

Topology = Union[Homogeneous, TwoPool, FleetOpt, Semantic]


@dataclasses.dataclass
class FleetAnalysis:
    """Result bundle: one FleetReport per requested topology."""

    workload: str
    gpu: str
    reports: Dict[str, FleetReport]
    gamma_star: Optional[float] = None

    def table(self) -> List[dict]:
        base = None
        rows = []
        for name, rep in self.reports.items():
            row = rep.row()
            row["topology"] = name
            if base is None:
                base = rep.tok_per_watt
                row["vs_baseline"] = "-"
            else:
                row["vs_baseline"] = f"{(rep.tok_per_watt / base - 1) * 100:+.0f}%"
            rows.append(row)
        return rows


def fleet_tpw_analysis(*, workload: Union[str, Workload],
                       profile: BaseProfile,
                       model: Union[str, ModelSpec] = "Llama-3.1-70B",
                       b_short: int = 4096,
                       gamma: Optional[float] = None,
                       topologies: tuple = ("homo", "pool", "fleetopt"),
                       ) -> FleetAnalysis:
    """Evaluate routing topologies for a workload on a GpuProfile.

    gamma=None grid-optimizes the FleetOpt overflow parameter (gamma*).
    """
    wl = WORKLOADS[workload] if isinstance(workload, str) else workload
    mdl = PAPER_MODELS[model] if isinstance(model, str) else model
    reports: Dict[str, FleetReport] = {}
    gamma_star = gamma
    for t in topologies:
        if t == "homo":
            reports[t] = Homogeneous().provision(wl, profile, mdl)
        elif t == "pool":
            reports[t] = TwoPool(b_short=b_short).provision(wl, profile, mdl)
        elif t == "fleetopt":
            if gamma is None:
                gamma_star, rep = optimize_gamma(wl, profile, mdl, b_short)
                reports[t] = rep
            else:
                reports[t] = FleetOpt(b_short=b_short, gamma=gamma) \
                    .provision(wl, profile, mdl)
        else:
            raise ValueError(f"unknown topology {t!r}")
    return FleetAnalysis(workload=wl.name, gpu=profile.chip.name,
                         reports=reports, gamma_star=gamma_star)
