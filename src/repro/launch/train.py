"""Training launcher.

CPU demo scale by default (this container); on a real TPU slice, pass
--mesh to pjit the train step over (data, model) with the sharding rules of
repro.launch.sharding — the same code path the dry-run AOT-verifies at
(16,16) and (2,16,16).

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --preset 100m \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import batch_iterator
from repro.models.spec import ArchConfig
from repro.training import AdamW, save_checkpoint, train_loop

PRESETS = {
    # ~paper-scale demo: ~100M params (the deliverable-b training driver)
    "100m": dict(d_model=768, n_repeat=6, d_ff=2048, vocab=32000,
                 n_heads=12, n_kv_heads=4, head_dim=64),
    "10m": dict(d_model=256, n_repeat=4, d_ff=704, vocab=8192,
                n_heads=4, n_kv_heads=2, head_dim=64),
    "smoke": None,   # the arch's reduced() variant
}


def scaled_config(arch: str, preset: str) -> ArchConfig:
    base = get_config(arch)
    if preset == "smoke" or PRESETS.get(preset) is None:
        return base.reduced()
    p = dict(PRESETS[preset])
    if base.n_experts:
        p["moe_d_ff"] = p["d_ff"] // 4
        p["n_experts"], p["top_k"] = 8, 2
        p["capacity_factor"] = 4.0
    if base.ssm_state:
        p["ssm_state"] = 64
    return dataclasses.replace(base, name=f"{base.name}-{preset}",
                               dtype="float32", **p)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = scaled_config(args.arch, args.preset)
    print(f"config {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{cfg.n_layers} layers")
    it = ({k: jnp.asarray(v) for k, v in b.items()}
          for b in batch_iterator(cfg, batch=args.batch, seq=args.seq))
    t0 = time.time()

    def log(step, m):
        tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
        print(f"step {step:4d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.2f} lr {m['lr']:.2e} "
              f"({tok_s:.0f} tok/s)", flush=True)

    params, _, hist = train_loop(
        cfg, steps=args.steps, batch_iter=it,
        opt=AdamW(lr=args.lr, total_steps=args.steps), log_every=10,
        callback=log)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, step=args.steps)
        print("checkpoint:", args.ckpt)


if __name__ == "__main__":
    main()
