"""Launch layer: production mesh, sharding rules, input shapes, dry-run,
train/serve drivers.  NOTE: import repro.launch.dryrun only in a fresh
process — it sets XLA_FLAGS for 512 host devices at import time.
"""
from . import hlo_analysis, mesh, shapes, sharding  # noqa: F401
from .mesh import data_axes, make_local_mesh, make_production_mesh
from .shapes import SHAPES, InputShape, applicability, input_specs

__all__ = ["hlo_analysis", "mesh", "shapes", "sharding", "data_axes",
           "make_local_mesh", "make_production_mesh", "SHAPES", "InputShape",
           "applicability", "input_specs"]
