"""Sharding rules: parameter/optimizer/cache/batch PartitionSpecs.

Scheme (DESIGN.md §6): 2D tensor parallelism —
  * `model` axis: attention heads, ffn hidden, experts (when divisible),
    vocab;
  * `data` axis: FSDP over the d_model dimension of large matrices + batch;
  * `pod` axis: pure data parallelism (batch), params replicated per pod.

Rules are name-based over the pytree paths produced by models.model.
Any dimension that does not divide evenly by its axis falls back to
replication (checked explicitly — GSPMD would otherwise error).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.spec import ArchConfig

from .mesh import data_axes


def _fits(dim: int, mesh, axis) -> bool:
    if axis is None:
        return True
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


def _spec_for_param(path: str, shape: tuple, cfg: ArchConfig, mesh,
                    fsdp: Optional[str] = "data") -> P:
    """Choose a spec by parameter name, then drop non-dividing axes.

    fsdp=None (serving mode) keeps weights model-sharded only: decode is
    executed every iteration, so FSDP's per-use weight all-gather costs
    ~params/model_shards bytes of ICI per step — §Perf iteration 1 measured
    it at 97 % of yi-6b decode_32k's collective term.
    """
    name = path.split("/")[-1]
    stacked = "unit/" in path   # scan-stacked leaves: leading n_repeat axis
    dims = list(shape[1:]) if stacked else list(shape)

    tp = "model"

    def spec(*ax):
        ax = list(ax)
        # pad to rank
        while len(ax) < len(dims):
            ax.append(None)
        # drop axes that don't divide
        ax = [a if _fits(dims[i], mesh, a) else None
              for i, a in enumerate(ax)]
        return P(*([None] + ax if stacked else ax))

    if len(dims) == 0:
        return P()
    if name in ("embed",):
        # vocab replicated, d_model sharded: the token-id gather stays
        # local.  Sharding vocab on `model` made GSPMD emit a (B,S,d)-sized
        # masked all-reduce per lookup (§Perf iter 2).
        return spec(None, tp)
    if name in ("lm_head",):
        # vocab on model only: FSDP-sharding d as well makes the CE
        # backward all-gather the full f32 logits (12 GiB/chip on
        # granite-moe train_4k) instead of partial-dot + (B,S,d)
        # all-reduce (§Perf iter 2c).
        return spec(None, tp)
    if name in ("wq", "wk", "wv", "w_up", "w_gate", "Wr", "Wk", "Wv", "Wg",
                "Wk_cm", "Wr_cm", "w_in", "wA"):
        return spec(fsdp, tp)
    if name in ("wo", "w_down", "w_out", "Wo", "Wv_cm", "wB"):
        return spec(tp, fsdp)
    if name == "router":
        return spec(fsdp, None)
    if name in ("conv_w", "conv_b"):
        return spec(None, tp) if len(dims) == 2 else spec(tp)
    if name in ("A_log", "dt_bias", "D"):
        return spec(tp)
    if name in ("w0", "u"):
        return spec(tp, None)
    if name in ("norm_y",):
        return spec(tp)
    return spec()  # norms, maa, biases: replicated


def _spec_for_moe_param(path: str, shape: tuple, cfg: ArchConfig, mesh,
                        fsdp: Optional[str] = "data") -> Optional[P]:
    """MoE expert tensors: expert-parallel when E divides the model axis,
    otherwise TP inside each expert's ffn dim.

    In the EP case the expert weights are NOT additionally FSDP-sharded:
    §Perf iteration 2 showed the per-layer data-axis gathers + the
    d-contraction partial-sum all-reduces dominate granite-moe train_4k's
    collective term, while EP-only expert storage costs just
    E/model * 3*d*fe bytes per chip (~6 MB/layer for granite)."""
    name = path.split("/")[-1]
    if name not in ("w_gate", "w_up", "w_down") or "_moe" not in path:
        return None
    stacked = "unit/" in path
    E = cfg.n_experts
    ep = _fits(E, mesh, "model")
    if name in ("w_gate", "w_up"):          # (E, d, fe)
        body = P("model", None, None) if ep else P(None, fsdp, "model")
    else:                                    # (E, fe, d)
        body = P("model", None, None) if ep else P(None, "model", fsdp)
    # check remaining dims divide
    dims = shape[1:] if stacked else shape
    fixed = []
    for d_, a in zip(dims, body):
        fixed.append(a if _fits(d_, mesh, a) else None)
    return P(*([None] + fixed if stacked else fixed))


def param_specs(cfg: ArchConfig, params_shape: Any, mesh,
                *, mode: str = "train") -> Any:
    """PartitionSpec pytree matching a (possibly abstract) params tree.

    mode="train": FSDP over `data` + TP over `model` (optimizer state is
    16x params — sharding it is non-negotiable).
    mode="serve": TP over `model` only; weights replicated across the
    data-parallel axis so the per-step FSDP all-gather disappears
    (§Perf iteration 1).

    Small-model exception (§Perf iteration 3b): under ~8B params the
    optimizer state fits replicated-per-model-shard (~1 GiB/chip at 1.6B),
    while FSDP's contraction-dim weight sharding makes GSPMD emit
    activation-shaped all-gathers/all-reduces around every d x d matmul —
    5x per RWKV time-mix.  FSDP only pays for itself when param+opt memory
    actually needs the data axis."""
    if mode == "train":
        fsdp = "data" if cfg.param_count() > 8e9 else None
    else:
        # serve: drop FSDP only when the TP-sharded weights fit comfortably
        # replicated per data-rank (2 bytes/param / model-axis); command-r
        # (13 GiB/chip) and grok (39 GiB/chip) keep FSDP + per-step gather.
        per_chip = 2.0 * cfg.param_count() / max(mesh.shape.get("model", 1),
                                                 1)
        fsdp = None if per_chip < 6e9 else "data"
    if mode == "train" and pure_dp(cfg, mesh):
        # §Perf iteration 3d: at <=2-3B params, 16-way TP pays ~12
        # activation-shaped collectives per layer (every dot_general fwd
        # + bwd) while the whole param+opt state fits on one chip.  Map
        # the model axis to extra *data* parallelism instead: weights
        # replicated, batch sharded 256-way, and the only collective left
        # is the once-per-step gradient all-reduce.
        return jax.tree.map(lambda _: P(), params_shape)

    def one(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path_elems)
        moe = _spec_for_moe_param(path, leaf.shape, cfg, mesh, fsdp=fsdp)
        return moe if moe is not None \
            else _spec_for_param(path, leaf.shape, cfg, mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cfg: ArchConfig, cache_shape: Any, mesh,
                *, batch: int) -> Any:
    """Decode-cache specs: batch on data axes; KV heads on model when they
    divide, else the cache *sequence* dim on model (context parallelism)."""
    dp = data_axes(mesh)
    dp_ax = dp if _fits(batch, mesh, dp) else (
        dp[-1] if _fits(batch, mesh, dp[-1]) else None)

    def one(path_elems, leaf):
        path = "/".join(str(getattr(p, "key", p)) for p in path_elems)
        shp = leaf.shape          # leading axis = n_repeat
        if "wkv" in path or "ssm" in path or "conv" in path \
                or "shift" in path:
            return P(None, dp_ax)             # O(1) state: batch only
        # attention kv: (R, B, T, K, hd)
        T, K = shp[2], shp[3]
        k_ax = "model" if _fits(K, mesh, "model") else None
        t_ax = None
        if dp_ax is None:
            # batch unshardable (long_500k): context parallelism on `data`
            # (+ `model` too when KV heads can't use it)
            if k_ax is None and _fits(T, mesh, ("data", "model")):
                t_ax = ("data", "model")
            elif _fits(T, mesh, ("data",)):
                t_ax = "data"
        elif k_ax is None and _fits(T, mesh, ("model",)):
            t_ax = "model"                    # seq-sharded KV (K < model)
        return P(None, dp_ax, t_ax, k_ax, None)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def pure_dp(cfg: ArchConfig, mesh, threshold: float = 3e9) -> bool:
    """True when a training model is small enough to replicate entirely
    (params + f32 optimizer state under ~half an accelerator's HBM) and the
    mesh should be used as pure data parallelism (§Perf iteration 3d)."""
    return cfg.param_count() < threshold


def batch_specs(mesh, batch: int, *, wide: bool = False) -> P:
    dp = data_axes(mesh)
    if wide:
        axes = tuple(dp) + ("model",)
        if _fits(batch, mesh, axes):
            return P(axes)
    if _fits(batch, mesh, dp):
        return P(dp)
    if _fits(batch, mesh, dp[-1]):
        return P(dp[-1])
    return P(None)


def to_shardings(specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
