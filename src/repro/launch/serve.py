"""Serving launcher: context-length-routed pools over a real model.

Runs the paper's technique end-to-end at CPU demo scale: requests drawn
from a reconstructed trace are routed (homo / two_pool / fleetopt) into
continuous-batching PoolEngines; every decode iteration is charged
P(b) * tau, and the fleet report compares measured tok/W across topologies
— the Table-3 experiment as an executing system.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 24
"""
from __future__ import annotations

import argparse
import json
import math

import jax
import numpy as np

from repro.configs import get_config
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import WORKLOADS
from repro.models import model as M
from repro.serving import (ContextRouter, PoolEngine, RouterPolicy,
                           synthetic_requests)


def build_router(cfg, params, policy: str, *, b_short: int, window_long: int,
                 profile, p99_output: int = 8) -> ContextRouter:
    if policy == "homo":
        pools = {"long": PoolEngine(cfg, params, window=window_long,
                                    profile=profile, n_slots=4, name="long")}
        return ContextRouter(pools, RouterPolicy(
            kind="homo", ladder=[("long", math.inf)]))
    pools = {
        "short": PoolEngine(cfg, params, window=2 * b_short, profile=profile,
                            n_slots=16, name="short"),
        "long": PoolEngine(cfg, params, window=window_long, profile=profile,
                           n_slots=4, name="long"),
    }
    # explicit admission ladders (the TopologySpec compilation of each
    # legacy kind): two_pool admits at b_short on the conservative
    # prompt + p99 metric; fleetopt at gamma * b_short on predicted total
    boundary = float(b_short) if policy == "two_pool" \
        else float(int(2.0 * b_short))
    return ContextRouter(pools, RouterPolicy(
        kind=policy, b_short=b_short, gamma=2.0, p99_output=p99_output,
        metric_kind="prompt_plus_p99" if policy == "two_pool"
        else "predicted_total",
        ladder=[("short", boundary), ("long", math.inf)]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--workload", default="azure-conv",
                    choices=list(WORKLOADS))
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--b-short", type=int, default=24)
    ap.add_argument("--window-long", type=int, default=192)
    ap.add_argument("--policies", default="homo,two_pool,fleetopt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    wl = WORKLOADS[args.workload]
    # draw raw trace lengths, then scale the whole distribution into the
    # demo windows (scaling preserves the short/long mix; clipping doesn't)
    lens = wl.sample_requests(args.requests, seed=0).astype(float)
    scale = (args.window_long - 8) / float(np.quantile(lens.sum(1), 0.99))
    rng = np.random.default_rng(7)
    base = []
    from repro.serving import Request
    for i, (p, o) in enumerate(lens * scale):
        p = int(np.clip(p, 1, args.window_long - 9))
        o = int(np.clip(o, 1, args.window_long - 8 - p))
        base.append(Request(rid=i,
                            prompt=rng.integers(0, cfg.vocab, size=p),
                            max_new_tokens=o))

    p99_out = int(np.quantile([r.max_new_tokens for r in base], 0.99)) + 1
    results = {}
    for policy in args.policies.split(","):
        import copy
        reqs = copy.deepcopy(base)
        router = build_router(cfg, params, policy, b_short=args.b_short,
                              window_long=args.window_long,
                              profile=H100_LLAMA70B, p99_output=p99_out)
        rep = router.run(reqs, max_iters=20000)
        results[policy] = rep
        print(f"\n== {policy} ==")
        for name, stats in rep.items():
            print(" ", name, json.dumps(stats))
    if {"homo", "fleetopt"} <= results.keys():
        gain = (results["fleetopt"]["fleet"]["tok_per_watt"]
                / results["homo"]["fleet"]["tok_per_watt"])
        print(f"\nFleetOpt vs Homo tok/W gain: {gain:.2f}x "
              "(paper fleet-scale: ~2.5x)")


if __name__ == "__main__":
    main()
