"""Compiled-HLO analysis: collective bytes + roofline terms.

collective_bytes is not in cost_analysis(); we parse the compiled HLO text
and sum the *result* buffer sizes of every collective op (all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).  Result
sizes are per-participant, i.e. bytes that cross the interconnect per chip
per step (all-gather result counts gathered bytes received; all-reduce
counts the reduced buffer once — a ring all-reduce moves ~2x that, which we
fold into the ring factor below).

Roofline terms (per the brief; TPU v5e constants from core.hardware):
  compute    = HLO_FLOPs / peak_FLOPs            (per chip, cost_analysis
                                                  is already per-device)
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes * ring_factor / ICI_bw
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from repro.core.hardware import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_FLOPS

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shape of an op line:  %x = bf16[8,128]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+(" + "|".join(_COLLECTIVES)
    + r")")
# tuple results:  %x = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+(" + "|".join(_COLLECTIVES) + r")")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dtype, dims)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ring factors: bytes actually moved per chip relative to result bytes
_RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-chip HLO FLOPs
    hbm_bytes: float             # per-chip bytes accessed
    coll_bytes: float            # per-chip interconnect bytes (ring-adjusted)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    per_collective: Dict[str, int]

    def row(self) -> dict:
        return dict(flops=self.flops, hbm_bytes=self.hbm_bytes,
                    coll_bytes=self.coll_bytes,
                    compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, dominant=self.dominant)


def roofline_from_counts(flops: float, hbm_bytes: float,
                         per_collective: Dict[str, int],
                         *, peak_flops: float = V5E_PEAK_FLOPS,
                         hbm_bw: float = V5E_HBM_BW,
                         ici_bw: float = V5E_ICI_BW) -> "RooflineTerms":
    """Roofline terms from already-corrected per-chip counts."""
    adj = sum(per_collective.get(k, 0) * _RING_FACTOR[k]
              for k in _COLLECTIVES)
    terms = dict(compute_s=flops / peak_flops, memory_s=hbm_bytes / hbm_bw,
                 collective_s=adj / ici_bw)
    dominant = max(terms, key=terms.get)
    return RooflineTerms(flops=flops, hbm_bytes=hbm_bytes, coll_bytes=adj,
                         dominant=dominant.replace("_s", ""),
                         per_collective=dict(per_collective), **terms)


def roofline_terms(cost: dict, hlo_text: str,
                   *, peak_flops: float = V5E_PEAK_FLOPS,
                   hbm_bw: float = V5E_HBM_BW,
                   ici_bw: float = V5E_ICI_BW) -> RooflineTerms:
    coll = collective_bytes(hlo_text)
    adj = sum(coll[k] * _RING_FACTOR[k] for k in _COLLECTIVES)
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    terms = dict(compute_s=flops / peak_flops, memory_s=hbm / hbm_bw,
                 collective_s=adj / ici_bw)
    dominant = max(terms, key=terms.get)
    return RooflineTerms(flops=flops, hbm_bytes=hbm, coll_bytes=adj,
                         dominant=dominant.replace("_s", ""),
                         per_collective=coll, **terms)
