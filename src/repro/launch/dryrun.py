import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh).

This proves the distribution config is coherent without hardware: a
sharding mismatch, compile-time OOM, or unsupported collective is a bug.
Results (memory_analysis, cost_analysis, collective schedule, roofline
terms) are written to benchmarks/results/dryrun/*.json and consumed by
EXPERIMENTS.md §Dry-run/§Roofline and the perf loop.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch import hlo_analysis
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models.compat import cost_analysis as compat_cost_analysis, set_mesh
from repro.launch.shapes import SHAPES, applicability, input_specs
from repro.launch.sharding import (batch_specs, cache_specs, param_specs,
                                   pure_dp, to_shardings)
from repro.models import model as M
from repro.training.optimizer import AdamW

from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "benchmarks" / "results" / "dryrun"


def _shaped(tree, shardings):
    """Attach shardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def build_step(arch: str, shape_name: str, mesh, cfg=None):
    """Returns (step_fn, example_args_abstract, donate) for one pair."""
    cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    skip = applicability(cfg, shape)
    if skip:
        raise SkipPair(skip)
    dp = data_axes(mesh)
    pspecs = param_specs(cfg, jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg)), mesh,
        mode="train" if shape.kind == "train" else "serve")
    pshard = to_shardings(pspecs, mesh)
    params_abs = _shaped(jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg)), pshard)
    specs = input_specs(cfg, shape)
    wide = shape.kind == "train" and pure_dp(cfg, mesh)
    from repro.models import common as MC
    MC.BATCH_AXES_OVERRIDE = (("pod", "data", "model") if wide else None)
    # sequence-parallel residuals for large-model training (§Perf D3)
    M.SEQ_SHARD_RESIDUAL = (shape.kind == "train"
                            and cfg.param_count() > 3e10)
    bspec = batch_specs(mesh, shape.global_batch, wide=wide)

    def shard_tok(t):
        return jax.ShapeDtypeStruct(
            t.shape, t.dtype, sharding=NamedSharding(mesh, P(*(
                [bspec[0] if bspec else None]
                + [None] * (len(t.shape) - 1)))))

    if shape.kind == "train":
        opt = AdamW(total_steps=1000)
        opt_abs_raw = jax.eval_shape(
            lambda p: opt.init(p), jax.eval_shape(
                lambda: M.init_params(jax.random.PRNGKey(0), cfg)))
        ospec = type(opt_abs_raw)(step=P(),
                                  mu=pspecs, nu=pspecs)
        oshard = to_shardings(ospec, mesh)
        opt_abs = _shaped(opt_abs_raw, oshard)
        batch_abs = {k: shard_tok(v) for k, v in specs.items()}

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, batch, remat=True))(params)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss

        fn = jax.jit(train_step,
                     out_shardings=(pshard, oshard,
                                    NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch_abs)

    if shape.kind == "prefill":
        batch_abs = {k: shard_tok(v) for k, v in specs.items()}

        def prefill_step(params, batch):
            logits, cache, _ = M.forward(params, cfg, batch, mode="prefill")
            return logits, cache

        cshape = jax.eval_shape(
            lambda p, b: prefill_step(p, b)[1], params_abs, batch_abs)
        cshard = to_shardings(
            cache_specs(cfg, cshape, mesh, batch=shape.global_batch), mesh)
        fn = jax.jit(prefill_step,
                     out_shardings=(NamedSharding(mesh, P(bspec[0] if bspec
                                                          else None)),
                                    cshard))
        return fn, (params_abs, batch_abs)

    # decode / serve_step
    cshard = to_shardings(
        cache_specs(cfg, specs["cache"], mesh, batch=shape.global_batch),
        mesh)
    cache_abs = _shaped(specs["cache"], cshard)
    tok_abs = shard_tok(specs["tokens"])
    pos_abs = shard_tok(specs["pos"])

    def serve_step(params, tokens, cache, pos):
        logits, new_cache = M.decode_step(params, cfg, tokens, cache, pos)
        return logits, new_cache

    fn = jax.jit(serve_step,
                 out_shardings=(NamedSharding(mesh, P(bspec[0] if bspec
                                                      else None)),
                                cshard),
                 donate_argnums=(2,))
    return fn, (params_abs, tok_abs, cache_abs, pos_abs)


class SkipPair(Exception):
    pass


def _cost_vector(compiled) -> dict:
    cost = compat_cost_analysis(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return dict(flops=float(cost.get("flops", 0.0)),
                bytes_accessed=float(cost.get("bytes accessed", 0.0)),
                transcendentals=float(cost.get("transcendentals", 0.0)),
                collectives=coll)


def _extrapolate(c1: dict, c2: dict, repeats: int) -> dict:
    """XLA's HloCostAnalysis counts a while-loop body ONCE regardless of
    trip count, so a scanned layer stack under-reports by ~n_repeat.  We
    compile n_repeat=1 and n_repeat=2 variants and extrapolate
    cost(R) = cost(1) + (R-1) * (cost(2) - cost(1)) — exact for costs
    affine in the repeat count (all of ours are)."""
    out = {}
    for k in ("flops", "bytes_accessed", "transcendentals"):
        d = c2[k] - c1[k]
        out[k] = c1[k] + (repeats - 1) * d
    out["collectives"] = {
        k: int(c1["collectives"][k]
               + (repeats - 1) * (c2["collectives"][k]
                                  - c1["collectives"][k]))
        for k in c1["collectives"]}
    return out


def _corrected_cost(arch: str, shape_name: str, mesh, cfg) -> dict:
    import contextlib
    import dataclasses

    @contextlib.contextmanager
    def unrolled():
        old = M.SCAN_UNROLL
        M.SCAN_UNROLL = True   # no while loop -> every repeat is counted
        try:
            yield
        finally:
            M.SCAN_UNROLL = old

    costs = []
    with unrolled():
        for k in (1, 2):
            enc = (dataclasses.replace(cfg.encoder, n_layers=k)
                   if cfg.encoder is not None else None)
            cfg_k = dataclasses.replace(cfg, n_repeat=k, encoder=enc)
            fn, args = build_step(arch, shape_name, mesh, cfg=cfg_k)
            costs.append(_cost_vector(fn.lower(*args).compile()))
    # NOTE: whisper's encoder (24L) scales with the same factor as its
    # decoder n_repeat (24), so one extrapolation covers both stacks.
    return _extrapolate(costs[0], costs[1], cfg.n_repeat)


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    label = f"{arch}_{shape_name}_{mesh_name}"
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with set_mesh(mesh):   # ambient mesh for constrain()
            cfg = get_config(arch)
            fn, args = build_step(arch, shape_name, mesh, cfg=cfg)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            raw = _cost_vector(compiled)
            # trip-count correction (see _extrapolate): two small compiles
            cost = _corrected_cost(arch, shape_name, mesh, cfg)
        terms = hlo_analysis.roofline_from_counts(
            cost["flops"], cost["bytes_accessed"], cost["collectives"])
        result = dict(
            arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
            compile_s=round(time.time() - t0, 1),
            bytes_per_device=dict(
                arguments=mem.argument_size_in_bytes,
                outputs=mem.output_size_in_bytes,
                temps=mem.temp_size_in_bytes,
                aliased=mem.alias_size_in_bytes,
                peak_estimate=mem.argument_size_in_bytes
                + mem.temp_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes,
            ),
            cost=dict(flops=cost["flops"],
                      bytes_accessed=cost["bytes_accessed"],
                      transcendentals=cost["transcendentals"],
                      scan_body_raw=raw),
            roofline=terms.row(),
            collectives=cost["collectives"],
        )
    except SkipPair as e:
        result = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                      status="skip", reason=str(e))
    except Exception as e:  # a failure here is a bug in the system
        result = dict(arch=arch, shape=shape_name, mesh=mesh_name,
                      status="fail", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / f"{label}.json").write_text(
            json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--swa-variants", action="store_true",
                    help="also run -swa variants for long_500k-skipped archs")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                pairs.append((a, s))
                if args.swa_variants and s == "long_500k":
                    cfg = get_config(a)
                    if applicability(cfg, SHAPES[s]) and \
                            cfg.attn_block_count and not cfg.encoder:
                        pairs.append((a + "-swa", s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    for a, s in pairs:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        out = RESULTS_DIR / f"{a}_{s}_{mesh_name}.json"
        if args.skip_existing and out.exists():
            prev = json.loads(out.read_text())
            if prev.get("status") in ("ok", "skip"):
                print(f"[cached] {a} {s} {mesh_name}: {prev['status']}")
                continue
        r = run_pair(a, s, multi_pod=args.multi_pod)
        line = f"{a} {s} {mesh_name}: {r['status']}"
        if r["status"] == "ok":
            bpd = r["bytes_per_device"]["peak_estimate"] / 2**30
            line += (f" | {r['compile_s']}s | {bpd:.2f} GiB/dev | dominant "
                     f"{r['roofline']['dominant']}")
        elif r["status"] == "fail":
            line += f" | {r['error']}"
        else:
            line += f" | {r['reason']}"
        print(line, flush=True)


if __name__ == "__main__":
    main()
