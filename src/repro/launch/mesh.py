"""Production mesh builders.

Importing this module never touches jax device state; the dry-run entry
point (dryrun.py) sets XLA_FLAGS before any jax import so the 512 host
placeholder devices exist when make_mesh is first called.
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    import jax
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh ('pod' folds into data-parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
