"""Production mesh builders.

Importing this module never touches jax device state; the dry-run entry
point (dryrun.py) sets XLA_FLAGS before any jax import so the 512 host
placeholder devices exist when make_mesh is first called.
"""
from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    from repro.models.compat import make_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh over whatever devices exist (tests / CPU demos)."""
    from repro.models.compat import make_mesh
    return make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh ('pod' folds into data-parallel)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
