"""The four assigned input shapes + per-arch input_specs (ShapeDtypeStruct
stand-ins: weak-type-correct, shardable, zero allocation).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; sub-quadratic
                                                 attention only (DESIGN.md §5)

Applicability: long_500k runs for SSM / hybrid / native-SWA archs; dense /
MoE / VLM full-attention archs run it only as their explicit `-swa` variant;
whisper (enc-dec, 448-token decode horizon) skips it entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.spec import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def applicability(cfg: ArchConfig, shape: InputShape) -> Optional[str]:
    """None if the (arch, shape) pair runs; else the skip reason."""
    if shape.name != "long_500k":
        return None
    if cfg.encoder is not None:
        return "enc-dec full attention; whisper decode horizon is 448 tokens"
    sub_quadratic = (cfg.attn_block_count == 0          # pure SSM
                     or cfg.arch_type == "hybrid"        # Zamba2
                     or cfg.swa_window > 0)              # native / -swa SWA
    if not sub_quadratic:
        return ("full-attention KV at 524288 tokens; run the '-swa' variant "
                "config instead")
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, object]:
    """Abstract model inputs for one shape (no device allocation).

    train:   {tokens, labels [, patches, frames]}
    prefill: {tokens [, patches, frames]}
    decode:  {tokens (B,1), cache, pos}
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        n_text = S - (cfg.n_patches or 0)
        out = {"tokens": _sds((B, n_text), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = _sds((B, n_text), jnp.int32)
        if cfg.n_patches:
            out["patches"] = _sds((B, cfg.n_patches, cfg.d_model),
                                  jnp.bfloat16)
        if cfg.encoder is not None:
            out["frames"] = _sds((B, min(cfg.encoder.n_frames, S // 4),
                                  cfg.d_model), jnp.bfloat16)
        return out
    # decode: one token against a seq_len cache
    enc_frames = min(cfg.encoder.n_frames, S // 4) if cfg.encoder else 0
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, S, enc_frames=enc_frames))
    return {"tokens": _sds((B, 1), jnp.int32),
            "cache": cache,
            "pos": _sds((B,), jnp.int32)}
