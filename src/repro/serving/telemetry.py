"""FleetScope recording layer: request-lifecycle + charge tracing.

`TraceRecorder` is the single sink every engine and meter emits through
(tools/lint_invariants.py enforces that no ad-hoc print/list telemetry
creeps into the serving hot loops).  It is strictly opt-in: engines hold
`trace = None` by default and every hook is an `is not None` guard
around pure reads, so with telemetry off the committed baselines
reproduce bit-for-bit (the zero-overhead-when-off guarantee, DESIGN.md
§14).

Two channels, two cost classes:

* **events** — per-request lifecycle edges `(t, rid, kind, pool,
  instance)` appended by the engines' existing per-event paths (admit,
  first token, handoff, escalate, overflow, complete) and by FleetSim's
  router (arrive, route).  O(1) python tuples per request edge at both
  levels.  The jitted JAX drain emits nothing; `JaxPoolEngine._finalize`
  replays its event tape through the same hooks, so the compiled loop
  stays untouched and the *canonically ordered* stream (sorted by
  `(t, rid, kind)` — engines append in different global orders) matches
  the numpy engines: bit-identical between the scalar and SoA engines,
  to the rel-1e-9 parity tolerance per request for JAX (device
  accumulation order moves event times by ulps).
* **charges** (level="detail" only) — vectorized array-chunk appends
  from the `EnergyMeter`/`MeterBank` charge methods: one tuple per
  charge call carrying the *same* float64 energy values the meters
  accumulate.  Summing the channel therefore reconciles with the meter
  lifetime totals to float rounding (`reconcile_energy`), which is the
  <0.1% gate `benchmarks/fleet_trace_report.py` enforces per Table F
  cell.  JAX engines contribute no charge chunks (their meters are
  copied back post-hoc, not charged incrementally) — the trace report's
  cells run the numpy engines, which FleetSim requires under
  autoscaling anyway.

`build_timeline` bins both channels onto a fixed sim-time grid
(`core.timeline.MetricsTimeline`); `to_perfetto` renders events as one
Perfetto track per pool/instance with power/occupancy counter tracks.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.timeline import (
    EV_ADMIT, EV_ARRIVE, EV_ESCALATE, EV_FIRST_TOKEN, EV_OVERFLOW,
    EV_PREFILL, EV_ROUTE, EVENT_NAMES, LIFECYCLE_KINDS, MetricsTimeline,
    bin_intervals, chrome_trace_doc, counter_event, empty_series,
    instant_event, meta_event, span_event)

__all__ = ["TraceRecorder", "build_timeline", "to_perfetto",
           "phase_totals", "reconcile_energy"]


def _chunk_total(ref, val) -> float:
    """Total deposited by one charge chunk: scalar values replicate
    across the rows they were applied to (numpy fancy-index `+= e`
    broadcasts), arrays sum directly."""
    v = np.asarray(val, np.float64)
    if v.ndim == 0:
        r = np.asarray(ref, np.float64)
        return float(v) * (r.size if r.ndim else 1)
    return float(v.sum())


class TraceRecorder:
    """Append-only event/charge sink shared by every engine of a run.

    level="lifecycle": per-request edges only (cheap enough to ride the
    full quick bench inside the 1.5x wall budget).
    level="detail": adds admit/prefill-chunk events plus the vectorized
    charge and occupancy channels that power `build_timeline`,
    per-phase energy reconciliation, and the Perfetto counter tracks.
    """

    __slots__ = ("level", "detail", "events", "charges", "occupancy",
                 "pool_names", "_pool_ids", "pool_instances")

    def __init__(self, level: str = "lifecycle"):
        if level not in ("lifecycle", "detail"):
            raise ValueError(f"unknown trace level {level!r} "
                             "(expected 'lifecycle' or 'detail')")
        self.level = level
        self.detail = level == "detail"
        # (t, rid, kind, pool_id, instance) — tuple order IS the
        # canonical sort key prefix
        self.events: List[Tuple[float, int, int, int, int]] = []
        # (pool_id, phase, instance_rows, start, dur, joules, tokens,
        #  dispatch) — scalars or row-aligned arrays, appended verbatim
        self.charges: list = []
        # (pool_id, instance_rows, start, dur, n_occupied)
        self.occupancy: list = []
        self.pool_names: List[str] = []
        self._pool_ids: Dict[str, int] = {}
        self.pool_instances: Dict[int, int] = {}

    # --- recording ------------------------------------------------------

    def pool_id(self, name: str, instances: Optional[int] = None) -> int:
        pid = self._pool_ids.get(name)
        if pid is None:
            pid = self._pool_ids[name] = len(self.pool_names)
            self.pool_names.append(name)
        if instances is not None:
            self.pool_instances[pid] = int(instances)
        return pid

    def event(self, kind: int, rid: int, pool: int, instance: int,
              t: float) -> None:
        self.events.append((t, rid, kind, pool, instance))

    def charge(self, pool: int, phase: str, instance, start, dur, joules,
               tokens=None, dispatch=None) -> None:
        self.charges.append((pool, phase, instance, start, dur, joules,
                             tokens, dispatch))

    def occupancy_sample(self, pool: int, instance, start, dur,
                         n_occupied) -> None:
        self.occupancy.append((pool, instance, start, dur, n_occupied))

    # --- views ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self.events)

    def sorted_events(self, lifecycle_only: bool = False) \
            -> List[Tuple[float, int, int, int, int]]:
        """Events in canonical `(t, rid, kind)` order.  Engines append
        in different global orders (scalar per-instance loops, SoA
        lockstep, JAX terminal-tape replay); event *times* are
        bit-identical between the numpy engines, so this order is their
        cross-engine golden stream (JAX times agree to the rel-1e-9
        parity tolerance — compare per request, not globally sorted)."""
        evs = self.events
        if lifecycle_only:
            evs = [e for e in evs if e[2] in LIFECYCLE_KINDS]
        return sorted(evs)

    def golden_stream(self) -> List[Tuple[float, int, str, str, int]]:
        """Canonical lifecycle stream with names resolved — the unit the
        cross-engine parity tests compare."""
        return [(t, rid, EVENT_NAMES[kind], self.pool_names[pool], inst)
                for t, rid, kind, pool, inst
                in self.sorted_events(lifecycle_only=True)]

    def counts(self) -> Dict[str, int]:
        out = {name: 0 for name in EVENT_NAMES}
        for _, _, kind, _, _ in self.events:
            out[EVENT_NAMES[kind]] += 1
        return out

    def energy_by_phase(self, pool: Optional[int] = None) \
            -> Dict[str, float]:
        """Per-phase joules summed from the charge channel (lifetime,
        i.e. comparable to the meters' un-windowed totals).  `dispatch`
        is the MoE all-to-all share *inside* decode, never additive."""
        out = {"decode": 0.0, "prefill": 0.0, "idle": 0.0,
               "handoff": 0.0, "dispatch": 0.0, "total": 0.0}
        for p, phase, _, start, _, joules, _, dispatch in self.charges:
            if pool is not None and p != pool:
                continue
            e = _chunk_total(start, joules)
            out[phase] += e
            out["total"] += e
            if dispatch is not None:
                out["dispatch"] += _chunk_total(start, dispatch)
        return out


# --- meter-side totals + reconciliation ---------------------------------

def phase_totals(meters: Iterable) -> Dict[str, float]:
    """Lifetime per-phase joules summed over `EnergyMeter`/`MeterBank`
    objects.  Decode is the residual by construction (serving.energy
    keeps no separate decode accumulator): decode = total - prefill -
    idle - handoff; dispatch rides inside decode."""
    tot = {"total": 0.0, "prefill": 0.0, "idle": 0.0, "handoff": 0.0,
           "dispatch": 0.0}
    for m in meters:
        tot["total"] += float(np.sum(m.joules))
        tot["prefill"] += float(np.sum(m.prefill_joules))
        tot["idle"] += float(np.sum(m.idle_joules))
        tot["handoff"] += float(np.sum(m.handoff_joules))
        tot["dispatch"] += float(np.sum(m.dispatch_joules))
    tot["decode"] = (tot["total"] - tot["prefill"] - tot["idle"]
                     - tot["handoff"])
    return tot


def reconcile_energy(rec: TraceRecorder, meters: Iterable) \
        -> Dict[str, dict]:
    """Per-phase {trace, meter, rel_err} comparing the charge channel
    against the meters' lifetime totals.  The hooks record the *same*
    float64 values the meters accumulate, so rel_err is float-rounding
    small; the trace report gates every phase at <0.1%."""
    trace = rec.energy_by_phase()
    meter = phase_totals(meters)
    out = {}
    for phase in ("total", "decode", "prefill", "idle", "handoff",
                  "dispatch"):
        t, m = trace[phase], meter[phase]
        denom = max(abs(m), 1e-12)
        out[phase] = {"trace_j": t, "meter_j": m,
                      "rel_err": abs(t - m) / denom if (t or m) else 0.0}
    return out


# --- timeline construction ----------------------------------------------

_PHASE_SERIES = {"decode": "decode_j", "prefill": "prefill_j",
                 "idle": "idle_j", "handoff": "handoff_j"}


def build_timeline(rec: TraceRecorder, *, t0: float = 0.0,
                   t1: Optional[float] = None, n_bins: int = 96,
                   schedules: Optional[dict] = None) -> MetricsTimeline:
    """Bin both recorder channels onto a fixed [t0, t1] grid.

    `schedules` maps pool name -> `serving.autoscale.InstanceSchedule`;
    pools without one get their registered static instance count as a
    flat online curve.  Queue depth needs the detail-level ADMIT events
    (route enqueues, admit dequeues) — without them the series stays
    zero rather than counting a queue that never drains.
    """
    if t1 is None:
        t1 = t0
        for _, _, _, start, dur, _, _, _ in rec.charges:
            s = np.asarray(start, np.float64)
            d = np.asarray(dur, np.float64)
            if s.size:
                t1 = max(t1, float(np.max(s + d)))
        for t, _, _, _, _ in rec.events:
            t1 = max(t1, t)
        if t1 <= t0:
            t1 = t0 + 1.0
    edges = np.linspace(t0, t1, n_bins + 1)
    bin_s = (t1 - t0) / n_bins
    pools = {name: empty_series(n_bins) for name in rec.pool_names}
    by_id = [pools[name] for name in rec.pool_names]

    for pid, phase, _, start, dur, joules, tokens, dispatch \
            in rec.charges:
        s = by_id[pid]
        bin_intervals(start, dur, joules, edges, s[_PHASE_SERIES[phase]])
        bin_intervals(start, dur, joules, edges, s["joules"])
        if phase == "decode":
            if tokens is not None:
                tok = np.asarray(tokens, np.float64)
                bin_intervals(start, dur, tok, edges, s["tokens"])
                # decoding-population seconds -> mean in-flight per bin
                bin_intervals(start, dur,
                              tok * np.asarray(dur, np.float64),
                              edges, s["inflight"])
            if dispatch is not None:
                bin_intervals(start, dur, dispatch, edges,
                              s["dispatch_j"])

    for pid, _, start, dur, n_occ in rec.occupancy:
        d = np.asarray(dur, np.float64)
        bin_intervals(start, dur, np.asarray(n_occ, np.float64) * d,
                      edges, by_id[pid]["occupancy"])

    # queue depth as a step function sampled at bin centers:
    # ROUTE enqueues (+1), ADMIT dequeues (-1)
    centers = 0.5 * (edges[:-1] + edges[1:])
    routes: Dict[int, list] = {}
    admits: Dict[int, list] = {}
    for t, _, kind, pid, _ in rec.events:
        if kind == EV_ROUTE:
            routes.setdefault(pid, []).append(t)
        elif kind == EV_ADMIT:
            admits.setdefault(pid, []).append(t)
    for pid, rts in routes.items():
        ads = admits.get(pid)
        if not ads:
            continue        # lifecycle level: no dequeue edge recorded
        r = np.sort(np.asarray(rts))
        a = np.sort(np.asarray(ads))
        by_id[pid]["queue_depth"][:] = (
            np.searchsorted(r, centers, side="right")
            - np.searchsorted(a, centers, side="right"))

    for pid, name in enumerate(rec.pool_names):
        sched = (schedules or {}).get(name)
        if sched is not None:
            by_id[pid]["online"][:] = sched.online_at(centers)
        else:
            by_id[pid]["online"][:] = rec.pool_instances.get(pid, 0)
        s = by_id[pid]
        s["watts"] = s["joules"] / bin_s
        s["occupancy"] = s["occupancy"] / bin_s
        s["inflight"] = s["inflight"] / bin_s

    return MetricsTimeline(
        t0=float(t0), t1=float(t1), n_bins=n_bins, pools=pools,
        meta={"level": rec.level, "n_events": len(rec.events),
              "n_charge_chunks": len(rec.charges)})


# --- Perfetto export ----------------------------------------------------

def to_perfetto(rec: TraceRecorder, *, schedules: Optional[dict] = None,
                counter_bins: int = 240) -> dict:
    """Chrome trace-event document: one process per pool, one thread per
    instance, an "X" slice per request visit (queue->terminal) with the
    full edge list in its args, instants for first-token/evictions, and
    per-pool power/occupancy counter tracks when the detail charge
    channel is present.  Load the JSON straight into ui.perfetto.dev."""
    evs: List[dict] = []
    for pid, name in enumerate(rec.pool_names):
        evs.append(meta_event(pid, process_name=name))
    tids_seen = set()

    visits: Dict[Tuple[int, int], list] = {}
    for t, rid, kind, pid, inst in rec.events:
        visits.setdefault((rid, pid), []).append((t, kind, inst))
    for (rid, pid), items in sorted(visits.items()):
        items.sort()
        tid = max(max(i for _, _, i in items), 0)
        tids_seen.add((pid, tid))
        t_first, t_last = items[0][0], items[-1][0]
        kinds = {k for _, k, _ in items}
        if kinds <= {EV_ARRIVE}:     # fleet-track arrival marker
            evs.append(instant_event("arrive", pid, tid, t_first))
            continue
        evs.append(span_event(
            f"r{rid}", pid, tid, t_first, t_last - t_first,
            args={"events": [[EVENT_NAMES[k], round(t, 6)]
                             for t, k, _ in items]}))
        for t, k, _ in items:
            if k in (EV_FIRST_TOKEN, EV_ESCALATE, EV_OVERFLOW):
                evs.append(instant_event(EVENT_NAMES[k], pid, tid, t))
    for pid, tid in sorted(tids_seen):
        evs.append(meta_event(pid, tid=tid,
                              thread_name=f"instance {tid}"))

    if rec.charges or rec.occupancy:
        tl = build_timeline(rec, n_bins=counter_bins,
                            schedules=schedules)
        edges = tl.edges
        for name, series in tl.pools.items():
            pid = rec._pool_ids[name]
            if not (series["joules"].any() or series["occupancy"].any()):
                continue
            for b in range(tl.n_bins):
                evs.append(counter_event(
                    f"{name} power (W)", pid, edges[b],
                    {"watts": series["watts"][b]}))
                evs.append(counter_event(
                    f"{name} occupancy", pid, edges[b],
                    {"slots": series["occupancy"][b],
                     "inflight": series["inflight"][b]}))

    return chrome_trace_doc(evs, meta={"level": rec.level,
                                       "pools": list(rec.pool_names)})
