"""jit/vmap'd scenario-batched twin of the SoA pool engine.

`JaxPoolEngine` extends `serving.soa.BatchedPoolEngine` (which stays the
bit-exact parity oracle against the scalar `PoolEngine`) with a drain that
runs as one compiled XLA program: the (I, S) slot arrays plus the MeterBank
rows become a `lax.while_loop` step over a pytree of arrays, and whole
*scenarios* batch as a leading vmap axis so a grid of fleet configurations
(different chips, misroute rates, dispatch floors, pool counts) drains in
one `jit(vmap(...))` call instead of hundreds of Python step loops.

Layout / padding / masking
  * Queues are frozen to (I, Q) arrays at drain start (FleetSim injects and
    sorts before a pool runs, exactly like the numpy engine's `_freeze`).
  * Ragged dims are padded to the batch max, bucketed to powers of two so
    nearby shapes reuse one executable: padded queue entries carry
    `ready = inf` and sit beyond `qlen`; padded slots are masked by
    `n_slots`; padded instances have `qlen = 0` and never wake up; padded
    scenarios are all-empty clones.  Masked lanes add exactly `+0.0` /
    `+0` to every accumulator, which float64 keeps exact.
  * Per-event Python work (finish / evict / escalate / handoff) moves to
    post-hoc reconstruction: the step logs one terminal event per queue
    entry into (I, Q) out-arrays (kind, time, first-token time, token
    count, step, slot) with `scatter(mode="drop")` masking, and
    `_finalize` replays them in (step, time, slot) order — the numpy
    engine's exact per-category append order — onto the live `Request`
    objects and the numpy `MeterBank`, so FleetSim's cross-pool flow
    (overflow / escalation / KV handoff) is byte-identical downstream.

Parity contract: every meter expression replicates `energy.MeterBank`
operation-for-operation in float64 (`jax.experimental.enable_x64` is
scoped to the drain so the model-mode f32 default is untouched).  The only
divergence is accumulation *order* on multi-slot chunk spills (the numpy
slow path charges sequentially; the kernel sums a masked cumsum), which is
last-ulp noise — the acceptance gate is 0.1% per tok/W cell, the observed
delta is ~1e-12 relative.  The decode-token LCG stream is elided entirely:
token *values* never feed back into any meter or event (the analytical
engines throw them away), except a prefill handoff's first token, which is
a pure function of (rid, seed) and is re-derived at reconstruction.

Not supported (use the numpy oracle): the legacy unchunked immediate-
prefill decode path (`prefill_chunk in (0, None)`), whose admission loop
advances the clock mid-admission, and model mode (cfg/params) — FleetSim
only ever builds chunked analytical pools.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.timeline import (EV_COMPLETE, EV_ESCALATE, EV_FIRST_TOKEN,
                                 EV_HANDOFF, EV_OVERFLOW)

from .engine import _LCG_A, _LCG_C, _NEVER, DrainTruncatedError
from .soa import BatchedPoolEngine

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
except ImportError:                                    # pragma: no cover
    jax = None          # numpy-only environments (the perf-regression CI
    #                     job): constructing a JaxPoolEngine raises.

_EV_NONE, _EV_DONE, _EV_OVERFLOW, _EV_ESCALATE, _EV_HANDOFF = 0, 1, 2, 3, 4

# per-instance accumulator rows the device fills and _finalize copies back
_METER_KEYS = ("joules", "idle_joules", "prefill_joules", "dispatch_joules",
               "m_joules", "m_prefill_joules", "m_idle_joules",
               "m_dispatch_joules", "tokens", "m_tokens", "prefill_tokens")


def _bucket(n: int, floor: int = 8) -> int:
    """Round a ragged dim up to a power of two (>= floor) so stacked
    grids of nearby shapes reuse one compiled drain."""
    return max(floor, 1 << max(int(n) - 1, 0).bit_length())


# --------------------------------------------------------------------------
# the compiled drain: one scenario = one (I, S, Q) pool; vmap adds axis 0
# --------------------------------------------------------------------------

def _drain_one(p: Dict[str, "jax.Array"], *, phase: str,
               n_slots_pad: int) -> Dict[str, "jax.Array"]:
    """One compiled drain over a row-concatenated batch of pools.

    Every piece of engine state is per-instance, so *many* pools — across
    scenarios, chips, even flag combinations — concatenate along the
    instance axis into a single (I, S) / (I, Q) problem: per-pool scalars
    (roofline/power constants, window, chunk, the evict/respect flags)
    ride in `p` as (I,) arrays, costing one broadcast per use but keeping
    the whole batch on one compiled program.  On a single-core CPU runner
    a distinct signature costs a ~2 s XLA build — an order of magnitude
    more than running the warmed program — so shape (S, Q, total I) is
    deliberately the only thing that forces a retrace, and rows pay no
    padding for their neighbors' instance counts."""
    S = n_slots_pad
    evict = p["evict"]
    respect = p["respect"]
    I, Q = p["q_ready"].shape
    f64 = jnp.float64
    # token counts / step indices all fit comfortably in int32 (the
    # escalation sentinel _NEVER is iinfo(int32).max by construction) and
    # the drain is memory-bound on a CPU backend, so narrow integers buy a
    # near-2x on half the carried arrays
    i32 = jnp.int32
    qidx = jnp.arange(Q, dtype=i32)[None, :]
    sidx = jnp.arange(S, dtype=i32)[None, :]
    slot_ok = sidx < p["n_slots"][:, None]

    def zero_f(*shape):
        return jnp.zeros(shape, f64)

    def zero_i(*shape):
        return jnp.zeros(shape, i32)

    st0 = dict(
        sim_time=zero_f(I), qpos=zero_i(I), it=jnp.asarray(0, i32),
        active=jnp.zeros((I, S), bool),
        pos=zero_i(I, S), gen_count=zero_i(I, S), m_gen=zero_i(I, S),
        max_new=zero_i(I, S), prefill_left=zero_i(I, S),
        esc=jnp.full((I, S), _NEVER, i32), ready_ts=zero_f(I, S),
        slot_q=zero_i(I, S),
        slot_seconds=zero_f(I), m_slot_seconds=zero_f(I),
        preempted=zero_i(I), n_escalated=zero_i(I),
        out_kind=zero_i(I, Q), out_time=zero_f(I, Q),
        out_first=jnp.full((I, Q), -1.0, f64),
        out_ngen=zero_i(I, Q), out_step=zero_i(I, Q), out_slot=zero_i(I, Q),
        q_slot=zero_i(I, Q),
        **{k: (zero_i(I) if k in ("tokens", "m_tokens", "prefill_tokens")
               else zero_f(I)) for k in _METER_KEYS})

    def emit(st, mask, kind, time_val, ngen=None, first=None):
        """Record one terminal/drain event per masked slot into the
        queue-indexed out arrays.  Event masks/values live in slot space
        (I, S); rather than scattering them to queue columns (XLA:CPU
        lowers scatters — and (I, S, Q) one-hot reductions — to ~ms-scale
        loops), every queue entry *gathers* from the slot recorded in
        `q_slot` at its admission.  A gather lane is live only while
        `slot_q` still points back at the entry (its slot has not been
        recycled), which makes the stale-mapping check one (I, Q)
        compare."""
        sq = st["q_slot"]

        def g(v):                      # (I,S) slot values at each entry
            return jnp.take_along_axis(jnp.broadcast_to(v, (I, S)), sq,
                                       axis=1)

        hit = g(mask) & (g(st["slot_q"]) == qidx)
        if kind is not None:
            k = g(kind) if jnp.ndim(kind) == 2 else kind
            st["out_kind"] = jnp.where(hit, k, st["out_kind"])
            st["out_time"] = jnp.where(hit, g(time_val), st["out_time"])
            st["out_step"] = jnp.where(hit, st["it"], st["out_step"])
            st["out_slot"] = jnp.where(hit, sq, st["out_slot"])
        if ngen is not None:
            st["out_ngen"] = jnp.where(hit, g(ngen), st["out_ngen"])
        if first is not None:
            st["out_first"] = jnp.where(hit, g(first), st["out_first"])
        return st

    def window_overlap(start, end):
        t0, t1 = p["t0"], p["t1"]
        if jnp.ndim(start) == 2:          # (I, S) spans vs (I,) windows
            t0, t1 = t0[:, None], t1[:, None]
        return jnp.maximum(0.0, jnp.minimum(t1, end)
                           - jnp.maximum(t0, start))

    def charge_prefill_span(st, take, overlap_s, sim):
        """Vectorized twin of the numpy engine's sequential per-slot chunk
        charges: per-slot work times via `MeterBank.charge_prefill_rows`'s
        expressions, per-slot charge instants via an exclusive cumsum of
        the clock advances (the numpy slow path's sequential `sim_time`).
        Returns (st, sim', t_after) with t_after the post-charge instant
        per slot (first-token / handoff timestamps)."""
        t = (p["pf_num"][:, None] * take) / p["pf_den"][:, None]
        e = p["p_nom"][:, None] * t
        hidden = jnp.minimum(overlap_s, t)
        dt = t - hidden
        cum_dt_excl = jnp.cumsum(dt, axis=1) - dt
        t_before = sim[:, None] + cum_dt_excl
        ovl = window_overlap(t_before - hidden, t_before + dt)
        safe_t = jnp.where(t > 0, t, 1.0)
        e_in = jnp.where((ovl > 0) & (t > 0),
                         e * jnp.minimum(ovl / safe_t, 1.0), 0.0)
        st["m_joules"] += e_in.sum(1)
        st["m_prefill_joules"] += e_in.sum(1)
        st["joules"] += e.sum(1)
        st["prefill_joules"] += e.sum(1)
        st["prefill_tokens"] += take.sum(1, dtype=jnp.int32)
        return st, sim + dt.sum(1), t_before + dt

    def admit(st, sim):
        """Head-gated FIFO admission of the ready queue prefix into the
        lowest free slots (chunked mode never advances the clock here, so
        the whole wave vectorizes: the j-th admitted entry lands in the
        j-th lowest inactive slot)."""
        rem = (qidx >= st["qpos"][:, None]) & (qidx < p["qlen"][:, None])
        # respect=False degenerates to "whole queue is ready now"
        notready = rem & (p["q_ready"] > sim[:, None]) & respect[:, None]
        first_nr = jnp.argmax(notready, axis=1).astype(i32)
        prefix_end = jnp.where(notready.any(1), first_nr, p["qlen"])
        n_ready = jnp.maximum(prefix_end - st["qpos"], 0)
        free = (~st["active"]) & slot_ok
        cum_free = jnp.cumsum(free, axis=1, dtype=i32)
        n_admit = jnp.minimum(n_ready, cum_free[:, -1])
        free_rank = cum_free - free
        adm = free & (free_rank < n_admit[:, None])
        src = jnp.clip(st["qpos"][:, None] + free_rank, 0, Q - 1)
        # inverse mapping for `emit`: the j-th admitted queue entry lands
        # in the j-th lowest free slot = first s with cum_free[s] == j+1
        adm_q = (qidx >= st["qpos"][:, None]) \
            & (qidx < (st["qpos"] + n_admit)[:, None])
        ranks = qidx - st["qpos"][:, None] + 1
        slot_of_q = jax.vmap(jnp.searchsorted)(cum_free, ranks).astype(i32)
        st["q_slot"] = jnp.where(adm_q, jnp.clip(slot_of_q, 0, S - 1),
                                 st["q_slot"])
        gather = lambda a: jnp.take_along_axis(a, src, axis=1)  # noqa: E731
        a_plen = gather(p["q_plen"])
        a_pd = gather(p["q_pdone"])
        st["active"] = st["active"] | adm
        st["pos"] = jnp.where(adm, a_plen, st["pos"])
        st["max_new"] = jnp.where(adm, gather(p["q_maxnew"]), st["max_new"])
        st["ready_ts"] = jnp.where(adm, gather(p["q_ready"]), st["ready_ts"])
        st["esc"] = jnp.where(adm, gather(p["q_esc"]), st["esc"])
        st["slot_q"] = jnp.where(adm, src, st["slot_q"])
        st["gen_count"] = jnp.where(adm, jnp.where(a_pd, 1, 0),
                                    st["gen_count"])
        st["prefill_left"] = jnp.where(adm, jnp.where(a_pd, 0, a_plen),
                                       st["prefill_left"])
        st["m_gen"] = jnp.where(adm, 0, st["m_gen"])
        st["qpos"] = st["qpos"] + n_admit
        return st

    def decode_step(st, sim):
        n_occ = st["active"].sum(1, dtype=i32)
        dec = st["active"] & (st["prefill_left"] == 0)
        n_dec = dec.sum(1, dtype=i32)
        has_dec = n_dec > 0
        nf = n_dec.astype(f64)
        mean_ctx = (st["pos"] * dec).sum(1) / jnp.where(has_dec, n_dec, 1)
        tau_ms = p["w_ms"] + (p["h0_ms"] * (mean_ctx / p["l_calib"])) * nf
        tau_s = tau_ms * 1e-3
        safe_b = jnp.maximum(nf, 1e-9)
        logistic = p["p_range"] / (
            1.0 + jnp.exp(-p["k"] * (jnp.log2(safe_b) - p["x0"])))
        power = jnp.where(nf <= 0, p["p_idle"], p["p_idle"] + logistic)
        mid = sim + 0.5 * tau_s
        in_win = (p["t0"] <= mid) & (mid <= p["t1"])
        e = power * tau_s
        dj = power * jnp.minimum(p["dispatch_s"], tau_s)
        win = has_dec & in_win
        st["m_tokens"] += jnp.where(win, n_dec, 0)
        st["m_joules"] += jnp.where(win, e, 0.0)
        st["m_dispatch_joules"] += jnp.where(win, dj, 0.0)
        st["joules"] += jnp.where(has_dec, e, 0.0)
        st["dispatch_joules"] += jnp.where(has_dec, dj, 0.0)
        st["tokens"] += jnp.where(has_dec, n_dec, 0)
        sim = sim + jnp.where(has_dec, tau_s, 0.0)
        tau_full = jnp.where(has_dec, tau_s, 0.0)
        # post-decode bookkeeping + terminal events
        st["m_gen"] += (dec & win[:, None]).astype(i32)
        st["gen_count"] += dec
        st["pos"] += dec
        gc = st["gen_count"]
        done = dec & (gc >= st["max_new"])
        escalate = dec & ~done & (gc >= st["esc"])
        at_ceiling = dec & ~done & ~escalate \
            & (st["pos"] >= p["window"][:, None] - 1)
        # no-evict pools finish a request at the context ceiling instead
        done = done | (at_ceiling & ~evict[:, None])
        at_ceiling = at_ceiling & evict[:, None]
        ev = escalate | at_ceiling
        # one fused emit for all three terminal kinds: reconstruction only
        # reads ngen on DONE rows, so charging it unconditionally is free
        kind = jnp.where(done, _EV_DONE,
                         jnp.where(escalate, _EV_ESCALATE, _EV_OVERFLOW))
        st = emit(st, done | ev, kind.astype(i32), sim[:, None], ngen=gc)
        # eviction backout: decode tokens beyond the (uncharged) first are
        # clawed back so escalated/overflowed output is never double-counted
        st["tokens"] -= (jnp.maximum(gc - 1, 0) * ev).sum(1, dtype=i32)
        st["m_tokens"] -= (st["m_gen"] * ev).sum(1, dtype=i32)
        st["preempted"] += ev.sum(1, dtype=i32)
        st["n_escalated"] += escalate.sum(1, dtype=i32)
        clr = done | ev
        st["active"] = st["active"] & ~clr
        st["prefill_left"] = jnp.where(clr, 0, st["prefill_left"])
        st["gen_count"] = jnp.where(clr, 0, st["gen_count"])
        st["m_gen"] = jnp.where(clr, 0, st["m_gen"])
        st["esc"] = jnp.where(clr, _NEVER, st["esc"])
        # chunked-prefill interleave riding this row's decode tau: the
        # chunk budget spills across pending slots in slot order, only the
        # first charge hides behind the decode pass
        pend = st["active"] & (st["prefill_left"] > 0)
        pl = jnp.where(pend, st["prefill_left"], 0)
        cum_excl = jnp.cumsum(pl, axis=1) - pl
        take = jnp.minimum(pl, jnp.maximum(p["chunk"][:, None]
                                           - cum_excl, 0))
        charged = take > 0
        is_first = charged & ((jnp.cumsum(charged, axis=1) - charged) == 0)
        ov = jnp.where(is_first, tau_full[:, None], 0.0)
        st, sim, t_after = charge_prefill_span(st, take, ov, sim)
        drained = charged & (take == pl)
        st = emit(st, drained, None, None, first=t_after)
        st["gen_count"] = jnp.where(drained, 1, st["gen_count"])
        st["prefill_left"] = st["prefill_left"] - take
        return st, sim, n_occ

    def coast(st, sim):
        """Event-free fast-forward for decode rows.  When a row's in-flight
        set is static — no slot will reach done/escalate/ceiling, no prompt
        chunks are pending, no admission can land, and every step midpoint
        stays on one side of the measurement window — the decode recurrence
        is closed-form: batch size and power are constant and the mean
        context grows by exactly one per step, so tau is linear in the step
        index and each accumulator advance is an arithmetic series.  The
        jump length is bounded conservatively (tau at the last candidate
        step upper-bounds every step), so a window/arrival/dispatch
        boundary is approached in a few geometrically-shrinking coasts and
        crossed by normal single steps.  Rows coast independently — all
        engine state is per-row, and per-row event order only needs `it`
        to grow per kernel iteration — so the jumped state matches the
        stepped oracle to accumulation-order ulps."""
        act = st["active"]
        n = act.sum(1, dtype=i32)
        has_act = n > 0
        nf = n.astype(f64)
        no_pf = ~(act & (st["prefill_left"] > 0)).any(1)
        c0 = (st["pos"] * act).sum(1) / jnp.where(has_act, n, 1)
        tau1 = (p["w_ms"] + (p["h0_ms"] * (c0 / p["l_calib"])) * nf) * 1e-3
        dtau = (p["h0_ms"] / p["l_calib"]) * nf * 1e-3
        big = jnp.asarray(1 << 30, i32)
        bigf = jnp.asarray(float(1 << 30), f64)

        def floor_div(x, y):
            return jnp.floor(jnp.minimum(x / y, bigf)).astype(i32)

        # steps until the first slot event: done at max_new-gc, escalate at
        # esc-gc, ceiling at (window-1)-pos; coast strictly before the min
        rem = jnp.minimum(jnp.minimum(st["max_new"] - st["gen_count"],
                                      st["esc"] - st["gen_count"]),
                          (p["window"][:, None] - 1) - st["pos"])
        j_ev = jnp.min(jnp.where(act, rem, big), axis=1) - 1

        remq = (qidx >= st["qpos"][:, None]) & (qidx < p["qlen"][:, None])
        has_q = remq.any(1)
        free_any = ((~act) & slot_ok).any(1)
        gap_a = jnp.where(respect,                  # else "ready now"
                          jnp.min(jnp.where(remq, p["q_ready"], jnp.inf),
                                  axis=1) - sim, 0.0)
        after = sim > p["t1"]
        inwin = ~after & (sim >= p["t0"])
        gap_w = jnp.where(inwin, p["t1"] - sim, p["t0"] - sim)
        d = p["dispatch_s"]

        def bounds(t_ub):
            j_win = jnp.where(after, big, floor_div(gap_w, t_ub))
            # an arrival only binds while a free slot could accept it
            j_arr = jnp.where(has_q & free_any,
                              floor_div(jnp.maximum(gap_a, 0.0), t_ub), big)
            # min(dispatch_s, tau) must not switch branch mid-jump
            j_dis = jnp.where((d > tau1) & (dtau > 0),
                              floor_div(d - tau1, dtau) + 1, big)
            return jnp.minimum(jnp.minimum(j_win, j_arr), j_dis)

        t_ub = jnp.maximum(tau1 + jnp.maximum(j_ev - 1, 0) * dtau, 1e-12)
        j = jnp.minimum(j_ev, bounds(t_ub))
        t_ub = jnp.maximum(tau1 + jnp.maximum(j - 1, 0) * dtau, 1e-12)
        j = jnp.minimum(j_ev, bounds(t_ub))     # tightening pass
        go = has_act & no_pf & (j >= 1)
        jn = jnp.where(go, j, 0)
        jf = jn.astype(f64)
        span = jf * tau1 + dtau * (jf * (jf - 1) * 0.5)
        safe_b = jnp.maximum(nf, 1e-9)
        logistic = p["p_range"] / (
            1.0 + jnp.exp(-p["k"] * (jnp.log2(safe_b) - p["x0"])))
        power = p["p_idle"] + logistic
        e = power * span
        dj = power * jnp.where(d <= tau1, jf * d, span)
        win = go & inwin
        st["tokens"] += jnp.where(go, jn * n, 0)
        st["joules"] += jnp.where(go, e, 0.0)
        st["dispatch_joules"] += jnp.where(go, dj, 0.0)
        st["m_tokens"] += jnp.where(win, jn * n, 0)
        st["m_joules"] += jnp.where(win, e, 0.0)
        st["m_dispatch_joules"] += jnp.where(win, dj, 0.0)
        adv = jnp.where(go, span, 0.0)
        st["slot_seconds"] += nf * adv
        st["m_slot_seconds"] += nf * window_overlap(sim, sim + adv)
        coasted = act & go[:, None]
        st["gen_count"] += jnp.where(coasted, jn[:, None], 0)
        st["pos"] += jnp.where(coasted, jn[:, None], 0)
        st["m_gen"] += jnp.where(coasted & win[:, None], jn[:, None], 0)
        return st, sim + adv

    def prefill_step(st, sim):
        """Prefill-phase lockstep: drain up to one chunk across occupied
        slots oldest-first (stable sort on ready_ts, ties to the lowest
        slot); a slot whose prompt drains emits its handoff event."""
        n_occ = st["active"].sum(1, dtype=i32)
        pend = st["active"] & (st["prefill_left"] > 0)
        key = jnp.where(pend, st["ready_ts"], jnp.inf)
        order = jnp.argsort(key, axis=1, stable=True)
        inv = jnp.argsort(order, axis=1)
        pl_srt = jnp.take_along_axis(
            jnp.where(pend, st["prefill_left"], 0), order, axis=1)
        cum_excl = jnp.cumsum(pl_srt, axis=1) - pl_srt
        take_srt = jnp.minimum(pl_srt,
                               jnp.maximum(p["chunk"][:, None] - cum_excl, 0))
        st, sim, t_after_srt = charge_prefill_span(
            st, take_srt, jnp.zeros((I, S)), sim)
        drained_srt = (take_srt > 0) & (take_srt == pl_srt)
        unsort = lambda a: jnp.take_along_axis(a, inv, axis=1)  # noqa: E731
        take = unsort(take_srt)
        drained = unsort(drained_srt)
        t_after = unsort(t_after_srt)
        st["prefill_left"] = st["prefill_left"] - take
        st = emit(st, drained, _EV_HANDOFF, t_after, ngen=1, first=t_after)
        st["active"] = st["active"] & ~drained
        st["gen_count"] = jnp.where(drained, 0, st["gen_count"])
        st["esc"] = jnp.where(drained, _NEVER, st["esc"])
        return st, sim, n_occ

    def body(st):
        st = dict(st)
        sim = st["sim_time"]
        active_any = st["active"].any(1)
        has_q = st["qpos"] < p["qlen"]
        # event-driven idle skip (respect_arrival only): rows with nothing
        # in flight jump to their queue's next arrival, idle power
        # accruing over the gap
        rem = (qidx >= st["qpos"][:, None]) & (qidx < p["qlen"][:, None])
        min_ready = jnp.min(jnp.where(rem, p["q_ready"], jnp.inf), axis=1)
        dt = min_ready - sim
        do = respect & (~active_any) & has_q & (dt > 0)
        dtc = jnp.where(do, dt, 0.0)
        e = p["p_idle"] * dtc
        ovl = window_overlap(sim, sim + dtc)
        e_in = jnp.where(do & (ovl > 0), p["p_idle"] * ovl, 0.0)
        st["m_joules"] += e_in
        st["m_idle_joules"] += e_in
        st["joules"] += jnp.where(do, e, 0.0)
        st["idle_joules"] += jnp.where(do, e, 0.0)
        sim = sim + dtc
        t_start = sim
        st = admit(st, sim)
        if phase == "prefill":
            st, sim, n_occ = prefill_step(st, sim)
        else:
            st, sim, n_occ = decode_step(st, sim)
        st["slot_seconds"] += n_occ * (sim - t_start)
        st["m_slot_seconds"] += n_occ * window_overlap(t_start, sim)
        if phase != "prefill":
            st, sim = coast(st, sim)
        st["sim_time"] = sim
        st["it"] = st["it"] + 1
        return st

    def cond(st):
        alive = st["active"].any() | (st["qpos"] < p["qlen"]).any()
        return alive & (st["it"] < p["max_iters"])

    return jax.lax.while_loop(cond, body, st0)


_DRAIN_CACHE: Dict[tuple, object] = {}


def _get_drain(phase: str, n_slots_pad: int):
    key = (phase, n_slots_pad)
    fn = _DRAIN_CACHE.get(key)
    if fn is None:
        from functools import partial
        fn = jax.jit(partial(
            _drain_one, phase=phase, n_slots_pad=n_slots_pad))
        _DRAIN_CACHE[key] = fn
    return fn


# --------------------------------------------------------------------------
# host side: pack queues, batch drains, reconstruct events
# --------------------------------------------------------------------------

# row-pad fills: benign values for instance rows that exist only to pad
# the concatenated batch up to its bucketed shape (qlen=0 / n_slots=0
# keeps them permanently idle; 1.0 in the divisor constants avoids
# spurious NaNs in their — discarded — accumulator rows)
_PAD_ONES = ("w_ms", "h0_ms", "l_calib", "pf_den")


def drain_engines(engines: Sequence["JaxPoolEngine"], *,
                  max_iters: int = 100_000,
                  pad_floors: Optional[Sequence[tuple]] = None) -> None:
    """Drain many pools (typically one per grid scenario) as a handful of
    compiled calls.  Every piece of engine state is per-instance, so the
    pools *concatenate along the instance axis*: engines are grouped by
    padded (S, Q), their packed arrays stacked row-wise (per-pool scalars
    were broadcast to (I,) rows by `_pack`), and each group drains as one
    jitted program over the merged (sum-of-I, S/Q) arrays.  Results are
    staged on each engine by row span; its next `run_until_drained` call
    finalizes instead of re-simulating.  Rows never pay padding for a
    neighbor pool's instance count or flag/chip constants — only S and Q
    are padded, and the row total rounds up to a power-of-two bucket.

    `pad_floors` is an optional list of (i_floor, s_cap, q_cap) shape
    classes: each engine joins the cheapest (s_cap, q_cap) class that
    fits it (falling back to per-engine power-of-two buckets), and the
    class's merged row count pads to at least `i_floor` so calls of
    slightly different pool mixtures land on one compiled signature.  On
    a single-core CPU runner each distinct signature costs a ~2 s XLA
    build — which is why callers that sweep hundreds of cells
    (benchmarks/fleet_grid_bench.py) pin a survey-derived class list."""
    if jax is None:
        raise RuntimeError("jax is not installed; use the numpy engine")
    groups: Dict[tuple, List[JaxPoolEngine]] = {}
    packed = {}
    for eng in engines:
        params = eng._pack(max_iters)
        packed[id(eng)] = params
        S, Q = eng.n_slots, params["q_ready"].shape[1]
        dims = None
        if pad_floors:
            fits = [c for c in pad_floors if S <= c[1] and Q <= c[2]]
            if fits:        # cheapest by per-row footprint, then row floor
                dims = min(fits, key=lambda c: (c[1] + c[2], c[0]))
        if dims is None:
            dims = (1, _bucket(S), _bucket(Q))
        groups.setdefault((eng.phase, *dims), []).append(eng)
    with enable_x64():
        for (phase, i_floor, s_pad, q_pad), engs in groups.items():
            i_tot = sum(e.instances for e in engs)
            i_pad = _bucket(max(i_tot, i_floor))
            merged = {}
            for k in packed[id(engs[0])]:
                rows = [packed[id(e)][k] for e in engs]
                if np.ndim(rows[0]) == 0:       # max_iters: shared scalar
                    merged[k] = jnp.asarray(max(rows))
                    continue
                if rows[0].ndim == 2:
                    fill = np.inf if k == "q_ready" else (
                        _NEVER if k == "q_esc" else 0)
                    a = np.full((i_pad, q_pad), fill, rows[0].dtype)
                else:
                    a = np.full((i_pad,),
                                1 if k in _PAD_ONES else 0, rows[0].dtype)
                off = 0
                for r in rows:
                    n = r.shape[0]
                    if r.ndim == 2:
                        a[off:off + n, :r.shape[1]] = r
                    else:
                        a[off:off + n] = r
                    off += n
                merged[k] = jnp.asarray(a)
            out = _get_drain(phase, s_pad)(merged)
            out = {k: np.asarray(v) for k, v in out.items()}
            off = 0
            for eng in engs:
                I, S = eng.instances, eng.n_slots
                Q = packed[id(eng)]["q_ready"].shape[1]
                res = {}
                for k, v in out.items():
                    if v.ndim == 0:             # the shared `it` counter
                        res[k] = v
                        continue
                    s = v[off:off + I]
                    if s.ndim == 2:
                        s = s[:, :Q] if (k.startswith("out_")
                                         or k == "q_slot") else s[:, :S]
                    res[k] = s
                eng._staged = res
                off += I


class JaxPoolEngine(BatchedPoolEngine):
    """Drop-in `BatchedPoolEngine` whose drive loop runs on XLA.

    Construction, submission, queue sorting, the outboxes and every
    aggregate the fleet simulator reads are inherited; only
    `run_until_drained` is replaced by pack -> compiled drain ->
    reconstruct.  `serving.jax_engine.drain_engines` batches the drains of
    many engines (a scenario grid) into single compiled calls and stages
    the results, which this method then just finalizes."""

    def __init__(self, **kw):
        if jax is None:
            raise RuntimeError(
                "JaxPoolEngine needs jax; this environment is numpy-only "
                "(FleetSim(engine='numpy') is the oracle path)")
        super().__init__(**kw)
        if self.phase != "prefill" and not self.prefill_chunk:
            raise NotImplementedError(
                "the unchunked immediate-prefill decode path advances the "
                "clock mid-admission and is not vectorizable; use the "
                "numpy BatchedPoolEngine or pass a prefill_chunk")
        self._staged: Optional[Dict[str, np.ndarray]] = None

    # --- pack -----------------------------------------------------------

    def _pack(self, max_iters: int) -> Dict[str, np.ndarray]:
        """Freeze queues into device-ready arrays + scalar params (the
        scenario pytree drain_engines stacks on the vmap axis)."""
        self._freeze()
        I = self.instances
        Q = max(1, int(self.qlen.max()))
        q_ready = np.full((I, Q), np.inf)
        q_plen = np.zeros((I, Q), np.int32)
        q_maxnew = np.zeros((I, Q), np.int32)
        q_esc = np.full((I, Q), _NEVER, np.int32)
        q_pdone = np.zeros((I, Q), bool)
        for i, q in enumerate(self.queues):
            for j, r in enumerate(q):
                q_ready[i, j] = self._ready(r)
                q_plen[i, j] = r.prompt_len
                q_maxnew[i, j] = r.max_new_tokens
                if r.escalate_at is not None:
                    q_esc[i, j] = r.escalate_at
                q_pdone[i, j] = r.prefill_done
        prof, pm, rl = self.profile, self.profile.power_model, \
            self.profile.roofline
        # pool-level constants broadcast to (I,) so row-concatenated pools
        # with different chips/flags share one compiled drain
        def ff(v):
            return np.full(I, v, np.float64)

        def fi(v):
            return np.full(I, v, np.int32)

        return dict(
            q_ready=q_ready, q_plen=q_plen, q_maxnew=q_maxnew, q_esc=q_esc,
            q_pdone=q_pdone, qlen=self.qlen.astype(np.int32),
            w_ms=ff(rl.w_ms), h0_ms=ff(rl.h0_ms), l_calib=ff(rl.l_calib),
            p_idle=ff(pm.p_idle_w), p_range=ff(pm.p_range_w),
            k=ff(pm.k), x0=ff(pm.x0), p_nom=ff(pm.p_nom_w),
            pf_num=ff(2.0 * self._streamed_params),
            pf_den=ff(prof.tp * prof.chip.peak_bf16_flops
                      * self.prefill_mfu),
            dispatch_s=ff(self.bank.dispatch_s),
            t0=ff(self.bank.measure_t0), t1=ff(self.bank.measure_t1),
            chunk=fi(self.prefill_chunk or 0),
            window=fi(self.window), n_slots=fi(self.n_slots),
            evict=np.full(I, self.evict_on_overflow, bool),
            respect=np.full(I, self.respect_arrival, bool),
            max_iters=np.int32(min(max_iters, np.iinfo(np.int32).max)))

    # --- drive ----------------------------------------------------------

    def run_until_drained(self, max_iters: int = 100_000) -> None:
        res = self._staged
        self._staged = None
        if res is None:
            drain_engines([self], max_iters=max_iters)
            res, self._staged = self._staged, None
        self._finalize(res, max_iters)

    # --- reconstruct ----------------------------------------------------

    def _finalize(self, res: Dict[str, np.ndarray],
                  max_iters: int) -> None:
        alive = bool(res["active"].any()) \
            or bool((res["qpos"] < self.qlen).any())
        if alive:
            qleft = int((self.qlen - res["qpos"]).sum())
            raise DrainTruncatedError(
                self.name, max_iters,
                f"{qleft} queued, {int(res['active'].sum())} in flight")
        b = self.bank
        for k in _METER_KEYS:
            getattr(b, k)[:] = res[k]
        b.sim_time_s[:] = res["sim_time"]
        self.slot_seconds[:] = res["slot_seconds"]
        self.m_slot_seconds[:] = res["m_slot_seconds"]
        self.preempted[:] = res["preempted"]
        self.n_escalated[:] = res["n_escalated"]
        self.qpos[:] = self.qlen
        self._refresh_heads(np.arange(self.instances))
        kinds, times = res["out_kind"], res["out_time"]
        firsts, ngens = res["out_first"], res["out_ngen"]
        tr = self.trace
        for i in range(self.instances):
            n = int(self.qlen[i])
            if not n:
                continue
            # numpy append order: step, then within a step the per-slot
            # event sweeps (slot-ascending) / the FIFO handoff charges
            # (time-ascending — identical within a decode step)
            order = np.lexsort((res["out_slot"][i, :n], times[i, :n],
                                res["out_step"][i, :n]))
            q = self.queues[i]
            for j in order:
                j = int(j)
                kind = int(kinds[i, j])
                assert kind != _EV_NONE, (self.name, i, j)
                req = q[j]
                t = float(times[i, j])
                if firsts[i, j] >= 0:
                    # the request's prompt drained here (chunk interleave):
                    # first token emitted at that instant
                    req.first_token_time = float(firsts[i, j])
                    req.n_generated = 1
                    if tr is not None:
                        tr.event(EV_FIRST_TOKEN, req.rid, self._trace_pool,
                                 i, req.first_token_time)
                if kind == _EV_DONE:
                    req.n_generated = int(ngens[i, j])
                    req.generated = None
                    req.finish_time = t
                    self.completed[i].append(req)
                    if tr is not None:
                        tr.event(EV_COMPLETE, req.rid, self._trace_pool,
                                 i, t)
                elif kind == _EV_HANDOFF:
                    req.n_generated = 1
                    req.generated = [int(
                        (np.int64(req.rid) * _LCG_A + self.seeds[i]
                         + _LCG_C) % self.vocab)]
                    req.prefill_done = True
                    req.ready_time = t
                    self.handoff[i].append(req)
                    self.relayed[i].append(req)
                    if tr is not None:
                        tr.event(EV_HANDOFF, req.rid, self._trace_pool, i, t)
                else:                       # overflow / escalation eviction
                    req.generated = None
                    req.prefill_done = False
                    req.preemptions += 1
                    req.ready_time = t
                    req.escalate_at = None
                    if kind == _EV_ESCALATE:
                        req.escalations += 1
                        self.escalated[i].append(req)
                        if tr is not None:
                            tr.event(EV_ESCALATE, req.rid, self._trace_pool,
                                     i, t)
                    else:
                        self.overflowed[i].append(req)
                        if tr is not None:
                            tr.event(EV_OVERFLOW, req.rid, self._trace_pool,
                                     i, t)
