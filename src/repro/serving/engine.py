"""Continuous-batching decode engine.

One `PoolEngine` is one "instance" in the paper's terms: a model replica
serving one context window.  It owns:

  * a slotted KV/state cache slab of exactly `n_max` sequences — Eq. 3's
    concurrency ceiling enforced as the scheduler's admission limit;
  * a jitted decode step over all slots (inactive slots compute masked
    garbage, as real continuous-batching engines do);
  * an EnergyMeter charging every iteration P(b) * tau.

Prefill runs per-request at admission and its K/V is spliced into the slab
(the chunked-prefill interleave is modeled on the energy side only —
see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiles import BaseProfile
from repro.models import model as M
from repro.models.spec import ArchConfig

from .energy import EnergyMeter
from .request import Request


class PoolEngine:
    def __init__(self, cfg: ArchConfig, params, *, window: int,
                 profile: BaseProfile, n_slots: Optional[int] = None,
                 name: str = "pool", rng_seed: int = 0):
        self.cfg, self.params = cfg, params
        self.window = window
        self.name = name
        self.profile = profile
        self.n_slots = n_slots if n_slots is not None \
            else max(profile.n_max(window), 1)
        self.meter = EnergyMeter(profile)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * self.n_slots
        self.pos = np.zeros(self.n_slots, np.int32)       # next write position
        self.tokens = np.zeros(self.n_slots, np.int64)    # last emitted token
        self.preempted = 0
        self.cache = M.init_cache(cfg, self.n_slots, window)
        self._step = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos))
        self._prefill = jax.jit(
            lambda p, toks: M.forward(p, cfg, {"tokens": toks},
                                      mode="prefill"))
        self.completed: List[Request] = []

    # --- admission ------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def submit(self, req: Request) -> None:
        req.pool = self.name
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and None in self.slots:
            req = self.queue.popleft()
            slot = self.slots.index(None)
            prompt = jnp.asarray(req.prompt[None, :])
            logits, cache, _ = self._prefill(self.params, prompt)
            self.meter.charge_prefill(
                req.prompt_len,
                streamed_params=self.cfg.analytical_spec().streamed_params)
            self._splice(cache, slot, req.prompt_len)
            self.slots[slot] = req
            self.pos[slot] = req.prompt_len
            self.tokens[slot] = int(jnp.argmax(logits[0, -1]))
            req.generated = [int(self.tokens[slot])]
            req.first_token_time = self.meter.sim_time_s

    def _splice(self, prefill_cache, slot: int, plen: int) -> None:
        """Write a single-sequence prefill cache into slab slot `slot`."""
        def put(slab, piece):
            piece0 = piece[:, 0]  # drop the size-1 prefill batch axis
            if piece0.shape == slab.shape[:1] + slab.shape[2:]:
                return slab.at[:, slot].set(piece0)      # O(1)-state caches
            # attention K/V: prefill wrote t <= slab-seq slots (SWA caches
            # arrive already ring-aligned from attention_full)
            t = min(piece0.shape[1], slab.shape[2])
            return slab.at[:, slot, :t].set(piece0[:, -t:])

        self.cache = jax.tree.map(put, self.cache, prefill_cache)

    # --- preemption (paper §10.1: "KV-cache eviction under memory
    # pressure ... reduces achievable throughput") ------------------------
    def preempt(self, slot: int) -> None:
        """Evict a running request back to the queue (its KV is dropped;
        it will re-prefill on re-admission — the real cost of eviction)."""
        req = self.slots[slot]
        if req is None:
            return
        req.generated = None      # restart generation on re-admission
        req.preemptions += 1
        self.queue.appendleft(req)
        self.slots[slot] = None
        self.preempted += 1

    def shrink(self, new_slots: int) -> None:
        """Memory-pressure response: reduce live concurrency by evicting
        the youngest requests (least wasted work)."""
        while self.n_active > new_slots:
            ages = [(self.pos[i] - s.prompt_len, i)
                    for i, s in enumerate(self.slots) if s is not None]
            _, victim = min(ages)
            self.preempt(victim)

    # --- one continuous-batching iteration ------------------------------
    def step(self) -> int:
        self._admit()
        n_act = self.n_active
        if n_act == 0:
            return 0
        active = np.array([s is not None for s in self.slots])
        toks = jnp.asarray(self.tokens[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._step(self.params, toks, self.cache, pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        mean_ctx = float(self.pos[active].mean()) if active.any() else 0.0
        self.meter.charge_decode_step(n_act, mean_ctx)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.generated.append(int(nxt[i]))
            self.tokens[i] = nxt[i]
            self.pos[i] += 1
            if req.done or self.pos[i] >= self.window - 1:
                req.finish_time = self.meter.sim_time_s
                self.completed.append(req)
                self.slots[i] = None
        return n_act

    def run_until_drained(self, max_iters: int = 100_000) -> None:
        it = 0
        while (self.queue or self.n_active) and it < max_iters:
            self.step()
            it += 1

    def latency_percentiles(self) -> Dict[str, float]:
        """TTFT / end-to-end percentiles over completed requests (sim
        time; arrival_time treated as submission into this engine)."""
        if not self.completed:
            return {}
        ttft = np.array([r.first_token_time - r.arrival_time
                         for r in self.completed if r.first_token_time >= 0])
        e2e = np.array([r.finish_time - r.arrival_time
                        for r in self.completed if r.finish_time >= 0])
        out = {}
        if len(ttft):
            out["ttft_p50_s"] = round(float(np.quantile(ttft, 0.5)), 4)
            out["ttft_p99_s"] = round(float(np.quantile(ttft, 0.99)), 4)
        if len(e2e):
            out["e2e_p99_s"] = round(float(np.quantile(e2e, 0.99)), 4)
        return out

    def stats(self) -> Dict[str, float]:
        return dict(name=self.name, window=self.window,
                    n_slots=self.n_slots,
                    completed=len(self.completed),
                    preempted=self.preempted,
                    tokens=self.meter.tokens,
                    joules=round(self.meter.joules, 1),
                    tok_per_watt=round(self.meter.tok_per_watt, 3),
                    sim_time_s=round(self.meter.sim_time_s, 3),
                    **self.latency_percentiles())
