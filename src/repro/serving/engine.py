"""Continuous-batching decode engine.

One `PoolEngine` is one "instance" in the paper's terms: a model replica
serving one context window.  It owns:

  * a slotted KV/state cache slab of exactly `n_max` sequences — Eq. 3's
    concurrency ceiling enforced as the scheduler's admission limit;
  * a jitted decode step over all slots (inactive slots compute masked
    garbage, as real continuous-batching engines do);
  * an EnergyMeter charging every iteration P(b) * tau.

The engine runs in one of two modes:

  model mode      — cfg/params given: real jitted prefill + decode over the
                    slab; token streams are exact greedy generations
                    (asserted against sequential decoding in
                    tests/serving/test_serving.py).
  analytical mode — cfg=None: no neural net; token ids come from a
                    deterministic LCG stream and only the *scheduler* and
                    the EnergyMeter run.  The fleet simulator used to
                    instantiate these by the dozen; it now runs every
                    instance of a pool inside one `BatchedPoolEngine`
                    (serving/soa.py), which extends this engine's slot
                    arrays with an instance axis and replays these exact
                    semantics bit-for-bit (tests/serving/test_soa_parity).
                    The scalar engine remains the reference
                    implementation and the token-level (model-mode)
                    serving path.

And (orthogonally) serves one of two phases:

  decode phase  — the default: continuous-batching token generation with
                  (optionally chunked) prefill riding the decode passes.
  prefill phase — `phase="prefill"` (core.disagg / Splitwise): a dedicated
                  compute-bound chunk processor.  No decode iterations
                  ever run; each step drains up to `prefill_chunk` prompt
                  tokens across the occupied slots (oldest request first —
                  FIFO over slot refills keeps the TTFT tail honest) at
                  the engine's `prefill_mfu`, and a slot whose prompt
                  drains emits the request's first token and moves it to
                  the `handoff` outbox for the paired decode pool (the
                  fleet simulator applies the KV-migration delay and
                  energy).  Analytical mode only — a model-mode prefill
                  phase would need real KV transport.

All post-decode bookkeeping (token emission, position advance, completion,
window-ceiling handling) is slot-batched over numpy arrays — there is no
per-slot Python loop on the hot path; Python-level loops only touch the
(rare) slots that complete or migrate on a given iteration.

Prefill: in model mode K/V is computed per-request at admission and spliced
into the slab.  Energy/time accounting supports two policies: immediate
(the whole prompt charged at admission — legacy behaviour) and chunked
interleave (`prefill_chunk` tokens ride along each decode iteration, the
Sarathi-style schedule; the request holds its slot but emits no tokens
until its prefill budget drains, which is what makes simulated TTFT honest
under load).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.fleet import PREFILL_MFU
from repro.core.hardware import H100
from repro.core.profiles import BaseProfile
from repro.core.timeline import (EV_ADMIT, EV_COMPLETE, EV_ESCALATE,
                                 EV_FIRST_TOKEN, EV_HANDOFF, EV_OVERFLOW,
                                 EV_PREFILL)

from .energy import EnergyMeter
from .request import Request, latency_percentiles

# Shared with the SoA batched engine (serving.soa), which must generate
# identical token streams and sentinels for bit-exact parity.
_LCG_A, _LCG_C = 1664525, 1013904223   # Numerical Recipes LCG
_NEVER = np.iinfo(np.int32).max        # escalate_at sentinel: no escalation


class DrainTruncatedError(RuntimeError):
    """`run_until_drained` hit its iteration cap with work still queued or
    in flight.  A truncated drain has charged energy for only part of the
    request stream, so every downstream ratio (tok/W, SLO feasibility)
    would be plausible-but-wrong — callers must treat this as a hard
    failure, never as a result."""

    def __init__(self, name: str, max_iters: int, detail: str = ""):
        self.pool = name
        self.max_iters = max_iters
        super().__init__(
            f"pool {name!r} still busy after max_iters={max_iters}"
            f"{': ' + detail if detail else ''} — raise max_iters; a"
            " truncated drain under-counts tokens and energy")


def resolve_prefill_chunk(profile: BaseProfile,
                          prefill_chunk: Optional[int],
                          phase: str) -> Optional[int]:
    """Single source of the engines' prefill-chunk fallback.

    Decode engines keep the caller's value (None/0 = legacy unchunked
    immediate prefill).  Prefill-phase engines always work chunkwise — a 0
    budget would spin `_step_prefill` without ever draining — so a missing
    chunk falls back to `scaled_prefill_chunk(profile)`: the bandwidth-
    scaled default, *not* a hard-coded 512 (which would pin H200/B200
    disagg prefill pools to the H100 chunk rate and understate the
    generation gain; on the H100 the two are identical)."""
    if not prefill_chunk and phase == "prefill":
        return scaled_prefill_chunk(profile)
    return prefill_chunk


def scaled_prefill_chunk(profile: BaseProfile, base: int = 512,
                         floor: int = 64) -> int:
    """Prefill-chunk budget scaled by the profile's HBM bandwidth relative
    to the H100 the base chunk was calibrated on.

    Chunked prefill rides decode iterations, and a faster generation's
    iterations are shorter in proportion to its bandwidth — so a *constant*
    chunk caps prefill throughput at the H100 rate and squanders the new
    chip's surplus FLOPs on the prompt phase (the measured §4.2
    generation-gain compression of DESIGN.md §5).  Scaling the chunk by the
    bandwidth ratio keeps prefill tokens *per second* generation-invariant:
    B200 (8/3.35x) carries ~2.4x the prompt tokens per (2.4x shorter)
    iteration."""
    ratio = profile.chip.mem_bw_Bps / H100.mem_bw_Bps
    return max(int(round(base * ratio)), floor)


class PoolEngine:
    def __init__(self, cfg, params, *, window: int,
                 profile: BaseProfile, n_slots: Optional[int] = None,
                 name: str = "pool", rng_seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 evict_on_overflow: bool = False,
                 respect_arrival: bool = False,
                 streamed_params: Optional[float] = None,
                 vocab: int = 32000, phase: str = "decode",
                 prefill_mfu: Optional[float] = None,
                 dispatch_ms: float = 0.0):
        self.cfg, self.params = cfg, params
        self.window = window
        self.name = name
        self.profile = profile
        self.n_slots = n_slots if n_slots is not None \
            else max(profile.n_max(window), 1)
        if phase not in ("decode", "prefill"):
            raise ValueError(f"unknown engine phase {phase!r}")
        if phase == "prefill" and cfg is not None:
            raise ValueError("prefill-phase engines are analytical-only")
        self.phase = phase
        self.prefill_chunk = resolve_prefill_chunk(profile, prefill_chunk,
                                                   phase)
        # MFU every prefill charge is drawn at: the calibrated interleave
        # MFU by default; disagg prefill pools pass their dedicated-prefill
        # MFU (core.disagg.Disaggregated.prefill_mfu)
        self.prefill_mfu = PREFILL_MFU if prefill_mfu is None else prefill_mfu
        self.evict_on_overflow = evict_on_overflow
        self.respect_arrival = respect_arrival
        self.vocab = vocab
        self.meter = EnergyMeter(profile)
        # MoE all-to-all attribution: the floor is already inside the
        # profile roofline's w_ms (core.moe.with_dispatch_floor); telling
        # the meter lets it label that share of every decode charge
        self.meter.dispatch_s = max(dispatch_ms, 0.0) * 1e-3
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * self.n_slots
        n = self.n_slots
        self.pos = np.zeros(n, np.int32)            # next write position
        self.tokens = np.zeros(n, np.int64)         # last emitted token
        self.gen_count = np.zeros(n, np.int32)      # emitted tokens per slot
        self.m_gen = np.zeros(n, np.int32)          # ...metered in-window
        self.max_new = np.zeros(n, np.int32)
        self.prefill_left = np.zeros(n, np.int64)   # unmetered prefill tokens
        self.escalate_at = np.full(n, _NEVER, np.int32)  # misroute detection
        self._active = np.zeros(n, bool)
        self.preempted = 0
        self.n_escalated = 0                        # misroutes evicted here
        self.slot_seconds = 0.0                     # occupancy integral
        self.completed: List[Request] = []
        self.overflowed: List[Request] = []         # evicted at the window
        self.escalated: List[Request] = []          # semantic misroutes out
        self.handoff: List[Request] = []            # prefill-phase outbox
        self.relayed: List[Request] = []            # all handed-off (stats)
        if cfg is not None:
            self._streamed_params = cfg.analytical_spec().streamed_params
            self._init_model(cfg, params)
        else:
            if streamed_params is None:
                raise ValueError("analytical mode needs streamed_params")
            self._streamed_params = float(streamed_params)
            self.cache = None
            self._step_fn = self._prefill = None
            self._gen_buf = None
        self._seed = np.int64(rng_seed)
        # FleetScope sink (serving.telemetry.TraceRecorder): None =
        # telemetry off; every hook is an `is not None` guard around
        # pure reads, so disabled runs are bit-identical
        self.trace = None
        self._trace_pool = 0
        self._trace_inst = 0

    def attach_trace(self, recorder, *, name: Optional[str] = None,
                     instance: int = 0) -> None:
        """Opt this engine into FleetScope tracing.  `name` overrides the
        trace pool label (parity tests run scalar reference engines under
        the batched pool's name with their instance index); the meter's
        charge channel is only wired at level="detail"."""
        self.trace = recorder
        self._trace_pool = recorder.pool_id(name or self.name,
                                            instances=1)
        self._trace_inst = instance
        self.meter.trace = recorder if recorder.detail else None
        self.meter.trace_pool = self._trace_pool
        self.meter.trace_instance = instance

    def _init_model(self, cfg, params) -> None:
        import jax
        from repro.models import model as M
        self.cache = M.init_cache(cfg, self.n_slots, self.window)
        self._step_fn = jax.jit(
            lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos))
        self._prefill = jax.jit(
            lambda p, toks: M.forward(p, cfg, {"tokens": toks},
                                      mode="prefill"))
        # exact token streams are kept per-slot; grown on demand in _admit
        self._gen_buf = np.zeros((self.n_slots, 64), np.int64)

    # --- admission ------------------------------------------------------
    @property
    def n_active(self) -> int:
        return int(self._active.sum())

    @property
    def busy(self) -> bool:
        return bool(self.queue or self._active.any())

    def submit(self, req: Request) -> None:
        req.pool = self.name
        self.queue.append(req)

    def _ready(self, req: Request) -> float:
        return req.ready_time if req.ready_time is not None \
            else req.arrival_time

    def advance_to(self, t: float) -> None:
        """Idle the engine forward to wall time t (idle power accrues)."""
        if t > self.meter.sim_time_s:
            self.meter.charge_idle(t - self.meter.sim_time_s)

    def _admit(self) -> None:
        while self.queue and not self._active.all():
            req = self.queue[0]
            if self.respect_arrival \
                    and self._ready(req) > self.meter.sim_time_s:
                break
            self.queue.popleft()
            slot = int(np.flatnonzero(~self._active)[0])
            plen = req.prompt_len
            if self.trace is not None and self.trace.detail:
                self.trace.event(EV_ADMIT, req.rid, self._trace_pool,
                                 self._trace_inst, self.meter.sim_time_s)
            if req.prefill_done:
                # disagg decode pool: the prompt was drained by a dedicated
                # prefill pool and its KV arrived over the interconnect —
                # no prefill work, charge or first-token emission here
                assert self.cfg is None, \
                    "prefilled admission is analytical-mode only"
                self.slots[slot] = req
                self._active[slot] = True
                self.pos[slot] = plen
                self.max_new[slot] = req.max_new_tokens
                self.prefill_left[slot] = 0
                self.gen_count[slot] = 1
                self.escalate_at[slot] = req.escalate_at \
                    if req.escalate_at is not None else _NEVER
                self.tokens[slot] = int(req.generated[0]) if req.generated \
                    else int((np.int64(req.rid) * _LCG_A + self._seed
                              + _LCG_C) % self.vocab)
                continue
            if self._prefill is not None:
                import jax.numpy as jnp
                prompt = jnp.asarray(req.prompt[None, :])
                logits, cache, _ = self._prefill(self.params, prompt)
                self._splice(cache, slot, plen)
                first_tok = int(jnp.argmax(logits[0, -1]))
                if self._gen_buf.shape[1] < req.max_new_tokens:
                    grow = np.zeros((self.n_slots, req.max_new_tokens),
                                    np.int64)
                    grow[:, :self._gen_buf.shape[1]] = self._gen_buf
                    self._gen_buf = grow
                self._gen_buf[slot, 0] = first_tok
            else:
                # analytical mode: deterministic LCG token stream
                first_tok = int((np.int64(req.rid) * _LCG_A + self._seed
                                 + _LCG_C) % self.vocab)
            self.slots[slot] = req
            self._active[slot] = True
            self.pos[slot] = plen
            self.max_new[slot] = req.max_new_tokens
            self.escalate_at[slot] = req.escalate_at \
                if req.escalate_at is not None else _NEVER
            if self.prefill_chunk:
                # chunked interleave: prefill energy rides decode iterations
                self.prefill_left[slot] = plen
                self.gen_count[slot] = 0
                self.tokens[slot] = first_tok  # emitted when prefill drains
                req.generated = []
            else:
                self.meter.charge_prefill(
                    plen, mfu=self.prefill_mfu,
                    streamed_params=self._streamed_params)
                self.prefill_left[slot] = 0
                self.gen_count[slot] = 1
                self.tokens[slot] = first_tok
                req.generated = [first_tok]
                req.n_generated = 1
                req.first_token_time = self.meter.sim_time_s
                if self.trace is not None:
                    self.trace.event(EV_FIRST_TOKEN, req.rid,
                                     self._trace_pool, self._trace_inst,
                                     req.first_token_time)

    def _splice(self, prefill_cache, slot: int, plen: int) -> None:
        """Write a single-sequence prefill cache into slab slot `slot`."""
        import jax

        def put(slab, piece):
            piece0 = piece[:, 0]  # drop the size-1 prefill batch axis
            if piece0.shape == slab.shape[:1] + slab.shape[2:]:
                return slab.at[:, slot].set(piece0)      # O(1)-state caches
            # attention K/V: prefill wrote t <= slab-seq slots (SWA caches
            # arrive already ring-aligned from attention_full)
            t = min(piece0.shape[1], slab.shape[2])
            return slab.at[:, slot, :t].set(piece0[:, -t:])

        self.cache = jax.tree.map(put, self.cache, prefill_cache)

    # --- preemption (paper §10.1: "KV-cache eviction under memory
    # pressure ... reduces achievable throughput") ------------------------
    def _clear_slot(self, slot: int) -> None:
        self.slots[slot] = None
        self._active[slot] = False
        self.prefill_left[slot] = 0
        self.gen_count[slot] = 0
        self.m_gen[slot] = 0
        self.escalate_at[slot] = _NEVER

    def preempt(self, slot: int) -> None:
        """Evict a running request back to the queue (its KV is dropped;
        it will re-prefill on re-admission — the real cost of eviction)."""
        req = self.slots[slot]
        if req is None:
            return
        req.generated = None      # restart generation on re-admission
        req.preemptions += 1
        self.queue.appendleft(req)
        self._clear_slot(slot)
        self.preempted += 1

    def shrink(self, new_slots: int) -> None:
        """Memory-pressure response: reduce live concurrency by evicting
        the youngest requests (least wasted work)."""
        while self.n_active > new_slots:
            ages = [(self.pos[i] - s.prompt_len, i)
                    for i, s in enumerate(self.slots) if s is not None]
            _, victim = min(ages)
            self.preempt(victim)

    def _back_out_and_evict(self, slot: int) -> Request:
        """Shared eviction bookkeeping: the slot's decode work so far is
        wasted (the request re-prefills elsewhere), so the emitted tokens
        are backed out of the meter — mirroring the analytical accounting
        in core.routing (FleetOpt and Semantic both subtract wasted-pool
        output from tokens_per_s).  The energy stays: it was really
        spent."""
        req = self.slots[slot]
        # metered decode tokens only: the first token came from prefill;
        # the windowed counter gives back exactly the slot's in-window share
        self.meter.tokens -= max(int(self.gen_count[slot]) - 1, 0)
        self.meter.m_tokens -= int(self.m_gen[slot])
        req.generated = None
        req.prefill_done = False    # its KV is dropped: the destination
        req.preemptions += 1        # (re-)prefills from scratch
        req.ready_time = self.meter.sim_time_s
        req.escalate_at = None      # any eviction lands the request in the
        self._clear_slot(slot)      # large pool: never re-escalate there
        self.preempted += 1
        return req

    def _evict_overflow(self, slot: int) -> None:
        """FleetOpt migration: the request hit the pool window mid-flight
        and re-prefills one rung up the ladder."""
        req = self._back_out_and_evict(slot)
        if self.trace is not None:
            self.trace.event(EV_OVERFLOW, req.rid, self._trace_pool,
                             self._trace_inst, req.ready_time)
        self.overflowed.append(req)

    def _evict_escalation(self, slot: int) -> None:
        """Semantic misroute detected: the small model generated
        `escalate_at` tokens before the quality monitor caught it.  The
        request leaves for the large-model pool (FleetSim's escalation
        edge) to be re-served from scratch; the wasted small-pool tokens
        were backed out, so escalated output is never double-counted."""
        req = self._back_out_and_evict(slot)   # clears the escalation tag
        req.escalations += 1
        self.n_escalated += 1
        if self.trace is not None:
            self.trace.event(EV_ESCALATE, req.rid, self._trace_pool,
                             self._trace_inst, req.ready_time)
        self.escalated.append(req)

    # --- one continuous-batching iteration ------------------------------
    def _next_tokens(self) -> np.ndarray:
        """(n_slots,) next token per slot — jitted argmax in model mode,
        LCG stream in analytical mode."""
        if self._step_fn is not None:
            import jax.numpy as jnp
            toks = jnp.asarray(self.tokens[:, None])
            pos = jnp.asarray(self.pos)
            logits, self.cache = self._step_fn(self.params, toks,
                                               self.cache, pos)
            return np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        return (self.tokens * _LCG_A + _LCG_C + self._seed) % self.vocab

    def _drain_prefill_chunk(self, overlap_s: float = 0.0) -> None:
        """Meter up to `prefill_chunk` pending prefill tokens riding this
        iteration; slots whose budget drains emit their first token.  The
        first chunk hides behind this iteration's decode tau (`overlap_s`)
        — compute-bound prefill piggybacking on the memory-bound decode."""
        budget = self.prefill_chunk
        pending = np.flatnonzero(self._active & (self.prefill_left > 0))
        for i in pending:           # few slots are ever mid-prefill
            if budget <= 0:
                break
            take = int(min(budget, self.prefill_left[i]))
            if self.trace is not None and self.trace.detail:
                self.trace.event(EV_PREFILL, self.slots[i].rid,
                                 self._trace_pool, self._trace_inst,
                                 self.meter.sim_time_s)
            self.meter.charge_prefill(
                take, mfu=self.prefill_mfu,
                streamed_params=self._streamed_params,
                overlap_s=overlap_s)
            overlap_s = 0.0         # only one chunk rides each decode pass
            self.prefill_left[i] -= take
            budget -= take
            if self.prefill_left[i] == 0:
                req = self.slots[i]
                self.gen_count[i] = 1
                req.generated = [int(self.tokens[i])] \
                    if self._gen_buf is None else [int(self._gen_buf[i, 0])]
                req.n_generated = 1
                req.first_token_time = self.meter.sim_time_s
                if self.trace is not None:
                    self.trace.event(EV_FIRST_TOKEN, req.rid,
                                     self._trace_pool, self._trace_inst,
                                     req.first_token_time)

    def _finish_prefill(self, slot: int) -> None:
        """Prefill-phase completion: the prompt drained, the last forward
        emitted the first token; the request leaves for the paired decode
        pool via the `handoff` outbox (FleetSim adds the KV-migration
        delay on top of `ready_time` and charges the transfer energy)."""
        req = self.slots[slot]
        req.n_generated = 1
        req.generated = [int(self.tokens[slot])]
        req.first_token_time = self.meter.sim_time_s
        req.prefill_done = True
        req.ready_time = self.meter.sim_time_s
        if self.trace is not None:
            self.trace.event(EV_FIRST_TOKEN, req.rid, self._trace_pool,
                             self._trace_inst, req.first_token_time)
            self.trace.event(EV_HANDOFF, req.rid, self._trace_pool,
                             self._trace_inst, req.ready_time)
        self.handoff.append(req)
        self.relayed.append(req)
        self._clear_slot(slot)

    def _step_prefill(self) -> int:
        """One prefill-phase iteration: drain up to `prefill_chunk` prompt
        tokens across the occupied slots, oldest request first (slot
        indices recycle, so raw index order would let a fresh giant prompt
        starve an almost-drained older one)."""
        t_start = self.meter.sim_time_s
        self._admit()
        n_occupied = int(self._active.sum())
        pending = sorted(
            np.flatnonzero(self._active & (self.prefill_left > 0)),
            key=lambda i: self._ready(self.slots[int(i)]))
        budget = self.prefill_chunk
        n_work = 0
        for i in pending:
            if budget <= 0:
                break
            take = int(min(budget, self.prefill_left[i]))
            if self.trace is not None and self.trace.detail:
                self.trace.event(EV_PREFILL, self.slots[int(i)].rid,
                                 self._trace_pool, self._trace_inst,
                                 self.meter.sim_time_s)
            self.meter.charge_prefill(
                take, mfu=self.prefill_mfu,
                streamed_params=self._streamed_params)
            self.prefill_left[i] -= take
            budget -= take
            n_work += take
            if self.prefill_left[i] == 0:
                self._finish_prefill(int(i))
        self.slot_seconds += n_occupied * (self.meter.sim_time_s - t_start)
        if self.trace is not None and self.trace.detail:
            dt = self.meter.sim_time_s - t_start
            if dt > 0.0:
                self.trace.occupancy_sample(self._trace_pool,
                                            self._trace_inst, t_start,
                                            dt, n_occupied)
        return n_work

    def step(self) -> int:
        if self.phase == "prefill":
            return self._step_prefill()
        t_start = self.meter.sim_time_s
        self._admit()
        # occupancy counts every held slot — including those still waiting
        # on chunked prefill — for however long this iteration takes
        n_occupied = int(self._active.sum())
        dec = self._active & (self.prefill_left == 0)
        n_dec = int(dec.sum())
        tau = 0.0
        if n_dec:
            nxt = self._next_tokens()
            mean_ctx = float(self.pos[dec].mean())
            tau = self.meter.charge_decode_step(n_dec, mean_ctx)
            # --- slot-batched bookkeeping (no per-slot Python here) ------
            if self.meter.last_charge_in_window:
                self.m_gen[dec] += 1
            self.tokens[dec] = nxt[dec]
            if self._gen_buf is not None:
                self._gen_buf[dec, self.gen_count[dec]] = nxt[dec]
            self.gen_count[dec] += 1
            self.pos[dec] += 1
            done = dec & (self.gen_count >= self.max_new)
            # semantic misroute detection fires before the window ceiling:
            # a misrouted giant prompt escalates on quality, not on length
            # (a request that finishes under the detection latency simply
            # completes — short outputs never reach the monitor)
            escalate = dec & ~done & (self.gen_count >= self.escalate_at)
            at_ceiling = dec & ~done & ~escalate \
                & (self.pos >= self.window - 1)
            if not self.evict_on_overflow:
                done |= at_ceiling      # legacy: truncate at the window
            for i in np.flatnonzero(done):  # touches finishing slots only
                self._finish(int(i))
            for i in np.flatnonzero(escalate):
                self._evict_escalation(int(i))
            if self.evict_on_overflow:
                for i in np.flatnonzero(at_ceiling):
                    self._evict_overflow(int(i))
        if self.prefill_chunk:
            self._drain_prefill_chunk(overlap_s=tau)
        self.slot_seconds += n_occupied * (self.meter.sim_time_s - t_start)
        if self.trace is not None and self.trace.detail:
            dt = self.meter.sim_time_s - t_start
            if dt > 0.0:
                self.trace.occupancy_sample(self._trace_pool,
                                            self._trace_inst, t_start,
                                            dt, n_occupied)
        return n_dec

    def _finish(self, slot: int) -> None:
        req = self.slots[slot]
        n = int(self.gen_count[slot])
        req.n_generated = n
        if self._gen_buf is not None:
            req.generated = [int(t) for t in self._gen_buf[slot, :n]]
        else:
            req.generated = None    # analytical mode: ids are synthetic
        req.finish_time = self.meter.sim_time_s
        if self.trace is not None:
            self.trace.event(EV_COMPLETE, req.rid, self._trace_pool,
                             self._trace_inst, req.finish_time)
        self.completed.append(req)
        self._clear_slot(slot)

    def run_until_drained(self, max_iters: int = 100_000) -> None:
        it = 0
        while self.busy and it < max_iters:
            if self.respect_arrival and self.n_active == 0 and self.queue:
                # event-driven idle skip: jump to the next arrival
                self.advance_to(min(self._ready(r) for r in self.queue))
            self.step()
            it += 1
        if self.busy:
            raise DrainTruncatedError(
                self.name, max_iters,
                f"{len(self.queue)} queued, {self.n_active} in flight")

    def latency_percentiles(self) -> Dict[str, float]:
        return latency_percentiles(self.completed)

    def measured_totals(self) -> Dict[str, float]:
        """Unrounded steady-state-windowed (tokens, joules) — the fleet
        roll-up sums these so report paths agree exactly."""
        return dict(tokens=self.meter.m_tokens, joules=self.meter.m_joules)

    @property
    def occupancy(self) -> float:
        """Mean fraction of the slot slab in use while the clock ran."""
        denom = self.n_slots * self.meter.sim_time_s
        return self.slot_seconds / denom if denom else 0.0

    def stats(self) -> Dict[str, float]:
        return dict(name=self.name, window=self.window,
                    n_slots=self.n_slots,
                    completed=len(self.completed),
                    relayed=len(self.relayed),
                    preempted=self.preempted,
                    tokens=self.meter.tokens,
                    joules=round(self.meter.joules, 1),
                    # steady-state-windowed counters (mirror the totals when
                    # the meter window is left at its (0, inf) default)
                    m_tokens=self.meter.m_tokens,
                    m_joules=round(self.meter.m_joules, 1),
                    tok_per_watt=round(self.meter.tok_per_watt, 3),
                    sim_time_s=round(self.meter.sim_time_s, 3),
                    occupancy=round(self.occupancy, 3),
                    **self.latency_percentiles())
