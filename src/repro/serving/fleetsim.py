"""Fleet-scale serving simulator: the measured side of the paper's claims.

The analytical layer (core.fleet / core.routing) *predicts* fleet tok/W from
closed-form sizing; everything here *measures* it by actually running the
fleet: N analytical-mode `PoolEngine`s per provisioned pool, fed Poisson
arrivals drawn from the shared `core.workloads` traces through the same
`ContextRouter` the token-level engine uses, with chunked-prefill
interleave, FleetOpt overflow migration (preemption + re-prefill in the
long pool), and per-iteration `EnergyMeter` charging.  The output is
measured fleet tok/s, tok/W, TTFT/TPOT percentiles and per-pool occupancy
that can be put head-to-head against the `core.fleet` prediction — the
TokenPowerBench-style measurement cross-check of the 1/W law.

Execution model (event-driven, per-engine timelines):

  * Routing is context-length-based and time-independent, so every request
    is routed up front; each engine then advances its own clock through its
    private event sequence (idle-skip to next arrival, decode iterations of
    tau(n, L), chunked prefill charges).  Engines never need a shared clock
    — except for cross-pool request flow, which is always *forward* in the
    pool order: overflow migrations flow toward larger windows (pool i ->
    pool i+1 in the admission ladder; FleetOpt's short -> long is the K = 2
    case), the disaggregated kinds add the prefill -> decode KV-handoff
    hop within each window slice (plus decode-short -> prefill-long
    re-prefill on overflow), and the semantic kinds add the small-model ->
    large-model escalation hop for detected misroutes (serving.router).
    Every dependency forms a DAG, so pools run in
    topological order — ascending window, prefill before its paired decode
    — each pool drains, and its evicted / handed-off requests are injected
    into the destination pool's (time-sorted) queue carrying their eviction
    or handoff-completion timestamps (a handoff's `ready_time` includes the
    KV-migration delay over the interconnect, whose link + HBM energy is
    charged to the prefill engine's meter as non-output energy).  A K-pool
    request can migrate several hops (short -> mid -> long); `migrations`
    counts overflow hops, `handoffs` counts KV migrations.
  * Within a pool, requests are balanced over the N engine replicas by
    least *total assigned* predicted work (prompt + predicted output
    tokens).  All routing happens before any engine runs, so "outstanding"
    work cannot decay between assignments — cumulative assigned work is
    the correct (and intended) balancing key.

Energy accounting note: the analytical Eq. 4 number charges decode power
only; the simulator additionally meters prefill energy and idle power, so
its all-in tok/W sits *below* the analytical prediction.  The report
exposes both `tok_per_watt` (all-in) and `decode_tok_per_watt` (prefill
and idle energy backed out) — the latter is the like-for-like comparison
the integration test asserts against `core.fleet`.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.disagg import (HANDOFF_J_PER_BYTE, INTERCONNECT_BPS,
                               Disaggregated)
from repro.core.fleet import FleetReport, PoolOverride, apply_overrides
from repro.core.modelspec import LLAMA31_8B, ModelSpec
from repro.core.moe import with_dispatch_floor
from repro.core.multipool import MultiPool
from repro.core.profiles import BaseProfile, computed_profile
from repro.core.routing import (LONG_WINDOW, FleetOpt, Homogeneous, Semantic,
                                TwoPool)
from repro.core.workloads import Workload

from .engine import PoolEngine, scaled_prefill_chunk
from .models import ModelBinding, ModelProfileRegistry
from .request import (Request, latency_percentiles as _percentiles,
                      sample_trace)
from .router import SEMANTIC_KINDS, ContextRouter, RouterPolicy


def trace_requests(workload: Workload, n: int, *, seed: int = 0,
                   max_total: int = LONG_WINDOW,
                   arrival_rate: Optional[float] = None) -> List[Request]:
    """n requests with (prompt, output) drawn from the workload trace and
    Poisson arrivals.  Prompts are zero-copy broadcast views — analytical
    engines only read the shape, so a 10k-request trace costs ~nothing."""
    mean_out = int(round(workload.mean_output))
    return [Request(
        rid=i, prompt=np.broadcast_to(np.int64(0), (p,)),
        max_new_tokens=o, arrival_time=t,
        # honest routing: the router sees prompt + E[output], never the
        # actual sampled output (core.routing.FleetOpt's assumption)
        predicted_output=mean_out)
        for i, (p, o, t) in enumerate(
            sample_trace(workload, n, seed=seed, max_total=max_total,
                         arrival_rate=arrival_rate))]


def topology_roles(kind: str, plan: FleetReport) -> List[str]:
    """Router role name per plan pool, ascending-window order.  Ties
    (a disagg slice's prefill and decode pools share a window) keep the
    plan's prefill-before-decode provisioning order — Python's sort is
    stable, and `core.fleet.apply_overrides` sorts the same way, so role
    alignment holds everywhere."""
    pools = sorted(plan.pools, key=lambda p: p.window)
    if kind == "homo":
        return ["homo"]
    if kind == "moe_pool":
        return ["moe"]
    if kind in ("two_pool", "fleetopt"):
        assert len(pools) == 2, [p.name for p in pools]
        return ["short", "long"]
    if kind in SEMANTIC_KINDS:
        assert len(pools) == 2, [p.name for p in pools]
        return ["small", "large"]
    if kind in ("multipool", "disagg", "disagg_fleetopt"):
        return [p.name for p in pools]
    raise ValueError(kind)


def build_topology(kind: str, workload: Workload, profile: BaseProfile,
                   model: ModelSpec, *, b_short: int = 4096,
                   gamma: float = 2.0, long_window: int = LONG_WINDOW,
                   windows: Optional[Sequence[int]] = None,
                   pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                   small_model: Optional[ModelSpec] = None,
                   small_profile: Optional[BaseProfile] = None,
                   misroute_rate: float = 0.0,
                   dispatch_ms: float = 0.0,
                   misroute_seed: int = 0,
                   ) -> Tuple[RouterPolicy, FleetReport, ModelProfileRegistry]:
    """(router policy, analytical sizing plan, model registry) for one §4
    topology, a K >= 3 `core.multipool` ladder (`kind="multipool"`, pass
    `windows`), or a model-heterogeneous kind — the same provisioning the
    simulator instantiates and the prediction it is measured against.
    `pool_overrides` layers per-role SLO recalibrations (core.slo) on the
    closed-form plan.

    Model-heterogeneous kinds (DESIGN.md §9):

      moe_pool          — homo ladder, but `model`/`profile` are an MoE and
                          `dispatch_ms` adds the expert all-to-all floor to
                          every decode iteration (core.moe).
      semantic          — §5.1: `small_model`/`small_profile` (default
                          Llama-8B @ TP1 on the same chip) behind the
                          B_short rung, `model` behind the long rung; no
                          overflow headroom (small pool serves at B_short).
      semantic_fleetopt — semantic + FleetOpt headroom: the small pool
                          serves at gamma * B_short so output mispredictions
                          finish in place; only semantic misroutes (rate
                          `misroute_rate`) and >gamma*B_short overflows
                          escalate.
      moe_semantic      — semantic_fleetopt with the MoE as the large model.
    """
    if misroute_rate and kind not in SEMANTIC_KINDS:
        raise ValueError(f"misroute_rate only applies to semantic kinds,"
                         f" not {kind!r}")
    if dispatch_ms and kind not in ("moe_pool", "moe_semantic"):
        raise ValueError(f"dispatch_ms only applies to MoE kinds,"
                         f" not {kind!r}")
    registry = ModelProfileRegistry.homogeneous(model, profile)
    if kind == "homo":
        rep = Homogeneous(window=long_window).provision(
            workload, profile, model)
        policy = RouterPolicy(kind="homo", b_short=b_short)
    elif kind == "moe_pool":
        # the MoE's per-iteration weight stream is already active-params
        # (the profile's roofline); the dispatch floor is folded into w_ms
        # so provisioning and simulation pay it identically
        prof = with_dispatch_floor(profile, dispatch_ms)
        rep = Homogeneous(window=long_window).provision(
            workload, prof, model)
        policy = RouterPolicy(kind="moe_pool", b_short=b_short)
        registry = ModelProfileRegistry.homogeneous(
            model, prof, dispatch_ms=dispatch_ms)
    elif kind in SEMANTIC_KINDS:
        if small_model is None:
            small_model = LLAMA31_8B
        if small_profile is None:
            # the paper's §5.1 small pool: the 8B-class model at TP1 on
            # the same accelerator generation as the large pool
            small_profile = computed_profile(
                small_model, profile.chip, profile.power_model, tp=1)
        large_profile = with_dispatch_floor(profile, dispatch_ms) \
            if kind == "moe_semantic" else profile
        sem = Semantic(b_short=b_short, small_profile=small_profile,
                       small_model=small_model,
                       gamma=1.0 if kind == "semantic" else gamma,
                       long_window=long_window,
                       misroute_rate=misroute_rate)
        rep = sem.provision(workload, large_profile, model)
        policy = RouterPolicy(kind=kind, b_short=b_short, gamma=sem.gamma,
                              misroute_rate=misroute_rate,
                              detect_tokens=sem.detect_tokens,
                              misroute_seed=misroute_seed)
        registry = ModelProfileRegistry(
            default=ModelBinding(model, large_profile,
                                 dispatch_ms=dispatch_ms))
        registry.bind("small", ModelBinding(small_model, small_profile))
        registry.bind("large", ModelBinding(model, large_profile,
                                            dispatch_ms=dispatch_ms))
    elif kind == "two_pool":
        rep = TwoPool(b_short=b_short, long_window=long_window).provision(
            workload, profile, model)
        policy = RouterPolicy(kind="two_pool", b_short=b_short,
                              p99_output=int(np.quantile(workload.outputs,
                                                         0.99)))
    elif kind == "fleetopt":
        # The serving RouterPolicy admits short iff predicted total <=
        # gamma * b_short and the short pool serves window gamma * b_short
        # (router.py semantics).  The analytical twin with the identical
        # traffic split and overflow boundary is FleetOpt(gamma*b_short,
        # gamma=1): admission and window both at gamma*b_short, requests
        # whose actual total overgrows it migrate.
        rep = FleetOpt(b_short=int(gamma * b_short), gamma=1.0,
                       long_window=long_window).provision(
            workload, profile, model)
        policy = RouterPolicy(kind="fleetopt", b_short=b_short, gamma=gamma)
    elif kind == "multipool":
        if not windows:
            raise ValueError("kind='multipool' needs an ascending `windows`"
                             " ladder (e.g. core.multipool.ladder_windows)")
        rep = MultiPool(windows=list(windows), gamma=gamma).provision(
            workload, profile, model)
        pools = sorted(rep.pools, key=lambda p: p.window)
        if not pools:
            raise ValueError("multipool plan provisioned no pools")
        # admission at window/gamma (route-at-w/gamma, serve-at-w overflow
        # headroom); the largest surviving pool takes everything else
        ladder = [(p.name, p.window / gamma) for p in pools[:-1]]
        ladder.append((pools[-1].name, math.inf))
        policy = RouterPolicy(kind="multipool", gamma=gamma, ladder=ladder)
    elif kind in ("disagg", "disagg_fleetopt"):
        # Same analytical-twin convention as fleetopt: the serving router
        # admits short iff predicted total <= gamma * b_short and the short
        # slice serves that same window, so the twin is
        # Disaggregated(gamma * b_short, gamma=1).  Admission routes to the
        # *prefill* roles; decode pools are fed only by the handoff hop.
        dis = Disaggregated(b_short=int(gamma * b_short), gamma=1.0,
                            long_window=long_window,
                            split=(kind == "disagg_fleetopt"))
        rep = dis.provision(workload, profile, model)
        prefill = [p for p in sorted(rep.pools, key=lambda p: p.window)
                   if p.phase == "prefill"]
        ladder = [(p.name, float(p.window)) for p in prefill[:-1]]
        ladder.append((prefill[-1].name, math.inf))
        policy = RouterPolicy(kind=kind, b_short=b_short, gamma=gamma,
                              ladder=ladder)
    else:
        raise ValueError(kind)
    if pool_overrides:
        roles = topology_roles(kind, rep)
        apply_overrides(rep, pool_overrides, roles=roles,
                        streamed_params=registry.streamed_params_by_role(
                            roles))
    return policy, rep, registry


class PoolGroup:
    """N engine replicas serving one provisioned pool, balanced by least
    *total assigned* predicted work (prompt + predicted output for decode
    pools; prompt only for prefill-phase pools, whose work ends at the
    handoff).  Every request is routed before any engine runs (see the
    execution model above), so there is no notion of work "draining"
    between assignments — `_pending` is deliberately a monotone
    cumulative-assignment counter, which load-balances the whole trace
    across replicas.  Quacks like a PoolEngine for the router
    (submit / stats)."""

    def __init__(self, role: str, engines: List[PoolEngine]):
        self.role = role
        self.engines = engines
        self.phase = engines[0].phase
        self._pending = np.zeros(len(engines), np.float64)

    def submit(self, req: Request) -> None:
        i = int(np.argmin(self._pending))
        self._pending[i] += req.prompt_len if self.phase == "prefill" \
            else req.predicted_total
        self.engines[i].submit(req)

    @property
    def completed(self) -> List[Request]:
        return [r for e in self.engines for r in e.completed]

    @property
    def relayed(self) -> List[Request]:
        """Requests whose prefill this (prefill-phase) pool drained."""
        return [r for e in self.engines for r in e.relayed]

    def latency_percentiles(self) -> Dict[str, float]:
        """TTFT/TPOT/e2e percentiles of the requests that *finished* in
        this pool (a migrated request's TTFT counts where its prefill
        finally drained).  A prefill-phase pool finishes nothing — its
        percentiles cover the requests it relayed (their TTFT is this
        pool's doing; the downstream metrics are informational)."""
        return _percentiles(self.completed or self.relayed)

    def measured_totals(self) -> Dict[str, float]:
        return dict(tokens=sum(e.meter.m_tokens for e in self.engines),
                    joules=sum(e.meter.m_joules for e in self.engines))

    def stats(self) -> Dict[str, float]:
        tok = sum(e.meter.tokens for e in self.engines)
        joules = sum(e.meter.joules for e in self.engines)
        times = [e.meter.sim_time_s for e in self.engines]
        slot_s = sum(e.slot_seconds for e in self.engines)
        avail = sum(e.n_slots * t for e, t in zip(self.engines, times))
        return dict(role=self.role,
                    phase=self.phase,
                    window=self.engines[0].window,
                    instances=len(self.engines),
                    n_slots=self.engines[0].n_slots,
                    completed=sum(len(e.completed) for e in self.engines),
                    relayed=sum(len(e.relayed) for e in self.engines),
                    preempted=sum(e.preempted for e in self.engines),
                    escalated=sum(e.n_escalated for e in self.engines),
                    tokens=tok, joules=round(joules, 1),
                    m_tokens=sum(e.meter.m_tokens for e in self.engines),
                    m_joules=round(sum(e.meter.m_joules
                                       for e in self.engines), 1),
                    m_prefill_joules=round(sum(e.meter.m_prefill_joules
                                               for e in self.engines), 1),
                    tok_per_watt=round(tok / joules, 3) if joules else 0.0,
                    occupancy=round(slot_s / avail, 3) if avail else 0.0,
                    sim_time_s=round(max(times), 3) if times else 0.0)


class FleetSim:
    """Instantiate an analytical sizing plan as a fleet of running engines.

    `registry` (serving.models) binds each role to the model its pool
    serves; passing only `model` builds a homogeneous registry, which is
    every pre-model-heterogeneity topology.  Each engine streams *its own
    pool's* model bytes, and the per-engine prefill chunk is scaled by its
    pool profile's HBM bandwidth (`scaled_prefill_chunk`) so faster
    generations spend their surplus FLOPs on prompt processing instead of
    idling at the H100-calibrated chunk rate."""

    def __init__(self, policy: RouterPolicy, plan: FleetReport, *,
                 model: Optional[ModelSpec] = None,
                 registry: Optional[ModelProfileRegistry] = None,
                 prefill_chunk: int = 512,
                 rng_seed: int = 0,
                 kv_interconnect_Bps: float = INTERCONNECT_BPS,
                 kv_handoff_j_per_byte: float = HANDOFF_J_PER_BYTE):
        self.policy = policy
        self.plan = plan
        pools = sorted(plan.pools, key=lambda p: p.window)
        if registry is None:
            if model is None:
                raise ValueError("FleetSim needs a model or a registry")
            registry = ModelProfileRegistry.homogeneous(
                model, pools[0].profile)
        self.registry = registry
        self.model = registry.default.model
        self.kv_interconnect_Bps = kv_interconnect_Bps
        self.kv_handoff_j_per_byte = kv_handoff_j_per_byte
        role_names = topology_roles(policy.kind, plan)
        roles = list(zip(role_names, pools))
        # topological DAG order: ascending window, and within a disagg
        # slice prefill before its paired decode (the provisioning order —
        # the window sort is stable)
        self.order = role_names
        self.groups: Dict[str, PoolGroup] = {}
        decode_roles = [(r, p) for r, p in roles if p.phase != "prefill"]
        terminal_decode = decode_roles[-1][0] if decode_roles else None
        for idx, (role, p) in enumerate(roles):
            # Overflow headroom ends at the pool window: a request routed
            # here that outgrows it migrates one hop up the ladder
            # (preemption + re-prefill in the next pool).  FleetOpt's short
            # pool, every non-terminal multipool rung, every non-terminal
            # disagg decode pool and the semantic small-model pool evict;
            # terminal pools truncate at their window, like the token-level
            # engine.
            evict = (policy.kind == "fleetopt" and role == "short") \
                or (policy.kind == "multipool" and idx < len(roles) - 1) \
                or (policy.kind in SEMANTIC_KINDS and role == "small") \
                or (policy.kind == "disagg_fleetopt"
                    and p.phase != "prefill" and role != terminal_decode)
            binding = registry.for_role(role)
            chunk = scaled_prefill_chunk(p.profile, prefill_chunk) \
                if prefill_chunk else prefill_chunk
            engines = [
                PoolEngine(None, None, window=p.window, profile=p.profile,
                           name=f"{p.name}#{j}",
                           prefill_chunk=chunk,
                           phase=p.phase,
                           prefill_mfu=p.prefill_engine_mfu,
                           evict_on_overflow=evict, respect_arrival=True,
                           streamed_params=binding.streamed_params,
                           dispatch_ms=binding.dispatch_ms,
                           rng_seed=rng_seed + 7919 * j)
                for j in range(max(p.instances, 1))]
            self.groups[role] = PoolGroup(role, engines)
        # cross-pool edges, all pointing forward in `order`:
        #   handoff_to  — prefill role -> its slice's decode role
        #   overflow_to — evicting role -> where its evictions re-enter
        #                 (ladder kinds: next rung; disagg: next slice's
        #                 *prefill* pool, where the request re-prefills)
        #   escalate_to — semantic small-model role -> the large-model role
        #                 that re-serves detected misroutes from scratch
        self.handoff_to: Dict[str, str] = {}
        self.overflow_to: Dict[str, str] = {}
        self.escalate_to: Dict[str, str] = {}
        if policy.kind in ("disagg", "disagg_fleetopt"):
            dec_by_window = {p.window: r for r, p in decode_roles}
            pf_roles = [(r, p) for r, p in roles if p.phase == "prefill"]
            for r, p in pf_roles:
                self.handoff_to[r] = dec_by_window[p.window]
            for (r1, p1), (_, p2) in zip(decode_roles, decode_roles[1:]):
                pf_next = next(r for r, p in pf_roles
                               if p.window == p2.window)
                self.overflow_to[r1] = pf_next
            # per-role whole-instance KV bytes per prompt token
            self._kv_bytes_per_tok = {
                r: registry.for_role(r).model.kv_bytes_per_token(
                    tp=p.profile.tp) * p.profile.tp for r, p in pf_roles}
        else:
            for a, b in zip(self.order, self.order[1:]):
                self.overflow_to[a] = b
            if policy.kind in SEMANTIC_KINDS:
                self.escalate_to["small"] = "large"
            self._kv_bytes_per_tok = {}
        self.router = ContextRouter(self.groups, policy)
        self.migrations = 0
        self.handoffs = 0
        self.escalations = 0
        self._window: Tuple[float, float] = (0.0, float("inf"))

    def run(self, requests: List[Request], *, warmup_frac: float = 0.35,
            max_iters: int = 20_000_000) -> Dict[str, dict]:
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        # steady-state measurement window: skip the fleet fill-up, stop at
        # the last arrival (the drain tail is not steady state either)
        t_last = reqs[-1].arrival_time if reqs else 0.0
        self._window = (warmup_frac * t_last, t_last)
        for grp in self.groups.values():
            for e in grp.engines:
                e.meter.measure_t0, e.meter.measure_t1 = self._window
        for r in reqs:
            self.router.route(r)
        # topological order: cross-pool flow (overflow migrations and KV
        # handoffs) only points forward, so draining pools in `order` sees
        # every injected request before its destination runs
        inbox: Dict[str, List[Request]] = {role: [] for role in self.order}
        for role in self.order:
            grp = self.groups[role]
            if inbox[role]:
                for r in sorted(inbox[role], key=lambda r: r.ready_time):
                    grp.submit(r)
                for e in grp.engines:   # keep queues time-sorted for the
                    e.queue = deque(    # head-gated admission
                        sorted(e.queue, key=e._ready))
                inbox[role] = []
            for e in grp.engines:
                e.run_until_drained(max_iters=max_iters)
                if e.overflowed:
                    dest = self.overflow_to.get(role)
                    assert dest is not None, \
                        "the terminal pool may not overflow-evict"
                    self.migrations += len(e.overflowed)
                    inbox[dest].extend(e.overflowed)
                    e.overflowed = []
                if e.escalated:
                    dest = self.escalate_to.get(role)
                    assert dest is not None, \
                        "only the semantic small pool may escalate"
                    self.escalations += len(e.escalated)
                    inbox[dest].extend(e.escalated)
                    e.escalated = []
                if e.handoff:
                    dest = self.handoff_to[role]
                    kappa = self._kv_bytes_per_tok[role]
                    for r in e.handoff:
                        n_bytes = kappa * r.prompt_len
                        delay = n_bytes / self.kv_interconnect_Bps
                        e.meter.charge_handoff(
                            n_bytes, start_s=r.ready_time,
                            duration_s=delay,
                            j_per_byte=self.kv_handoff_j_per_byte)
                        r.ready_time += delay
                        r.prefill_role = role
                    self.handoffs += len(e.handoff)
                    inbox[dest].extend(e.handoff)
                    e.handoff = []
        assert not any(inbox.values()), "undelivered cross-pool requests"
        return self.report()

    def latency_by_role(self) -> Dict[str, Dict[str, float]]:
        """Per-pool latency percentiles (SLO-loop attribution: which rung
        of the ladder is busting the fleet TTFT)."""
        return {role: self.groups[role].latency_percentiles()
                for role in self.order}

    def report(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        completed: List[Request] = []
        tok = joules = prefill_j = idle_j = handoff_j = handoff_b = 0.0
        dispatch_j = 0.0
        for role, grp in self.groups.items():
            out[role] = grp.stats()
            completed += grp.completed
            tok += sum(e.meter.m_tokens for e in grp.engines)
            joules += sum(e.meter.m_joules for e in grp.engines)
            prefill_j += sum(e.meter.m_prefill_joules for e in grp.engines)
            idle_j += sum(e.meter.m_idle_joules for e in grp.engines)
            handoff_j += sum(e.meter.m_handoff_joules for e in grp.engines)
            handoff_b += sum(e.meter.m_handoff_bytes for e in grp.engines)
            dispatch_j += sum(e.meter.m_dispatch_joules
                              for e in grp.engines)
        # engines that sat idle past the window end never saw those idle
        # watts: charge the gap so the fleet denominator is wall-clock honest
        t0, t1 = self._window
        for grp in self.groups.values():
            for e in grp.engines:
                gap = t1 - max(e.meter.sim_time_s, t0)
                if gap > 0:
                    extra = e.profile.power_model.p_idle_w * gap
                    joules += extra
                    idle_j += extra
        span = max(t1 - t0, 1e-9)
        # decode-only backs out every non-output charge: prefill compute,
        # idle draw and the KV-handoff interconnect energy (core.disagg)
        decode_j = joules - prefill_j - idle_j - handoff_j
        out["fleet"] = dict(
            completed=len(completed),
            migrations=self.migrations,
            handoffs=self.handoffs,
            escalations=self.escalations,
            measure_window_s=(round(t0, 3), round(t1, 3)),
            tokens=int(tok), joules=round(joules, 1),
            tokens_per_s=round(tok / span, 1),
            tok_per_watt=round(tok / joules, 3) if joules else 0.0,
            decode_tok_per_watt=round(tok / decode_j, 3) if decode_j else 0.0,
            prefill_energy_frac=round(prefill_j / joules, 3) if joules
            else 0.0,
            idle_energy_frac=round(idle_j / joules, 3) if joules else 0.0,
            kv_handoff_joules=round(handoff_j, 3),
            kv_handoff_gb=round(handoff_b / 1e9, 3),
            kv_handoff_energy_frac=round(handoff_j / joules, 6) if joules
            else 0.0,
            # MoE all-to-all attribution: the dispatch share is *inside*
            # the decode charges (the roofline floor), so it is reported
            # as a fraction of fleet energy, never backed out
            moe_dispatch_joules=round(dispatch_j, 1),
            moe_dispatch_energy_frac=round(dispatch_j / joules, 4)
            if joules else 0.0,
            **_percentiles(completed))
        return out


def analytical_decode_tok_per_watt(plan: FleetReport) -> float:
    """Eq. 4 over the decode pools only — the closed-form twin of the
    simulator's `decode_tok_per_watt`.  Identical to `plan.tok_per_watt`
    for plans without prefill-phase pools."""
    dec = [p for p in plan.pools if p.phase != "prefill"]
    pw = sum(p.instances * p.power_w_per_instance for p in dec)
    return sum(p.tokens_per_s for p in dec) / pw if pw else 0.0


@dataclasses.dataclass
class SimVsAnalytical:
    """One head-to-head cell: measured fleet vs closed-form sizing.

    `analytical_tok_per_watt` is the like-for-like twin of
    `sim_decode_tok_per_watt`: for the disagg kinds that is the *decode
    fleet only* (the analytical whole-fleet number, which also pays the
    dedicated prefill pools, is kept in `analytical_fleet_tok_per_watt`);
    for every other kind the two analytical numbers coincide."""

    workload: str
    topology: str
    analytical_tok_per_watt: float
    sim_tok_per_watt: float          # all-in (prefill + idle metered)
    sim_decode_tok_per_watt: float   # like-for-like with Eq. 4
    report: Dict[str, dict]
    analytical_fleet_tok_per_watt: float = 0.0

    @property
    def delta_pct(self) -> float:
        """Decode-only simulated vs analytical, in percent."""
        return 100.0 * (self.sim_decode_tok_per_watt
                        / self.analytical_tok_per_watt - 1.0)

    def row(self) -> dict:
        f = self.report["fleet"]
        return dict(workload=self.workload, topology=self.topology,
                    analytical=round(self.analytical_tok_per_watt, 2),
                    simulated=round(self.sim_decode_tok_per_watt, 2),
                    delta_pct=round(self.delta_pct, 1),
                    all_in=round(self.sim_tok_per_watt, 2),
                    ttft_p99_s=f.get("ttft_p99_s", 0.0),
                    migrations=f["migrations"])


def simulate_topology(kind: str, workload: Workload, profile: BaseProfile,
                      model: ModelSpec, *, b_short: int = 4096,
                      gamma: float = 2.0,
                      n_requests: int = 4000, seed: int = 0,
                      arrival_rate: Optional[float] = None,
                      prefill_chunk: int = 512,
                      windows: Optional[Sequence[int]] = None,
                      pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                      small_model: Optional[ModelSpec] = None,
                      small_profile: Optional[BaseProfile] = None,
                      misroute_rate: float = 0.0,
                      dispatch_ms: float = 0.0,
                      long_window: int = LONG_WINDOW) -> SimVsAnalytical:
    """Provision a topology analytically, then measure it end-to-end."""
    if arrival_rate is not None and arrival_rate != workload.arrival_rate:
        workload = dataclasses.replace(workload, arrival_rate=arrival_rate)
    if kind == "multipool" and windows:
        long_window = int(max(windows))
    policy, plan, registry = build_topology(
        kind, workload, profile, model, b_short=b_short, gamma=gamma,
        long_window=long_window, windows=windows,
        pool_overrides=pool_overrides, small_model=small_model,
        small_profile=small_profile, misroute_rate=misroute_rate,
        dispatch_ms=dispatch_ms, misroute_seed=seed)
    sim = FleetSim(policy, plan, registry=registry,
                   prefill_chunk=prefill_chunk, rng_seed=seed)
    reqs = trace_requests(workload, n_requests, seed=seed,
                          max_total=long_window)
    report = sim.run(reqs)
    return SimVsAnalytical(
        workload=workload.name, topology=kind,
        analytical_tok_per_watt=analytical_decode_tok_per_watt(plan),
        analytical_fleet_tok_per_watt=plan.tok_per_watt,
        sim_tok_per_watt=report["fleet"]["tok_per_watt"],
        sim_decode_tok_per_watt=report["fleet"]["decode_tok_per_watt"],
        report=report)
