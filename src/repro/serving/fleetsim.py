"""Fleet-scale serving simulator: the measured side of the paper's claims.

The analytical layer (core.fleet / core.routing) *predicts* fleet tok/W from
closed-form sizing; everything here *measures* it by actually running the
fleet: N analytical-mode `PoolEngine`s per provisioned pool, fed Poisson
arrivals drawn from the shared `core.workloads` traces through the same
`ContextRouter` the token-level engine uses, with chunked-prefill
interleave, FleetOpt overflow migration (preemption + re-prefill in the
long pool), and per-iteration `EnergyMeter` charging.  The output is
measured fleet tok/s, tok/W, TTFT/TPOT percentiles and per-pool occupancy
that can be put head-to-head against the `core.fleet` prediction — the
TokenPowerBench-style measurement cross-check of the 1/W law.

Execution model (event-driven, per-engine timelines):

  * Routing is context-length-based and time-independent, so every request
    is routed up front; each engine then advances its own clock through its
    private event sequence (idle-skip to next arrival, decode iterations of
    tau(n, L), chunked prefill charges).  Engines never need a shared clock
    — except for overflow migrations, which only flow toward larger
    windows (pool i -> pool i+1 in the admission ladder; FleetOpt's
    short -> long is the K = 2 case).  That dependency is a DAG, so pools
    run in ascending-window topological order: each pool drains, its
    evicted requests are injected into the next pool's (time-sorted) queue
    carrying their eviction timestamps, then the next pool drains.  A
    K-pool request can migrate several hops (short -> mid -> long);
    `migrations` counts hops, not unique requests.
  * Within a pool, requests are balanced over the N engine replicas by
    least *total assigned* predicted work (prompt + predicted output
    tokens).  All routing happens before any engine runs, so "outstanding"
    work cannot decay between assignments — cumulative assigned work is
    the correct (and intended) balancing key.

Energy accounting note: the analytical Eq. 4 number charges decode power
only; the simulator additionally meters prefill energy and idle power, so
its all-in tok/W sits *below* the analytical prediction.  The report
exposes both `tok_per_watt` (all-in) and `decode_tok_per_watt` (prefill
and idle energy backed out) — the latter is the like-for-like comparison
the integration test asserts against `core.fleet`.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fleet import FleetReport, PoolOverride, apply_overrides
from repro.core.modelspec import ModelSpec
from repro.core.multipool import MultiPool
from repro.core.profiles import BaseProfile
from repro.core.routing import LONG_WINDOW, FleetOpt, Homogeneous, TwoPool
from repro.core.workloads import Workload

from .engine import PoolEngine
from .request import (Request, latency_percentiles as _percentiles,
                      sample_trace)
from .router import ContextRouter, RouterPolicy


def trace_requests(workload: Workload, n: int, *, seed: int = 0,
                   max_total: int = LONG_WINDOW,
                   arrival_rate: Optional[float] = None) -> List[Request]:
    """n requests with (prompt, output) drawn from the workload trace and
    Poisson arrivals.  Prompts are zero-copy broadcast views — analytical
    engines only read the shape, so a 10k-request trace costs ~nothing."""
    mean_out = int(round(workload.mean_output))
    return [Request(
        rid=i, prompt=np.broadcast_to(np.int64(0), (p,)),
        max_new_tokens=o, arrival_time=t,
        # honest routing: the router sees prompt + E[output], never the
        # actual sampled output (core.routing.FleetOpt's assumption)
        predicted_output=mean_out)
        for i, (p, o, t) in enumerate(
            sample_trace(workload, n, seed=seed, max_total=max_total,
                         arrival_rate=arrival_rate))]


def topology_roles(kind: str, plan: FleetReport) -> List[str]:
    """Router role name per plan pool, ascending-window order."""
    pools = sorted(plan.pools, key=lambda p: p.window)
    if kind == "homo":
        return ["homo"]
    if kind in ("two_pool", "fleetopt"):
        assert len(pools) == 2, [p.name for p in pools]
        return ["short", "long"]
    if kind == "multipool":
        return [p.name for p in pools]
    raise ValueError(kind)


def build_topology(kind: str, workload: Workload, profile: BaseProfile,
                   model: ModelSpec, *, b_short: int = 4096,
                   gamma: float = 2.0, long_window: int = LONG_WINDOW,
                   windows: Optional[Sequence[int]] = None,
                   pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                   ) -> Tuple[RouterPolicy, FleetReport]:
    """(router policy, analytical sizing plan) for one §4 topology or a
    K >= 3 `core.multipool` ladder (`kind="multipool"`, pass `windows`) —
    the same provisioning the simulator instantiates and the prediction it
    is measured against.  `pool_overrides` layers per-role SLO
    recalibrations (core.slo) on the closed-form plan."""
    if kind == "homo":
        rep = Homogeneous(window=long_window).provision(
            workload, profile, model)
        policy = RouterPolicy(kind="homo", b_short=b_short)
    elif kind == "two_pool":
        rep = TwoPool(b_short=b_short, long_window=long_window).provision(
            workload, profile, model)
        policy = RouterPolicy(kind="two_pool", b_short=b_short,
                              p99_output=int(np.quantile(workload.outputs,
                                                         0.99)))
    elif kind == "fleetopt":
        # The serving RouterPolicy admits short iff predicted total <=
        # gamma * b_short and the short pool serves window gamma * b_short
        # (router.py semantics).  The analytical twin with the identical
        # traffic split and overflow boundary is FleetOpt(gamma*b_short,
        # gamma=1): admission and window both at gamma*b_short, requests
        # whose actual total overgrows it migrate.
        rep = FleetOpt(b_short=int(gamma * b_short), gamma=1.0,
                       long_window=long_window).provision(
            workload, profile, model)
        policy = RouterPolicy(kind="fleetopt", b_short=b_short, gamma=gamma)
    elif kind == "multipool":
        if not windows:
            raise ValueError("kind='multipool' needs an ascending `windows`"
                             " ladder (e.g. core.multipool.ladder_windows)")
        rep = MultiPool(windows=list(windows), gamma=gamma).provision(
            workload, profile, model)
        pools = sorted(rep.pools, key=lambda p: p.window)
        if not pools:
            raise ValueError("multipool plan provisioned no pools")
        # admission at window/gamma (route-at-w/gamma, serve-at-w overflow
        # headroom); the largest surviving pool takes everything else
        ladder = [(p.name, p.window / gamma) for p in pools[:-1]]
        ladder.append((pools[-1].name, math.inf))
        policy = RouterPolicy(kind="multipool", gamma=gamma, ladder=ladder)
    else:
        raise ValueError(kind)
    if pool_overrides:
        apply_overrides(rep, pool_overrides,
                        roles=topology_roles(kind, rep),
                        streamed_params=model.streamed_params)
    return policy, rep


class PoolGroup:
    """N engine replicas serving one provisioned pool, balanced by least
    *total assigned* predicted work (prompt + predicted output).  Every
    request is routed before any engine runs (see the execution model
    above), so there is no notion of work "draining" between assignments —
    `_pending` is deliberately a monotone cumulative-assignment counter,
    which load-balances the whole trace across replicas.  Quacks like a
    PoolEngine for the router (submit / stats)."""

    def __init__(self, role: str, engines: List[PoolEngine]):
        self.role = role
        self.engines = engines
        self._pending = np.zeros(len(engines), np.float64)

    def submit(self, req: Request) -> None:
        i = int(np.argmin(self._pending))
        self._pending[i] += req.predicted_total
        self.engines[i].submit(req)

    @property
    def completed(self) -> List[Request]:
        return [r for e in self.engines for r in e.completed]

    def latency_percentiles(self) -> Dict[str, float]:
        """TTFT/TPOT/e2e percentiles of the requests that *finished* in
        this pool (a migrated request's TTFT counts where its prefill
        finally drained)."""
        return _percentiles(self.completed)

    def measured_totals(self) -> Dict[str, float]:
        return dict(tokens=sum(e.meter.m_tokens for e in self.engines),
                    joules=sum(e.meter.m_joules for e in self.engines))

    def stats(self) -> Dict[str, float]:
        tok = sum(e.meter.tokens for e in self.engines)
        joules = sum(e.meter.joules for e in self.engines)
        times = [e.meter.sim_time_s for e in self.engines]
        slot_s = sum(e.slot_seconds for e in self.engines)
        avail = sum(e.n_slots * t for e, t in zip(self.engines, times))
        return dict(role=self.role,
                    window=self.engines[0].window,
                    instances=len(self.engines),
                    n_slots=self.engines[0].n_slots,
                    completed=sum(len(e.completed) for e in self.engines),
                    preempted=sum(e.preempted for e in self.engines),
                    tokens=tok, joules=round(joules, 1),
                    m_tokens=sum(e.meter.m_tokens for e in self.engines),
                    m_joules=round(sum(e.meter.m_joules
                                       for e in self.engines), 1),
                    tok_per_watt=round(tok / joules, 3) if joules else 0.0,
                    occupancy=round(slot_s / avail, 3) if avail else 0.0,
                    sim_time_s=round(max(times), 3) if times else 0.0)


class FleetSim:
    """Instantiate an analytical sizing plan as a fleet of running engines."""

    def __init__(self, policy: RouterPolicy, plan: FleetReport, *,
                 model: ModelSpec, prefill_chunk: int = 512,
                 rng_seed: int = 0):
        self.policy = policy
        self.plan = plan
        pools = sorted(plan.pools, key=lambda p: p.window)
        role_names = topology_roles(policy.kind, plan)
        roles = list(zip(role_names, pools))
        self.order = role_names              # ascending-window DAG order
        self.groups: Dict[str, PoolGroup] = {}
        for idx, (role, p) in enumerate(roles):
            # Overflow headroom ends at the pool window: a request routed
            # here that outgrows it migrates one hop up the ladder
            # (preemption + re-prefill in the next pool).  FleetOpt's short
            # pool and every non-terminal multipool rung evict; terminal
            # pools truncate at their window, like the token-level engine.
            evict = (policy.kind == "fleetopt" and role == "short") \
                or (policy.kind == "multipool" and idx < len(roles) - 1)
            engines = [
                PoolEngine(None, None, window=p.window, profile=p.profile,
                           name=f"{p.name}#{j}",
                           prefill_chunk=prefill_chunk,
                           evict_on_overflow=evict, respect_arrival=True,
                           streamed_params=model.streamed_params,
                           rng_seed=rng_seed + 7919 * j)
                for j in range(max(p.instances, 1))]
            self.groups[role] = PoolGroup(role, engines)
        self.router = ContextRouter(self.groups, policy)
        self.migrations = 0
        self._window: Tuple[float, float] = (0.0, float("inf"))

    def run(self, requests: List[Request], *, warmup_frac: float = 0.35,
            max_iters: int = 20_000_000) -> Dict[str, dict]:
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        # steady-state measurement window: skip the fleet fill-up, stop at
        # the last arrival (the drain tail is not steady state either)
        t_last = reqs[-1].arrival_time if reqs else 0.0
        self._window = (warmup_frac * t_last, t_last)
        for grp in self.groups.values():
            for e in grp.engines:
                e.meter.measure_t0, e.meter.measure_t1 = self._window
        for r in reqs:
            self.router.route(r)
        # topological order: overflow migrations only flow up the ladder
        # (pool i -> pool i+1), so draining pools in ascending-window order
        # sees every migration before its destination runs
        migrated: List[Request] = []
        for role in self.order:
            grp = self.groups[role]
            if migrated:
                self.migrations += len(migrated)
                for r in sorted(migrated, key=lambda r: r.ready_time):
                    grp.submit(r)
                for e in grp.engines:   # keep queues time-sorted for the
                    e.queue = deque(    # head-gated admission
                        sorted(e.queue, key=e._ready))
                migrated = []
            for e in grp.engines:
                e.run_until_drained(max_iters=max_iters)
                migrated.extend(e.overflowed)
                e.overflowed = []
        assert not migrated, "the terminal pool may not overflow-evict"
        return self.report()

    def latency_by_role(self) -> Dict[str, Dict[str, float]]:
        """Per-pool latency percentiles (SLO-loop attribution: which rung
        of the ladder is busting the fleet TTFT)."""
        return {role: self.groups[role].latency_percentiles()
                for role in self.order}

    def report(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        completed: List[Request] = []
        tok = joules = prefill_j = idle_j = 0.0
        for role, grp in self.groups.items():
            out[role] = grp.stats()
            completed += grp.completed
            tok += sum(e.meter.m_tokens for e in grp.engines)
            joules += sum(e.meter.m_joules for e in grp.engines)
            prefill_j += sum(e.meter.m_prefill_joules for e in grp.engines)
            idle_j += sum(e.meter.m_idle_joules for e in grp.engines)
        # engines that sat idle past the window end never saw those idle
        # watts: charge the gap so the fleet denominator is wall-clock honest
        t0, t1 = self._window
        for grp in self.groups.values():
            for e in grp.engines:
                gap = t1 - max(e.meter.sim_time_s, t0)
                if gap > 0:
                    extra = e.profile.power_model.p_idle_w * gap
                    joules += extra
                    idle_j += extra
        span = max(t1 - t0, 1e-9)
        decode_j = joules - prefill_j - idle_j
        out["fleet"] = dict(
            completed=len(completed),
            migrations=self.migrations,
            measure_window_s=(round(t0, 3), round(t1, 3)),
            tokens=int(tok), joules=round(joules, 1),
            tokens_per_s=round(tok / span, 1),
            tok_per_watt=round(tok / joules, 3) if joules else 0.0,
            decode_tok_per_watt=round(tok / decode_j, 3) if decode_j else 0.0,
            prefill_energy_frac=round(prefill_j / joules, 3) if joules
            else 0.0,
            idle_energy_frac=round(idle_j / joules, 3) if joules else 0.0,
            **_percentiles(completed))
        return out


@dataclasses.dataclass
class SimVsAnalytical:
    """One head-to-head cell: measured fleet vs closed-form sizing."""

    workload: str
    topology: str
    analytical_tok_per_watt: float
    sim_tok_per_watt: float          # all-in (prefill + idle metered)
    sim_decode_tok_per_watt: float   # like-for-like with Eq. 4
    report: Dict[str, dict]

    @property
    def delta_pct(self) -> float:
        """Decode-only simulated vs analytical, in percent."""
        return 100.0 * (self.sim_decode_tok_per_watt
                        / self.analytical_tok_per_watt - 1.0)

    def row(self) -> dict:
        f = self.report["fleet"]
        return dict(workload=self.workload, topology=self.topology,
                    analytical=round(self.analytical_tok_per_watt, 2),
                    simulated=round(self.sim_decode_tok_per_watt, 2),
                    delta_pct=round(self.delta_pct, 1),
                    all_in=round(self.sim_tok_per_watt, 2),
                    ttft_p99_s=f.get("ttft_p99_s", 0.0),
                    migrations=f["migrations"])


def simulate_topology(kind: str, workload: Workload, profile: BaseProfile,
                      model: ModelSpec, *, b_short: int = 4096,
                      gamma: float = 2.0,
                      n_requests: int = 4000, seed: int = 0,
                      arrival_rate: Optional[float] = None,
                      prefill_chunk: int = 512,
                      windows: Optional[Sequence[int]] = None,
                      pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                      long_window: int = LONG_WINDOW) -> SimVsAnalytical:
    """Provision a topology analytically, then measure it end-to-end."""
    if arrival_rate is not None and arrival_rate != workload.arrival_rate:
        workload = dataclasses.replace(workload, arrival_rate=arrival_rate)
    if kind == "multipool" and windows:
        long_window = int(max(windows))
    policy, plan = build_topology(kind, workload, profile, model,
                                  b_short=b_short, gamma=gamma,
                                  long_window=long_window, windows=windows,
                                  pool_overrides=pool_overrides)
    sim = FleetSim(policy, plan, model=model, prefill_chunk=prefill_chunk,
                   rng_seed=seed)
    reqs = trace_requests(workload, n_requests, seed=seed,
                          max_total=long_window)
    report = sim.run(reqs)
    return SimVsAnalytical(
        workload=workload.name, topology=kind,
        analytical_tok_per_watt=plan.tok_per_watt,
        sim_tok_per_watt=report["fleet"]["tok_per_watt"],
        sim_decode_tok_per_watt=report["fleet"]["decode_tok_per_watt"],
        report=report)
