"""Fleet-scale serving simulator: the measured side of the paper's claims.

The analytical layer (core.fleet / core.routing) *predicts* fleet tok/W from
closed-form sizing; everything here *measures* it by actually running the
fleet: one structure-of-arrays `BatchedPoolEngine` (serving.soa) per
provisioned pool — all `instances x n_slots` slots in one set of numpy
arrays, every instance stepped in lockstep — fed Poisson arrivals drawn
from the shared `core.workloads` traces through the same `ContextRouter`
the token-level engine uses, with chunked-prefill interleave, FleetOpt
overflow migration (preemption + re-prefill in the long pool), and
per-iteration `MeterBank` charging.  The output is measured fleet tok/s,
tok/W, TTFT/TPOT percentiles and per-pool occupancy that can be put
head-to-head against the `core.fleet` prediction — the TokenPowerBench-
style measurement cross-check of the 1/W law.  (The batched engines
replay the scalar `PoolEngine` semantics bit-for-bit — DESIGN.md §10.)

Execution model (event-driven, per-instance timelines):

  * Routing is context-length-based and time-independent, so every request
    is routed up front; each instance then advances its own clock through
    its private event sequence (idle-skip to next arrival, decode
    iterations of tau(n, L), chunked prefill charges) — the batched
    engine carries the diverging clocks as a `MeterBank` row per
    instance.  Instances never need a shared clock
    — except for cross-pool request flow, which is always *forward* in the
    pool order: overflow migrations flow toward larger windows (pool i ->
    pool i+1 in the admission ladder; FleetOpt's short -> long is the K = 2
    case), the disaggregated kinds add the prefill -> decode KV-handoff
    hop within each window slice (plus decode-short -> prefill-long
    re-prefill on overflow), and the semantic kinds add the small-model ->
    large-model escalation hop for detected misroutes (serving.router).
    Every dependency forms a DAG, so pools run in
    topological order — ascending window, prefill before its paired decode
    — each pool drains, and its evicted / handed-off requests are injected
    into the destination pool's (time-sorted) queue carrying their eviction
    or handoff-completion timestamps (a handoff's `ready_time` includes the
    KV-migration delay over the interconnect, whose link + HBM energy is
    charged to the prefill engine's meter as non-output energy).  A K-pool
    request can migrate several hops (short -> mid -> long); `migrations`
    counts overflow hops, `handoffs` counts KV migrations.
  * Within a pool, requests are balanced over the N engine replicas by
    least *total assigned* predicted work (prompt + predicted output
    tokens).  All routing happens before any engine runs, so "outstanding"
    work cannot decay between assignments — cumulative assigned work is
    the correct (and intended) balancing key.

Energy accounting note: the analytical Eq. 4 number charges decode power
only; the simulator additionally meters prefill energy and idle power, so
its all-in tok/W sits *below* the analytical prediction.  The report
exposes both `tok_per_watt` (all-in) and `decode_tok_per_watt` (prefill
and idle energy backed out) — the latter is the like-for-like comparison
the integration test asserts against `core.fleet`.
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autoscale import AutoscalePolicy
from repro.core.timeline import EV_ARRIVE, EV_ROUTE
from repro.core.disagg import HANDOFF_J_PER_BYTE, INTERCONNECT_BPS
from repro.core.fleet import FleetReport, PoolOverride
from repro.core.modelspec import ModelSpec
from repro.core.profiles import BaseProfile
from repro.core.routing import LONG_WINDOW
from repro.core.topospec import TopologySpec, plan_roles
from repro.core.workloads import Workload

from .autoscale import Autoscaler, InstanceSchedule
from .engine import scaled_prefill_chunk
from .models import ModelProfileRegistry
from .request import (Request, latency_percentiles as _percentiles,
                      latency_percentiles_arrays, sample_trace)
from .router import ContextRouter, RouterPolicy
from .soa import BatchedPoolEngine


def trace_requests(workload: Workload, n: int, *, seed: int = 0,
                   max_total: int = LONG_WINDOW,
                   arrival_rate: Optional[float] = None,
                   trace: Optional[List[Tuple[int, int, float]]] = None,
                   ) -> List[Request]:
    """n requests with (prompt, output) drawn from the workload trace and
    Poisson arrivals.  Prompts are zero-copy broadcast views — analytical
    engines only read the shape, so a 10k-request trace costs ~nothing.

    Pass `trace` (pre-sampled `sample_trace` triples) to materialise
    fresh Request objects over a *frozen* trace instead of re-sampling —
    the SLO loop's common-random-numbers path.  This function is the
    single source of the request-construction convention (zero-broadcast
    prompts, predicted_output = E[output] honest routing) for every
    consumer."""
    mean_out = int(round(workload.mean_output))
    if trace is None:
        trace = sample_trace(workload, n, seed=seed, max_total=max_total,
                             arrival_rate=arrival_rate)
    return [Request(
        rid=i, prompt=np.broadcast_to(np.int64(0), (p,)),
        max_new_tokens=o, arrival_time=t,
        # honest routing: the router sees prompt + E[output], never the
        # actual sampled output (core.routing.FleetOpt's assumption)
        predicted_output=mean_out)
        for i, (p, o, t) in enumerate(trace)]


def build_topology(kind: str, workload: Workload, profile: BaseProfile,
                   model: ModelSpec, *, b_short: int = 4096,
                   gamma: float = 2.0, long_window: int = LONG_WINDOW,
                   windows: Optional[Sequence[int]] = None,
                   pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                   small_model: Optional[ModelSpec] = None,
                   small_profile: Optional[BaseProfile] = None,
                   misroute_rate: float = 0.0,
                   dispatch_ms: float = 0.0,
                   misroute_seed: int = 0,
                   ) -> Tuple[RouterPolicy, FleetReport, ModelProfileRegistry]:
    """(router policy, analytical sizing plan, model registry) for one §4
    topology, a K >= 3 `core.multipool` ladder (`kind="multipool"`, pass
    `windows`), or a model-heterogeneous kind — the same provisioning the
    simulator instantiates and the prediction it is measured against.
    `pool_overrides` layers per-role SLO recalibrations (core.slo) on the
    closed-form plan.

    This is a thin legacy-kind front end: the kind string compiles to a
    `core.topospec.TopologySpec` (the declarative IR every layer reads —
    DESIGN.md §12) and everything is derived from the spec.  Build the
    spec directly (`TopologySpec.from_kind` or by hand) to keep it —
    e.g. for `core.topo_search.optimize_topology`."""
    spec = TopologySpec.from_kind(
        kind, profile, model, b_short=b_short, gamma=gamma,
        long_window=long_window, windows=windows, small_model=small_model,
        small_profile=small_profile, misroute_rate=misroute_rate,
        dispatch_ms=dispatch_ms, misroute_seed=misroute_seed)
    return spec.build(workload, pool_overrides=pool_overrides)


@dataclasses.dataclass
class PoolSummary:
    """Everything the fleet roll-up, the SLO loop and the cross-pool
    replay need from one drained pool, computed in a single pass.

    This is both the "single cached summary per measurement window" that
    deduplicates the old per-field `sum(... for e in self.engines)`
    aggregation passes in `FleetSim.report` / `PoolGroup.measured_totals`,
    and the unit of **incremental re-simulation**: `core.slo`'s sizing
    loop hands a prior round's summaries back to `FleetSim.run(reuse=...)`
    for every pool whose provisioning did not change, and the pool is
    replayed from this snapshot — its outbox clones re-injected downstream
    — instead of being re-simulated."""

    role: str
    phase: str
    window: int
    instances: int
    n_slots: int
    # steady-state-windowed occupancy integral + the window span it was
    # measured over: the SLO HOL calibration derives the pool's mean
    # occupied-slot population (m_slot_seconds / measure_span) from
    # these, unrounded and with ramp-in/drain transients excluded —
    # consistent with every other windowed measurement in the loop
    m_slot_seconds: float
    measure_span: float
    stats: Dict[str, float]
    lat: Dict[str, float]            # latency_by_role percentiles
    # steady-state-windowed meter roll-ups + lifetime totals
    m_tokens: int
    m_joules: float
    m_prefill_joules: float
    m_idle_joules: float
    m_handoff_joules: float
    m_handoff_bytes: float
    m_dispatch_joules: float
    tokens: int
    joules: float
    sim_times: np.ndarray            # per-instance clock at drain
    p_idle_w: float
    # per-completed-request metric columns (vectorized SLO attribution)
    arrival: np.ndarray
    first_token: np.ndarray
    finish: np.ndarray
    n_generated: np.ndarray
    ttft_role: np.ndarray            # index into FleetSim.order
    # cross-pool flow
    n_overflowed: int
    n_escalated: int
    n_handoffs: int
    outbox: Dict[str, List[Request]]  # dest role -> request snapshots
    # autoscaled pools: per-row retire times (serving.autoscale) — the
    # fleet roll-up stops charging a row's trailing idle at its retire
    # time instead of the window end.  None = always-on (steady state).
    online_until: Optional[np.ndarray] = None


class PoolGroup:
    """One provisioned pool: a `BatchedPoolEngine` simulating all its
    instance replicas in lockstep, plus the replica load balancer.
    Requests are balanced by least *total assigned* predicted work
    (prompt + predicted output for decode pools; prompt only for
    prefill-phase pools, whose work ends at the handoff).  Every request
    is routed before any engine runs (see the execution model above), so
    there is no notion of work "draining" between assignments —
    `_pending` is deliberately a monotone cumulative-assignment counter,
    which load-balances the whole trace across replicas.  Quacks like a
    PoolEngine for the router (submit / stats)."""

    def __init__(self, role: str, engine: BatchedPoolEngine):
        self.role = role
        self.engine = engine
        self.phase = engine.phase
        self._pending = np.zeros(engine.instances, np.float64)
        self.summary: Optional[PoolSummary] = None

    @property
    def instances(self) -> int:
        return self.engine.instances

    def submit(self, req: Request) -> None:
        eng = self.engine
        if eng.online_from is not None:
            # autoscaled pool: balance only over the rows whose online
            # window covers the request's ready time (a retired or
            # not-yet-started incarnation cannot admit).  The controller
            # keeps >= 1 row always online; the fallbacks below are
            # belt-and-braces, not a load-bearing path.
            t = eng._ready(req)
            elig = (eng.online_from <= t) & (t < eng.online_until)
            if not elig.any():
                elig = eng.online_from <= t
            if not elig.any():
                elig = np.ones(eng.instances, bool)
            i = int(np.argmin(np.where(elig, self._pending, np.inf)))
        else:
            i = int(np.argmin(self._pending))
        self._pending[i] += req.prompt_len if self.phase == "prefill" \
            else req.predicted_total
        self.engine.submit(req, i)

    def queue_rids(self, instance: int) -> List[int]:
        """Request ids queued on one replica (tests/debug)."""
        return [r.rid for r in self.engine.queues[instance]]

    @property
    def completed(self) -> List[Request]:
        return [r for lst in self.engine.completed for r in lst]

    @property
    def relayed(self) -> List[Request]:
        """Requests whose prefill this (prefill-phase) pool drained."""
        return [r for lst in self.engine.relayed for r in lst]

    @property
    def streamed_params(self) -> float:
        return self.engine._streamed_params

    @property
    def prefill_chunk(self) -> Optional[int]:
        return self.engine.prefill_chunk

    @property
    def dispatch_s(self) -> float:
        return self.engine.bank.dispatch_s

    @property
    def lifetime_tokens(self) -> int:
        return int(self.engine.bank.tokens.sum())

    def latency_percentiles(self) -> Dict[str, float]:
        """TTFT/TPOT/e2e percentiles of the requests that *finished* in
        this pool (a migrated request's TTFT counts where its prefill
        finally drained).  A prefill-phase pool finishes nothing — its
        percentiles cover the requests it relayed (their TTFT is this
        pool's doing; the downstream metrics are informational)."""
        if self.summary is not None:
            return dict(self.summary.lat)
        return _percentiles(self.completed or self.relayed)

    def measured_totals(self) -> Dict[str, float]:
        if self.summary is not None:
            return dict(tokens=self.summary.m_tokens,
                        joules=self.summary.m_joules)
        b = self.engine.bank
        return dict(tokens=int(b.m_tokens.sum()),
                    joules=float(b.m_joules.sum()))

    def stats(self) -> Dict[str, float]:
        if self.summary is not None:
            return dict(self.summary.stats)
        return self._compute_stats()

    def _compute_stats(self) -> Dict[str, float]:
        eng, b = self.engine, self.engine.bank
        tok = int(b.tokens.sum())
        joules = float(b.joules.sum())
        slot_s = float(eng.slot_seconds.sum())
        avail = eng.n_slots * float(b.sim_time_s.sum())
        extra = {}
        if eng.online_from is not None:
            # autoscaled pool: mean live instance count over the
            # measurement window (the steady-state path adds no keys, so
            # committed baseline stats are byte-identical)
            span = max(b.measure_t1 - b.measure_t0, 1e-9)
            lo = np.maximum(eng.online_from, b.measure_t0)
            hi = np.minimum(eng.online_until, b.measure_t1)
            extra["avg_online_instances"] = round(
                float(np.maximum(0.0, hi - lo).sum()) / span, 2)
        return dict(role=self.role,
                    **extra,
                    phase=self.phase,
                    window=eng.window,
                    instances=eng.instances,
                    n_slots=eng.n_slots,
                    completed=sum(len(c) for c in eng.completed),
                    relayed=sum(len(c) for c in eng.relayed),
                    preempted=int(eng.preempted.sum()),
                    escalated=int(eng.n_escalated.sum()),
                    tokens=tok, joules=round(joules, 1),
                    m_tokens=int(b.m_tokens.sum()),
                    m_joules=round(float(b.m_joules.sum()), 1),
                    m_prefill_joules=round(
                        float(b.m_prefill_joules.sum()), 1),
                    tok_per_watt=round(tok / joules, 3) if joules else 0.0,
                    occupancy=round(slot_s / avail, 3) if avail else 0.0,
                    sim_time_s=round(float(b.sim_time_s.max()), 3)
                    if eng.instances else 0.0)

    def summarize(self, role_idx: Dict[str, int],
                  outbox: Dict[str, List[Request]],
                  n_overflowed: int, n_escalated: int,
                  n_handoffs: int) -> PoolSummary:
        """One-pass aggregation after the pool drains; cached so every
        later report path (stats / measured_totals / fleet roll-up /
        SLO attribution) reads the same numbers without re-summing."""
        eng, b = self.engine, self.engine.bank
        comp = self.completed
        own = role_idx[self.role]
        self.summary = PoolSummary(
            role=self.role, phase=self.phase, window=eng.window,
            instances=eng.instances, n_slots=eng.n_slots,
            m_slot_seconds=float(eng.m_slot_seconds.sum()),
            measure_span=max(b.measure_t1 - b.measure_t0, 1e-9),
            stats=self._compute_stats(),
            lat=_percentiles(comp or self.relayed),
            m_tokens=int(b.m_tokens.sum()),
            m_joules=float(b.m_joules.sum()),
            m_prefill_joules=float(b.m_prefill_joules.sum()),
            m_idle_joules=float(b.m_idle_joules.sum()),
            m_handoff_joules=float(b.m_handoff_joules.sum()),
            m_handoff_bytes=float(b.m_handoff_bytes.sum()),
            m_dispatch_joules=float(b.m_dispatch_joules.sum()),
            tokens=int(b.tokens.sum()),
            joules=float(b.joules.sum()),
            sim_times=b.sim_time_s.copy(),
            p_idle_w=eng.profile.power_model.p_idle_w,
            arrival=np.array([r.arrival_time for r in comp]),
            first_token=np.array([r.first_token_time for r in comp]),
            finish=np.array([r.finish_time for r in comp]),
            n_generated=np.array([r.n_generated for r in comp], np.int64),
            ttft_role=np.array([role_idx.get(r.prefill_role, own)
                                for r in comp], np.int64),
            n_overflowed=n_overflowed, n_escalated=n_escalated,
            n_handoffs=n_handoffs, outbox=outbox,
            online_until=None if eng.online_until is None
            else eng.online_until.copy())
        return self.summary


class FleetSim:
    """Instantiate an analytical sizing plan as a fleet of running engines.

    `registry` (serving.models) binds each role to the model its pool
    serves; passing only `model` builds a homogeneous registry, which is
    every pre-model-heterogeneity topology.  Each engine streams *its own
    pool's* model bytes, and the per-engine prefill chunk is scaled by its
    pool profile's HBM bandwidth (`scaled_prefill_chunk`) so faster
    generations spend their surplus FLOPs on prompt processing instead of
    idling at the H100-calibrated chunk rate."""

    def __init__(self, policy: RouterPolicy, plan: FleetReport, *,
                 model: Optional[ModelSpec] = None,
                 registry: Optional[ModelProfileRegistry] = None,
                 prefill_chunk: int = 512,
                 rng_seed: int = 0,
                 kv_interconnect_Bps: float = INTERCONNECT_BPS,
                 kv_handoff_j_per_byte: float = HANDOFF_J_PER_BYTE,
                 engine: str = "numpy",
                 autoscale: Optional[AutoscalePolicy] = None,
                 telemetry=None):
        self.policy = policy
        self.plan = plan
        self.autoscale = autoscale
        # FleetScope: explicit kwarg wins; the class attribute is the
        # bench's opt-in hook (`fleet_sim_bench --trace` sets it once and
        # every sim the harness builds records into the shared recorder)
        self.telemetry = telemetry if telemetry is not None \
            else FleetSim.default_telemetry
        if autoscale is not None and engine != "numpy":
            # the jitted drain (serving.jax_engine) initialises every
            # row's clock to zero inside the compiled while_loop, so
            # per-row online offsets would be silently dropped
            raise ValueError("autoscale requires the numpy engine")
        if engine == "numpy":
            engine_cls = BatchedPoolEngine
        elif engine == "jax":
            from .jax_engine import JaxPoolEngine
            engine_cls = JaxPoolEngine
        else:
            raise ValueError(f"unknown engine {engine!r} "
                             "(expected 'numpy' or 'jax')")
        self.engine_kind = engine
        pools = sorted(plan.pools, key=lambda p: p.window)
        if registry is None:
            if model is None:
                raise ValueError("FleetSim needs a model or a registry")
            registry = ModelProfileRegistry.homogeneous(
                model, pools[0].profile)
        self.registry = registry
        self.model = registry.default.model
        self.kv_interconnect_Bps = kv_interconnect_Bps
        self.kv_handoff_j_per_byte = kv_handoff_j_per_byte
        spec: Optional[TopologySpec] = getattr(policy, "spec", None)
        if spec is None:
            raise ValueError(
                "FleetSim needs a spec-compiled policy: every pool's wiring"
                " (roles, eviction, overflow/escalation/handoff edges) is"
                " read from policy.spec — build the topology through"
                " core.topospec.TopologySpec (from_kind / build) or"
                " serving.fleetsim.build_topology")
        self.spec = spec
        role_names = plan_roles(plan)
        roles = list(zip(role_names, pools))
        # topological DAG order: ascending window, and within a disagg
        # slice prefill before its paired decode (the provisioning order —
        # the window sort is stable)
        self.order = role_names
        self.groups: Dict[str, PoolGroup] = {}
        surviving = set(role_names)
        spec_by_role = {sp.role: sp for sp in spec.pools}

        def _overflow_dest(role: str) -> Optional[str]:
            # follow the spec's overflow chain through pools the workload
            # dropped (a rung that routed no traffic provisions no pool):
            # its predecessor overflows straight to the next survivor
            dest = spec_by_role[role].overflow_to
            while dest is not None and dest not in surviving:
                dest = spec_by_role[dest].overflow_to
            return dest

        self._plan_by_role: Dict[str, object] = dict(roles)
        self._engine_kwargs: Dict[str, dict] = {}
        for role, p in roles:
            sp = spec_by_role[role]
            # Overflow headroom ends at the pool window: a request routed
            # here that outgrows it migrates one hop along the spec's
            # overflow edge (preemption + re-prefill in the destination
            # pool).  A pool whose edge resolves to no surviving
            # destination is terminal in practice and truncates at its
            # window, like the token-level engine.
            evict = sp.evict_on_overflow and _overflow_dest(role) is not None
            binding = registry.for_role(role)
            chunk = scaled_prefill_chunk(p.profile, prefill_chunk) \
                if prefill_chunk else prefill_chunk
            kwargs = dict(
                instances=max(p.instances, 1), window=p.window,
                profile=p.profile, name=p.name,
                prefill_chunk=chunk, phase=p.phase,
                prefill_mfu=p.prefill_engine_mfu,
                evict_on_overflow=evict, respect_arrival=True,
                streamed_params=binding.streamed_params,
                dispatch_ms=binding.dispatch_ms,
                rng_seed=rng_seed)
            # kept so the autoscaler can rebuild the pool with one row
            # per planned incarnation (serving.autoscale)
            self._engine_kwargs[role] = kwargs
            self.groups[role] = PoolGroup(role, engine_cls(**kwargs))
            if self.telemetry is not None:
                self.groups[role].engine.attach_trace(self.telemetry)
        # cross-pool edges, read straight off the spec's pools (all point
        # forward in `order` — validated at spec construction):
        #   handoff_to  — prefill role -> its slice's decode role
        #   overflow_to — evicting role -> where its evictions re-enter
        #                 (ladder specs: next surviving rung; disagg: the
        #                 next slice's *prefill* pool, where the request
        #                 re-prefills)
        #   escalate_to — semantic small-model role -> the large-model role
        #                 that re-serves detected misroutes from scratch
        self.handoff_to: Dict[str, str] = {}
        self.overflow_to: Dict[str, str] = {}
        self.escalate_to: Dict[str, str] = {}
        self._kv_bytes_per_tok: Dict[str, float] = {}
        for role, p in roles:
            sp = spec_by_role[role]
            dest = _overflow_dest(role)
            if dest is not None:
                self.overflow_to[role] = dest
            if sp.escalate_to is not None and sp.escalate_to in surviving:
                self.escalate_to[role] = sp.escalate_to
            if sp.handoff_to is not None and sp.handoff_to in surviving:
                self.handoff_to[role] = sp.handoff_to
                # per-role whole-instance KV bytes per prompt token
                self._kv_bytes_per_tok[role] = \
                    registry.for_role(role).kv_bytes_per_instance_token(
                        p.profile)
        self.router = ContextRouter(self.groups, policy)
        self.migrations = 0
        self.handoffs = 0
        self.escalations = 0
        self._window: Tuple[float, float] = (0.0, float("inf"))
        self.summaries: Dict[str, PoolSummary] = {}
        self.fresh_roles: List[str] = []
        # role -> InstanceSchedule planned by the autoscaler this run
        self.schedules: Dict[str, InstanceSchedule] = {}

    # simulated seconds served across every FleetSim.run in this process
    # (per-run horizon = the last arrival).  Instrumentation for the
    # bench's sim-seconds-per-wall-second throughput metric.
    sim_seconds_total: float = 0.0

    # process-wide FleetScope recorder picked up by sims built without an
    # explicit `telemetry=` kwarg (how the bench harness opts whole runs
    # into tracing without threading a kwarg through every call site)
    default_telemetry = None

    def run(self, requests: List[Request], *, warmup_frac: float = 0.35,
            max_iters: int = 20_000_000,
            reuse: Optional[Dict[str, PoolSummary]] = None
            ) -> Dict[str, dict]:
        """Route every request, drain the pools in topological order, and
        return `report()`.

        `reuse` maps a *prefix* of `self.order` to `PoolSummary`
        snapshots from a previous, identically-provisioned run over the
        identical trace (the SLO loop's incremental re-simulation —
        core.slo validates the prefix): those pools are replayed from
        their snapshots (summary adopted, outbox clones re-injected into
        downstream fresh pools) instead of being simulated again.
        Cross-pool flow only points forward, so a reused prefix can never
        receive requests from a fresh pool; the trailing assert enforces
        it."""
        self.begin_run(requests, warmup_frac=warmup_frac, reuse=reuse)
        for role in self.order:
            self.pre_role(role)
            self.drain_role(role, max_iters=max_iters)
        return self.finish_run()

    # --- staged drive: begin_run -> (pre_role, drain_role)* -> finish_run.
    # `run` composes these; the grid driver (`run_fleet_grid`) interleaves
    # them across many sims so each stage's JAX pools batch into one
    # compiled drain.

    def begin_run(self, requests: List[Request], *,
                  warmup_frac: float = 0.35,
                  reuse: Optional[Dict[str, PoolSummary]] = None) -> None:
        """Route the trace, set every pool's measurement window, and open
        the per-run cross-pool inbox state."""
        reqs = sorted(requests, key=lambda r: r.arrival_time)
        # steady-state measurement window: skip the fleet fill-up, stop at
        # the last arrival (the drain tail is not steady state either)
        t_last = reqs[-1].arrival_time if reqs else 0.0
        FleetSim.sim_seconds_total += t_last
        self._window = (warmup_frac * t_last, t_last)
        for grp in self.groups.values():
            grp.engine.bank.measure_t0, grp.engine.bank.measure_t1 = \
                self._window
        for r in reqs:
            self.router.route(r)
        if self.autoscale is not None:
            self._apply_autoscale()
        tr = self.telemetry
        if tr is not None:
            # emitted after routing *and* autoscale so `r.pool` reflects
            # the final replica assignment (the autoscale rebuild
            # re-submits the routed queues onto the scheduled rows)
            fleet_pid = tr.pool_id("fleet")
            for r in reqs:
                tr.event(EV_ARRIVE, r.rid, fleet_pid, -1, r.arrival_time)
                name, _, inst = (r.pool or "").partition("#")
                tr.event(EV_ROUTE, r.rid,
                         tr.pool_id(name) if name else fleet_pid,
                         int(inst) if inst else -1, r.arrival_time)
        self.summaries = {}
        self.fresh_roles = []
        # topological order: cross-pool flow (overflow migrations and KV
        # handoffs) only points forward, so draining pools in `order` sees
        # every injected request before its destination runs
        self._run_state = dict(
            reuse=reuse or {},
            role_idx={r: k for k, r in enumerate(self.order)},
            inbox={role: [] for role in self.order})

    def _apply_autoscale(self) -> None:
        """Replace each pool's peak-provisioned engine with an
        incarnation-per-row engine planned by the reactive autoscaler
        (serving.autoscale).  Runs inside `begin_run`, after primary
        routing (each pool's queues hold exactly its routed ingress —
        the controller's arrival-rate signal) and before any engine has
        stepped, so the rebuild replays the identical submissions onto
        the scheduled rows."""
        scaler = Autoscaler(self.autoscale)
        horizon = self._window[1]
        for role in self.order:
            grp = self.groups[role]
            eng = grp.engine
            routed = [r for q in eng.queues for r in q]
            times = [BatchedPoolEngine._ready(r) for r in routed]
            plan = self._plan_by_role[role]
            rate_per_inst = plan.arrival_rate / max(plan.instances, 1)
            binding = self.registry.for_role(role)
            load_s = binding.model.weight_bytes(active_only=False) \
                / self.autoscale.weight_load_Bps
            sched = scaler.plan_pool(
                times, n_peak=eng.instances,
                rate_per_instance=rate_per_inst,
                horizon_s=horizon, load_s=load_s)
            self.schedules[role] = sched
            kwargs = dict(self._engine_kwargs[role],
                          instances=sched.n_rows)
            new_eng = BatchedPoolEngine(**kwargs)
            if self.telemetry is not None:
                new_eng.attach_trace(self.telemetry)
            new_eng.bank.measure_t0, new_eng.bank.measure_t1 = self._window
            new_eng.set_online_windows(sched.online_from,
                                       sched.online_until,
                                       load_s=sched.load_s)
            new_grp = PoolGroup(role, new_eng)
            self.groups[role] = new_grp    # the router reads this dict
            for r in sorted(routed, key=BatchedPoolEngine._ready):
                new_grp.submit(r)

    def pre_role(self, role: str) -> Optional[BatchedPoolEngine]:
        """Inject the role's inbox and time-sort its queues; returns the
        engine about to drain (None when the role replays a reused
        snapshot).  Split from `drain_role` so a grid driver can collect a
        stage's prepared engines and batch their drains."""
        rs = self._run_state
        if role in rs["reuse"]:
            return None
        grp = self.groups[role]
        inbox = rs["inbox"]
        if inbox[role]:
            tr = self.telemetry
            for r in sorted(inbox[role], key=lambda r: r.ready_time):
                grp.submit(r)
                if tr is not None:
                    # re-entry hop (overflow / escalation / KV handoff):
                    # a second ROUTE at the destination replica
                    name, _, inst = r.pool.partition("#")
                    tr.event(EV_ROUTE, r.rid, tr.pool_id(name),
                             int(inst) if inst else -1, r.ready_time)
            inbox[role] = []
        grp.engine.sort_queues()    # keep queues time-sorted for the
        return grp.engine           # head-gated admission

    def drain_role(self, role: str, *,
                   max_iters: int = 20_000_000) -> None:
        """Drain one prepared pool (or adopt its reused snapshot) and
        deliver its outflow to the downstream inboxes."""
        rs = self._run_state
        reuse, inbox = rs["reuse"], rs["inbox"]
        if role in reuse:
            s = reuse[role]
            self.groups[role].summary = s
            self.summaries[role] = s
            self.migrations += s.n_overflowed
            self.escalations += s.n_escalated
            self.handoffs += s.n_handoffs
            for dest, snaps in s.outbox.items():
                if dest not in reuse:   # flow into a reused pool is
                    inbox[dest].extend(  # already inside its snapshot
                        copy.copy(r) for r in snaps)
            return
        self.fresh_roles.append(role)
        grp = self.groups[role]
        eng = grp.engine
        eng.run_until_drained(max_iters=max_iters)
        outbox: Dict[str, List[Request]] = {}
        n_over = n_esc = n_hand = 0
        for i in range(eng.instances):
            if eng.overflowed[i]:
                dest = self.overflow_to.get(role)
                assert dest is not None, \
                    "the terminal pool may not overflow-evict"
                n_over += len(eng.overflowed[i])
                inbox[dest].extend(eng.overflowed[i])
                outbox.setdefault(dest, []).extend(
                    copy.copy(r) for r in eng.overflowed[i])
                eng.overflowed[i] = []
            if eng.escalated[i]:
                dest = self.escalate_to.get(role)
                assert dest is not None, \
                    "only the semantic small pool may escalate"
                n_esc += len(eng.escalated[i])
                inbox[dest].extend(eng.escalated[i])
                outbox.setdefault(dest, []).extend(
                    copy.copy(r) for r in eng.escalated[i])
                eng.escalated[i] = []
            if eng.handoff[i]:
                dest = self.handoff_to[role]
                kappa = self._kv_bytes_per_tok[role]
                for r in eng.handoff[i]:
                    n_bytes = kappa * r.prompt_len
                    delay = n_bytes / self.kv_interconnect_Bps
                    eng.bank.charge_handoff_one(
                        i, n_bytes, start_s=r.ready_time,
                        duration_s=delay,
                        j_per_byte=self.kv_handoff_j_per_byte)
                    r.ready_time += delay
                    r.prefill_role = role
                n_hand += len(eng.handoff[i])
                inbox[dest].extend(eng.handoff[i])
                outbox.setdefault(dest, []).extend(
                    copy.copy(r) for r in eng.handoff[i])
                eng.handoff[i] = []
        self.migrations += n_over
        self.escalations += n_esc
        self.handoffs += n_hand
        self.summaries[role] = grp.summarize(rs["role_idx"], outbox,
                                             n_over, n_esc, n_hand)

    def finish_run(self) -> Dict[str, dict]:
        assert not any(self._run_state["inbox"].values()), \
            "undelivered cross-pool requests"
        # a prefill pool's latency snapshot was taken at its drain, before
        # the downstream decode pool filled in its relayed requests'
        # finish/TPOT — refresh those percentiles now that the whole
        # fleet has drained (the relayed objects are live, not clones),
        # so latency_by_role keeps reporting the informational
        # e2e/tpot keys and replayed summaries carry them too
        for role in self.fresh_roles:
            grp = self.groups[role]
            if grp.phase == "prefill" and grp.summary is not None:
                grp.summary.lat = _percentiles(grp.completed
                                               or grp.relayed)
        return self.report()

    def latency_by_role(self) -> Dict[str, Dict[str, float]]:
        """Per-pool latency percentiles (SLO-loop attribution: which rung
        of the ladder is busting the fleet TTFT)."""
        return {role: self.groups[role].latency_percentiles()
                for role in self.order}

    def report(self) -> Dict[str, dict]:
        """Fleet roll-up assembled from the cached per-pool summaries in
        one pass (no per-engine re-aggregation — the summaries were
        computed once when each pool drained)."""
        out: Dict[str, dict] = {}
        tok = joules = prefill_j = idle_j = handoff_j = handoff_b = 0.0
        dispatch_j = 0.0
        n_completed = 0
        arrival, first, finish, ngen = [], [], [], []
        for role in self.order:
            s = self.summaries[role]
            out[role] = dict(s.stats)
            n_completed += len(s.arrival)
            arrival.append(s.arrival)
            first.append(s.first_token)
            finish.append(s.finish)
            ngen.append(s.n_generated)
            tok += s.m_tokens
            joules += s.m_joules
            prefill_j += s.m_prefill_joules
            idle_j += s.m_idle_joules
            handoff_j += s.m_handoff_joules
            handoff_b += s.m_handoff_bytes
            dispatch_j += s.m_dispatch_joules
        # engines that sat idle past the window end never saw those idle
        # watts: charge the gap so the fleet denominator is wall-clock
        # honest.  An autoscaled row's gap ends at its retire time — a
        # powered-off incarnation draws nothing.
        t0, t1 = self._window
        for role in self.order:
            s = self.summaries[role]
            cap = t1 if s.online_until is None \
                else np.minimum(t1, s.online_until)
            gap = np.maximum(0.0, cap - np.maximum(s.sim_times, t0))
            extra = s.p_idle_w * float(gap.sum())
            joules += extra
            idle_j += extra
        span = max(t1 - t0, 1e-9)
        arrival = np.concatenate(arrival) if arrival else np.empty(0)
        first = np.concatenate(first) if first else np.empty(0)
        finish = np.concatenate(finish) if finish else np.empty(0)
        ngen = np.concatenate(ngen) if ngen else np.empty(0, np.int64)
        # decode-only backs out every non-output charge: prefill compute,
        # idle draw and the KV-handoff interconnect energy (core.disagg)
        decode_j = joules - prefill_j - idle_j - handoff_j
        out["fleet"] = dict(
            completed=n_completed,
            migrations=self.migrations,
            handoffs=self.handoffs,
            escalations=self.escalations,
            measure_window_s=(round(t0, 3), round(t1, 3)),
            tokens=int(tok), joules=round(joules, 1),
            tokens_per_s=round(tok / span, 1),
            tok_per_watt=round(tok / joules, 3) if joules else 0.0,
            decode_tok_per_watt=round(tok / decode_j, 3) if decode_j else 0.0,
            prefill_energy_frac=round(prefill_j / joules, 3) if joules
            else 0.0,
            idle_energy_frac=round(idle_j / joules, 3) if joules else 0.0,
            kv_handoff_joules=round(handoff_j, 3),
            kv_handoff_gb=round(handoff_b / 1e9, 3),
            kv_handoff_energy_frac=round(handoff_j / joules, 6) if joules
            else 0.0,
            # MoE all-to-all attribution: the dispatch share is *inside*
            # the decode charges (the roofline floor), so it is reported
            # as a fraction of fleet energy, never backed out
            moe_dispatch_joules=round(dispatch_j, 1),
            moe_dispatch_energy_frac=round(dispatch_j / joules, 4)
            if joules else 0.0,
            **latency_percentiles_arrays(arrival, first, finish, ngen))
        return out


def analytical_decode_tok_per_watt(plan: FleetReport) -> float:
    """Eq. 4 over the decode pools only — the closed-form twin of the
    simulator's `decode_tok_per_watt`.  Identical to `plan.tok_per_watt`
    for plans without prefill-phase pools."""
    dec = [p for p in plan.pools if p.phase != "prefill"]
    pw = sum(p.instances * p.power_w_per_instance for p in dec)
    return sum(p.tokens_per_s for p in dec) / pw if pw else 0.0


@dataclasses.dataclass
class SimVsAnalytical:
    """One head-to-head cell: measured fleet vs closed-form sizing.

    `analytical_tok_per_watt` is the like-for-like twin of
    `sim_decode_tok_per_watt`: for the disagg kinds that is the *decode
    fleet only* (the analytical whole-fleet number, which also pays the
    dedicated prefill pools, is kept in `analytical_fleet_tok_per_watt`);
    for every other kind the two analytical numbers coincide."""

    workload: str
    topology: str
    analytical_tok_per_watt: float
    sim_tok_per_watt: float          # all-in (prefill + idle metered)
    sim_decode_tok_per_watt: float   # like-for-like with Eq. 4
    report: Dict[str, dict]
    analytical_fleet_tok_per_watt: float = 0.0

    @property
    def delta_pct(self) -> float:
        """Decode-only simulated vs analytical, in percent."""
        return 100.0 * (self.sim_decode_tok_per_watt
                        / self.analytical_tok_per_watt - 1.0)

    def row(self) -> dict:
        f = self.report["fleet"]
        return dict(workload=self.workload, topology=self.topology,
                    analytical=round(self.analytical_tok_per_watt, 2),
                    simulated=round(self.sim_decode_tok_per_watt, 2),
                    delta_pct=round(self.delta_pct, 1),
                    all_in=round(self.sim_tok_per_watt, 2),
                    ttft_p99_s=f.get("ttft_p99_s", 0.0),
                    migrations=f["migrations"])


def prepare_spec(spec: TopologySpec, workload: Workload, *,
                 n_requests: int = 4000, seed: int = 0,
                 arrival_rate: Optional[float] = None,
                 prefill_chunk: int = 512,
                 pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                 engine: str = "numpy",
                 trace: Optional[List[Tuple[int, int, float]]] = None,
                 autoscale: bool = False,
                 telemetry=None):
    """Provision a `TopologySpec` analytically and synthesise its trace;
    returns `(sim, reqs, plan)` ready for `sim.run(reqs)` — the common
    front half of `simulate_spec`, split out so the grid driver (and the
    SLO / topology-search loops) can prepare many scenarios before
    batch-draining them.  The trace's clipping bound is the spec's largest
    serve window (`spec.max_window`) — no per-kind special cases.

    `trace` supplies pre-sampled (prompt, output, arrival) triples — the
    diurnal bench's non-stationary arrivals (`sample_diurnal_trace`) —
    instead of the steady Poisson default.  `autoscale=True` opts the
    sim into the spec's `autoscale` policy (or the default
    `AutoscalePolicy` if the spec carries none); the sizing plan itself
    is *always* peak-provisioned — the SLO loop sizes at
    `workload.arrival_rate` and never autoscales, per the spec contract.
    """
    if arrival_rate is not None and arrival_rate != workload.arrival_rate:
        workload = dataclasses.replace(workload, arrival_rate=arrival_rate)
    policy, plan, registry = spec.build(workload,
                                        pool_overrides=pool_overrides)
    as_policy = None
    if autoscale:
        as_policy = spec.autoscale if spec.autoscale is not None \
            else AutoscalePolicy()
    sim = FleetSim(policy, plan, registry=registry,
                   prefill_chunk=prefill_chunk, rng_seed=seed,
                   engine=engine, autoscale=as_policy,
                   telemetry=telemetry)
    sim.workload_name = workload.name     # grid-driver report labels
    sim.topology_kind = spec.kind
    reqs = trace_requests(workload, n_requests, seed=seed,
                          max_total=spec.max_window, trace=trace)
    return sim, reqs, plan


def prepare_topology(kind: str, workload: Workload, profile: BaseProfile,
                     model: ModelSpec, *, b_short: int = 4096,
                     gamma: float = 2.0,
                     n_requests: int = 4000, seed: int = 0,
                     arrival_rate: Optional[float] = None,
                     prefill_chunk: int = 512,
                     windows: Optional[Sequence[int]] = None,
                     pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                     small_model: Optional[ModelSpec] = None,
                     small_profile: Optional[BaseProfile] = None,
                     misroute_rate: float = 0.0,
                     dispatch_ms: float = 0.0,
                     long_window: int = LONG_WINDOW,
                     engine: str = "numpy"):
    """Legacy-kind front end of `prepare_spec`: compile the kind string to
    a `TopologySpec` and prepare it."""
    spec = TopologySpec.from_kind(
        kind, profile, model, b_short=b_short, gamma=gamma,
        long_window=long_window, windows=windows, small_model=small_model,
        small_profile=small_profile, misroute_rate=misroute_rate,
        dispatch_ms=dispatch_ms, misroute_seed=seed)
    return prepare_spec(spec, workload, n_requests=n_requests, seed=seed,
                        arrival_rate=arrival_rate,
                        prefill_chunk=prefill_chunk,
                        pool_overrides=pool_overrides, engine=engine)


def _sim_vs_analytical(sim: FleetSim, plan, kind: str,
                       workload_name: str,
                       report: Dict[str, dict]) -> SimVsAnalytical:
    return SimVsAnalytical(
        workload=workload_name, topology=kind,
        analytical_tok_per_watt=analytical_decode_tok_per_watt(plan),
        analytical_fleet_tok_per_watt=plan.tok_per_watt,
        sim_tok_per_watt=report["fleet"]["tok_per_watt"],
        sim_decode_tok_per_watt=report["fleet"]["decode_tok_per_watt"],
        report=report)


def simulate_topology(kind: str, workload: Workload, profile: BaseProfile,
                      model: ModelSpec, *, b_short: int = 4096,
                      gamma: float = 2.0,
                      n_requests: int = 4000, seed: int = 0,
                      arrival_rate: Optional[float] = None,
                      prefill_chunk: int = 512,
                      windows: Optional[Sequence[int]] = None,
                      pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                      small_model: Optional[ModelSpec] = None,
                      small_profile: Optional[BaseProfile] = None,
                      misroute_rate: float = 0.0,
                      dispatch_ms: float = 0.0,
                      long_window: int = LONG_WINDOW,
                      engine: str = "numpy") -> SimVsAnalytical:
    """Provision a topology analytically, then measure it end-to-end.
    `engine="jax"` opts the pools into the jit/vmap drain loop
    (serving.jax_engine); the default numpy engine is the bit-exact
    oracle."""
    sim, reqs, plan = prepare_topology(
        kind, workload, profile, model, b_short=b_short, gamma=gamma,
        n_requests=n_requests, seed=seed, arrival_rate=arrival_rate,
        prefill_chunk=prefill_chunk, windows=windows,
        pool_overrides=pool_overrides, small_model=small_model,
        small_profile=small_profile, misroute_rate=misroute_rate,
        dispatch_ms=dispatch_ms, long_window=long_window, engine=engine)
    report = sim.run(reqs)
    return _sim_vs_analytical(sim, plan, kind, workload.name, report)


def simulate_spec(spec: TopologySpec, workload: Workload, *,
                  n_requests: int = 4000, seed: int = 0,
                  arrival_rate: Optional[float] = None,
                  prefill_chunk: int = 512,
                  pool_overrides: Optional[Dict[str, PoolOverride]] = None,
                  engine: str = "numpy") -> SimVsAnalytical:
    """Measure an arbitrary `TopologySpec` end-to-end — `simulate_topology`
    for specs that never had a kind string (hand-built or searched)."""
    sim, reqs, plan = prepare_spec(
        spec, workload, n_requests=n_requests, seed=seed,
        arrival_rate=arrival_rate, prefill_chunk=prefill_chunk,
        pool_overrides=pool_overrides, engine=engine)
    report = sim.run(reqs)
    return _sim_vs_analytical(sim, plan, spec.kind, workload.name, report)


def run_fleet_grid(scenarios: List[Tuple[FleetSim, List[Request], object]],
                   *, max_iters: int = 20_000_000,
                   warmup_frac: float = 0.35,
                   pad_floors: Optional[Sequence[tuple]] = None
                   ) -> List[SimVsAnalytical]:
    """Drain many prepared scenarios stage-by-stage so each topological
    stage's JAX pools compile and drain as **one** vmapped call.

    `scenarios` is a list of `prepare_topology(...)` triples (every sim
    built with `engine="jax"`; numpy sims also work — they just drain
    serially inside the stage loop).  Stage k collects the k-th pool of
    every scenario, batch-drains the JAX ones via
    `jax_engine.drain_engines`, then lets each sim finish its per-stage
    bookkeeping (outbox routing, KV-handoff charging, summaries) on the
    host exactly as `FleetSim.run` would.  `pad_floors` forwards shape
    classes to `drain_engines` so sweeps spanning many pool geometries
    share a handful of compiled programs."""
    from .jax_engine import JaxPoolEngine, drain_engines
    for sim, reqs, _ in scenarios:
        sim.begin_run(reqs, warmup_frac=warmup_frac)
    n_stages = max(len(sim.order) for sim, _, _ in scenarios)
    for k in range(n_stages):
        staged = []
        for sim, _, _ in scenarios:
            if k >= len(sim.order):
                continue
            eng = sim.pre_role(sim.order[k])
            if isinstance(eng, JaxPoolEngine):
                staged.append(eng)
        if staged:
            drain_engines(staged, max_iters=max_iters,
                          pad_floors=pad_floors)
        for sim, _, _ in scenarios:
            if k < len(sim.order):
                sim.drain_role(sim.order[k], max_iters=max_iters)
    out = []
    for sim, _, plan in scenarios:
        report = sim.finish_run()
        out.append(_sim_vs_analytical(
            sim, plan, sim.topology_kind, sim.workload_name, report))
    return out
