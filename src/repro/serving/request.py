"""Request/stream abstractions for the serving runtime."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    # runtime state
    generated: Optional[List[int]] = None
    pool: str = ""
    finish_time: float = -1.0
    first_token_time: float = -1.0
    preemptions: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def predicted_total(self) -> int:
        return self.prompt_len + self.max_new_tokens

    @property
    def done(self) -> bool:
        return (self.generated is not None
                and len(self.generated) >= self.max_new_tokens)


def synthetic_requests(workload, n: int, vocab: int, *, seed: int = 0,
                       max_total: int = 4096) -> List[Request]:
    """Draw (prompt_len, output_len) from a core.workloads trace and attach
    synthetic token ids (clipped so tiny CPU demos stay tractable)."""
    lens = workload.sample_requests(n, seed=seed)
    rng = np.random.default_rng(seed + 7)
    reqs = []
    t = 0.0
    for i, (p, o) in enumerate(lens):
        p = int(min(p, max_total - 1))
        o = int(min(o, max_total - p))
        t += rng.exponential(1.0 / workload.arrival_rate)
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, size=max(p, 1)),
            max_new_tokens=max(o, 1), arrival_time=t))
    return reqs
