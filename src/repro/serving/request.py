"""Request/stream abstractions for the serving runtime + shared trace
sampling and latency-percentile helpers (used by both the token-level
engine and the fleet simulator, so the two layers can never diverge on
clipping rules or metric definitions)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int token ids
    max_new_tokens: int
    arrival_time: float = 0.0
    # runtime state
    generated: Optional[List[int]] = None
    pool: str = ""
    finish_time: float = -1.0
    first_token_time: float = -1.0
    preemptions: int = 0
    n_generated: int = 0
    # admission gate after a FleetOpt overflow migration; latency metrics
    # keep counting from the original arrival_time
    ready_time: Optional[float] = None
    # router-visible output-length prediction (e.g. E[output] from the
    # workload trace).  None = oracle routing on the actual length.
    predicted_output: Optional[int] = None
    # disaggregated serving: set when a dedicated prefill pool already
    # drained the prompt (the KV arrives over the interconnect), so the
    # decode pool must not re-charge or re-run prefill.  `prefill_role`
    # names the router role that drained it (SLO-loop TTFT attribution).
    prefill_done: bool = False
    prefill_role: str = ""
    # semantic routing (core.routing.Semantic / serving.router): set by the
    # router when the classifier misroutes a true-large request into the
    # small-model pool — the engine evicts it after `escalate_at` decode
    # tokens (the quality monitor's detection latency) and FleetSim
    # re-serves it from scratch in the large pool.  `escalations` counts
    # those hops; `misrouted` marks every flipped decision (including
    # short-into-large, which never escalates).
    misrouted: bool = False
    escalate_at: Optional[int] = None
    escalations: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def predicted_total(self) -> int:
        o = self.predicted_output if self.predicted_output is not None \
            else self.max_new_tokens
        return self.prompt_len + o

    @property
    def done(self) -> bool:
        """Finished generating: n_generated is authoritative (the engine
        keeps in-flight counts in its slot arrays and flushes at finish;
        analytical-mode requests never materialise `generated`)."""
        n = max(self.n_generated, len(self.generated or ()))
        return n >= self.max_new_tokens


def sample_trace(workload, n: int, *, seed: int = 0, max_total: int = 4096,
                 arrival_rate: Optional[float] = None,
                 ) -> List[Tuple[int, int, float]]:
    """(prompt_len, output_len, arrival_time) triples: workload lengths
    clipped to max_total and Poisson arrivals.  The single source of the
    clipping rule and the arrival process for every serving consumer."""
    lens = workload.sample_requests(n, seed=seed)
    rng = np.random.default_rng(seed + 7)
    lam = arrival_rate if arrival_rate is not None else workload.arrival_rate
    ts = np.cumsum(rng.exponential(1.0 / lam, size=n))
    out = []
    for i, (p, o) in enumerate(lens):
        p = int(min(p, max_total - 1))
        o = int(min(o, max_total - p))
        out.append((max(p, 1), max(o, 1), float(ts[i])))
    return out


def sample_diurnal_trace(workload, profile, t_end: float, *, seed: int = 0,
                         max_total: int = 4096,
                         ) -> List[Tuple[int, int, float]]:
    """(prompt_len, output_len, arrival_time) triples under a
    `core.workloads.DiurnalProfile` envelope on [0, t_end).

    Arrival *times* come from the profile's exact time-rescaled
    non-homogeneous Poisson sampler; lengths reuse the same
    `workload.sample_requests` path and clipping rule as `sample_trace`,
    so the steady-state and diurnal layers can never diverge on the
    length distribution."""
    ts = profile.sample_arrivals(t_end, seed=seed)
    lens = workload.sample_requests(len(ts), seed=seed)
    out = []
    for i, (p, o) in enumerate(lens):
        p = int(min(p, max_total - 1))
        o = int(min(o, max_total - p))
        out.append((max(p, 1), max(o, 1), float(ts[i])))
    return out


def synthetic_requests(workload, n: int, vocab: int, *, seed: int = 0,
                       max_total: int = 4096) -> List[Request]:
    """Draw (prompt_len, output_len) from a core.workloads trace and attach
    synthetic token ids (clipped so tiny CPU demos stay tractable)."""
    rng = np.random.default_rng(seed + 7)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=p),
                    max_new_tokens=o, arrival_time=t)
            for i, (p, o, t) in enumerate(
                sample_trace(workload, n, seed=seed, max_total=max_total))]


def latency_percentiles(reqs: Sequence[Request]) -> Dict[str, float]:
    """TTFT / TPOT / end-to-end percentiles over completed requests (sim
    time; arrival_time is submission into the fleet)."""
    if not reqs:
        return {}
    return latency_percentiles_arrays(
        np.array([r.arrival_time for r in reqs]),
        np.array([r.first_token_time for r in reqs]),
        np.array([r.finish_time for r in reqs]),
        np.array([r.n_generated for r in reqs], np.int64))


def latency_percentiles_arrays(arrival: np.ndarray, first_token: np.ndarray,
                               finish: np.ndarray, n_generated: np.ndarray,
                               *, strict_keys: bool = False,
                               ) -> Dict[str, float]:
    """Column-oriented twin of `latency_percentiles` — the fleet
    simulator's cached pool summaries carry per-request metric columns,
    so the roll-up never rebuilds Request lists.  Shared metric
    definitions live here, once: TTFT needs a first token, e2e a finish,
    TPOT both plus >1 generated token.

    The legacy default *drops* the keys of empty populations (an empty
    measurement window returns {}), which forces every consumer into
    `.get(..., default)` guesswork.  `strict_keys=True` always returns
    all five keys, with NaN marking "no observations" — the trace
    report renders those as "no data" instead of a silent 0.0."""
    out: Dict[str, float] = {}
    if strict_keys:
        out = {k: float("nan") for k in ("ttft_p50_s", "ttft_p99_s",
                                         "e2e_p99_s", "tpot_p50_ms",
                                         "tpot_p99_ms")}
    if not len(arrival):
        return out
    ttft = (first_token - arrival)[first_token >= 0]
    e2e = (finish - arrival)[finish >= 0]
    tmask = (finish >= 0) & (first_token >= 0) & (n_generated > 1)
    tpot = (finish[tmask] - first_token[tmask]) \
        / (n_generated[tmask] - 1)
    if len(ttft):
        out["ttft_p50_s"] = round(float(np.quantile(ttft, 0.5)), 4)
        out["ttft_p99_s"] = round(float(np.quantile(ttft, 0.99)), 4)
    if len(e2e):
        out["e2e_p99_s"] = round(float(np.quantile(e2e, 0.99)), 4)
    if len(tpot):
        out["tpot_p50_ms"] = round(float(np.quantile(tpot, 0.5)) * 1e3, 3)
        out["tpot_p99_ms"] = round(float(np.quantile(tpot, 0.99)) * 1e3, 3)
    return out
