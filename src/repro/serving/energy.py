"""Per-pool energy metering — the serving-side realisation of Eq. 2/4.

Every engine iteration is charged P(b) * tau analytically (this container
has no power sensors; tau comes from the calibrated decode roofline, P(b)
from the logistic power model).  The integration test in
tests/serving/test_serving.py checks the meter converges to the analytical
tok/W of core.tokenomics under the same operating point — closing the loop
between the executable system and the paper's closed-form law.

Steady-state measurement window: a fleet simulation starts from an empty
fleet and drains at the end, but the analytical Eq. 4 number describes
steady state.  Setting `measure_t0`/`measure_t1` makes the meter
additionally accumulate in-window charges into the `m_*` counters, so
ramp-in and drain-out transients can be excluded from the measured tok/W
(the totals are always kept too).  Decode charges are ms-scale and
midpoint-tested; idle and prefill charges can straddle the boundary (idle
skips span seconds, prefill chunks hide behind decode overlap) and are
pro-rated by exact interval overlap.  With the window left at its (0, inf)
default the `m_*` counters simply mirror the totals.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.profiles import BaseProfile


@dataclasses.dataclass
class EnergyMeter:
    profile: BaseProfile
    joules: float = 0.0
    idle_joules: float = 0.0
    prefill_joules: float = 0.0
    handoff_joules: float = 0.0   # KV-migration interconnect energy
    handoff_bytes: float = 0.0
    m_handoff_bytes: float = 0.0  # in-window share (pro-rated like joules)
    # MoE expert-dispatch attribution: the engine sets `dispatch_s` to its
    # pool's per-iteration all-to-all floor (core.moe.with_dispatch_floor —
    # already *inside* the roofline's tau, so this never adds energy, it
    # only labels the share of each decode charge spent moving activations
    # between experts instead of streaming weights)
    dispatch_s: float = 0.0
    dispatch_joules: float = 0.0
    m_dispatch_joules: float = 0.0
    tokens: int = 0
    prefill_tokens: int = 0
    sim_time_s: float = 0.0
    # steady-state measurement window + windowed counters
    measure_t0: float = 0.0
    measure_t1: float = math.inf
    m_tokens: int = 0
    m_joules: float = 0.0
    m_prefill_joules: float = 0.0
    m_idle_joules: float = 0.0
    m_handoff_joules: float = 0.0
    # whether the latest decode charge landed inside the window (engines
    # use this to attribute in-window tokens to slots for eviction backout)
    last_charge_in_window: bool = True
    # FleetScope charge-channel sink (serving.telemetry.TraceRecorder),
    # attached by the owning engine's attach_trace — never at
    # construction, so telemetry-off runs skip one None check per charge
    trace: object = dataclasses.field(default=None, repr=False,
                                      compare=False)
    trace_pool: int = dataclasses.field(default=0, repr=False,
                                        compare=False)
    trace_instance: int = dataclasses.field(default=0, repr=False,
                                            compare=False)

    def _in_window(self, dt_s: float) -> bool:
        mid = self.sim_time_s + 0.5 * dt_s
        return self.measure_t0 <= mid <= self.measure_t1

    def charge_decode_step(self, n_active: int, mean_context: float) -> float:
        """Charge one continuous-batching iteration; returns tau (s)."""
        tau_s = float(self.profile.roofline.tau_ms(max(n_active, 1),
                                                   mean_context)) * 1e-3
        power = self.profile.power_w(n_active)
        self.last_charge_in_window = self._in_window(tau_s)
        dispatch_j = power * min(self.dispatch_s, tau_s)
        if self.last_charge_in_window:
            self.m_tokens += n_active
            self.m_joules += power * tau_s
            self.m_dispatch_joules += dispatch_j
        self.joules += power * tau_s
        self.dispatch_joules += dispatch_j
        self.tokens += n_active
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "decode",
                              self.trace_instance, self.sim_time_s,
                              tau_s, power * tau_s, tokens=n_active,
                              dispatch=dispatch_j)
        self.sim_time_s += tau_s
        return tau_s

    def charge_prefill(self, n_tokens: int, *, mfu: float = 0.8,
                       streamed_params: float = 1e9,
                       overlap_s: float = 0.0) -> float:
        """Charge prefill compute.  Energy is always work-proportional and
        drawn at the compute-bound operating point — the logistic's
        saturated draw P_nom (Eq. 1 as b -> inf), not the b = 1 decode
        point: prompt processing saturates the FLOP units.  `overlap_s` is
        decode-iteration time the chunk hides behind (chunked prefill
        piggybacks on the memory-bound decode pass), so only the excess
        advances the clock.  The work therefore spans
        [sim_time - hidden, sim_time + dt]; in-window attribution pro-rates
        the energy by overlap with the measurement window exactly like
        `charge_idle` — midpoint-testing dt would see a zero-length
        interval whenever the chunk fully piggybacks (dt = 0) and
        misattribute boundary-straddling chunks wholesale."""
        flops = 2.0 * streamed_params * n_tokens
        t = flops / (self.profile.tp * self.profile.chip.peak_bf16_flops
                     * mfu)
        e = self.profile.power_model.p_nom_w * t
        hidden = min(overlap_s, t)
        dt = t - hidden
        start, end = self.sim_time_s - hidden, self.sim_time_s + dt
        overlap = max(0.0, min(self.measure_t1, end)
                      - max(self.measure_t0, start))
        if overlap > 0 and t > 0:
            e_in = e * min(overlap / t, 1.0)
            self.m_joules += e_in
            self.m_prefill_joules += e_in
        self.joules += e
        self.prefill_joules += e
        self.prefill_tokens += n_tokens
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "prefill",
                              self.trace_instance, start, t, e,
                              tokens=n_tokens)
        self.sim_time_s += dt
        return dt

    def charge_handoff(self, n_bytes: float, *, start_s: float,
                       duration_s: float, j_per_byte: float) -> float:
        """Charge a prefill->decode KV migration (core.disagg): link + HBM
        energy for `n_bytes` moved over [start_s, start_s + duration_s].
        Non-output energy — it never touches the token counters, so it is
        backed out of `decode_tok_per_watt` like prefill and idle.  The
        transfer runs on the interconnect concurrently with compute, so
        the clock does NOT advance; in-window attribution pro-rates by
        exact interval overlap (the interval is wall time, not this
        meter's own timeline)."""
        e = n_bytes * j_per_byte
        end = start_s + duration_s
        if duration_s > 0:
            overlap = max(0.0, min(self.measure_t1, end)
                          - max(self.measure_t0, start_s))
            frac = overlap / duration_s
        else:   # instantaneous: midpoint-test the start instant
            frac = 1.0 if self.measure_t0 <= start_s <= self.measure_t1 \
                else 0.0
        if frac > 0:
            self.m_joules += e * frac
            self.m_handoff_joules += e * frac
            self.m_handoff_bytes += n_bytes * frac
        self.joules += e
        self.handoff_joules += e
        self.handoff_bytes += n_bytes
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "handoff",
                              self.trace_instance, start_s, duration_s, e)
        return e

    def charge_idle(self, dt_s: float) -> None:
        e = self.profile.power_model.p_idle_w * dt_s
        # idle skips can span seconds: pro-rate the in-window share exactly
        # (decode/prefill charges are ms-scale, midpoint-tested instead)
        overlap = max(0.0, min(self.measure_t1, self.sim_time_s + dt_s)
                      - max(self.measure_t0, self.sim_time_s))
        if overlap > 0:
            e_in = self.profile.power_model.p_idle_w * overlap
            self.m_joules += e_in
            self.m_idle_joules += e_in
        self.joules += e
        self.idle_joules += e
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "idle",
                              self.trace_instance, self.sim_time_s,
                              dt_s, e)
        self.sim_time_s += dt_s

    @property
    def tok_per_watt(self) -> float:
        """Output tokens per watt == tokens / joules * seconds... i.e.
        (tokens/s) / (joules/s); output-only accounting per the paper."""
        return self.tokens / self.joules if self.joules else 0.0


class MeterBank:
    """Structure-of-arrays `EnergyMeter`: one row per pool instance.

    The batched pool engine (serving.soa) simulates every instance of a
    provisioned pool in lockstep; each instance still owns its *own*
    timeline of charges, so the bank keeps every counter as an
    (instances,) float64/int64 array and the vectorized charge methods
    replicate `EnergyMeter`'s arithmetic expression-for-expression (same
    float64 operations, same order, per row).  An instance's accumulator
    therefore receives the identical sequence of additions it would have
    received from a scalar meter — the SoA parity suite asserts the
    results are bit-equal.

    Vector charges take `rows` (an index array over instances) plus
    per-row operands; `*_one` variants serve the rare slow paths (multi-
    slot prefill drains, KV handoffs) one instance at a time.
    """

    def __init__(self, profile: BaseProfile, n: int):
        self.profile = profile
        self.n = n
        f = lambda: np.zeros(n, np.float64)        # noqa: E731
        i = lambda: np.zeros(n, np.int64)          # noqa: E731
        self.joules = f()
        self.idle_joules = f()
        self.prefill_joules = f()
        self.handoff_joules = f()
        self.handoff_bytes = f()
        self.m_handoff_bytes = f()
        self.dispatch_s = 0.0                      # shared per-pool floor
        self.dispatch_joules = f()
        self.m_dispatch_joules = f()
        self.tokens = i()
        self.prefill_tokens = i()
        self.sim_time_s = f()
        self.measure_t0 = 0.0
        self.measure_t1 = math.inf
        self.m_tokens = i()
        self.m_joules = f()
        self.m_prefill_joules = f()
        self.m_idle_joules = f()
        self.m_handoff_joules = f()
        self.last_charge_in_window = np.ones(n, bool)
        # FleetScope charge sink (see EnergyMeter.trace) — attach_trace
        # only wires it at level="detail", keeping lifecycle tracing off
        # the vectorized charge path entirely
        self.trace = None
        self.trace_pool = 0

    # --- vectorized twins of the EnergyMeter charges --------------------

    def charge_decode_rows(self, rows: np.ndarray, n_active: np.ndarray,
                           mean_context: np.ndarray) -> np.ndarray:
        """One continuous-batching iteration on every `rows` instance;
        returns tau (s) per row.  `DecodeRoofline.tau_ms` and
        `PowerModel.power_w` are already numpy-vectorized, so the single
        source of Eq. 1 / the roofline stays in core — and the scalar
        meter evaluates the identical float64 expressions, which is what
        keeps batched-vs-scalar parity bit-exact."""
        nf = n_active.astype(np.float64)
        tau_s = self.profile.roofline.tau_ms(nf, mean_context) * 1e-3
        power = self.profile.power_model.power_w(nf)
        mid = self.sim_time_s[rows] + 0.5 * tau_s
        in_win = (self.measure_t0 <= mid) & (mid <= self.measure_t1)
        e = power * tau_s
        dispatch_j = power * np.minimum(self.dispatch_s, tau_s)
        self.last_charge_in_window[rows] = in_win
        self.m_tokens[rows] += np.where(in_win, n_active, 0)
        self.m_joules[rows] += np.where(in_win, e, 0.0)
        self.m_dispatch_joules[rows] += np.where(in_win, dispatch_j, 0.0)
        self.joules[rows] += e
        self.dispatch_joules[rows] += dispatch_j
        self.tokens[rows] += n_active
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "decode", rows,
                              self.sim_time_s[rows], tau_s, e,
                              tokens=n_active, dispatch=dispatch_j)
        self.sim_time_s[rows] += tau_s
        return tau_s

    def charge_prefill_rows(self, rows: np.ndarray, n_tokens: np.ndarray,
                            *, mfu: float, streamed_params: float,
                            overlap_s: np.ndarray) -> np.ndarray:
        prof = self.profile
        flops = (2.0 * streamed_params) * n_tokens.astype(np.float64)
        t = flops / (prof.tp * prof.chip.peak_bf16_flops * mfu)
        e = prof.power_model.p_nom_w * t
        hidden = np.minimum(overlap_s, t)
        dt = t - hidden
        start = self.sim_time_s[rows] - hidden
        end = self.sim_time_s[rows] + dt
        overlap = np.maximum(0.0, np.minimum(self.measure_t1, end)
                             - np.maximum(self.measure_t0, start))
        safe_t = np.where(t > 0, t, 1.0)
        e_in = np.where((overlap > 0) & (t > 0),
                        e * np.minimum(overlap / safe_t, 1.0), 0.0)
        self.m_joules[rows] += e_in
        self.m_prefill_joules[rows] += e_in
        self.joules[rows] += e
        self.prefill_joules[rows] += e
        self.prefill_tokens[rows] += n_tokens
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "prefill", rows, start, t,
                              e, tokens=n_tokens)
        self.sim_time_s[rows] += dt
        return dt

    def charge_idle_rows(self, rows: np.ndarray, dt_s: np.ndarray) -> None:
        p_idle = self.profile.power_model.p_idle_w
        e = p_idle * dt_s
        t = self.sim_time_s[rows]
        overlap = np.maximum(0.0, np.minimum(self.measure_t1, t + dt_s)
                             - np.maximum(self.measure_t0, t))
        e_in = np.where(overlap > 0, p_idle * overlap, 0.0)
        self.m_joules[rows] += e_in
        self.m_idle_joules[rows] += e_in
        self.joules[rows] += e
        self.idle_joules[rows] += e
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "idle", rows, t, dt_s, e)
        self.sim_time_s[rows] += dt_s

    # --- scalar slow paths ----------------------------------------------

    def charge_prefill_one(self, i: int, n_tokens: int, *, mfu: float,
                           streamed_params: float,
                           overlap_s: float = 0.0) -> float:
        rows = np.array([i])
        return float(self.charge_prefill_rows(
            rows, np.array([n_tokens], np.int64), mfu=mfu,
            streamed_params=streamed_params,
            overlap_s=np.array([overlap_s]))[0])

    def charge_handoff_one(self, i: int, n_bytes: float, *, start_s: float,
                           duration_s: float, j_per_byte: float) -> float:
        """Per-request KV-migration charge — mirrors
        `EnergyMeter.charge_handoff` (wall-time interval, clock never
        advances)."""
        e = n_bytes * j_per_byte
        end = start_s + duration_s
        if duration_s > 0:
            overlap = max(0.0, min(self.measure_t1, end)
                          - max(self.measure_t0, start_s))
            frac = overlap / duration_s
        else:
            frac = 1.0 if self.measure_t0 <= start_s <= self.measure_t1 \
                else 0.0
        if frac > 0:
            self.m_joules[i] += e * frac
            self.m_handoff_joules[i] += e * frac
            self.m_handoff_bytes[i] += n_bytes * frac
        self.joules[i] += e
        self.handoff_joules[i] += e
        self.handoff_bytes[i] += n_bytes
        if self.trace is not None:
            self.trace.charge(self.trace_pool, "handoff", i, start_s,
                              duration_s, e)
        return e


# --- conservation invariants --------------------------------------------

def conservation_violations(meter, *, rtol: float = 1e-9,
                            atol: float = 1e-6) -> list:
    """Invariant audit for an `EnergyMeter` or `MeterBank` (per row).

    Checks the accounting identities the rest of the stack leans on:
    every windowed `m_*` counter is bounded by its lifetime total, no
    counter has gone negative, the derived decode residual
    (joules - prefill - idle - handoff) is non-negative, and the MoE
    dispatch share fits inside it (dispatch rides *inside* decode
    charges, never additive).  Returns human-readable violation strings;
    empty list == conserved.  Tolerance is `atol + rtol * |joules|` per
    row — charges are exact float64 sums, so violations beyond rounding
    mean a charge path double-counted or backed out too much.
    """
    out = []

    def arr(name):
        return np.atleast_1d(np.asarray(getattr(meter, name), np.float64))

    joules = arr("joules")
    prefill = arr("prefill_joules")
    idle = arr("idle_joules")
    handoff = arr("handoff_joules")
    tol = atol + rtol * np.abs(joules)

    def chk(ok, msg):
        bad = np.flatnonzero(~ok)
        if len(bad):
            out.append(f"{msg} (rows {bad.tolist()})")

    decode = joules - prefill - idle - handoff
    chk(decode >= -tol,
        "decode residual negative: prefill+idle+handoff > joules")
    chk(arr("dispatch_joules") <= decode + tol,
        "dispatch_joules exceeds the decode share it must ride inside")
    m_sum = (arr("m_prefill_joules") + arr("m_idle_joules")
             + arr("m_handoff_joules"))
    chk(m_sum <= arr("m_joules") + tol,
        "windowed phase joules exceed windowed total")
    for m, t in (("m_joules", "joules"),
                 ("m_prefill_joules", "prefill_joules"),
                 ("m_idle_joules", "idle_joules"),
                 ("m_handoff_joules", "handoff_joules"),
                 ("m_dispatch_joules", "dispatch_joules"),
                 ("m_handoff_bytes", "handoff_bytes")):
        chk(arr(m) <= arr(t) + tol, f"{m} > {t}")
        chk(arr(m) >= -tol, f"{m} negative")
    m_tok = np.atleast_1d(np.asarray(meter.m_tokens))
    tok = np.atleast_1d(np.asarray(meter.tokens))
    chk(m_tok <= tok, "m_tokens > tokens")
    chk(m_tok >= 0, "m_tokens negative")
    chk(tok >= 0, "tokens negative")
    return out
