"""Per-pool energy metering — the serving-side realisation of Eq. 2/4.

Every engine iteration is charged P(b) * tau analytically (this container
has no power sensors; tau comes from the calibrated decode roofline, P(b)
from the logistic power model).  The integration test in
tests/serving/test_serving.py checks the meter converges to the analytical
tok/W of core.tokenomics under the same operating point — closing the loop
between the executable system and the paper's closed-form law.

Steady-state measurement window: a fleet simulation starts from an empty
fleet and drains at the end, but the analytical Eq. 4 number describes
steady state.  Setting `measure_t0`/`measure_t1` makes the meter
additionally accumulate in-window charges into the `m_*` counters, so
ramp-in and drain-out transients can be excluded from the measured tok/W
(the totals are always kept too).  Decode charges are ms-scale and
midpoint-tested; idle and prefill charges can straddle the boundary (idle
skips span seconds, prefill chunks hide behind decode overlap) and are
pro-rated by exact interval overlap.  With the window left at its (0, inf)
default the `m_*` counters simply mirror the totals.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.profiles import BaseProfile


@dataclasses.dataclass
class EnergyMeter:
    profile: BaseProfile
    joules: float = 0.0
    idle_joules: float = 0.0
    prefill_joules: float = 0.0
    handoff_joules: float = 0.0   # KV-migration interconnect energy
    handoff_bytes: float = 0.0
    m_handoff_bytes: float = 0.0  # in-window share (pro-rated like joules)
    # MoE expert-dispatch attribution: the engine sets `dispatch_s` to its
    # pool's per-iteration all-to-all floor (core.moe.with_dispatch_floor —
    # already *inside* the roofline's tau, so this never adds energy, it
    # only labels the share of each decode charge spent moving activations
    # between experts instead of streaming weights)
    dispatch_s: float = 0.0
    dispatch_joules: float = 0.0
    m_dispatch_joules: float = 0.0
    tokens: int = 0
    prefill_tokens: int = 0
    sim_time_s: float = 0.0
    # steady-state measurement window + windowed counters
    measure_t0: float = 0.0
    measure_t1: float = math.inf
    m_tokens: int = 0
    m_joules: float = 0.0
    m_prefill_joules: float = 0.0
    m_idle_joules: float = 0.0
    m_handoff_joules: float = 0.0
    # whether the latest decode charge landed inside the window (engines
    # use this to attribute in-window tokens to slots for eviction backout)
    last_charge_in_window: bool = True

    def _in_window(self, dt_s: float) -> bool:
        mid = self.sim_time_s + 0.5 * dt_s
        return self.measure_t0 <= mid <= self.measure_t1

    def charge_decode_step(self, n_active: int, mean_context: float) -> float:
        """Charge one continuous-batching iteration; returns tau (s)."""
        tau_s = float(self.profile.roofline.tau_ms(max(n_active, 1),
                                                   mean_context)) * 1e-3
        power = self.profile.power_w(n_active)
        self.last_charge_in_window = self._in_window(tau_s)
        dispatch_j = power * min(self.dispatch_s, tau_s)
        if self.last_charge_in_window:
            self.m_tokens += n_active
            self.m_joules += power * tau_s
            self.m_dispatch_joules += dispatch_j
        self.joules += power * tau_s
        self.dispatch_joules += dispatch_j
        self.tokens += n_active
        self.sim_time_s += tau_s
        return tau_s

    def charge_prefill(self, n_tokens: int, *, mfu: float = 0.8,
                       streamed_params: float = 1e9,
                       overlap_s: float = 0.0) -> float:
        """Charge prefill compute.  Energy is always work-proportional and
        drawn at the compute-bound operating point — the logistic's
        saturated draw P_nom (Eq. 1 as b -> inf), not the b = 1 decode
        point: prompt processing saturates the FLOP units.  `overlap_s` is
        decode-iteration time the chunk hides behind (chunked prefill
        piggybacks on the memory-bound decode pass), so only the excess
        advances the clock.  The work therefore spans
        [sim_time - hidden, sim_time + dt]; in-window attribution pro-rates
        the energy by overlap with the measurement window exactly like
        `charge_idle` — midpoint-testing dt would see a zero-length
        interval whenever the chunk fully piggybacks (dt = 0) and
        misattribute boundary-straddling chunks wholesale."""
        flops = 2.0 * streamed_params * n_tokens
        t = flops / (self.profile.tp * self.profile.chip.peak_bf16_flops
                     * mfu)
        e = self.profile.power_model.p_nom_w * t
        hidden = min(overlap_s, t)
        dt = t - hidden
        start, end = self.sim_time_s - hidden, self.sim_time_s + dt
        overlap = max(0.0, min(self.measure_t1, end)
                      - max(self.measure_t0, start))
        if overlap > 0 and t > 0:
            e_in = e * min(overlap / t, 1.0)
            self.m_joules += e_in
            self.m_prefill_joules += e_in
        self.joules += e
        self.prefill_joules += e
        self.prefill_tokens += n_tokens
        self.sim_time_s += dt
        return dt

    def charge_handoff(self, n_bytes: float, *, start_s: float,
                       duration_s: float, j_per_byte: float) -> float:
        """Charge a prefill->decode KV migration (core.disagg): link + HBM
        energy for `n_bytes` moved over [start_s, start_s + duration_s].
        Non-output energy — it never touches the token counters, so it is
        backed out of `decode_tok_per_watt` like prefill and idle.  The
        transfer runs on the interconnect concurrently with compute, so
        the clock does NOT advance; in-window attribution pro-rates by
        exact interval overlap (the interval is wall time, not this
        meter's own timeline)."""
        e = n_bytes * j_per_byte
        end = start_s + duration_s
        if duration_s > 0:
            overlap = max(0.0, min(self.measure_t1, end)
                          - max(self.measure_t0, start_s))
            frac = overlap / duration_s
        else:   # instantaneous: midpoint-test the start instant
            frac = 1.0 if self.measure_t0 <= start_s <= self.measure_t1 \
                else 0.0
        if frac > 0:
            self.m_joules += e * frac
            self.m_handoff_joules += e * frac
            self.m_handoff_bytes += n_bytes * frac
        self.joules += e
        self.handoff_joules += e
        self.handoff_bytes += n_bytes
        return e

    def charge_idle(self, dt_s: float) -> None:
        e = self.profile.power_model.p_idle_w * dt_s
        # idle skips can span seconds: pro-rate the in-window share exactly
        # (decode/prefill charges are ms-scale, midpoint-tested instead)
        overlap = max(0.0, min(self.measure_t1, self.sim_time_s + dt_s)
                      - max(self.measure_t0, self.sim_time_s))
        if overlap > 0:
            e_in = self.profile.power_model.p_idle_w * overlap
            self.m_joules += e_in
            self.m_idle_joules += e_in
        self.joules += e
        self.idle_joules += e
        self.sim_time_s += dt_s

    @property
    def tok_per_watt(self) -> float:
        """Output tokens per watt == tokens / joules * seconds... i.e.
        (tokens/s) / (joules/s); output-only accounting per the paper."""
        return self.tokens / self.joules if self.joules else 0.0
