"""Per-pool energy metering — the serving-side realisation of Eq. 2/4.

Every engine iteration is charged P(b) * tau analytically (this container
has no power sensors; tau comes from the calibrated decode roofline, P(b)
from the logistic power model).  The integration test in
tests/serving/test_energy.py checks the meter converges to the analytical
tok/W of core.tokenomics under the same operating point — closing the loop
between the executable system and the paper's closed-form law.
"""
from __future__ import annotations

import dataclasses

from repro.core.profiles import BaseProfile


@dataclasses.dataclass
class EnergyMeter:
    profile: BaseProfile
    joules: float = 0.0
    idle_joules: float = 0.0
    tokens: int = 0
    prefill_tokens: int = 0
    sim_time_s: float = 0.0

    def charge_decode_step(self, n_active: int, mean_context: float) -> float:
        """Charge one continuous-batching iteration; returns tau (s)."""
        tau_s = float(self.profile.roofline.tau_ms(max(n_active, 1),
                                                   mean_context)) * 1e-3
        power = self.profile.power_w(n_active)
        self.joules += power * tau_s
        self.tokens += n_active
        self.sim_time_s += tau_s
        return tau_s

    def charge_prefill(self, n_tokens: int, *, mfu: float = 0.8,
                       streamed_params: float = 1e9) -> float:
        flops = 2.0 * streamed_params * n_tokens
        t = flops / (self.profile.tp * self.profile.chip.peak_bf16_flops
                     * mfu)
        self.joules += self.profile.power_w(1) * t
        self.prefill_tokens += n_tokens
        self.sim_time_s += t
        return t

    def charge_idle(self, dt_s: float) -> None:
        self.joules += self.profile.power_model.p_idle_w * dt_s
        self.idle_joules += self.profile.power_model.p_idle_w * dt_s
        self.sim_time_s += dt_s

    @property
    def tok_per_watt(self) -> float:
        """Output tokens per watt == tokens / joules * seconds... i.e.
        (tokens/s) / (joules/s); output-only accounting per the paper."""
        return self.tokens / self.joules if self.joules else 0.0
