"""Model-heterogeneity registry: which model each pool role serves.

Until PR 4 the fleet simulator held exactly one `(ModelSpec, profile)`
pair for every pool — enough for context-length routing, where the pools
differ only in window, but structurally unable to serve the paper's other
two levers: semantic routing (§5.1 — a *small* model behind the short
window, the large model behind the long one) and MoE active-parameter
streaming (§3.2 — a pool whose per-iteration weight stream is
`active_param_bytes` plus an all-to-all dispatch floor).

`ModelProfileRegistry` binds each router role to its own `ModelBinding`:
the analytical `ModelSpec` (streamed params for prefill/decode charging,
KV geometry for handoff sizing) plus the `BaseProfile` the pool's
engines run on, and the MoE dispatch floor used for per-iteration energy
*attribution* (the dispatch latency itself lives inside the profile's
roofline — see `core.moe.with_dispatch_floor` — so time and energy can
never disagree; the binding's `dispatch_ms` only labels the share).

`serving.fleetsim.build_topology` constructs the registry next to the
router policy and sizing plan; `FleetSim` consumes it when instantiating
engines.  Homogeneous topologies get a registry with only a default
binding, so nothing changes for them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.modelspec import ModelSpec
from repro.core.profiles import BaseProfile


@dataclasses.dataclass(frozen=True)
class ModelBinding:
    """One pool role's serving identity."""

    model: ModelSpec
    profile: BaseProfile
    # MoE expert-dispatch floor folded into profile.roofline.w_ms (ms).
    # Kept on the binding so meters can attribute the dispatch share of
    # each decode iteration's energy (EnergyMeter.dispatch_joules).
    dispatch_ms: float = 0.0

    @property
    def streamed_params(self) -> float:
        return self.model.streamed_params

    def kv_bytes_per_instance_token(self,
                                    profile: Optional[BaseProfile] = None,
                                    ) -> float:
        """Whole-instance KV bytes per prompt token — what a prefill ->
        decode handoff moves over the interconnect per token (the
        per-GPU KV share times the TP degree of the pool the prefill ran
        on; pass `profile` when the pool runs on a different deployment
        than the binding's default)."""
        prof = profile if profile is not None else self.profile
        return self.model.kv_bytes_per_token(tp=prof.tp) * prof.tp


@dataclasses.dataclass
class ModelProfileRegistry:
    """role -> ModelBinding, with a default for unbound roles."""

    default: ModelBinding
    bindings: Dict[str, ModelBinding] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def homogeneous(cls, model: ModelSpec, profile: BaseProfile, *,
                    dispatch_ms: float = 0.0) -> "ModelProfileRegistry":
        return cls(default=ModelBinding(model=model, profile=profile,
                                        dispatch_ms=dispatch_ms))

    def bind(self, role: str, binding: ModelBinding) -> "ModelProfileRegistry":
        self.bindings[role] = binding
        return self

    def for_role(self, role: str) -> ModelBinding:
        return self.bindings.get(role, self.default)

    def streamed_params_by_role(self, roles) -> Dict[str, float]:
        """Per-role streamed params in `core.fleet.apply_overrides` form."""
        return {r: self.for_role(r).streamed_params for r in roles}

    @property
    def heterogeneous(self) -> bool:
        return any(b.model is not self.default.model
                   or b.profile is not self.default.profile
                   for b in self.bindings.values())
