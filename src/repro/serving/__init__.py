from .energy import EnergyMeter
from .engine import PoolEngine, scaled_prefill_chunk
from .fleetsim import (FleetSim, PoolGroup, SimVsAnalytical,
                       analytical_decode_tok_per_watt, build_topology,
                       simulate_topology, topology_roles, trace_requests)
from .models import ModelBinding, ModelProfileRegistry
from .request import Request, synthetic_requests
from .router import SEMANTIC_KINDS, ContextRouter, RouterPolicy

__all__ = ["EnergyMeter", "PoolEngine", "Request", "synthetic_requests",
           "ContextRouter", "RouterPolicy", "FleetSim", "PoolGroup",
           "SimVsAnalytical", "analytical_decode_tok_per_watt",
           "build_topology", "simulate_topology", "topology_roles",
           "trace_requests", "ModelBinding", "ModelProfileRegistry",
           "SEMANTIC_KINDS", "scaled_prefill_chunk"]
