from .energy import EnergyMeter
from .engine import PoolEngine
from .request import Request, synthetic_requests
from .router import ContextRouter, RouterPolicy

__all__ = ["EnergyMeter", "PoolEngine", "Request", "synthetic_requests",
           "ContextRouter", "RouterPolicy"]
