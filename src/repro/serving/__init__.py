from .autoscale import Autoscaler, AutoscalePolicy, InstanceSchedule
from .energy import EnergyMeter, MeterBank, conservation_violations
from .engine import (DrainTruncatedError, PoolEngine, resolve_prefill_chunk,
                     scaled_prefill_chunk)
from .fleetsim import (FleetSim, PoolGroup, PoolSummary, SimVsAnalytical,
                       analytical_decode_tok_per_watt, build_topology,
                       prepare_spec, prepare_topology, run_fleet_grid,
                       simulate_spec, simulate_topology, trace_requests)
from .models import ModelBinding, ModelProfileRegistry
from .request import Request, sample_diurnal_trace, synthetic_requests
from .router import SEMANTIC_KINDS, ContextRouter, RouterPolicy
from .soa import BatchedPoolEngine
from .telemetry import (TraceRecorder, build_timeline, phase_totals,
                        reconcile_energy, to_perfetto)

__all__ = ["EnergyMeter", "MeterBank", "PoolEngine", "BatchedPoolEngine",
           "TraceRecorder", "build_timeline", "phase_totals",
           "reconcile_energy", "to_perfetto", "conservation_violations",
           "Request", "synthetic_requests", "sample_diurnal_trace",
           "Autoscaler", "AutoscalePolicy", "InstanceSchedule",
           "ContextRouter", "RouterPolicy", "FleetSim", "PoolGroup",
           "PoolSummary",
           "SimVsAnalytical", "analytical_decode_tok_per_watt",
           "build_topology", "simulate_topology", "simulate_spec",
           "trace_requests", "ModelBinding", "ModelProfileRegistry",
           "SEMANTIC_KINDS", "DrainTruncatedError", "resolve_prefill_chunk",
           "scaled_prefill_chunk", "prepare_topology", "prepare_spec",
           "run_fleet_grid"]
