from .energy import EnergyMeter
from .engine import PoolEngine
from .fleetsim import (FleetSim, PoolGroup, SimVsAnalytical, build_topology,
                       simulate_topology, topology_roles, trace_requests)
from .request import Request, synthetic_requests
from .router import ContextRouter, RouterPolicy

__all__ = ["EnergyMeter", "PoolEngine", "Request", "synthetic_requests",
           "ContextRouter", "RouterPolicy", "FleetSim", "PoolGroup",
           "SimVsAnalytical", "build_topology", "simulate_topology",
           "topology_roles", "trace_requests"]
