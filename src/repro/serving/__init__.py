from .energy import EnergyMeter
from .engine import PoolEngine
from .fleetsim import (FleetSim, PoolGroup, SimVsAnalytical,
                       analytical_decode_tok_per_watt, build_topology,
                       simulate_topology, topology_roles, trace_requests)
from .request import Request, synthetic_requests
from .router import ContextRouter, RouterPolicy

__all__ = ["EnergyMeter", "PoolEngine", "Request", "synthetic_requests",
           "ContextRouter", "RouterPolicy", "FleetSim", "PoolGroup",
           "SimVsAnalytical", "analytical_decode_tok_per_watt",
           "build_topology", "simulate_topology", "topology_roles",
           "trace_requests"]
