"""Structure-of-arrays pool engine: a whole provisioned pool in lockstep.

`PoolEngine` (serving.engine) simulates ONE instance with slot-batched
numpy arrays; an SLO-sized fleet pool is 20-100+ instances, and stepping
them in per-engine Python loops made the Python interpreter — not the
simulation — the bottleneck (each ~15-numpy-op step costs ~170 us on
arrays of 5-256 slots).  `BatchedPoolEngine` extends those slot arrays
with an **instance axis**: all `instances x n_slots` slots of a pool live
in one set of (I, S) arrays, and one global step advances *every* busy
instance by one continuous-batching iteration.  Instances are mutually
independent (cross-instance flow exists only between pools, handled by
FleetSim after a pool drains), so lockstep stepping replays exactly the
per-instance event sequences the scalar engines would have produced — the
clocks simply diverge per row, carried in a `MeterBank` row per instance.

Parity contract (asserted by tests/serving/test_soa_parity.py): for any
request stream, the batched engine reproduces the scalar `PoolEngine`
semantics *bit-for-bit* per instance — admission order, chunked-prefill
interleave, window-ceiling eviction, escalation detection and backout,
prefill-phase FIFO draining, and every meter counter.  The vectorized
arithmetic in `MeterBank` evaluates the same float64 expressions in the
same order as `EnergyMeter`, so this is an equality, not a tolerance.

Hot-path structure per global step (decode phase):

  * idle-skip, admission gating, decode charge, token/position advance,
    completion/escalation/ceiling masks: vectorized over (I, S);
  * per-*event* work (a request finishing, evicting, escalating, or
    draining its last prefill chunk) stays in Python — events are O(one
    per request), not O(steps);
  * the chunked-prefill drain takes a vectorized fast path for the
    overwhelmingly common case (the row's first pending slot absorbs the
    whole chunk budget without draining) and falls back to the scalar
    loop otherwise.

Analytical mode only: model-mode (jitted) serving keeps the scalar
`PoolEngine`, which remains the reference implementation.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.fleet import PREFILL_MFU
from repro.core.profiles import BaseProfile
from repro.core.timeline import (EV_ADMIT, EV_COMPLETE, EV_ESCALATE,
                                 EV_FIRST_TOKEN, EV_HANDOFF, EV_OVERFLOW,
                                 EV_PREFILL)

from .energy import MeterBank
from .engine import (_LCG_A, _LCG_C, _NEVER, DrainTruncatedError,
                     resolve_prefill_chunk)
from .request import Request


class BatchedPoolEngine:
    """All `instances` replicas of one pool as (instances, n_slots) SoA."""

    def __init__(self, *, instances: int, window: int,
                 profile: BaseProfile, n_slots: Optional[int] = None,
                 name: str = "pool", rng_seed: int = 0,
                 seed_stride: int = 7919,
                 prefill_chunk: Optional[int] = None,
                 evict_on_overflow: bool = False,
                 respect_arrival: bool = False,
                 streamed_params: Optional[float] = None,
                 vocab: int = 32000, phase: str = "decode",
                 prefill_mfu: Optional[float] = None,
                 dispatch_ms: float = 0.0):
        if instances < 1:
            raise ValueError("need at least one instance")
        if streamed_params is None:
            raise ValueError("analytical mode needs streamed_params")
        if phase not in ("decode", "prefill"):
            raise ValueError(f"unknown engine phase {phase!r}")
        self.instances = instances
        self.window = window
        self.name = name
        self.profile = profile
        self.n_slots = n_slots if n_slots is not None \
            else max(profile.n_max(window), 1)
        self.phase = phase
        self.prefill_chunk = resolve_prefill_chunk(profile, prefill_chunk,
                                                   phase)
        self.prefill_mfu = PREFILL_MFU if prefill_mfu is None else prefill_mfu
        self.evict_on_overflow = evict_on_overflow
        self.respect_arrival = respect_arrival
        self.vocab = vocab
        self._streamed_params = float(streamed_params)
        self.dispatch_ms = dispatch_ms
        I, S = instances, self.n_slots
        self.bank = MeterBank(profile, I)
        self.bank.dispatch_s = max(dispatch_ms, 0.0) * 1e-3
        # per-(instance, slot) state — the scalar engine's arrays + 1 axis
        self.pos = np.zeros((I, S), np.int32)
        self.tokens = np.zeros((I, S), np.int64)
        self.gen_count = np.zeros((I, S), np.int32)
        self.m_gen = np.zeros((I, S), np.int32)
        self.max_new = np.zeros((I, S), np.int32)
        self.prefill_left = np.zeros((I, S), np.int64)
        self.escalate_at = np.full((I, S), _NEVER, np.int32)
        self.ready_ts = np.zeros((I, S), np.float64)  # prefill-phase FIFO
        self._active = np.zeros((I, S), bool)
        self.slots: List[List[Optional[Request]]] = \
            [[None] * S for _ in range(I)]
        # per-instance state
        self.seeds = np.int64(rng_seed) \
            + np.int64(seed_stride) * np.arange(I, dtype=np.int64)
        self.queues: List[List[Request]] = [[] for _ in range(I)]
        self.preempted = np.zeros(I, np.int64)
        self.n_escalated = np.zeros(I, np.int64)
        self.slot_seconds = np.zeros(I, np.float64)
        # steady-state-windowed occupancy integral (pro-rated by overlap
        # with the bank's measurement window, like the m_* counters) —
        # the SLO loop's HOL calibration reads populations from this so
        # ramp-in/drain transients don't deflate the measurement
        self.m_slot_seconds = np.zeros(I, np.float64)
        self.completed: List[List[Request]] = [[] for _ in range(I)]
        self.overflowed: List[List[Request]] = [[] for _ in range(I)]
        self.escalated: List[List[Request]] = [[] for _ in range(I)]
        self.handoff: List[List[Request]] = [[] for _ in range(I)]
        self.relayed: List[List[Request]] = [[] for _ in range(I)]
        # autoscaling (serving.autoscale): per-row online windows.  None
        # (the default) means every row is online for the whole run —
        # the exact pre-autoscale behaviour, down to the float ops.
        self.online_from: Optional[np.ndarray] = None
        self.online_until: Optional[np.ndarray] = None
        # admission bookkeeping, built by _freeze() at run start
        self.qpos = np.zeros(I, np.int64)
        self.qlen = np.zeros(I, np.int64)
        self.head_ready = np.full(I, np.inf)
        self.min_ready = np.full(I, np.inf)
        self._ready_arr: List[np.ndarray] = [np.empty(0)] * I
        self._sufmin: List[np.ndarray] = [np.empty(0)] * I
        # FleetScope sink (serving.telemetry.TraceRecorder): None =
        # telemetry off; every hook is an `is not None` guard around
        # pure reads, so disabled runs are bit-identical
        self.trace = None
        self._trace_pool = 0

    def attach_trace(self, recorder, *,
                     name: Optional[str] = None) -> None:
        """Opt the pool into FleetScope tracing.  Lifecycle events ride
        the per-event Python paths (O(1) per request edge); the
        vectorized charge/occupancy channels are wired only at
        level="detail" so lifecycle tracing never touches the hot
        array path."""
        self.trace = recorder
        self._trace_pool = recorder.pool_id(name or self.name,
                                            instances=self.instances)
        self.bank.trace = recorder if recorder.detail else None
        self.bank.trace_pool = self._trace_pool

    # --- submission -----------------------------------------------------

    @staticmethod
    def _ready(req: Request) -> float:
        return req.ready_time if req.ready_time is not None \
            else req.arrival_time

    def submit(self, req: Request, instance: int) -> None:
        req.pool = f"{self.name}#{instance}"
        self.queues[instance].append(req)

    def set_online_windows(self, online_from, online_until, *,
                           load_s: float = 0.0) -> None:
        """Configure per-row `[online_from, online_until)` availability
        (serving.autoscale).  Each row's clock starts at its online time
        — the hours before a scale-up incarnation exists are simply
        never simulated, so no idle accrues for them — and every live
        late-start row is charged `load_s` of weight-streaming idle draw
        ending exactly at its online instant.  Call after the bank's
        measurement window is set (the load charge pro-rates against
        it) and before any submission."""
        self.online_from = np.asarray(online_from, np.float64)
        self.online_until = np.asarray(online_until, np.float64)
        if self.online_from.shape != (self.instances,) \
                or self.online_until.shape != (self.instances,):
            raise ValueError("online windows must be (instances,) arrays")
        self.bank.sim_time_s[:] = np.maximum(self.online_from, 0.0)
        live = (self.online_from > 0) \
            & (self.online_until > self.online_from)
        if load_s > 0 and live.any():
            rows = np.flatnonzero(live)
            self.bank.sim_time_s[rows] = self.online_from[rows] - load_s
            self.bank.charge_idle_rows(rows, np.full(rows.size, load_s))

    def sort_queues(self) -> None:
        """Stable time-sort every instance queue (head-gated admission) —
        the batched twin of FleetSim's per-engine inbox re-sort."""
        for q in self.queues:
            q.sort(key=self._ready)

    def _freeze(self) -> None:
        """Queues are static once the pool runs (all routing and inbox
        injection happen first): precompute per-row ready arrays and
        suffix minima so head gating and idle-skip are O(1) lookups."""
        for i, q in enumerate(self.queues):
            r = np.array([self._ready(x) for x in q], np.float64)
            self._ready_arr[i] = r
            self._sufmin[i] = np.minimum.accumulate(r[::-1])[::-1] \
                if len(r) else r
            self.qlen[i] = len(q)
        self.qpos[:] = 0
        self._refresh_heads(np.arange(self.instances))

    def _refresh_heads(self, rows) -> None:
        for i in np.atleast_1d(rows):
            k = int(self.qpos[i])
            if k < self.qlen[i]:
                self.head_ready[i] = self._ready_arr[i][k]
                self.min_ready[i] = self._sufmin[i][k]
            else:
                self.head_ready[i] = self.min_ready[i] = np.inf

    # --- admission ------------------------------------------------------

    def _admit_all(self) -> None:
        gate = (self.qpos < self.qlen) & ~self._active.all(axis=1)
        if self.respect_arrival:
            gate &= self.head_ready <= self.bank.sim_time_s
        if not gate.any():
            return
        for i in np.flatnonzero(gate):
            self._admit_row(int(i))

    def _admit_row(self, i: int) -> None:
        q = self.queues[i]
        while self.qpos[i] < self.qlen[i]:
            inactive = np.flatnonzero(~self._active[i])
            if not inactive.size:
                break
            req = q[int(self.qpos[i])]
            if self.respect_arrival \
                    and self._ready(req) > self.bank.sim_time_s[i]:
                break
            self.qpos[i] += 1
            s = int(inactive[0])
            plen = req.prompt_len
            if self.trace is not None and self.trace.detail:
                self.trace.event(EV_ADMIT, req.rid, self._trace_pool, i,
                                 float(self.bank.sim_time_s[i]))
            self.slots[i][s] = req
            self._active[i, s] = True
            self.pos[i, s] = plen
            self.max_new[i, s] = req.max_new_tokens
            self.ready_ts[i, s] = self._ready(req)
            if req.prefill_done:
                # disagg decode pool: prompt drained upstream, KV arrived
                # over the interconnect — no prefill work or charge here
                self.prefill_left[i, s] = 0
                self.gen_count[i, s] = 1
                self.escalate_at[i, s] = req.escalate_at \
                    if req.escalate_at is not None else _NEVER
                self.tokens[i, s] = int(req.generated[0]) if req.generated \
                    else int((np.int64(req.rid) * _LCG_A + self.seeds[i]
                              + _LCG_C) % self.vocab)
                continue
            first_tok = int((np.int64(req.rid) * _LCG_A + self.seeds[i]
                             + _LCG_C) % self.vocab)
            self.escalate_at[i, s] = req.escalate_at \
                if req.escalate_at is not None else _NEVER
            if self.prefill_chunk:
                self.prefill_left[i, s] = plen
                self.gen_count[i, s] = 0
                self.tokens[i, s] = first_tok
                req.generated = []
            else:
                self.bank.charge_prefill_one(
                    i, plen, mfu=self.prefill_mfu,
                    streamed_params=self._streamed_params)
                self.prefill_left[i, s] = 0
                self.gen_count[i, s] = 1
                self.tokens[i, s] = first_tok
                req.generated = [first_tok]
                req.n_generated = 1
                req.first_token_time = float(self.bank.sim_time_s[i])
                if self.trace is not None:
                    self.trace.event(EV_FIRST_TOKEN, req.rid,
                                     self._trace_pool, i,
                                     req.first_token_time)
        self._refresh_heads(i)

    # --- per-event bookkeeping (Python: O(1) per request lifetime) ------

    def _clear_slot(self, i: int, s: int) -> None:
        self.slots[i][s] = None
        self._active[i, s] = False
        self.prefill_left[i, s] = 0
        self.gen_count[i, s] = 0
        self.m_gen[i, s] = 0
        self.escalate_at[i, s] = _NEVER

    def _finish(self, i: int, s: int) -> None:
        req = self.slots[i][s]
        req.n_generated = int(self.gen_count[i, s])
        req.generated = None          # analytical mode: ids are synthetic
        req.finish_time = float(self.bank.sim_time_s[i])
        if self.trace is not None:
            self.trace.event(EV_COMPLETE, req.rid, self._trace_pool, i,
                             req.finish_time)
        self.completed[i].append(req)
        self._clear_slot(i, s)

    def _back_out_and_evict(self, i: int, s: int) -> Request:
        req = self.slots[i][s]
        self.bank.tokens[i] -= max(int(self.gen_count[i, s]) - 1, 0)
        self.bank.m_tokens[i] -= int(self.m_gen[i, s])
        req.generated = None
        req.prefill_done = False
        req.preemptions += 1
        req.ready_time = float(self.bank.sim_time_s[i])
        req.escalate_at = None
        self._clear_slot(i, s)
        self.preempted[i] += 1
        return req

    def _evict_overflow(self, i: int, s: int) -> None:
        req = self._back_out_and_evict(i, s)
        if self.trace is not None:
            self.trace.event(EV_OVERFLOW, req.rid, self._trace_pool, i,
                             req.ready_time)
        self.overflowed[i].append(req)

    def _evict_escalation(self, i: int, s: int) -> None:
        req = self._back_out_and_evict(i, s)
        req.escalations += 1
        self.n_escalated[i] += 1
        if self.trace is not None:
            self.trace.event(EV_ESCALATE, req.rid, self._trace_pool, i,
                             req.ready_time)
        self.escalated[i].append(req)

    def _finish_prefill(self, i: int, s: int) -> None:
        req = self.slots[i][s]
        t = float(self.bank.sim_time_s[i])
        req.n_generated = 1
        req.generated = [int(self.tokens[i, s])]
        req.first_token_time = t
        req.prefill_done = True
        req.ready_time = t
        if self.trace is not None:
            self.trace.event(EV_FIRST_TOKEN, req.rid, self._trace_pool,
                             i, req.first_token_time)
            self.trace.event(EV_HANDOFF, req.rid, self._trace_pool, i,
                             req.ready_time)
        self.handoff[i].append(req)
        self.relayed[i].append(req)
        self._clear_slot(i, s)

    # --- the lockstep step ----------------------------------------------

    def _step_all(self) -> bool:
        active_any = self._active.any(axis=1)
        has_q = self.qpos < self.qlen
        alive = active_any | has_q
        if not alive.any():
            return False
        if self.respect_arrival:
            # event-driven idle skip: rows with nothing in flight jump to
            # their queue's next arrival (idle power accrues over the gap)
            idle = ~active_any & has_q
            if idle.any():
                rows = np.flatnonzero(idle)
                dt = self.min_ready[rows] - self.bank.sim_time_s[rows]
                fwd = dt > 0
                if fwd.any():
                    self.bank.charge_idle_rows(rows[fwd], dt[fwd])
        t_start = self.bank.sim_time_s.copy()
        self._admit_all()
        if self.phase == "prefill":
            self._step_prefill_rows(t_start)
            return True
        n_occ = self._active.sum(axis=1)
        dec = self._active & (self.prefill_left == 0)
        n_dec = dec.sum(axis=1)
        drows = np.flatnonzero(n_dec > 0)
        tau_full = np.zeros(self.instances)
        if drows.size:
            toks = self.tokens[drows]
            nxt = (toks * _LCG_A + _LCG_C + self.seeds[drows, None]) \
                % self.vocab
            d = dec[drows]
            nd = n_dec[drows]
            mean_ctx = (self.pos[drows] * d).sum(axis=1, dtype=np.int64) \
                / nd
            tau = self.bank.charge_decode_rows(drows, nd.astype(np.int64),
                                               mean_ctx)
            tau_full[drows] = tau
            in_win = self.bank.last_charge_in_window[drows]
            self.m_gen[drows] += d & in_win[:, None]
            self.tokens[drows] = np.where(d, nxt, toks)
            self.gen_count[drows] += d
            self.pos[drows] += d
            gc = self.gen_count[drows]
            done = d & (gc >= self.max_new[drows])
            escalate = d & ~done & (gc >= self.escalate_at[drows])
            at_ceiling = d & ~done & ~escalate \
                & (self.pos[drows] >= self.window - 1)
            if not self.evict_on_overflow:
                done = done | at_ceiling
            if done.any():
                for r, s in np.argwhere(done):
                    self._finish(int(drows[r]), int(s))
            if escalate.any():
                for r, s in np.argwhere(escalate):
                    self._evict_escalation(int(drows[r]), int(s))
            if self.evict_on_overflow and at_ceiling.any():
                for r, s in np.argwhere(at_ceiling):
                    self._evict_overflow(int(drows[r]), int(s))
        if self.prefill_chunk:
            self._drain_chunks(tau_full)
        self._accrue_occupancy(n_occ, t_start)
        return True

    def _accrue_occupancy(self, n_occ: np.ndarray,
                          t_start: np.ndarray) -> None:
        b = self.bank
        self.slot_seconds += n_occ * (b.sim_time_s - t_start)
        overlap = np.maximum(
            0.0, np.minimum(b.measure_t1, b.sim_time_s)
            - np.maximum(b.measure_t0, t_start))
        self.m_slot_seconds += n_occ * overlap
        if self.trace is not None and self.trace.detail:
            dt = b.sim_time_s - t_start
            live = dt > 0
            if live.any():
                rows = np.flatnonzero(live)
                self.trace.occupancy_sample(self._trace_pool, rows,
                                            t_start[rows], dt[rows],
                                            n_occ[rows])

    def _drain_chunks(self, tau_full: np.ndarray) -> None:
        """Chunked-prefill interleave across all rows.  Fast path: the
        row's first pending slot (lowest index, as in the scalar drain)
        absorbs the whole budget without draining — one vectorized charge
        riding that row's decode tau.  Anything else (a slot completes, or
        budget spills to the next slot) replays the scalar loop."""
        chunk = self.prefill_chunk
        pend = self._active & (self.prefill_left > 0)
        rows = np.flatnonzero(pend.any(axis=1))
        if not rows.size:
            return
        first = np.argmax(pend[rows], axis=1)
        pl = self.prefill_left[rows, first]
        fast = pl > chunk
        frows = rows[fast]
        if frows.size:
            if self.trace is not None and self.trace.detail:
                fslots = first[fast]
                for k, i in enumerate(frows):
                    self.trace.event(
                        EV_PREFILL, self.slots[int(i)][int(fslots[k])].rid,
                        self._trace_pool, int(i),
                        float(self.bank.sim_time_s[i]))
            self.bank.charge_prefill_rows(
                frows, np.full(frows.size, chunk, np.int64),
                mfu=self.prefill_mfu, streamed_params=self._streamed_params,
                overlap_s=tau_full[frows])
            self.prefill_left[frows, first[fast]] -= chunk
        for i in rows[~fast]:
            i = int(i)
            budget = chunk
            overlap = float(tau_full[i])
            for s in np.flatnonzero(pend[i]):
                if budget <= 0:
                    break
                s = int(s)
                take = int(min(budget, self.prefill_left[i, s]))
                if self.trace is not None and self.trace.detail:
                    self.trace.event(EV_PREFILL, self.slots[i][s].rid,
                                     self._trace_pool, i,
                                     float(self.bank.sim_time_s[i]))
                self.bank.charge_prefill_one(
                    i, take, mfu=self.prefill_mfu,
                    streamed_params=self._streamed_params,
                    overlap_s=overlap)
                overlap = 0.0         # one chunk rides each decode pass
                self.prefill_left[i, s] -= take
                budget -= take
                if self.prefill_left[i, s] == 0:
                    req = self.slots[i][s]
                    self.gen_count[i, s] = 1
                    req.generated = [int(self.tokens[i, s])]
                    req.n_generated = 1
                    req.first_token_time = float(self.bank.sim_time_s[i])
                    if self.trace is not None:
                        self.trace.event(EV_FIRST_TOKEN, req.rid,
                                         self._trace_pool, i,
                                         req.first_token_time)

    def _step_prefill_rows(self, t_start: np.ndarray) -> None:
        """Prefill-phase lockstep: each busy row drains up to one chunk
        budget across its occupied slots, oldest request first (the
        scalar engine's FIFO over slot recycling).  Fast path: the
        oldest pending slot alone absorbs the budget."""
        chunk = self.prefill_chunk
        n_occ = self._active.sum(axis=1)
        pend = self._active & (self.prefill_left > 0)
        rows = np.flatnonzero(pend.any(axis=1))
        if rows.size:
            rts = np.where(pend[rows], self.ready_ts[rows], np.inf)
            first = np.argmin(rts, axis=1)    # oldest; ties -> lowest slot
            pl = self.prefill_left[rows, first]
            fast = pl > chunk
            frows = rows[fast]
            if frows.size:
                if self.trace is not None and self.trace.detail:
                    fslots = first[fast]
                    for k, i in enumerate(frows):
                        self.trace.event(
                            EV_PREFILL,
                            self.slots[int(i)][int(fslots[k])].rid,
                            self._trace_pool, int(i),
                            float(self.bank.sim_time_s[i]))
                self.bank.charge_prefill_rows(
                    frows, np.full(frows.size, chunk, np.int64),
                    mfu=self.prefill_mfu,
                    streamed_params=self._streamed_params,
                    overlap_s=np.zeros(frows.size))
                self.prefill_left[frows, first[fast]] -= chunk
            for i in rows[~fast]:
                i = int(i)
                budget = chunk
                order = np.flatnonzero(pend[i])
                order = order[np.argsort(self.ready_ts[i, order],
                                         kind="stable")]
                for s in order:
                    if budget <= 0:
                        break
                    s = int(s)
                    take = int(min(budget, self.prefill_left[i, s]))
                    if self.trace is not None and self.trace.detail:
                        self.trace.event(EV_PREFILL, self.slots[i][s].rid,
                                         self._trace_pool, i,
                                         float(self.bank.sim_time_s[i]))
                    self.bank.charge_prefill_one(
                        i, take, mfu=self.prefill_mfu,
                        streamed_params=self._streamed_params)
                    self.prefill_left[i, s] -= take
                    budget -= take
                    if self.prefill_left[i, s] == 0:
                        self._finish_prefill(i, s)
        self._accrue_occupancy(n_occ, t_start)

    # --- drive ----------------------------------------------------------

    def run_until_drained(self, max_iters: int = 100_000) -> None:
        self._freeze()
        it = 0
        while it < max_iters:
            if not self._step_all():
                break
            it += 1
        if self.busy:
            qleft = sum(len(q) - int(p)
                        for q, p in zip(self.queues, self.qpos))
            raise DrainTruncatedError(
                self.name, max_iters,
                f"{qleft} queued, {int(self._active.sum())} in flight")

    # --- aggregates -----------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self._active.any()
                    or any(self.qpos[i] < len(self.queues[i])
                           for i in range(self.instances)))

    def occupancy(self) -> np.ndarray:
        denom = self.n_slots * self.bank.sim_time_s
        return np.divide(self.slot_seconds, denom,
                         out=np.zeros(self.instances), where=denom > 0)
