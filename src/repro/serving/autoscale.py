"""Reactive per-pool autoscaling for the fleet simulator.

Steady-state runs provision each pool once and keep every instance
powered for the whole trace; under a diurnal envelope (a ~5x day/night
swing — `core.workloads.DiurnalProfile`) that charges peak-sized idle
power all night, which is exactly the regime where the 1/W law's fleet
denominator is dominated by watts nobody is using.  `Autoscaler` turns
the routed trace into **per-instance online windows**: each pool tracks
its own per-epoch arrival rate and scales its live instance count
between a floor and the peak plan, paying scale-up actuation lag,
weight-load time and warm-spare idle power on the way (the friction
knobs live in `core.autoscale.AutoscalePolicy`).

Execution-model fit: routing is context-length-based and
time-independent, so every request's destination pool is known up front
— the per-pool arrival-rate signal the controller consumes is exactly
the primary routed trace (migrated/escalated re-entries are excluded,
like a real RPS autoscaler that keys on ingress traffic).  Each scale-up
incarnation becomes a *fresh engine row* with a single
``[online_from, online_until)`` window, so the event-driven per-row
clocks need no new machinery: a row's clock simply starts at its online
time (after its weight load is charged as idle draw), the balancer only
assigns it requests arriving inside its window, and the fleet report
stops charging its idle power at its retire time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np

from repro.core.autoscale import AutoscalePolicy

__all__ = ["AutoscalePolicy", "InstanceSchedule", "Autoscaler"]


@dataclasses.dataclass
class InstanceSchedule:
    """One pool's planned incarnations: row i of the (rebuilt) engine is
    online over ``[online_from[i], online_until[i])``.  The first
    `n_peak` rows are the initial (peak-provisioned) fleet; later rows
    are scale-up incarnations that pay `load_s` of weight streaming
    before their window opens.  A cancelled incarnation (scaled back
    down before it ever came online) has a zero-length window and is
    never charged."""

    online_from: np.ndarray
    online_until: np.ndarray
    n_peak: int
    load_s: float

    @property
    def n_rows(self) -> int:
        return int(len(self.online_from))

    def online_at(self, t) -> np.ndarray:
        """Live instance count at time(s) t (vectorised)."""
        t = np.asarray(t, dtype=np.float64)[..., None]
        return ((self.online_from[None, :] <= t)
                & (t < self.online_until[None, :])).sum(axis=-1)

    def online_instance_seconds(self, t0: float, t1: float) -> float:
        """Integral of the live instance count over [t0, t1]."""
        lo = np.maximum(self.online_from, t0)
        hi = np.minimum(self.online_until, t1)
        return float(np.maximum(0.0, hi - lo).sum())


class Autoscaler:
    """Plans `InstanceSchedule`s from routed per-pool arrival times.

    Deterministic and purely causal: the decision at epoch boundary t_e
    uses only the arrival counts observed over past epochs.  Target
    tracking is trend-aware — the last epoch-over-epoch rate *increase*
    is extrapolated forward by the known actuation delay (decision
    epoch + scale-up lag + weight load), the standard compensation for
    a controller whose capacity lands one delay behind its signal.
    Without it a steep diurnal morning ramp keeps capacity a full delay
    below the offered rate and the queue backlog it accrues can take
    hours of simulated day to drain.  Scale-*down* never extrapolates
    (the trend term is clamped at zero) and additionally waits out
    `scaledown_delay_s` of sustained low signal.
    """

    def __init__(self, policy: AutoscalePolicy):
        self.policy = policy

    def plan_pool(self, arrival_times: Sequence[float], *, n_peak: int,
                  rate_per_instance: float, horizon_s: float,
                  load_s: float = 0.0) -> InstanceSchedule:
        """Online windows for one pool.

        `rate_per_instance` is the request rate one instance sustains at
        the sized operating point — the peak plan's
        ``arrival_rate / instances`` — and the controller targets
        `target_utilization` of it.  `load_s` is the pool's weight-load
        duration (model bytes / `weight_load_Bps`), paid by every
        scale-up incarnation on top of `scaleup_lag_s`.
        """
        pol = self.policy
        n_peak = max(int(n_peak), 1)
        k_min = max(int(math.ceil(pol.min_frac * n_peak)), 1)
        cap = max(rate_per_instance, 1e-12) * pol.target_utilization
        dt = pol.control_interval_s
        n_epochs = max(int(math.ceil(horizon_s / dt)), 1)
        ts = np.asarray(arrival_times, dtype=np.float64)
        counts = np.bincount(
            np.clip((ts / dt).astype(np.int64), 0, n_epochs - 1),
            minlength=n_epochs) if len(ts) else np.zeros(n_epochs, np.int64)
        # rows: [on, off) per incarnation; the initial fleet is online
        # from t = 0 (the day starts peak-provisioned — the conservative
        # cold-start; the controller sheds from there)
        on: List[float] = [0.0] * n_peak
        off: List[float] = [math.inf] * n_peak
        live: List[int] = list(range(n_peak))  # LIFO retirement stack
        low_since = None
        # extrapolation horizon: how many epochs of growth the total
        # delay costs before a scale-up decision's capacity is live —
        # half an epoch of observation centring (the rate is an average
        # over the previous epoch) plus actuation lag plus weight load
        lead = 1.5 + (pol.scaleup_lag_s + load_s) / dt
        for e in range(1, n_epochs):
            t = e * dt
            rate = counts[e - 1] / dt
            growth = max(0.0, (counts[e - 1] - counts[e - 2]) / dt) \
                if e >= 2 else 0.0
            rate_hat = rate + growth * lead
            k_desired = min(
                max(int(math.ceil(rate_hat / cap)) + pol.spare_instances,
                    k_min), n_peak)
            k_cur = len(live)
            if k_desired > k_cur:
                t_on = t + pol.scaleup_lag_s + load_s
                for _ in range(k_desired - k_cur):
                    live.append(len(on))
                    on.append(t_on)
                    off.append(math.inf)
                low_since = None
            elif k_desired < k_cur:
                if low_since is None:
                    low_since = t
                if t - low_since >= pol.scaledown_delay_s:
                    for _ in range(k_cur - k_desired):
                        i = live.pop()      # LIFO: newest incarnation first
                        # a not-yet-online incarnation is cancelled
                        # outright (zero-length window, nothing charged)
                        off[i] = t if on[i] <= t else on[i]
                    low_since = None
            else:
                low_since = None
        return InstanceSchedule(online_from=np.asarray(on, np.float64),
                                online_until=np.asarray(off, np.float64),
                                n_peak=n_peak, load_s=load_s)
