"""Context-length router — the paper's technique as a serving-layer feature.

`ContextRouter` fronts a set of PoolEngines and routes each request by its
context-length prediction, implementing the three §4 topologies:

  homo      — one pool, the long window.
  two_pool  — conservative static split: short iff
              prompt + p99(output) <= B_short (no overflow handling).
  fleetopt  — overflow split: short iff predicted total <= gamma * B_short,
              with the short pool serving window gamma * B_short.

The router is what determines which segment of the logistic P(b) curve each
engine occupies — the mechanism behind the fleet-level 2.5x (paper §4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .engine import PoolEngine
from .request import Request


@dataclasses.dataclass
class RouterPolicy:
    kind: str                  # homo | two_pool | fleetopt
    b_short: int = 4096
    gamma: float = 2.0
    p99_output: int = 1024     # conservative two_pool admission margin


class ContextRouter:
    def __init__(self, pools: Dict[str, PoolEngine], policy: RouterPolicy):
        self.pools = pools
        self.policy = policy
        if policy.kind != "homo":
            assert "short" in pools and "long" in pools, sorted(pools)

    def route(self, req: Request) -> str:
        p = self.policy
        if p.kind == "homo":
            name = next(iter(self.pools))
        elif p.kind == "two_pool":
            name = ("short" if req.prompt_len + p.p99_output <= p.b_short
                    else "long")
        elif p.kind == "fleetopt":
            name = ("short" if req.predicted_total <= p.gamma * p.b_short
                    else "long")
        else:
            raise ValueError(p.kind)
        self.pools[name].submit(req)
        return name

    def run(self, requests: List[Request], *, max_iters: int = 100_000
            ) -> Dict[str, dict]:
        for r in requests:
            self.route(r)
        for eng in self.pools.values():
            eng.run_until_drained(max_iters=max_iters)
        return self.report()

    def report(self) -> Dict[str, dict]:
        out = {name: eng.stats() for name, eng in self.pools.items()}
        tot_tok = sum(s["tokens"] for s in out.values())
        tot_j = sum(s["joules"] for s in out.values())
        out["fleet"] = dict(tokens=tot_tok, joules=round(tot_j, 1),
                            tok_per_watt=round(tot_tok / tot_j, 3)
                            if tot_j else 0.0)
        return out
