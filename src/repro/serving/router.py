"""Context-length router — the paper's technique as a serving-layer feature.

`ContextRouter` fronts a set of PoolEngines and routes each request through
an **ordered admission ladder**: (role, boundary) pairs with strictly
ascending boundaries, the last infinite.  A request goes to the first role
whose boundary covers its routing metric.  The three §4 topologies and the
§10.3 K >= 3 generalisation are all instances of the ladder:

  homo      — [(only, inf)]: one pool, the long window.
  two_pool  — [(short, B_short), (long, inf)] on the conservative metric
              prompt + p99(output) (no overflow handling).
  fleetopt  — [(short, gamma * B_short), (long, inf)] on predicted total;
              the short pool serves window gamma * B_short.
  multipool — explicit K-entry ladder (core.multipool): K geometric
              windows, admission at window/gamma, per-hop overflow
              migration pool i -> pool i+1 (serving.fleetsim).
  disagg / disagg_fleetopt — prefill/decode disaggregation (core.disagg):
              an explicit ladder over the *prefill* roles only — every
              request enters through a prefill pool; the paired decode
              pools are fed exclusively by the KV-handoff hop inside
              serving.fleetsim, never by admission.
  moe_pool  — one pool, the long window, served by an MoE whose profile
              streams active params + a dispatch floor (core.moe); the
              ladder itself is the homo single rung.
  semantic / semantic_fleetopt / moe_semantic — §5.1 model-heterogeneous
              routing (`SemanticRouter`): a [small @ B_short, large @ inf]
              ladder where the rungs serve *different models*.  The
              classifier is the ladder metric (predicted total — a length
              proxy for task complexity) degraded by `misroute_rate`: each
              decision flips with that probability, deterministically per
              request id.  A true-short flipped large is just served
              inefficiently; a true-large flipped small is tagged
              `escalate_at = detect_tokens` — the small-model engine evicts
              it after that many decode tokens (quality detection) and
              FleetSim re-serves it from scratch in the large pool, its
              small-pool tokens backed out (never double-counted).
              `semantic_fleetopt` additionally gives the small pool
              FleetOpt overflow headroom (serve at gamma * B_short);
              `moe_semantic` binds the large rung to the MoE.

The router is what determines which segment of the logistic P(b) curve each
engine occupies — the mechanism behind the fleet-level 2.5x (paper §4.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.routing import ESCALATION_DETECT_TOKENS
from repro.core.topospec import SEMANTIC_KINDS  # noqa: F401  (re-export)

from .request import Request

_HASH_A = 2654435761          # Knuth multiplicative hash (mod 2^32)


def _misroute_u(rid: int, seed: int) -> float:
    """Deterministic per-request uniform in [0, 1) for the misroute draw —
    a pure function of (rid, seed) so routing is order-independent and a
    misroute-rate sweep flips a *nested* set of requests (rate 0.1 misroutes
    a superset of rate 0.05), which is what makes the degradation sweep
    monotone rather than resampled noise."""
    return ((rid * _HASH_A + seed * 0x9E3779B9) % (1 << 32)) / float(1 << 32)


@dataclasses.dataclass
class RouterPolicy:
    # the TopologySpec kind that compiled this policy (a label — routing
    # behaviour is fully determined by the explicit fields below)
    kind: str
    b_short: int = 4096
    gamma: float = 2.0
    p99_output: int = 1024     # conservative prompt_plus_p99 margin
    # Ordered (role, admission boundary) ladder — REQUIRED: every policy
    # carries its ladder explicitly (compiled by `TopologySpec.policy`);
    # the router never derives rungs from the kind string.
    ladder: Optional[List[Tuple[str, float]]] = None
    # routing metric: "predicted_total" (prompt + E[output]) or
    # "prompt_plus_p99" (prompt + p99_output — conservative two_pool)
    metric_kind: str = "predicted_total"
    # misroute channel: the (small, large) role pair the classifier's
    # decisions flip between; None disables flipping entirely
    flip: Optional[Tuple[str, str]] = None
    # classifier error rate, detection latency (decode tokens the small
    # model emits before a misroute escalates — the constant shared with
    # the analytical core.topospec semantic accounting so both layers
    # price the same latency) and the seed of the deterministic
    # per-request misroute draw
    misroute_rate: float = 0.0
    detect_tokens: int = ESCALATION_DETECT_TOKENS
    misroute_seed: int = 0
    # the TopologySpec this policy was compiled from (FleetSim reads pool
    # wiring — overflow/escalation/handoff edges — from it)
    spec: Optional[object] = dataclasses.field(default=None, repr=False)

    @property
    def is_semantic(self) -> bool:
        return self.flip is not None

    def admission_ladder(self, roles: Sequence[str] = ()
                         ) -> List[Tuple[str, float]]:
        """Ordered (role, boundary) pairs; route to the first role whose
        boundary >= the request's routing metric."""
        if not self.ladder:
            raise ValueError(
                f"{self.kind} policy needs an explicit ladder — compile it"
                f" via core.topospec.TopologySpec (from_kind / policy())")
        return list(self.ladder)

    def metric(self, req: Request) -> float:
        """The routing metric: predicted total for overflow-capable
        topologies; prompt + p99(output) for conservative two_pool."""
        if self.metric_kind == "prompt_plus_p99":
            return req.prompt_len + self.p99_output
        return req.predicted_total


class ContextRouter:
    """Routes requests over anything pool-shaped: a scalar `PoolEngine`,
    a whole `PoolGroup` (the fleet simulator's batched pool), or any
    object with submit / stats / measured_totals — the router only ever
    submits and aggregates."""

    def __init__(self, pools: Dict[str, object], policy: RouterPolicy):
        self.pools = pools
        self.policy = policy
        ladder = policy.admission_ladder(list(pools))
        missing = [r for r, _ in ladder if r not in pools]
        assert not missing, (missing, sorted(pools))
        bounds = [b for _, b in ladder]
        assert all(a < b for a, b in zip(bounds, bounds[1:])), \
            f"admission boundaries must be strictly ascending: {ladder}"
        assert math.isinf(bounds[-1]), \
            f"last ladder entry must admit everything: {ladder}"

    def route(self, req: Request) -> str:
        # the ladder is re-derived per call so policy mutation (and the
        # unknown-kind ValueError) behave as if routing were stateless
        ladder = self.policy.admission_ladder(list(self.pools))
        m = self.policy.metric(req)
        for name, boundary in ladder:
            if m <= boundary:
                name = self._semantic_flip(req, name)
                self.pools[name].submit(req)
                return name
        raise AssertionError(f"no ladder entry admits metric {m}: {ladder}")

    def _semantic_flip(self, req: Request, nominal: str) -> str:
        """SemanticRouter error channel: flip the classifier's decision
        with probability `misroute_rate` (deterministic per request).  A
        true-large request flipped into the small-model pool is tagged for
        escalation after `detect_tokens` of decode; a true-short flipped
        large just rides the big model."""
        pol = self.policy
        if not (pol.flip is not None and pol.misroute_rate > 0.0):
            return nominal
        if _misroute_u(req.rid, pol.misroute_seed) >= pol.misroute_rate:
            return nominal
        small, large = pol.flip
        if nominal not in (small, large):
            return nominal
        req.misrouted = True
        if nominal == large:
            req.escalate_at = pol.detect_tokens
            return small
        return large

    def run(self, requests: List[Request], *, max_iters: int = 100_000
            ) -> Dict[str, dict]:
        """Route every request, drain every pool, report.  A pool that is
        still busy at `max_iters` raises `serving.DrainTruncatedError`
        (propagated, never swallowed): a truncated drain would roll
        under-counted tokens/energy straight into the fleet tok/W."""
        for r in requests:
            self.route(r)
        for eng in self.pools.values():
            eng.run_until_drained(max_iters=max_iters)
        return self.report()

    def report(self) -> Dict[str, dict]:
        """Per-pool stats + fleet roll-up.  The fleet tok/W honours each
        meter's steady-state measurement window (the windowed `m_*`
        counters) so it agrees with FleetSim.report on identical runs; with
        the default (0, inf) window the `m_*` counters mirror the lifetime
        totals and nothing changes for standalone engines."""
        out = {name: eng.stats() for name, eng in self.pools.items()}
        totals = [eng.measured_totals() for eng in self.pools.values()]
        tot_tok = sum(t["tokens"] for t in totals)
        tot_j = sum(t["joules"] for t in totals)
        out["fleet"] = dict(tokens=tot_tok, joules=round(tot_j, 1),
                            tok_per_watt=round(tot_tok / tot_j, 3)
                            if tot_j else 0.0)
        return out
