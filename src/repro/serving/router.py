"""Context-length router — the paper's technique as a serving-layer feature.

`ContextRouter` fronts a set of PoolEngines and routes each request through
an **ordered admission ladder**: (role, boundary) pairs with strictly
ascending boundaries, the last infinite.  A request goes to the first role
whose boundary covers its routing metric.  The three §4 topologies and the
§10.3 K >= 3 generalisation are all instances of the ladder:

  homo      — [(only, inf)]: one pool, the long window.
  two_pool  — [(short, B_short), (long, inf)] on the conservative metric
              prompt + p99(output) (no overflow handling).
  fleetopt  — [(short, gamma * B_short), (long, inf)] on predicted total;
              the short pool serves window gamma * B_short.
  multipool — explicit K-entry ladder (core.multipool): K geometric
              windows, admission at window/gamma, per-hop overflow
              migration pool i -> pool i+1 (serving.fleetsim).
  disagg / disagg_fleetopt — prefill/decode disaggregation (core.disagg):
              an explicit ladder over the *prefill* roles only — every
              request enters through a prefill pool; the paired decode
              pools are fed exclusively by the KV-handoff hop inside
              serving.fleetsim, never by admission.

The router is what determines which segment of the logistic P(b) curve each
engine occupies — the mechanism behind the fleet-level 2.5x (paper §4.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .engine import PoolEngine
from .request import Request


@dataclasses.dataclass
class RouterPolicy:
    kind: str    # homo | two_pool | fleetopt | multipool | disagg[_fleetopt]
    b_short: int = 4096
    gamma: float = 2.0
    p99_output: int = 1024     # conservative two_pool admission margin
    # K-pool / disagg: explicit ordered (role, admission boundary) ladder.
    # Required for kind="multipool" and the disagg kinds (where it spans
    # the prefill roles); ignored (derived) for the named §4 topologies.
    ladder: Optional[List[Tuple[str, float]]] = None

    def admission_ladder(self, roles: Sequence[str]
                         ) -> List[Tuple[str, float]]:
        """Ordered (role, boundary) pairs; route to the first role whose
        boundary >= the request's routing metric."""
        if self.kind == "homo":
            return [(roles[0], math.inf)]
        if self.kind == "two_pool":
            return [("short", float(self.b_short)), ("long", math.inf)]
        if self.kind == "fleetopt":
            return [("short", self.gamma * self.b_short), ("long", math.inf)]
        if self.kind in ("multipool", "disagg", "disagg_fleetopt"):
            if not self.ladder:
                raise ValueError(f"{self.kind} policy needs an explicit"
                                 " ladder")
            return list(self.ladder)
        raise ValueError(self.kind)

    def metric(self, req: Request) -> float:
        """The routing metric: predicted total for overflow-capable
        topologies; prompt + p99(output) for conservative two_pool."""
        if self.kind == "two_pool":
            return req.prompt_len + self.p99_output
        return req.predicted_total


class ContextRouter:
    def __init__(self, pools: Dict[str, PoolEngine], policy: RouterPolicy):
        self.pools = pools
        self.policy = policy
        ladder = policy.admission_ladder(list(pools))
        missing = [r for r, _ in ladder if r not in pools]
        assert not missing, (missing, sorted(pools))
        bounds = [b for _, b in ladder]
        assert all(a < b for a, b in zip(bounds, bounds[1:])), \
            f"admission boundaries must be strictly ascending: {ladder}"
        assert math.isinf(bounds[-1]), \
            f"last ladder entry must admit everything: {ladder}"

    def route(self, req: Request) -> str:
        # the ladder is re-derived per call so policy mutation (and the
        # unknown-kind ValueError) behave as if routing were stateless
        ladder = self.policy.admission_ladder(list(self.pools))
        m = self.policy.metric(req)
        for name, boundary in ladder:
            if m <= boundary:
                self.pools[name].submit(req)
                return name
        raise AssertionError(f"no ladder entry admits metric {m}: {ladder}")

    def run(self, requests: List[Request], *, max_iters: int = 100_000
            ) -> Dict[str, dict]:
        for r in requests:
            self.route(r)
        for eng in self.pools.values():
            eng.run_until_drained(max_iters=max_iters)
        return self.report()

    def report(self) -> Dict[str, dict]:
        """Per-pool stats + fleet roll-up.  The fleet tok/W honours each
        meter's steady-state measurement window (the windowed `m_*`
        counters) so it agrees with FleetSim.report on identical runs; with
        the default (0, inf) window the `m_*` counters mirror the lifetime
        totals and nothing changes for standalone engines."""
        out = {name: eng.stats() for name, eng in self.pools.items()}
        totals = [eng.measured_totals() for eng in self.pools.values()]
        tot_tok = sum(t["tokens"] for t in totals)
        tot_j = sum(t["joules"] for t in totals)
        out["fleet"] = dict(tokens=tot_tok, joules=round(tot_j, 1),
                            tok_per_watt=round(tot_tok / tot_j, 3)
                            if tot_j else 0.0)
        return out
