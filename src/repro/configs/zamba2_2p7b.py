"""zamba2-2.7b [hybrid]: 54L d2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
Mamba2 (state 64) + shared attention blocks.  [arXiv:2411.15242]

Unit = 5 Mamba2 blocks followed by the *shared* attention + MLP pair
(one parameter set reused at every repeat — Zamba2's shared-block design);
9 repeats -> 45 Mamba2 + 9 shared-attn applications ~ 54 layers.
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000,
    unit=(BlockSpec("mamba2"), BlockSpec("mamba2"), BlockSpec("mamba2"),
          BlockSpec("mamba2"), BlockSpec("mamba2"),
          BlockSpec("attn", shared=True), BlockSpec("mlp", shared=True)),
    n_repeat=9,
    ssm_state=64, ssm_head_dim=64, expand=2,
    source="arXiv:2411.15242")
