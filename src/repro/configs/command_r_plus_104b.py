"""command-r-plus-104b [dense]: 64L d12288 96H (GQA kv=8) d_ff=33792
vocab=256000, GQA, no bias.  [hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="command-r-plus-104b", arch_type="dense",
    d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=64,
    attn_bias=False, rope_theta=7.5e4,
    source="hf:CohereForAI/c4ai-command-r-v01")
