"""Llama-3.1-8B — the paper's semantic-routing small model (§5.1).
[arXiv:2407.21783]
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="llama31-8b", arch_type="dense",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=32,
    rope_theta=5e5,
    source="arXiv:2407.21783")
