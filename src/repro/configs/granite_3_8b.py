"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="granite-3-8b", arch_type="dense",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab=49155,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=40,
    rope_theta=1e4,
    source="hf:ibm-granite/granite-3.0-2b-base")
