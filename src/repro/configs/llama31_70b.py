"""Llama-3.1-70B — the paper's own fleet model (Tables 1/3/4/5).
[arXiv:2407.21783]
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="llama31-70b", arch_type="dense",
    d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=80,
    rope_theta=5e5,
    source="arXiv:2407.21783")
