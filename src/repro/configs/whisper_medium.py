"""whisper-medium [audio]: 24L d1024 16H (kv=16) d_ff=4096 vocab=51865,
enc-dec with conv frontend STUB.  [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is the allowed modality stub:
`input_specs` supplies (B, n_frames, d_model) frame embeddings.  The 24-layer
bidirectional encoder and the 24-layer decoder (self-attn + cross-attn + GELU
MLP) are fully implemented.  Decode shapes cache decoder self-attention KV;
long_500k is skipped (full attention, 448-token trained decode horizon).
"""
from repro.models.spec import ArchConfig, BlockSpec, EncoderSpec

CONFIG = ArchConfig(
    name="whisper-medium", arch_type="audio",
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865,
    unit=(BlockSpec("attn"), BlockSpec("cross_attn"), BlockSpec("mlp")),
    n_repeat=24,
    mlp_act="gelu", attn_bias=True,
    encoder=EncoderSpec(n_layers=24, n_frames=1500),
    source="arXiv:2212.04356")
