"""yi-6b [dense]: 32L d4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA.  [arXiv:2403.04652]
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="yi-6b", arch_type="dense",
    d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=32,
    rope_theta=5e6,
    source="arXiv:2403.04652")
