"""h2o-danube-3-4b [dense]: 24L d3840 32H (GQA kv=8) d_ff=10240 vocab=32000,
llama+mistral mix with sliding-window attention.  [arXiv:2401.16818]

The native SWA (window 4096) gives this dense model a bounded KV cache, so
long_500k decode is feasible with a ring-buffer cache — the one dense arch
that runs the long-context shape without a variant config.
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", arch_type="dense",
    d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab=32000,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=24,
    swa_window=4096, rope_theta=1e4,
    source="arXiv:2401.16818")
