"""grok-1-314b [moe]: 64L d6144 48H (GQA kv=8) expert d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1]

8 experts < 16-way model axis, so expert-parallelism alone cannot fill the
mesh: each expert's ffn dim is TP-sharded across the model axis instead
(see repro.models.moe and the sharding rules in repro.launch.sharding).
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="grok-1-314b", arch_type="moe",
    d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072,
    unit=(BlockSpec("attn"), BlockSpec("moe")), n_repeat=64,
    n_experts=8, top_k=2, moe_d_ff=32768,
    source="hf:xai-org/grok-1")
