"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
anyres tiling.  [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/SigLIP vision tower + projector is the allowed modality stub:
`input_specs` supplies (B, n_patches, d_model) projected patch embeddings
(anyres tiling: base 576 + 4 tiles x 576 = 2880 patches) which the language
decoder consumes as a prefix.  Patch tokens inflate the effective context —
exactly the 1/W-law pressure the paper predicts for VLM serving.
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="llava-next-34b", arch_type="vlm",
    d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    unit=(BlockSpec("attn"), BlockSpec("mlp")), n_repeat=60,
    n_patches=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf")
