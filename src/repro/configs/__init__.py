"""Architecture config registry.

`get_config(name)` resolves an assigned architecture id (plus the paper's own
llama models).  A `-swa` suffix returns a sliding-window *variant* (window
4096) of a full-attention arch — the explicit opt-in that makes the
long_500k decode shape feasible for dense/MoE/VLM models (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.spec import ArchConfig

from . import (command_r_plus_104b, granite_3_8b, granite_moe_1b_a400m,
               grok_1_314b, h2o_danube_3_4b, llama31_8b, llama31_70b,
               llava_next_34b, rwkv6_1p6b, whisper_medium, yi_6b, zamba2_2p7b)

ASSIGNED: Dict[str, ArchConfig] = {
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "zamba2-2.7b": zamba2_2p7b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "h2o-danube-3-4b": h2o_danube_3_4b.CONFIG,
    "llava-next-34b": llava_next_34b.CONFIG,
    "granite-3-8b": granite_3_8b.CONFIG,
    "yi-6b": yi_6b.CONFIG,
    "rwkv6-1.6b": rwkv6_1p6b.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
}

PAPER_ARCHS: Dict[str, ArchConfig] = {
    "llama31-70b": llama31_70b.CONFIG,
    "llama31-8b": llama31_8b.CONFIG,
}

ARCHS: Dict[str, ArchConfig] = {**ASSIGNED, **PAPER_ARCHS}

SWA_VARIANT_WINDOW = 4096


def get_config(name: str) -> ArchConfig:
    if name.endswith("-swa"):
        base = get_config(name[: -len("-swa")])
        if base.swa_window or not base.attn_block_count:
            raise ValueError(f"{base.name} has no full-attention to window")
        return dataclasses.replace(base, name=name,
                                   swa_window=SWA_VARIANT_WINDOW)
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def list_archs() -> List[str]:
    return sorted(ASSIGNED)
