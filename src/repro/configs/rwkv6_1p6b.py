"""rwkv6-1.6b [ssm]: 24L d2048 (attention-free) d_ff=7168 vocab=65536,
Finch — data-dependent decay.  [arXiv:2404.05892]

Attention-free: O(1) recurrent state per layer, no KV growth — the
architecture for which the paper's 1/W law *vanishes* (n_max is set by
weights/activations, not context; see DESIGN.md §5 and the beyond-paper
analysis in EXPERIMENTS.md).
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="rwkv6-1.6b", arch_type="ssm",
    d_model=2048, n_heads=32, n_kv_heads=0, head_dim=64,
    d_ff=7168, vocab=65536,
    unit=(BlockSpec("rwkv6"),), n_repeat=24,
    rwkv_head_dim=64,
    source="arXiv:2404.05892")
