"""granite-moe-1b-a400m [moe]: 24L d1024 16H (GQA kv=8) expert d_ff=512,
vocab 49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.models.spec import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    unit=(BlockSpec("attn"), BlockSpec("moe")), n_repeat=24,
    n_experts=32, top_k=8, moe_d_ff=512,
    rope_theta=1e4,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base")
