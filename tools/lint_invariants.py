"""Grep-style lint for the repo's structural invariants.

Fast (no imports of the package, pure text scan) so CI can run it as a
seconds-long job on every PR.  Two invariants, both established by the
TopologySpec IR refactor and easy to erode one convenient `if` at a
time:

1. **Topology kind dispatch is centralised.**  String-kind topology
   dispatch (``kind == "fleetopt"`` etc.) exists in exactly one place:
   ``TopologySpec.from_kind`` in ``src/repro/core/topospec.py``, the
   legacy-kind -> IR compiler.  Everything downstream consumes the IR.
   A new ``if kind == ...`` anywhere else reintroduces the scattered
   dispatch the IR removed.  (Only *topology* kind literals are
   flagged — block kinds like ``b.kind == "attn"`` in repro.models and
   shape kinds like ``shape.kind == "train"`` in repro.launch are
   different enums and exempt by literal, not by path.)

2. **JAX mesh-context APIs are quarantined.**  The mesh-context API
   surface (``get_abstract_mesh`` / ``set_mesh`` / ``use_mesh`` /
   ``AxisType``) is version-dependent across jax releases; the repo
   funnels every touch through ``repro.models.compat``.  Importing or
   referencing those names from ``jax.sharding`` anywhere else breaks
   one of the two supported jax versions.  (Importing the shims *from*
   ``repro.models.compat`` is of course the sanctioned path and not
   flagged; stable names like ``NamedSharding``/``PartitionSpec`` are
   fine anywhere.)

3. **Serving telemetry goes through TraceRecorder.**  The engine hot
   loops (everything under ``src/repro/serving/``) emit observability
   through the FleetScope recorder (``serving.telemetry``) — that is
   what keeps the zero-overhead-when-off guarantee auditable.  An
   ad-hoc ``print(...)`` in the serving stack is either debug residue
   or a new side channel the trace schema doesn't know about; both are
   flagged.  (Benchmarks, tools and examples print freely — they are
   the presentation layer, not the hot path.)

Run:  python tools/lint_invariants.py          (from the repo root)
Exit: 0 clean, 1 with one ``path:line: message`` per violation.
"""
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# topology kinds as compiled by TopologySpec.from_kind (core/topospec.py)
_TOPOLOGY_KINDS = ("homo", "two_pool", "fleetopt", "multipool", "semantic",
                   "semantic_fleetopt", "moe_pool", "moe_semantic",
                   "disagg", "disagg_fleetopt")
_KIND_DISPATCH = re.compile(
    r"""kind\s*(?:==|!=)\s*["'](?:%s)["']""" % "|".join(_TOPOLOGY_KINDS))
_KIND_ALLOWED = ("src/repro/core/topospec.py",
                 "tools/lint_invariants.py")   # this docstring's example

_MESH_API = re.compile(
    r"jax\.sharding\.(?:get_abstract_mesh|set_mesh|use_mesh|AxisType)\b"
    r"|from\s+jax\.sharding\s+import\s+[^\n]*"
    r"\b(?:get_abstract_mesh|set_mesh|use_mesh|AxisType)\b")
_MESH_ALLOWED = ("src/repro/models/compat.py",)

# bare print calls in the serving hot path (telemetry must ride the
# FleetScope recorder); `# lint: allow-print` opts a line out explicitly
_PRINT_CALL = re.compile(r"(?<![\w.])print\s*\(")
_PRINT_SCOPE = "src/repro/serving/"
_PRINT_OPT_OUT = "# lint: allow-print"


def _scan(root: pathlib.Path = REPO) -> list:
    """All violations as (relpath, lineno, message) triples."""
    out = []
    for sub in ("src", "benchmarks", "examples", "tools"):
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            text = path.read_text()
            for n, line in enumerate(text.splitlines(), 1):
                if _KIND_DISPATCH.search(line) and rel not in _KIND_ALLOWED:
                    out.append((rel, n,
                                "topology kind dispatch outside "
                                "TopologySpec.from_kind — consume the IR "
                                "(spec.pools / spec.router_policy) instead"))
                if _MESH_API.search(line) and rel not in _MESH_ALLOWED:
                    out.append((rel, n,
                                "jax.sharding mesh-context API outside "
                                "repro.models.compat — import the shim "
                                "from repro.models.compat instead"))
                if (rel.startswith(_PRINT_SCOPE)
                        and _PRINT_CALL.search(line)
                        and _PRINT_OPT_OUT not in line):
                    out.append((rel, n,
                                "print() in the serving hot path — emit "
                                "through serving.telemetry.TraceRecorder "
                                "(or tag '# lint: allow-print' if this "
                                "is genuinely presentation code)"))
    return out


def main() -> int:
    violations = _scan()
    for rel, n, msg in violations:
        print(f"{rel}:{n}: {msg}")
    if violations:
        print(f"\n{len(violations)} invariant violation(s)")
        return 1
    print("invariants clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
