"""flash_attention (chunked online softmax) vs direct softmax oracle."""
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.models.attention import flash_attention


def direct_attention(q, k, v, *, causal=True, window=0):
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qh = q.reshape(B, S, K, G, D).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qh, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.asarray(D, jnp.float32))
    q_pos, k_pos = jnp.arange(S)[:, None], jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D)


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 2), S=st.sampled_from([1, 7, 64, 130]),
    K=st.sampled_from([1, 2]), G=st.sampled_from([1, 3]),
    D=st.sampled_from([8, 32]),
    window=st.sampled_from([0, 16]),
    qc=st.sampled_from([16, 64]),
)
def test_flash_vs_direct(B, S, K, G, D, window, qc):
    H = K * G
    rng = jax.random.PRNGKey(B * 1000 + S)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    out = flash_attention(q, k, v, causal=True, window=window,
                          q_chunk=qc, kv_chunk=qc)
    ref = direct_attention(q, k, v, causal=True, window=window)
    assert jnp.allclose(out, ref, atol=2e-5), float(jnp.abs(out - ref).max())


def test_bfloat16_path():
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 96, 8, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 96, 4, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 96, 4, 32), jnp.bfloat16)
    out = flash_attention(q, k, v, q_chunk=32, kv_chunk=32)
    ref = direct_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32))
    assert out.dtype == jnp.bfloat16
    assert jnp.allclose(out.astype(jnp.float32), ref, atol=3e-2)


def test_non_causal_cross():
    rng = jax.random.PRNGKey(1)
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 33, 4, 16))
    k = jax.random.normal(ks[1], (1, 50, 4, 16))
    v = jax.random.normal(ks[2], (1, 50, 4, 16))
    out = flash_attention(q, k, v, causal=False)
    ref = direct_attention(q, k, v, causal=False)
    assert jnp.allclose(out, ref, atol=2e-5)
