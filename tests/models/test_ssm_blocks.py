"""Chunked jnp scans (model blocks) vs naive sequential oracles."""
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import mamba_scan_ref, wkv6_ref
from repro.models.ssm import mamba2_chunk_scan, wkv6_chunk_scan


@settings(max_examples=12, deadline=None)
@given(B=st.integers(1, 2), S=st.sampled_from([5, 64, 129]),
       nh=st.sampled_from([1, 3]), hd=st.sampled_from([8, 32]),
       ds=st.sampled_from([4, 16]), chunk=st.sampled_from([16, 64]))
def test_mamba_chunked_vs_sequential(B, S, nh, hd, ds, chunk):
    rng = jax.random.PRNGKey(S * 7 + nh)
    ks = jax.random.split(rng, 4)
    xh = jax.random.normal(ks[0], (B, S, nh, hd))
    Bm = jax.random.normal(ks[1], (B, S, ds))
    Cm = jax.random.normal(ks[2], (B, S, ds))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh)))
    A = -jnp.linspace(0.5, 2.0, nh)
    D = jnp.zeros((nh,))
    y, st_ = mamba2_chunk_scan(xh, Bm, Cm, dt, A, D, chunk=chunk)
    # oracle consumes dt-scaled inputs and log-decay directly
    yr, str_ = mamba_scan_ref(xh * dt[..., None], Bm, Cm, dt * A)
    assert jnp.allclose(y, yr, atol=5e-4), float(jnp.abs(y - yr).max())
    assert jnp.allclose(st_, str_, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 2), S=st.sampled_from([3, 64, 100]),
       H=st.sampled_from([1, 2]), hd=st.sampled_from([8, 32]),
       chunk=st.sampled_from([16, 64]))
def test_wkv6_chunked_vs_sequential(B, S, H, hd, chunk):
    rng = jax.random.PRNGKey(S * 13 + H)
    ks = jax.random.split(rng, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    # realistic RWKV6 decay range (w = exp(-exp(w0 + small)), w0 ~ -6)
    w = jnp.exp(-jnp.exp(-6.0 + jax.random.normal(ks[3], (B, S, H, hd))))
    u = 0.5 * jax.random.normal(ks[4], (H, hd))
    y, st_ = wkv6_chunk_scan(r, k, v, w, u, chunk=chunk)
    yr, str_ = wkv6_ref(r, k, v, w, u)
    assert jnp.allclose(y, yr, atol=2e-3, rtol=1e-3), \
        float(jnp.abs(y - yr).max())
    assert jnp.allclose(st_, str_, atol=2e-3, rtol=1e-3)


def test_state_carry_composes():
    """Scanning [0:S1] then [S1:S] with carried state == one scan."""
    rng = jax.random.PRNGKey(9)
    ks = jax.random.split(rng, 4)
    B, S, nh, hd, ds = 1, 48, 2, 16, 8
    xh = jax.random.normal(ks[0], (B, S, nh, hd))
    Bm = jax.random.normal(ks[1], (B, S, ds))
    Cm = jax.random.normal(ks[2], (B, S, ds))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh)))
    A = -jnp.ones((nh,))
    D = jnp.zeros((nh,))
    y_all, st_all = mamba2_chunk_scan(xh, Bm, Cm, dt, A, D, chunk=16)
    y1, st1 = mamba2_chunk_scan(xh[:, :32], Bm[:, :32], Cm[:, :32],
                                dt[:, :32], A, D, chunk=16)
    y2, st2 = mamba2_chunk_scan(xh[:, 32:], Bm[:, 32:], Cm[:, 32:],
                                dt[:, 32:], A, D, chunk=16, init_state=st1)
    assert jnp.allclose(jnp.concatenate([y1, y2], 1), y_all, atol=1e-4)
    assert jnp.allclose(st2, st_all, atol=1e-4)
