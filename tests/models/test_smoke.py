"""Per-arch smoke tests: reduced variant, one forward + one train step on
CPU, asserting shapes and no NaNs (the deliverable-f requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.data import batch_iterator
from repro.models import model as M
from repro.training import AdamW, make_train_step


def _batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)))}
    if cfg.n_patches:
        out["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.encoder is not None:
        out["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder.n_frames, cfg.d_model))
            * 0.02, jnp.float32)
    return out


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_repeat == 2 and cfg.d_model <= 512
    assert (cfg.n_experts or 4) <= 4
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = M.forward(params, cfg, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S + (cfg.n_patches or 0), cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"
    assert jnp.isfinite(aux)

    opt = AdamW(lr=1e-3, total_steps=10)
    step = make_train_step(cfg, opt)
    new_params, opt_state, metrics = step(params, opt.init(params), batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    for leaf in jax.tree.leaves(new_params):
        assert not bool(jnp.isnan(leaf).any()), "NaN in updated params"


@pytest.mark.parametrize("arch", ["yi-6b", "h2o-danube-3-4b", "zamba2-2.7b",
                                  "rwkv6-1.6b", "granite-moe-1b-a400m",
                                  "whisper-medium", "grok-1-314b",
                                  "llava-next-34b"])
def test_decode_matches_forward(arch):
    """Prefill + stepwise decode reproduce the full-sequence logits — the
    serving path is numerically the training path."""
    cfg = get_config(arch).reduced()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B=B, S=S, seed=1)
    toks = batch["tokens"]
    logits_full, _ = M.forward(params, cfg, batch)
    t0 = S - 4
    pf = dict(batch)
    pf["tokens"] = toks[:, :t0]
    pf.pop("labels")
    lg, cache, _ = M.forward(params, cfg, pf, mode="prefill")
    off = cfg.n_patches or 0
    total = S + off
    slab = min(cfg.swa_window, total) if cfg.swa_window else total

    def pad_attn(bc):
        return {kk: jnp.pad(vv, ((0, 0), (0, 0),
                                 (0, max(slab - vv.shape[2], 0)),
                                 (0, 0), (0, 0))) for kk, vv in bc.items()}

    cache = {bn: (pad_attn(bc) if ("_attn" in bn and "cross" not in bn)
                  else bc)
             for bn, bc in cache.items()}
    errs = [float(jnp.abs(lg[:, -1] - logits_full[:, t0 - 1 + off]).max())]
    for i in range(4):
        pos = t0 + i
        lg, cache = M.decode_step(params, cfg, toks[:, pos:pos + 1], cache,
                                  jnp.asarray(pos + off))
        if pos + 1 < S:
            errs.append(float(
                jnp.abs(lg[:, 0] - logits_full[:, pos + off]).max()))
    assert max(errs) < 5e-4, errs


def test_vlm_patch_prefix():
    cfg = get_config("llava-next-34b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, _ = M.forward(params, cfg, batch)
    assert logits.shape[1] == batch["tokens"].shape[1] + cfg.n_patches


def test_swa_ring_buffer_decode():
    """SWA decode past the window: ring cache must keep matching the
    full-sequence (banded-mask) forward."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    assert cfg.swa_window == 64
    import dataclasses
    cfg = dataclasses.replace(cfg, swa_window=8)   # tiny window, S > window
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    B, S = 1, 24
    batch = _batch(cfg, B=B, S=S, seed=2)
    toks = batch["tokens"]
    logits_full, _ = M.forward(params, cfg, batch)
    t0 = 12
    lg, cache, _ = M.forward(params, cfg, {"tokens": toks[:, :t0]},
                             mode="prefill")
    errs = []
    for pos in range(t0, S - 1):
        lg, cache = M.decode_step(params, cfg, toks[:, pos:pos + 1], cache,
                                  jnp.asarray(pos))
        errs.append(float(jnp.abs(lg[:, 0] - logits_full[:, pos]).max()))
    assert max(errs) < 5e-4, errs


def test_data_pipeline_learnable():
    it = batch_iterator(get_config("yi-6b").reduced(), batch=2, seq=16)
    b1, b2 = next(it), next(it)
    assert b1["tokens"].shape == (2, 16)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
