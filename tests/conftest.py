import os
import sys

# tests run with `PYTHONPATH=src pytest tests/`; make that robust even when
# invoked from elsewhere.  NOTE: no XLA device-count flags here — smoke tests
# and benches must see 1 device (the 512-device mesh exists only inside
# repro.launch.dryrun subprocesses).
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
