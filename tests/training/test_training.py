"""Optimizer, checkpointing, and a real convergence run (~100-step)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import batch_iterator
from repro.models import model as M
from repro.training import (AdamW, load_checkpoint, make_train_step,
                            save_checkpoint, train_loop)


def test_adamw_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clip():
    opt = AdamW(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    new, _ = opt.update({"w": jnp.full(3, 1e6)}, state, params)
    assert float(jnp.abs(new["w"]).max()) < 1.0


def test_lr_schedule():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(opt.schedule(0)) == 0.0
    assert float(opt.schedule(10)) == pytest.approx(1.0)
    assert float(opt.schedule(100)) == pytest.approx(0.1, rel=0.01)


def test_loss_decreases_100_steps():
    """Markov-structured synthetic data is learnable: ~1.5+ nats in 100
    steps on a tiny model (deliverable-b training driver, miniaturized)."""
    cfg = get_config("granite-3-8b").reduced()
    it = ({k: jnp.asarray(v) for k, v in b.items()}
          for b in batch_iterator(cfg, batch=4, seq=32))
    _, _, hist = train_loop(cfg, steps=100, batch_iter=it,
                            opt=AdamW(lr=2e-3, total_steps=100),
                            log_every=25)
    assert hist[-1]["loss"] < hist[0]["loss"] - 1.0, hist


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("yi-6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params, step=17)
    template = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    restored, step = load_checkpoint(path, template)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
