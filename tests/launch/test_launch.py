"""Launch layer: sharding rules, shapes, HLO analysis, dry-run smoke.

The 512-device production dry-run runs in a subprocess (XLA device count is
process-global); the full 10x4x2 sweep is executed by
`python -m repro.launch.dryrun --all [--multi-pod]` and its results land in
benchmarks/results/dryrun/.
"""
import json
import os
import pathlib
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch.hlo_analysis import collective_bytes, roofline_terms
from repro.launch.shapes import SHAPES, applicability, input_specs
from repro.models import model as M

ROOT = pathlib.Path(__file__).resolve().parents[2]


def test_shapes_table():
    s = SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_long_500k_applicability():
    ok = {a: applicability(get_config(a), SHAPES["long_500k"]) is None
          for a in list_archs()}
    assert ok["zamba2-2.7b"] and ok["rwkv6-1.6b"] and ok["h2o-danube-3-4b"]
    assert not ok["whisper-medium"] and not ok["yi-6b"]
    # the -swa variants opt dense/MoE/VLM archs in
    assert applicability(get_config("yi-6b-swa"),
                         SHAPES["long_500k"]) is None


@pytest.mark.parametrize("arch", list_archs())
def test_input_specs_abstract(arch):
    """input_specs never allocates: everything is ShapeDtypeStruct."""
    cfg = get_config(arch)
    for sname, shape in SHAPES.items():
        if applicability(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)


def test_collective_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[4,4]{1,0}, f32[2,2]{1,0}) all-reduce(%a, %b)
  %cp = f32[16]{0} collective-permute(%y)
  %none = f32[9]{0} add(%p, %q)
"""
    c = collective_bytes(hlo)
    assert c["all-gather"] == 8 * 128 * 2
    assert c["all-reduce"] == 16 * 4 + 4 * 4
    assert c["collective-permute"] == 64
    assert c["total"] == c["all-gather"] + c["all-reduce"] + c["collective-permute"]


def test_roofline_terms_dominance():
    cost = {"flops": 197e12, "bytes accessed": 10.0}
    t = roofline_terms(cost, "")
    assert t.compute_s == pytest.approx(1.0)
    assert t.dominant == "compute"
    t2 = roofline_terms({"flops": 1.0, "bytes accessed": 819e9}, "")
    assert t2.dominant == "memory"


@pytest.mark.slow
def test_dryrun_subprocess_smoke():
    """One real 512-device lower+compile in a child process."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-medium", "--shape", "decode_32k"],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": str(ROOT / "src")})
    assert "ok" in r.stdout, r.stdout + r.stderr


def test_dryrun_results_complete_if_present():
    """When the sweep has been run, every (arch x shape x mesh) must be
    ok or an explicitly documented skip."""
    d = ROOT / "benchmarks" / "results" / "dryrun"
    files = list(d.glob("*.json")) if d.exists() else []
    if len(files) < 40:
        pytest.skip("full dry-run sweep not yet executed")
    bad = []
    for f in files:
        r = json.loads(f.read_text())
        if r["status"] == "fail":
            bad.append((f.name, r.get("error")))
    assert not bad, bad
