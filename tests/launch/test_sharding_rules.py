"""Sharding-rule unit tests against a stub 16x16 mesh (no devices needed:
the rules only consult mesh.shape / axis_names)."""
import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.sharding import (batch_specs, cache_specs, param_specs,
                                   pure_dp)
from repro.launch.shapes import SHAPES, input_specs
from repro.models import model as M

MESH = types.SimpleNamespace(shape={"data": 16, "model": 16},
                             axis_names=("data", "model"))
MESH3 = types.SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16},
                              axis_names=("pod", "data", "model"))


def _specs(arch, mode):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0),
                                                  cfg))
    return cfg, shapes, param_specs(cfg, shapes, MESH, mode=mode)


def _flat(specs):
    return {("/".join(str(getattr(p, "key", p)) for p in path)): s
            for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}


def test_divisibility_always_respected():
    """No spec may assign an axis to a non-dividing dim (GSPMD would
    reject the program)."""
    for arch in ("yi-6b", "grok-1-314b", "whisper-medium", "zamba2-2.7b",
                 "command-r-plus-104b"):
        cfg, shapes, specs = _specs(arch, "train")
        flat_shapes = _flat(jax.tree.map(
            lambda s: P(*[None] * len(s.shape)), shapes))  # structure only
        leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
        spec_map = _flat(specs)
        for path, leaf in leaves:
            key = "/".join(str(getattr(p, "key", p)) for p in path)
            spec = spec_map[key]
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = 1
                for a in axes:
                    size *= MESH.shape[a]
                assert dim % size == 0, (arch, key, leaf.shape, spec)


def test_serve_mode_drops_fsdp_for_small_models():
    _, _, train = _specs("llava-next-34b", "train")   # 34B: FSDP active
    _, _, serve = _specs("llava-next-34b", "serve")   # 4.3 GB/chip: TP only
    tr, sv = _flat(train), _flat(serve)
    k = "unit/b0_attn/wq"
    assert tr[k] == P(None, "data", "model")   # stacked + FSDP + TP
    assert sv[k] == P(None, None, "model")     # TP only
    # mid-size train (<8B): TP-only even in training
    _, _, yi_train = _specs("yi-6b", "train")
    assert _flat(yi_train)[k] == P(None, None, "model")


def test_serve_mode_keeps_fsdp_for_huge_models():
    _, _, serve = _specs("grok-1-314b", "serve")
    sv = _flat(serve)
    assert sv["unit/b0_attn/wq"] == P(None, "data", "model")


def test_pure_dp_for_small_training():
    cfg, shapes, specs = _specs("rwkv6-1.6b", "train")
    assert pure_dp(cfg, MESH)
    assert all(s == P() for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert not pure_dp(get_config("yi-6b"), MESH)


def test_moe_expert_parallel_vs_tp():
    _, _, granite = _specs("granite-moe-1b-a400m", "serve")
    g = _flat(granite)
    # 32 experts % 16 == 0 -> expert parallel
    assert g["unit/b1_moe/w_up"] == P(None, "model", None, None)
    _, _, grok = _specs("grok-1-314b", "serve")
    k = _flat(grok)
    # 8 experts < 16 -> TP inside expert ffn (+FSDP: grok is huge)
    assert k["unit/b1_moe/w_up"] == P(None, None, "data", "model")


def test_cache_specs_modes():
    for arch, shape_name, expect in [
        # kv=32 divides model -> heads sharded
        ("zamba2-2.7b", "decode_32k", P(None, ("data",), None, "model",
                                        None)),
        # kv=4 does not divide 16 -> sequence sharded on model
        ("yi-6b", "decode_32k", P(None, ("data",), "model", None, None)),
        # batch=1 -> context parallelism on data(+model)
        ("h2o-danube-3-4b", "long_500k", P(None, None, ("data", "model"),
                                           None, None)),
    ]:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        cache = input_specs(cfg, shape)["cache"]
        specs = cache_specs(cfg, cache, MESH, batch=shape.global_batch)
        flat = _flat(specs)
        key = next(k for k in flat if k.endswith("attn/k"))
        assert flat[key] == expect, (arch, flat[key])


def test_batch_specs():
    assert batch_specs(MESH, 256) == P(("data",))
    assert batch_specs(MESH3, 256) == P(("pod", "data"))
    assert batch_specs(MESH, 1) == P(None)
    assert batch_specs(MESH, 256, wide=True) == P(("data", "model"))
    # 256 does not divide pod*data*model=512 -> falls back
    assert batch_specs(MESH3, 256, wide=True) == P(("pod", "data"))
