"""Pallas kernels (interpret=True) vs pure-jnp oracles: shape/dtype sweeps.

Per the brief: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels import flash_decode, mamba_scan, wkv6
from repro.kernels import ops
from repro.kernels.ref import flash_decode_ref, mamba_scan_ref, wkv6_ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,D,T,bt", [
    (2, 8, 4, 64, 100, 64), (1, 16, 8, 128, 300, 256),
    (3, 4, 4, 32, 64, 16), (1, 4, 1, 128, 513, 128),
])
def test_flash_decode_sweep(B, H, K, D, T, bt, dtype):
    rng = jax.random.PRNGKey(B * 7 + T)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, K, D), dtype)
    v = jax.random.normal(ks[2], (B, T, K, D), dtype)
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    out = flash_decode(q, k, v, lengths, block_t=bt)
    ref = flash_decode_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), lengths)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               atol=ATOL[dtype], rtol=1e-2)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(1, 200), B=st.integers(1, 3))
def test_flash_decode_lengths_property(T, B):
    """Entries beyond `lengths` must not influence the output."""
    rng = jax.random.PRNGKey(T)
    ks = jax.random.split(rng, 4)
    H = K = 2
    D = 16
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, T, K, D))
    v = jax.random.normal(ks[2], (B, T, K, D))
    lengths = jax.random.randint(ks[3], (B,), 1, T + 1)
    out1 = flash_decode(q, k, v, lengths, block_t=32)
    mask = jnp.arange(T)[None, :, None, None] < lengths[:, None, None, None]
    k2 = jnp.where(mask, k, 999.0)   # garbage outside the valid range
    v2 = jnp.where(mask, v, -999.0)
    out2 = flash_decode(q, k2, v2, lengths, block_t=32)
    np.testing.assert_allclose(out1, out2, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,nh,hd,ds,ch", [
    (2, 64, 3, 32, 16, 32), (1, 100, 2, 64, 64, 32), (1, 16, 1, 8, 8, 16),
])
def test_mamba_scan_sweep(B, S, nh, hd, ds, ch, dtype):
    rng = jax.random.PRNGKey(S)
    ks = jax.random.split(rng, 4)
    xt = jax.random.normal(ks[0], (B, S, nh, hd), dtype)
    Bm = jax.random.normal(ks[1], (B, S, ds), dtype)
    Cm = jax.random.normal(ks[2], (B, S, ds), dtype)
    lA = -jnp.abs(jax.random.normal(ks[3], (B, S, nh))) * 0.5
    y, st_ = mamba_scan(xt, Bm, Cm, lA, chunk=ch)
    yr, sr = mamba_scan_ref(xt.astype(jnp.float32), Bm.astype(jnp.float32),
                            Cm.astype(jnp.float32), lA)
    np.testing.assert_allclose(y.astype(jnp.float32), yr,
                               atol=ATOL[dtype] * 20, rtol=5e-2)
    np.testing.assert_allclose(st_, sr, atol=ATOL[dtype] * 20, rtol=5e-2)


@pytest.mark.parametrize("wmin", [0.05, 0.8])
@pytest.mark.parametrize("B,S,H,hd,ch", [
    (2, 64, 2, 32, 32), (1, 100, 3, 64, 64), (1, 7, 1, 8, 16),
])
def test_wkv6_sweep(B, S, H, hd, ch, wmin):
    """Including strong decay (w -> 0.05): the exact pairwise-difference
    formulation must stay finite where the factored form would overflow."""
    rng = jax.random.PRNGKey(S + H)
    ks = jax.random.split(rng, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, hd)) for i in range(3))
    w = jax.random.uniform(ks[3], (B, S, H, hd), minval=wmin, maxval=1.0)
    u = 0.5 * jax.random.normal(ks[4], (H, hd))
    y, st_ = wkv6(r, k, v, w, u, chunk=ch)
    yr, sr = wkv6_ref(r, k, v, w, u)
    assert jnp.isfinite(y).all()
    np.testing.assert_allclose(y, yr, atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(st_, sr, atol=2e-3, rtol=1e-3)


def test_ops_dispatch_modes():
    rng = jax.random.PRNGKey(0)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (2, 4, 32))
    k = jax.random.normal(ks[1], (2, 50, 2, 32))
    v = jax.random.normal(ks[2], (2, 50, 2, 32))
    lengths = jnp.array([50, 13])
    a = ops.decode_attention(q, k, v, lengths, force="ref")
    b = ops.decode_attention(q, k, v, lengths, force="interpret")
    np.testing.assert_allclose(a, b, atol=1e-5)
    assert ops._mode(None) == "ref"   # CPU container default
