"""int8-KV flash decode: quantize -> kernel vs float reference, plus
quantization-error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode_int8 import flash_decode_int8, quantize_kv
from repro.kernels.ref import flash_decode_ref


@pytest.mark.parametrize("B,H,K,D,T,bt", [
    (2, 8, 4, 64, 100, 64), (1, 4, 2, 128, 300, 128), (3, 2, 2, 32, 50, 16),
])
def test_int8_flash_decode(B, H, K, D, T, bt):
    rng = jax.random.PRNGKey(B + T)
    ks_ = jax.random.split(rng, 4)
    q = jax.random.normal(ks_[0], (B, H, D))
    k = jax.random.normal(ks_[1], (B, T, K, D))
    v = jax.random.normal(ks_[2], (B, T, K, D))
    lengths = jax.random.randint(ks_[3], (B,), 1, T + 1)
    kq, vq, ks8, vs8 = quantize_kv(k, v)
    out = flash_decode_int8(q, kq, vq, ks8, vs8, lengths, block_t=bt)
    ref = flash_decode_ref(q, k, v, lengths)
    # int8 KV quantization error: attention output within ~1% relative
    denom = float(jnp.abs(ref).max())
    assert float(jnp.abs(out - ref).max()) / denom < 0.02


def test_quantize_roundtrip_error():
    rng = jax.random.PRNGKey(0)
    k = jax.random.normal(rng, (2, 64, 4, 64)) * 3.0
    kq, _, ks, _ = quantize_kv(k, k)
    deq = kq.astype(jnp.float32) * ks[..., None]
    rel = float(jnp.abs(deq - k).max() / jnp.abs(k).max())
    assert rel < 0.01            # 127-level symmetric quant
    assert kq.dtype == jnp.int8
    # the capacity lever: int8 cache is half the bytes of bf16
    assert kq.nbytes + ks.astype(jnp.bfloat16).nbytes \
        < 0.55 * k.astype(jnp.bfloat16).nbytes


def test_int8_matches_fp_kernel_when_exact():
    """With power-of-two values the quantization is exact and the int8
    kernel must agree with the float kernel bit-for-bit-ish."""
    from repro.kernels.flash_decode import flash_decode
    B, H, K, D, T = 1, 2, 2, 32, 40
    rng = jax.random.PRNGKey(3)
    q = jax.random.normal(rng, (B, H, D))
    base = jnp.sign(jax.random.normal(rng, (B, T, K, D)))  # +-1 exact
    lengths = jnp.array([T])
    kq, vq, ks, vs = quantize_kv(base, base)
    a = flash_decode_int8(q, kq, vq, ks, vs, lengths, block_t=16)
    b = flash_decode(q, base, base, lengths, block_t=16)
    np.testing.assert_allclose(a, b, atol=1e-5)
