"""Serving runtime: continuous batching correctness + energy accounting."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import AZURE
from repro.models import model as M
from repro.serving import (ContextRouter, EnergyMeter, PoolEngine, Request,
                           RouterPolicy, synthetic_requests)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("yi-6b").reduced()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    """Single-request greedy generation via repeated full forward."""
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = M.forward(params, cfg,
                              {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_sequential_generation(small_model):
    """Continuous batching with interleaved requests must emit exactly the
    tokens that isolated greedy decoding emits."""
    cfg, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(n))
               for n in (5, 9, 3, 12)]
    eng = PoolEngine(cfg, params, window=64, profile=H100_LLAMA70B,
                     n_slots=2, name="t")   # 2 slots, 4 reqs -> queueing
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_iters=500)
    assert len(eng.completed) == 4
    for r, p in zip(reqs, prompts):
        expect = _greedy_reference(cfg, params, list(map(int, p)), 6)
        assert r.generated[:6] == expect, (r.rid, r.generated, expect)


def test_nmax_admission(small_model):
    cfg, params = small_model
    eng = PoolEngine(cfg, params, window=32, profile=H100_LLAMA70B,
                     n_slots=3)
    reqs = synthetic_requests(AZURE, 8, cfg.vocab, seed=1, max_total=24)
    for r in reqs:
        r.max_new_tokens = min(r.max_new_tokens, 4)
        eng.submit(r)
    eng._admit()
    assert eng.n_active <= 3           # Eq. 3 ceiling enforced
    eng.run_until_drained(max_iters=500)
    assert len(eng.completed) == 8


def test_energy_meter_matches_eq2():
    """Charging N decode iterations at fixed (n, L) must converge to the
    analytical Eq. 2 tok/W — the serving system realises the paper's law."""
    prof = H100_LLAMA70B
    m = EnergyMeter(prof)
    n, L = 64, 8192
    for _ in range(500):
        m.charge_decode_step(n, L)
    assert m.tok_per_watt == pytest.approx(prof.tok_per_watt(n, L), rel=1e-6)


def test_router_policies(small_model):
    cfg, params = small_model
    mk = lambda: {
        "short": PoolEngine(cfg, params, window=32, profile=H100_LLAMA70B,
                            n_slots=2, name="short"),
        "long": PoolEngine(cfg, params, window=128, profile=H100_LLAMA70B,
                           n_slots=2, name="long")}
    r_fo = ContextRouter(mk(), RouterPolicy(
        kind="fleetopt", b_short=16, gamma=2.0,
        ladder=[("short", 32.0), ("long", math.inf)]))
    short_req = Request(rid=0, prompt=np.arange(10), max_new_tokens=8)
    long_req = Request(rid=1, prompt=np.arange(100), max_new_tokens=8)
    assert r_fo.route(short_req) == "short"     # 18 <= 2*16
    assert r_fo.route(long_req) == "long"
    r_tp = ContextRouter(mk(), RouterPolicy(
        kind="two_pool", b_short=16, p99_output=10,
        metric_kind="prompt_plus_p99",
        ladder=[("short", 16.0), ("long", math.inf)]))
    assert r_tp.route(Request(rid=2, prompt=np.arange(5),
                              max_new_tokens=8)) == "short"
    assert r_tp.route(Request(rid=3, prompt=np.arange(10),
                              max_new_tokens=8)) == "long"  # conservative


def test_two_pool_beats_homo_on_energy(small_model):
    """The paper's claim at miniature scale: context routing gives better
    fleet tok/W than a homogeneous long-window pool on mixed traffic."""
    cfg, params = small_model
    rng = np.random.default_rng(42)
    reqs = []
    for i in range(10):
        plen = 6 if i % 5 else 90           # 80% short, 20% long
        reqs.append(Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen),
                            max_new_tokens=5))

    homo = ContextRouter(
        {"only": PoolEngine(cfg, params, window=128,
                            profile=H100_LLAMA70B, n_slots=4, name="only")},
        RouterPolicy(kind="homo", ladder=[("only", math.inf)]))
    rep_h = homo.run([dataclasses.replace(r) for r in reqs], max_iters=500)

    routed = ContextRouter(
        {"short": PoolEngine(cfg, params, window=16,
                             profile=H100_LLAMA70B, n_slots=16, name="short"),
         "long": PoolEngine(cfg, params, window=128,
                            profile=H100_LLAMA70B, n_slots=4, name="long")},
        RouterPolicy(kind="fleetopt", b_short=8, gamma=2.0,
                     ladder=[("short", 16.0), ("long", math.inf)]))
    rep_r = routed.run([dataclasses.replace(r) for r in reqs], max_iters=500)

    assert rep_r["fleet"]["tok_per_watt"] > rep_h["fleet"]["tok_per_watt"]
