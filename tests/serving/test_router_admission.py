"""RouterPolicy admission boundaries — exact edges for all three §4
topologies plus the K >= 3 multipool ladder, against analytical-mode
engines (no model, no jax on the hot path)."""
import math

import numpy as np
import pytest

from repro.core.profiles import H100_LLAMA70B
from repro.serving import ContextRouter, PoolEngine, Request, RouterPolicy

STREAMED = 70e9


def _pool(name, window):
    return PoolEngine(None, None, window=window, profile=H100_LLAMA70B,
                      n_slots=4, name=name, streamed_params=STREAMED)


def _req(rid, plen, out, predicted=None):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new_tokens=out, predicted_output=predicted)


def _router(kind, *, b_short=4096, gamma=2.0, **kw):
    # explicit ladders, the TopologySpec.from_kind compilation of each
    # legacy kind (policies no longer derive rungs from the kind string)
    if kind == "homo":
        pools = {"only": _pool("only", 256)}
        ladder = [("only", math.inf)]
    else:
        pools = {"short": _pool("short", 64), "long": _pool("long", 256)}
        boundary = float(b_short) if kind == "two_pool" \
            else float(int(gamma * b_short))
        ladder = [("short", boundary), ("long", math.inf)]
    if kind == "two_pool":
        kw.setdefault("metric_kind", "prompt_plus_p99")
    return ContextRouter(pools, RouterPolicy(kind=kind, b_short=b_short,
                                             gamma=gamma, ladder=ladder,
                                             **kw))


def test_homo_routes_everything_to_the_single_pool():
    r = _router("homo", b_short=32)
    assert r.route(_req(0, 1, 1)) == "only"
    assert r.route(_req(1, 1000, 1000)) == "only"


def test_two_pool_admission_boundary_is_exact():
    # short iff prompt + p99_output <= b_short (conservative, no overflow)
    r = _router("two_pool", b_short=32, p99_output=10)
    assert r.route(_req(0, 22, 1)) == "short"      # 22 + 10 == 32, inclusive
    assert r.route(_req(1, 23, 1)) == "long"       # 23 + 10 == 33 > 32
    # actual output length is irrelevant: only the p99 margin counts
    assert r.route(_req(2, 22, 500)) == "short"


def test_two_pool_p99_margin_edge():
    # margin 0: boundary collapses to prompt_len <= b_short
    r = _router("two_pool", b_short=32, p99_output=0)
    assert r.route(_req(0, 32, 1)) == "short"
    assert r.route(_req(1, 33, 1)) == "long"


def test_fleetopt_admission_boundary_is_gamma_b_short():
    # short iff predicted_total <= gamma * b_short (overflow headroom)
    r = _router("fleetopt", b_short=32, gamma=2.0)
    assert r.route(_req(0, 54, 10)) == "short"     # 64 == 2 * 32, inclusive
    assert r.route(_req(1, 55, 10)) == "long"      # 65 > 64
    # gamma = 1: no headroom, boundary is b_short itself
    r1 = _router("fleetopt", b_short=32, gamma=1.0)
    assert r1.route(_req(2, 22, 10)) == "short"
    assert r1.route(_req(3, 23, 10)) == "long"


def test_fleetopt_routes_on_prediction_not_actual_length():
    """Honest routing: predicted_output (E[output]) drives the decision,
    not the sampled output length — the source of overflow migrations."""
    r = _router("fleetopt", b_short=32, gamma=2.0)
    # predicted 30 + 30 = 60 <= 64 -> short, though actual total is 530
    assert r.route(_req(0, 30, 500, predicted=30)) == "short"
    # predicted 30 + 40 = 70 > 64 -> long, though actual total is only 35
    assert r.route(_req(1, 30, 5, predicted=40)) == "long"


def test_unknown_policy_kind_raises():
    r = _router("homo")
    r.policy = RouterPolicy(kind="nope")
    with pytest.raises(ValueError):
        r.route(_req(0, 1, 1))


# --- K >= 3 admission ladders (paper §10.3 via core.multipool) -----------

def _k3_pools():
    return {"p0": _pool("p0", 128), "p1": _pool("p1", 512),
            "p2": _pool("p2", 2048)}


def _k3_router():
    ladder = [("p0", 64.0), ("p1", 256.0), ("p2", math.inf)]
    return ContextRouter(_k3_pools(),
                         RouterPolicy(kind="multipool", ladder=ladder))


def test_multipool_ladder_boundaries_are_exact():
    r = _k3_router()
    assert r.route(_req(0, 32, 32)) == "p0"     # 64 == boundary, inclusive
    assert r.route(_req(1, 33, 32)) == "p1"     # 65 > 64
    assert r.route(_req(2, 128, 128)) == "p1"   # 256 == boundary, inclusive
    assert r.route(_req(3, 129, 128)) == "p2"   # 257 > 256
    assert r.route(_req(4, 10_000, 1)) == "p2"  # terminal rung takes all


def test_multipool_routes_on_prediction_not_actual_length():
    r = _k3_router()
    # predicted 30 + 30 = 60 <= 64 -> p0, though the actual total is 530
    assert r.route(_req(0, 30, 500, predicted=30)) == "p0"
    assert r.route(_req(1, 30, 5, predicted=400)) == "p2"


def test_multipool_policy_requires_ladder():
    with pytest.raises(ValueError):
        ContextRouter({"p0": _pool("p0", 128)},
                      RouterPolicy(kind="multipool"))


def test_ladder_must_ascend_and_terminate_infinite():
    pools = _k3_pools()
    with pytest.raises(AssertionError):   # descending boundaries
        ContextRouter(pools, RouterPolicy(
            kind="multipool",
            ladder=[("p0", 256.0), ("p1", 64.0), ("p2", math.inf)]))
    with pytest.raises(AssertionError):   # last rung not infinite
        ContextRouter(pools, RouterPolicy(
            kind="multipool", ladder=[("p0", 64.0), ("p1", 256.0)]))


def test_ladder_roles_must_exist():
    with pytest.raises(AssertionError):
        ContextRouter({"p0": _pool("p0", 128)},
                      RouterPolicy(kind="multipool",
                                   ladder=[("nope", math.inf)]))
