"""RouterPolicy admission boundaries — exact edges for all three §4
topologies, against analytical-mode engines (no model, no jax on the hot
path)."""
import numpy as np
import pytest

from repro.core.profiles import H100_LLAMA70B
from repro.serving import ContextRouter, PoolEngine, Request, RouterPolicy

STREAMED = 70e9


def _pool(name, window):
    return PoolEngine(None, None, window=window, profile=H100_LLAMA70B,
                      n_slots=4, name=name, streamed_params=STREAMED)


def _req(rid, plen, out, predicted=None):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new_tokens=out, predicted_output=predicted)


def _router(kind, **kw):
    pools = {"short": _pool("short", 64), "long": _pool("long", 256)} \
        if kind != "homo" else {"only": _pool("only", 256)}
    return ContextRouter(pools, RouterPolicy(kind=kind, **kw))


def test_homo_routes_everything_to_the_single_pool():
    r = _router("homo", b_short=32)
    assert r.route(_req(0, 1, 1)) == "only"
    assert r.route(_req(1, 1000, 1000)) == "only"


def test_two_pool_admission_boundary_is_exact():
    # short iff prompt + p99_output <= b_short (conservative, no overflow)
    r = _router("two_pool", b_short=32, p99_output=10)
    assert r.route(_req(0, 22, 1)) == "short"      # 22 + 10 == 32, inclusive
    assert r.route(_req(1, 23, 1)) == "long"       # 23 + 10 == 33 > 32
    # actual output length is irrelevant: only the p99 margin counts
    assert r.route(_req(2, 22, 500)) == "short"


def test_two_pool_p99_margin_edge():
    # margin 0: boundary collapses to prompt_len <= b_short
    r = _router("two_pool", b_short=32, p99_output=0)
    assert r.route(_req(0, 32, 1)) == "short"
    assert r.route(_req(1, 33, 1)) == "long"


def test_fleetopt_admission_boundary_is_gamma_b_short():
    # short iff predicted_total <= gamma * b_short (overflow headroom)
    r = _router("fleetopt", b_short=32, gamma=2.0)
    assert r.route(_req(0, 54, 10)) == "short"     # 64 == 2 * 32, inclusive
    assert r.route(_req(1, 55, 10)) == "long"      # 65 > 64
    # gamma = 1: no headroom, boundary is b_short itself
    r1 = _router("fleetopt", b_short=32, gamma=1.0)
    assert r1.route(_req(2, 22, 10)) == "short"
    assert r1.route(_req(3, 23, 10)) == "long"


def test_fleetopt_routes_on_prediction_not_actual_length():
    """Honest routing: predicted_output (E[output]) drives the decision,
    not the sampled output length — the source of overflow migrations."""
    r = _router("fleetopt", b_short=32, gamma=2.0)
    # predicted 30 + 30 = 60 <= 64 -> short, though actual total is 530
    assert r.route(_req(0, 30, 500, predicted=30)) == "short"
    # predicted 30 + 40 = 70 > 64 -> long, though actual total is only 35
    assert r.route(_req(1, 30, 5, predicted=40)) == "long"


def test_unknown_policy_kind_raises():
    r = _router("homo")
    r.policy = RouterPolicy(kind="nope")
    with pytest.raises(ValueError):
        r.route(_req(0, 1, 1))
