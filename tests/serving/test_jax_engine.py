"""JAX-engine parity: the jit/vmap drain loop (serving.jax_engine) must
reproduce the numpy `BatchedPoolEngine` oracle — admission order, chunked
prefill interleave, window-ceiling eviction, escalation backout, the
prefill-phase FIFO, every meter counter, and the per-request event record
(finish/first-token times, preemption counts, outbox order).

The contract is float-parity, not bit-parity: masked-lane arithmetic adds
exactly +0.0 so almost every path is bit-identical, but multi-slot chunk
spills accumulate in a different association order on device
(ulp-level).  The acceptance gate is rtol=1e-9 on meters and exact
equality on every integer/ordering field; the numpy engine keeps its
bit-exact parity contract against the scalar engines untouched
(tests/serving/test_soa_parity.py).
"""
import copy

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import B200_LLAMA70B, H100_LLAMA70B
from repro.core.workloads import AZURE
from repro.serving import BatchedPoolEngine, Request
from repro.serving.jax_engine import JaxPoolEngine, drain_engines

STREAMED = LLAMA31_70B.streamed_params


def _req(rid, plen, out, t=0.0, pred=None, esc=None, pdone=False):
    r = Request(rid=rid, prompt=np.broadcast_to(np.int64(0), (plen,)),
                max_new_tokens=out, arrival_time=t, predicted_output=pred)
    r.escalate_at = esc
    r.prefill_done = pdone
    if pdone:
        r.ready_time = t
        r.generated = [7]
    return r


def _mk(cls, reqs_by_inst, *, profile=H100_LLAMA70B, **kw):
    eng = cls(instances=len(reqs_by_inst), profile=profile,
              streamed_params=STREAMED, rng_seed=11, name="p",
              respect_arrival=True, **kw)
    for j, reqs in enumerate(reqs_by_inst):
        for r in reqs:
            eng.submit(copy.copy(r), j)
    eng.sort_queues()
    return eng


def _run_both(reqs_by_inst, **kw):
    """The same per-instance streams through the numpy oracle and the JAX
    engine (identical construction)."""
    ref = _mk(BatchedPoolEngine, reqs_by_inst, **kw)
    jx = _mk(JaxPoolEngine, reqs_by_inst, **kw)
    ref.run_until_drained(max_iters=200_000)
    jx.run_until_drained(max_iters=200_000)
    return ref, jx


def _assert_parity(ref, jx, rtol=1e-9):
    b, c = ref.bank, jx.bank
    for k in ("joules", "m_joules", "prefill_joules", "m_prefill_joules",
              "idle_joules", "m_idle_joules", "dispatch_joules",
              "m_dispatch_joules", "sim_time_s"):
        np.testing.assert_allclose(getattr(c, k), getattr(b, k),
                                   rtol=rtol, atol=1e-12, err_msg=k)
    for k in ("tokens", "m_tokens", "prefill_tokens"):
        np.testing.assert_array_equal(getattr(c, k), getattr(b, k),
                                      err_msg=k)
    np.testing.assert_allclose(jx.slot_seconds, ref.slot_seconds,
                               rtol=rtol, atol=1e-12)
    np.testing.assert_allclose(jx.m_slot_seconds, ref.m_slot_seconds,
                               rtol=rtol, atol=1e-12)
    np.testing.assert_array_equal(jx.preempted, ref.preempted)
    np.testing.assert_array_equal(jx.n_escalated, ref.n_escalated)
    for field in ("completed", "overflowed", "escalated", "relayed",
                  "handoff"):
        for j in range(ref.instances):
            sa = getattr(ref, field)[j]
            sb = getattr(jx, field)[j]
            assert [r.rid for r in sa] == [r.rid for r in sb], (field, j)
            for ra, rb in zip(sa, sb):
                assert ra.n_generated == rb.n_generated, (field, ra.rid)
                assert ra.preemptions == rb.preemptions, (field, ra.rid)
                assert ra.escalations == rb.escalations, (field, ra.rid)
                assert ra.prefill_done == rb.prefill_done, (field, ra.rid)
                assert (ra.generated is None) == (rb.generated is None)
                if ra.generated is not None:
                    assert ra.generated == rb.generated, (field, ra.rid)
                for tk in ("finish_time", "first_token_time"):
                    ta, tb = getattr(ra, tk), getattr(rb, tk)
                    assert ta == pytest.approx(tb, rel=rtol, abs=1e-12), \
                        (field, ra.rid, tk)
                if ra.ready_time is None:
                    assert rb.ready_time is None, (field, ra.rid)
                else:
                    assert ra.ready_time == pytest.approx(
                        rb.ready_time, rel=rtol, abs=1e-12), (field, ra.rid)


def test_jax_parity_admission_and_chunked_interleave():
    rng = np.random.default_rng(3)
    reqs = [[_req(i + 100 * j, int(rng.integers(1, 3000)),
                  int(rng.integers(1, 150)), t=0.04 * i)
             for i in range(40)] for j in range(3)]
    _assert_parity(*_run_both(reqs, window=4096, n_slots=4,
                              prefill_chunk=256))


def test_jax_parity_window_ceiling_overflow_chain():
    reqs = [[_req(j * 50, 100, 5000)] +
            [_req(j * 50 + 1 + i, 40, 30, t=0.01 * i) for i in range(12)]
            for j in range(2)]
    ref, jx = _run_both(reqs, window=256, n_slots=2, prefill_chunk=128,
                        evict_on_overflow=True)
    _assert_parity(ref, jx)
    assert all(len(o) > 0 for o in jx.overflowed)


def test_jax_parity_escalation_backout_in_window():
    """Escalations *inside* the measurement window: the windowed m_*
    counters must back out exactly what the numpy oracle backs out."""
    reqs = [[_req(i, 64, 400, esc=6, t=0.05 * i) for i in range(5)]
            for _ in range(2)]
    ref = _mk(BatchedPoolEngine, reqs, window=8192, n_slots=2,
              prefill_chunk=128)
    jx = _mk(JaxPoolEngine, reqs, window=8192, n_slots=2,
             prefill_chunk=128)
    for e in (ref, jx):                   # window opens mid-run
        e.bank.measure_t0, e.bank.measure_t1 = 0.1, 1e9
    ref.run_until_drained(max_iters=200_000)
    jx.run_until_drained(max_iters=200_000)
    _assert_parity(ref, jx)
    assert int(jx.n_escalated.sum()) == 10


def test_jax_parity_prefill_phase_fifo():
    rng = np.random.default_rng(9)
    reqs = [[_req(i + 30 * j, int(rng.integers(64, 7000)), 1, t=0.03 * i)
             for i in range(25)] for j in range(2)]
    ref, jx = _run_both(reqs, window=8192, n_slots=4, prefill_chunk=512,
                        phase="prefill")
    _assert_parity(ref, jx)
    assert all(len(h) > 0 for h in jx.handoff)
    # handoff first tokens are live LCG values, not placeholders
    for j in range(jx.instances):
        for ra, rb in zip(ref.handoff[j], jx.handoff[j]):
            assert ra.generated == rb.generated


def test_jax_parity_prefilled_admission_and_dispatch():
    """disagg decode admission (prefill_done: no prefill charge) plus a
    per-step MoE dispatch floor."""
    pdone = [[_req(i, 128, 20, t=0.01 * i, pdone=True) for i in range(8)]
             for _ in range(2)]
    _assert_parity(*_run_both(pdone, window=4096, n_slots=2,
                              prefill_chunk=256, dispatch_ms=2.0))


def test_jax_unchunked_decode_unsupported():
    """The unchunked immediate-prefill admission path advances the clock
    mid-admission — explicitly out of the JAX engine's contract."""
    with pytest.raises(NotImplementedError):
        JaxPoolEngine(instances=1, window=4096, profile=H100_LLAMA70B,
                      streamed_params=STREAMED, prefill_chunk=0)


def test_drain_engines_ragged_batch():
    """One `drain_engines` call over engines with different instance
    counts, slot counts, queue lengths, profiles and phases must equal
    each engine drained alone by the numpy oracle — the padding masks may
    not leak work into (or out of) dead rows."""
    rng = np.random.default_rng(17)

    def mkstreams(n_inst, n, stride):
        return [[_req(1000 * stride + i + 100 * j,
                      int(rng.integers(1, 2000)),
                      int(rng.integers(1, 80)), t=0.05 * i)
                 for i in range(n)] for j in range(n_inst)]

    cfgs = [dict(window=4096, n_slots=4, prefill_chunk=256),
            dict(window=2048, n_slots=2, prefill_chunk=128,
                 evict_on_overflow=True),
            dict(window=8192, n_slots=3, prefill_chunk=512,
                 phase="prefill")]
    profiles = [H100_LLAMA70B, B200_LLAMA70B, H100_LLAMA70B]
    streams = [mkstreams(1, 30, 0), mkstreams(3, 7, 1), mkstreams(2, 18, 2)]
    refs = [_mk(BatchedPoolEngine, s, profile=p, **c)
            for s, p, c in zip(streams, profiles, cfgs)]
    jxs = [_mk(JaxPoolEngine, s, profile=p, **c)
           for s, p, c in zip(streams, profiles, cfgs)]
    for e in refs:
        e.run_until_drained(max_iters=200_000)
    drain_engines(jxs, max_iters=200_000)
    for e in jxs:
        e.run_until_drained(max_iters=200_000)   # consumes staged result
    for ref, jx in zip(refs, jxs):
        _assert_parity(ref, jx)


def test_jax_fleet_matches_numpy_fleet_seed_numbers():
    """End-to-end anchor: `simulate_topology(engine="jax")` reproduces the
    numpy fleet's committed seed cell (Azure fleetopt, 1000 requests,
    seed 0) to the rounding the baseline records."""
    from repro.serving import simulate_topology
    cell = simulate_topology("fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                             b_short=4096, n_requests=1000, seed=0,
                             engine="jax")
    f = cell.report["fleet"]
    assert f["completed"] == 1000
    assert round(cell.sim_decode_tok_per_watt, 2) == 5.66
    assert round(cell.sim_tok_per_watt, 2) == 1.81


# --- property test: random streams, numpy oracle vs JAX ------------------

try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    request_lists = st.lists(
        st.tuples(st.integers(1, 2000),     # prompt len
                  st.integers(1, 120),      # output len
                  st.floats(0.0, 2.0),      # inter-arrival gap
                  st.sampled_from([None, None, 4, 16])),  # escalate_at
        min_size=1, max_size=25)

    @settings(max_examples=15, deadline=None)
    @given(streams=st.lists(request_lists, min_size=1, max_size=3),
           n_slots=st.integers(1, 4),
           chunk=st.sampled_from([64, 256]),   # 0 = unchunked: unsupported
           window=st.sampled_from([512, 4096]),
           evict=st.booleans())
    def test_property_numpy_and_jax_step_identically(
            streams, n_slots, chunk, window, evict):
        rid = 0
        reqs_by_inst = []
        for stream in streams:
            t = 0.0
            reqs = []
            for plen, out, gap, esc in stream:
                t += gap
                reqs.append(_req(rid, plen, out, t=t, esc=esc))
                rid += 1
            reqs_by_inst.append(reqs)
        ref, jx = _run_both(reqs_by_inst, window=window, n_slots=n_slots,
                            prefill_chunk=chunk, evict_on_overflow=evict)
        _assert_parity(ref, jx)
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev)")
    def test_property_numpy_and_jax_step_identically():
        pass
