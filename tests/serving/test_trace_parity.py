"""Cross-engine trace parity: the canonical event stream is
engine-independent.

The engines append events in different global orders (scalar
per-instance loops, SoA lockstep sweeps, the JAX terminal-tape replay in
`_finalize`), but every event *time* is bit-identical under the parity
contract, so `TraceRecorder.sorted_events` / `golden_stream` must come
out identical for the same seeded workload regardless of which engine
produced it.  Scalar-vs-SoA runs at level="detail" (full stream
including admit/prefill chunks); the JAX comparison runs the lifecycle
level FleetSim emits for it — the jitted drain records no admissions.
"""
import copy

import numpy as np

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.topospec import TopologySpec
from repro.core.workloads import AZURE
from repro.serving import (BatchedPoolEngine, PoolEngine, Request,
                           TraceRecorder, prepare_spec)

STREAMED = LLAMA31_70B.streamed_params


def _req(rid, plen, out, t=0.0, esc=None):
    r = Request(rid=rid, prompt=np.broadcast_to(np.int64(0), (plen,)),
                max_new_tokens=out, arrival_time=t)
    r.escalate_at = esc
    return r


def _traced_both(reqs_by_inst, level="detail", **kw):
    """Same per-instance streams through N traced scalar engines (all
    registered under the batched pool's name, with their instance index)
    and one traced batched engine; returns both recorders."""
    rec_s = TraceRecorder(level=level)
    rec_b = TraceRecorder(level=level)
    n = len(reqs_by_inst)
    scalars = [PoolEngine(None, None, profile=H100_LLAMA70B,
                          streamed_params=STREAMED, rng_seed=11 + 7919 * j,
                          name=f"p#{j}", respect_arrival=True, **kw)
               for j in range(n)]
    batched = BatchedPoolEngine(instances=n, profile=H100_LLAMA70B,
                                streamed_params=STREAMED, rng_seed=11,
                                name="p", respect_arrival=True, **kw)
    for j, e in enumerate(scalars):
        e.attach_trace(rec_s, name="p", instance=j)
    batched.attach_trace(rec_b)
    for j, reqs in enumerate(reqs_by_inst):
        for r in reqs:
            scalars[j].submit(copy.copy(r))
        for r in reqs:
            batched.submit(copy.copy(r), j)
    for e in scalars:
        e.run_until_drained(max_iters=200_000)
    batched.run_until_drained(max_iters=200_000)
    return rec_s, rec_b


def _assert_streams_equal(rec_s, rec_b):
    assert rec_s.pool_names == rec_b.pool_names
    assert rec_s.sorted_events() == rec_b.sorted_events()
    assert rec_s.golden_stream() == rec_b.golden_stream()


def test_scalar_vs_soa_detail_stream_chunked():
    rng = np.random.default_rng(3)
    reqs = [[_req(i + 100 * j, int(rng.integers(1, 3000)),
                  int(rng.integers(1, 150)), t=0.04 * i)
             for i in range(30)] for j in range(3)]
    rec_s, rec_b = _traced_both(reqs, window=4096, n_slots=4,
                                prefill_chunk=256)
    _assert_streams_equal(rec_s, rec_b)
    counts = rec_b.counts()
    assert counts["complete"] == 90
    assert counts["admit"] == 90 and counts["prefill"] > 0
    # detail charge channels deposit the same per-phase energy
    for phase, e in rec_s.energy_by_phase().items():
        assert e == rec_b.energy_by_phase()[phase] or \
            abs(e - rec_b.energy_by_phase()[phase]) <= 1e-9 * abs(e), phase


def test_scalar_vs_soa_eviction_and_escalation_events():
    reqs = [[_req(j * 50, 100, 5000)] +
            [_req(j * 50 + 1 + i, 40, 30, t=0.01 * i, esc=6 if i % 3 else
                  None) for i in range(12)]
            for j in range(2)]
    rec_s, rec_b = _traced_both(reqs, window=256, n_slots=2,
                                prefill_chunk=128, evict_on_overflow=True)
    _assert_streams_equal(rec_s, rec_b)
    counts = rec_b.counts()
    assert counts["overflow"] > 0 and counts["escalate"] > 0


def test_scalar_vs_soa_prefill_phase_handoff():
    rng = np.random.default_rng(9)
    reqs = [[_req(i + 30 * j, int(rng.integers(64, 7000)), 1, t=0.03 * i)
             for i in range(20)] for j in range(2)]
    rec_s, rec_b = _traced_both(reqs, window=8192, n_slots=4,
                                prefill_chunk=512, phase="prefill")
    _assert_streams_equal(rec_s, rec_b)
    assert rec_b.counts()["handoff"] == 40


def _fleet_stream(engine):
    rec = TraceRecorder(level="lifecycle")
    spec = TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                                  b_short=4096)
    sim, reqs, _ = prepare_spec(spec, AZURE, n_requests=300, seed=0,
                                engine=engine, telemetry=rec)
    sim.run(reqs)
    return rec


def test_numpy_vs_jax_fleet_lifecycle_stream():
    """The jitted JAX drain emits nothing itself; `_finalize` replays
    its terminal tape through the same hooks.  Same seeded fleetopt
    cell -> identical per-request event sequences, with event times
    matching to the engines' rel-1e-9 parity tolerance (device
    accumulation order differs in the last ulp, so the *globally*
    sorted streams can transpose near-ties — the per-request view is
    the invariant)."""
    import pytest
    pytest.importorskip("jax")
    rec_np = _fleet_stream("numpy")
    rec_jx = _fleet_stream("jax")
    assert rec_np.counts() == rec_jx.counts()
    assert rec_np.pool_names == rec_jx.pool_names

    def by_rid(rec):
        out = {}
        for t, rid, kind, pool, inst in rec.sorted_events():
            out.setdefault(rid, []).append((kind, pool, inst, t))
        return out

    a, b = by_rid(rec_np), by_rid(rec_jx)
    assert a.keys() == b.keys()
    for rid in a:
        assert [e[:3] for e in a[rid]] == [e[:3] for e in b[rid]], rid
        np.testing.assert_allclose([e[3] for e in a[rid]],
                                   [e[3] for e in b[rid]],
                                   rtol=1e-9, atol=1e-12, err_msg=str(rid))
