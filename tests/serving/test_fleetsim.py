"""Fleet simulator: analytical-mode engine semantics + the tentpole
integration check — simulated FleetOpt >= 2x simulated Homo on Azure, and
simulated tok/W within tolerance of the analytical core.fleet prediction.

Everything is deterministic-seed; no jax touches the analytical engines.
"""
import numpy as np
import pytest

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import AGENT, AZURE
from repro.serving import (FleetSim, PoolEngine, Request, build_topology,
                           simulate_topology, trace_requests)

STREAMED = LLAMA31_70B.streamed_params


def _req(rid, plen, out, t=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new_tokens=out, arrival_time=t)


# --- analytical-mode engine unit behaviour ------------------------------

def test_analytical_engine_completes_and_meters():
    eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED)
    for i in range(5):
        eng.submit(_req(i, 8, 6))
    eng.run_until_drained(max_iters=500)
    assert len(eng.completed) == 5
    assert all(r.n_generated == 6 for r in eng.completed)
    # 5 requests x 5 metered decode tokens (the first token of each request
    # comes out of prefill and is not a decode-iteration token)
    assert eng.meter.tokens == 25
    assert eng.meter.joules > 0
    assert 0.0 < eng.occupancy <= 1.0


def test_analytical_engine_is_deterministic():
    def run():
        eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                         n_slots=2, streamed_params=STREAMED, rng_seed=3)
        for i in range(6):
            eng.submit(_req(i, 7, 5))
        eng.run_until_drained(max_iters=500)
        return (eng.meter.joules, eng.meter.tokens,
                [r.finish_time for r in eng.completed])

    assert run() == run()


def test_chunked_prefill_delays_first_token():
    """With the chunked interleave a long prompt drains over several
    iterations, so TTFT grows with prompt length."""
    def ttft(plen):
        eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                         n_slots=1, streamed_params=STREAMED,
                         prefill_chunk=128)
        eng.submit(_req(0, plen, 3))
        eng.run_until_drained(max_iters=200)
        (r,) = eng.completed
        return r.first_token_time - r.arrival_time

    assert ttft(1024) > ttft(64) > 0


def test_arrival_gating_charges_idle_power():
    eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED,
                     respect_arrival=True)
    eng.submit(_req(0, 8, 4, t=1.0))      # arrives after 1s of idleness
    eng.run_until_drained(max_iters=100)
    assert len(eng.completed) == 1
    assert eng.meter.idle_joules == pytest.approx(
        H100_LLAMA70B.power_model.p_idle_w * 1.0, rel=1e-6)
    assert eng.completed[0].first_token_time >= 1.0


def test_overflow_eviction_backs_out_wasted_tokens():
    eng = PoolEngine(None, None, window=16, profile=H100_LLAMA70B,
                     n_slots=1, streamed_params=STREAMED,
                     evict_on_overflow=True)
    eng.submit(_req(0, 8, 500))           # can never fit window 16
    eng.run_until_drained(max_iters=100)
    assert len(eng.completed) == 0
    assert len(eng.overflowed) == 1
    (r,) = eng.overflowed
    assert r.preemptions == 1 and r.ready_time is not None
    # wasted decode work produces no counted output tokens (energy stays)
    assert eng.meter.tokens == 0
    assert eng.meter.joules > 0


# --- fleet-level integration (the tentpole acceptance) ------------------

@pytest.fixture(scope="module")
def azure_cells():
    return {kind: simulate_topology(
        kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, n_requests=8000, seed=0)
        for kind in ("homo", "fleetopt")}


def test_simulated_fleetopt_at_least_2x_homo_on_azure(azure_cells):
    homo = azure_cells["homo"].sim_decode_tok_per_watt
    fo = azure_cells["fleetopt"].sim_decode_tok_per_watt
    assert fo >= 2.0 * homo, (fo, homo)


def test_simulated_within_tolerance_of_analytical(azure_cells):
    """Stated tolerance: measured steady-state decode tok/W within 25% of
    the closed-form core.fleet sizing it was provisioned from (observed
    at seed 0 / 8k requests: homo -15%, fleetopt -2%)."""
    for kind, cell in azure_cells.items():
        assert abs(cell.delta_pct) < 25.0, (kind, cell.delta_pct)


def test_fleet_conservation_and_report_shape(azure_cells):
    for cell in azure_cells.values():
        f = cell.report["fleet"]
        assert f["completed"] == 8000
        assert f["tok_per_watt"] <= f["decode_tok_per_watt"]
        assert 0.0 <= f["prefill_energy_frac"] < 1.0
        assert f["ttft_p99_s"] >= f["ttft_p50_s"] > 0
        for role, s in cell.report.items():
            if role == "fleet":
                continue
            assert 0.0 <= s["occupancy"] <= 1.0


def test_overflow_migration_end_to_end():
    """A tight gamma forces short-pool overflows; migrated requests must
    re-prefill in the long pool and every request still completes."""
    cell = simulate_topology("fleetopt", AGENT, H100_LLAMA70B, LLAMA31_70B,
                             b_short=8192, gamma=1.1, n_requests=1500,
                             seed=1)
    f = cell.report["fleet"]
    assert f["migrations"] > 0
    assert f["completed"] == 1500
    # every migration is a short-pool preemption, finished in the long pool
    assert cell.report["short"]["preempted"] == f["migrations"]
    assert cell.report["long"]["completed"] >= f["migrations"]


def test_build_topology_rejects_unknown_kind():
    with pytest.raises(ValueError):
        build_topology("nope", AZURE, H100_LLAMA70B, LLAMA31_70B,
                       b_short=4096)


def test_trace_requests_clips_and_predicts():
    reqs = trace_requests(AZURE, 200, seed=0, max_total=4096)
    assert len(reqs) == 200
    assert all(r.prompt_len + r.max_new_tokens <= 4096 for r in reqs)
    assert all(r.predicted_output == int(round(AZURE.mean_output))
               for r in reqs)
    # Poisson arrivals are strictly increasing
    ts = [r.arrival_time for r in reqs]
    assert all(b > a for a, b in zip(ts, ts[1:]))
