"""Fleet simulator: analytical-mode engine semantics + the tentpole
integration check — simulated FleetOpt >= 2x simulated Homo on Azure, and
simulated tok/W within tolerance of the analytical core.fleet prediction.

Everything is deterministic-seed; no jax touches the analytical engines.
"""
import math
import numpy as np
import pytest

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import AGENT, AZURE
from repro.serving import (ContextRouter, EnergyMeter, FleetSim, PoolEngine,
                           PoolGroup, Request, RouterPolicy, build_topology,
                           simulate_topology, trace_requests)

STREAMED = LLAMA31_70B.streamed_params


def _req(rid, plen, out, t=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new_tokens=out, arrival_time=t)


# --- analytical-mode engine unit behaviour ------------------------------

def test_analytical_engine_completes_and_meters():
    eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED)
    for i in range(5):
        eng.submit(_req(i, 8, 6))
    eng.run_until_drained(max_iters=500)
    assert len(eng.completed) == 5
    assert all(r.n_generated == 6 for r in eng.completed)
    # 5 requests x 5 metered decode tokens (the first token of each request
    # comes out of prefill and is not a decode-iteration token)
    assert eng.meter.tokens == 25
    assert eng.meter.joules > 0
    assert 0.0 < eng.occupancy <= 1.0


def test_analytical_engine_is_deterministic():
    def run():
        eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                         n_slots=2, streamed_params=STREAMED, rng_seed=3)
        for i in range(6):
            eng.submit(_req(i, 7, 5))
        eng.run_until_drained(max_iters=500)
        return (eng.meter.joules, eng.meter.tokens,
                [r.finish_time for r in eng.completed])

    assert run() == run()


def test_chunked_prefill_delays_first_token():
    """With the chunked interleave a long prompt drains over several
    iterations, so TTFT grows with prompt length."""
    def ttft(plen):
        eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                         n_slots=1, streamed_params=STREAMED,
                         prefill_chunk=128)
        eng.submit(_req(0, plen, 3))
        eng.run_until_drained(max_iters=200)
        (r,) = eng.completed
        return r.first_token_time - r.arrival_time

    assert ttft(1024) > ttft(64) > 0


def test_arrival_gating_charges_idle_power():
    eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED,
                     respect_arrival=True)
    eng.submit(_req(0, 8, 4, t=1.0))      # arrives after 1s of idleness
    eng.run_until_drained(max_iters=100)
    assert len(eng.completed) == 1
    assert eng.meter.idle_joules == pytest.approx(
        H100_LLAMA70B.power_model.p_idle_w * 1.0, rel=1e-6)
    assert eng.completed[0].first_token_time >= 1.0


def test_overflow_eviction_backs_out_wasted_tokens():
    eng = PoolEngine(None, None, window=16, profile=H100_LLAMA70B,
                     n_slots=1, streamed_params=STREAMED,
                     evict_on_overflow=True)
    eng.submit(_req(0, 8, 500))           # can never fit window 16
    eng.run_until_drained(max_iters=100)
    assert len(eng.completed) == 0
    assert len(eng.overflowed) == 1
    (r,) = eng.overflowed
    assert r.preemptions == 1 and r.ready_time is not None
    # wasted decode work produces no counted output tokens (energy stays)
    assert eng.meter.tokens == 0
    assert eng.meter.joules > 0


# --- fleet-level integration (the tentpole acceptance) ------------------

@pytest.fixture(scope="module")
def azure_cells():
    return {kind: simulate_topology(
        kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, n_requests=8000, seed=0)
        for kind in ("homo", "fleetopt")}


def test_simulated_fleetopt_at_least_2x_homo_on_azure(azure_cells):
    homo = azure_cells["homo"].sim_decode_tok_per_watt
    fo = azure_cells["fleetopt"].sim_decode_tok_per_watt
    assert fo >= 2.0 * homo, (fo, homo)


def test_simulated_within_tolerance_of_analytical(azure_cells):
    """Stated tolerance: measured steady-state decode tok/W within 25% of
    the closed-form core.fleet sizing it was provisioned from (observed
    at seed 0 / 8k requests: homo -15%, fleetopt -2%)."""
    for kind, cell in azure_cells.items():
        assert abs(cell.delta_pct) < 25.0, (kind, cell.delta_pct)


def test_fleet_conservation_and_report_shape(azure_cells):
    for cell in azure_cells.values():
        f = cell.report["fleet"]
        assert f["completed"] == 8000
        assert f["tok_per_watt"] <= f["decode_tok_per_watt"]
        assert 0.0 <= f["prefill_energy_frac"] < 1.0
        assert f["ttft_p99_s"] >= f["ttft_p50_s"] > 0
        for role, s in cell.report.items():
            if role == "fleet":
                continue
            assert 0.0 <= s["occupancy"] <= 1.0


def test_overflow_migration_end_to_end():
    """A tight gamma forces short-pool overflows; migrated requests must
    re-prefill in the long pool and every request still completes."""
    cell = simulate_topology("fleetopt", AGENT, H100_LLAMA70B, LLAMA31_70B,
                             b_short=8192, gamma=1.1, n_requests=1500,
                             seed=1)
    f = cell.report["fleet"]
    assert f["migrations"] > 0
    assert f["completed"] == 1500
    # every migration is a short-pool preemption, finished in the long pool
    assert cell.report["short"]["preempted"] == f["migrations"]
    assert cell.report["long"]["completed"] >= f["migrations"]


def test_multipool_migration_chain_short_mid_long():
    """K = 3 ladder: a request whose actual total outgrows both the 2K and
    the 8K windows must migrate twice (pool-2K -> pool-8K -> pool-64K) and
    still complete in full."""
    policy, plan, _registry = build_topology("multipool", AGENT, H100_LLAMA70B,
                                  LLAMA31_70B, gamma=2.0,
                                  windows=[2048, 8192, 65536])
    assert [p.name for p in sorted(plan.pools, key=lambda p: p.window)] \
        == ["pool-2K", "pool-8K", "pool-64K"]
    sim = FleetSim(policy, plan, model=LLAMA31_70B)
    # predicted total 900 + 100 = 1000 <= 2048/2 -> admitted to pool-2K;
    # actual total 8900 overflows the 2K window, then the 8K window
    chain = Request(rid=0, prompt=np.zeros(900, np.int64),
                    max_new_tokens=8000, arrival_time=0.0,
                    predicted_output=100)
    filler = [Request(rid=i, prompt=np.zeros(64, np.int64),
                      max_new_tokens=16, arrival_time=0.01 * i,
                      predicted_output=16) for i in range(1, 40)]
    rep = sim.run([chain] + filler)
    assert rep["fleet"]["completed"] == 40
    assert rep["fleet"]["migrations"] == 2      # hops, not unique requests
    assert chain.preemptions == 2
    assert chain.pool.startswith("pool-64K")    # finished in the top rung
    assert chain.n_generated == 8000


def test_multipool_end_to_end_on_trace():
    """A K = 3 plan runs a real trace through FleetSim: every request
    completes and each rung of the ladder serves traffic."""
    cell = simulate_topology("multipool", AZURE, H100_LLAMA70B, LLAMA31_70B,
                             windows=[4096, 16384, 65536], n_requests=1000,
                             seed=0)
    f = cell.report["fleet"]
    assert f["completed"] == 1000
    roles = [r for r in cell.report if r != "fleet"]
    assert roles == ["pool-4K", "pool-16K", "pool-64K"]
    assert all(cell.report[r]["completed"] > 0 for r in roles)
    assert f["tok_per_watt"] > 0


def test_pool_group_balances_by_total_assigned_work():
    """Regression pin for the intended PoolGroup semantics: replicas are
    balanced by cumulative *assigned* predicted work (routing happens
    before any engine runs, so there is no draining to track)."""
    from repro.serving import BatchedPoolEngine
    grp = PoolGroup("g", BatchedPoolEngine(
        instances=2, window=4096, profile=H100_LLAMA70B, n_slots=4,
        name="e", streamed_params=STREAMED))
    for i, total in enumerate((10, 10, 4, 30)):
        grp.submit(Request(rid=i, prompt=np.zeros(1, np.int64),
                           max_new_tokens=1, predicted_output=total - 1))
    # argmin of cumulative work: e0 <- r0 (10), e1 <- r1 (10),
    # e0 <- r2 (14), e1 <- r3 (40)
    assert grp.queue_rids(0) == [0, 2]
    assert grp.queue_rids(1) == [1, 3]
    assert list(grp._pending) == [14.0, 40.0]


def test_router_report_honors_measurement_window():
    """ContextRouter.report and the meters' steady-state window must agree:
    with an empty window the fleet roll-up reports nothing even though the
    lifetime totals are non-zero."""
    eng = PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED)
    router = ContextRouter({"only": eng}, RouterPolicy(
        kind="homo", ladder=[("only", math.inf)]))
    eng.meter.measure_t1 = 0.0
    rep = router.run([_req(i, 8, 6) for i in range(3)])
    assert eng.meter.tokens > 0
    assert rep["fleet"]["tokens"] == 0
    assert rep["fleet"]["tok_per_watt"] == 0.0


def test_router_and_fleetsim_agree_on_measured_tokens():
    """The two report paths count the same steady-state window — they can
    no longer disagree on identical runs (the PR-1 defect)."""
    policy, plan, _registry = build_topology("fleetopt", AZURE, H100_LLAMA70B,
                                  LLAMA31_70B, b_short=4096)
    sim = FleetSim(policy, plan, model=LLAMA31_70B)
    rep = sim.run(trace_requests(AZURE, 600, seed=2))
    router_rep = sim.router.report()
    assert router_rep["fleet"]["tokens"] == rep["fleet"]["tokens"]
    # FleetSim additionally wall-clock-pads idle engines, so its joule
    # denominator can only be larger (both roll-ups sum raw meter values;
    # only the final display rounding differs)
    assert router_rep["fleet"]["joules"] <= rep["fleet"]["joules"] + 0.1


# --- prefill energy attribution (EnergyMeter.charge_prefill) ------------

def _prefill_time(n_tokens, mfu=0.8):
    prof = H100_LLAMA70B
    return (2.0 * STREAMED * n_tokens
            / (prof.tp * prof.chip.peak_bf16_flops * mfu))


def test_prefill_charged_at_compute_bound_power():
    m = EnergyMeter(H100_LLAMA70B)
    m.charge_prefill(1000, streamed_params=STREAMED)
    t = _prefill_time(1000)
    nom = H100_LLAMA70B.power_model.p_nom_w
    assert m.prefill_joules == pytest.approx(nom * t, rel=1e-9)
    # the old b = 1 decode operating point undercharged by ~2x
    assert m.prefill_joules > 1.5 * H100_LLAMA70B.power_w(1) * t


def test_fully_piggybacked_prefill_attributed_by_real_interval():
    """A chunk that fully hides behind decode has dt = 0, but its work
    happened over [sim_time - t, sim_time].  With sim_time just past the
    window end, the old code midpoint-tested the zero-length dt at
    sim_time and attributed *nothing*; pro-rating credits the in-window
    share of the real interval."""
    m = EnergyMeter(H100_LLAMA70B)
    t = _prefill_time(100)
    m.sim_time_s = 5.0
    m.measure_t0, m.measure_t1 = 0.0, 5.0 - t / 2.0  # half interval inside
    dt = m.charge_prefill(100, streamed_params=STREAMED, overlap_s=1e9)
    assert dt == 0.0
    assert m.prefill_joules > 0
    assert m.m_prefill_joules == pytest.approx(0.5 * m.prefill_joules,
                                               rel=1e-9)


def test_boundary_straddling_prefill_prorated():
    """A charge interval straddling the window boundary is attributed by
    exact overlap, like charge_idle — not all-or-nothing."""
    m = EnergyMeter(H100_LLAMA70B)
    t = _prefill_time(4096)
    m.measure_t0, m.measure_t1 = 0.0, t / 2.0   # half the interval inside
    m.charge_prefill(4096, streamed_params=STREAMED)
    assert m.m_prefill_joules == pytest.approx(0.5 * m.prefill_joules,
                                               rel=1e-9)


def test_build_topology_rejects_unknown_kind():
    with pytest.raises(ValueError):
        build_topology("nope", AZURE, H100_LLAMA70B, LLAMA31_70B,
                       b_short=4096)
    with pytest.raises(ValueError):   # multipool without a window ladder
        build_topology("multipool", AZURE, H100_LLAMA70B, LLAMA31_70B)


def test_trace_requests_clips_and_predicts():
    reqs = trace_requests(AZURE, 200, seed=0, max_total=4096)
    assert len(reqs) == 200
    assert all(r.prompt_len + r.max_new_tokens <= 4096 for r in reqs)
    assert all(r.predicted_output == int(round(AZURE.mean_output))
               for r in reqs)
    # Poisson arrivals are strictly increasing
    ts = [r.arrival_time for r in reqs]
    assert all(b > a for a, b in zip(ts, ts[1:]))
