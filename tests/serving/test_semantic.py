"""Model-heterogeneous serving (DESIGN.md §9): the SemanticRouter's
misroute/escalation channel, per-role model bindings through
ModelProfileRegistry, the MoE dispatch-floor attribution, the
bandwidth-scaled prefill chunk, and the tentpole integration check —
measured semantic / MoE fleet tok/W within 25% of the analytical
core.routing.Semantic / core.moe provisioning at zero misroute and zero
dispatch.  Deterministic seeds; no jax."""
import math

import numpy as np
import pytest

from repro.core.hardware import H100
from repro.core.modelspec import (LLAMA31_8B, LLAMA31_70B, QWEN3_235B_A22B)
from repro.core.moe import moe_profile, with_dispatch_floor
from repro.core.power import H100_POWER
from repro.core.profiles import (B200_LLAMA70B_FLEET, H100_LLAMA70B,
                                 V5E_LLAMA70B)
from repro.core.workloads import AZURE
from repro.serving import (ContextRouter, EnergyMeter, FleetSim, PoolEngine,
                           Request, RouterPolicy, build_topology,
                           scaled_prefill_chunk, simulate_topology)

STREAMED = LLAMA31_70B.streamed_params


def _req(rid, plen, out, pred=None, t=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new_tokens=out, arrival_time=t,
                   predicted_output=pred)


def _pools():
    return {"small": PoolEngine(None, None, window=64,
                                profile=H100_LLAMA70B, n_slots=4,
                                streamed_params=LLAMA31_8B.streamed_params),
            "large": PoolEngine(None, None, window=4096,
                                profile=H100_LLAMA70B, n_slots=4,
                                streamed_params=STREAMED)}


# --- SemanticRouter misroute channel ------------------------------------

def test_semantic_routes_by_predicted_total_at_zero_misroute():
    r = ContextRouter(_pools(), RouterPolicy(
        kind="semantic", b_short=64, flip=("small", "large"),
        ladder=[("small", 64.0), ("large", math.inf)]))
    assert r.route(_req(0, 32, 500, pred=32)) == "small"   # 64, inclusive
    assert r.route(_req(1, 33, 1, pred=32)) == "large"     # 65 > 64
    # zero misroute never flips or tags
    for rid in range(50):
        req = _req(100 + rid, 10, 10, pred=10)
        r.route(req)
        assert not req.misrouted and req.escalate_at is None


def test_misroute_flip_tags_only_large_into_small():
    pol = RouterPolicy(kind="semantic", b_short=64, misroute_rate=0.5,
                       detect_tokens=7, misroute_seed=3,
                       flip=("small", "large"),
                       ladder=[("small", 64.0), ("large", math.inf)])
    r = ContextRouter(_pools(), pol)
    tagged = flipped_large = 0
    for rid in range(400):
        truly_large = rid % 2
        req = _req(rid, 100 if truly_large else 10, 10, pred=10)
        dest = r.route(req)
        if req.misrouted:
            if truly_large:           # large flipped into the small pool
                assert dest == "small"
                assert req.escalate_at == 7
                tagged += 1
            else:                     # short flipped large: no escalation
                assert dest == "large"
                assert req.escalate_at is None
                flipped_large += 1
        else:
            assert dest == ("large" if truly_large else "small")
            assert req.escalate_at is None
    # rate 0.5 over 200 per class: both directions actually exercised
    assert tagged > 50 and flipped_large > 50


def test_misroute_draw_is_deterministic_and_nested():
    """The per-request uniform is a pure function of (rid, seed), so a
    higher misroute rate flips a *superset* of a lower rate's requests —
    the property that makes the degradation sweep monotone."""
    def misrouted(rate):
        pol = RouterPolicy(kind="semantic", b_short=64, misroute_rate=rate,
                           flip=("small", "large"),
                           ladder=[("small", 64.0), ("large", math.inf)])
        r = ContextRouter(_pools(), pol)
        out = set()
        for rid in range(500):
            req = _req(rid, 10, 10, pred=10)
            r.route(req)
            if req.misrouted:
                out.add(rid)
        return out

    lo, hi = misrouted(0.1), misrouted(0.3)
    assert misrouted(0.1) == lo          # deterministic
    assert lo < hi                       # strictly nested


# --- engine escalation eviction -----------------------------------------

def test_engine_escalates_after_detect_tokens_and_backs_out():
    eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                     n_slots=1, streamed_params=STREAMED)
    req = _req(0, 8, 100)
    req.escalate_at = 4
    eng.submit(req)
    eng.run_until_drained(max_iters=50)
    assert len(eng.completed) == 0
    assert len(eng.escalated) == 1 and eng.n_escalated == 1
    assert req.escalations == 1 and req.preemptions == 1
    assert req.escalate_at is None       # detected once, never re-tagged
    assert req.ready_time is not None and not req.prefill_done
    # the 3 wasted decode tokens are backed out; the energy stays
    assert eng.meter.tokens == 0
    assert eng.meter.joules > 0


def test_short_output_completes_before_detection():
    """A misrouted request whose output ends under the detection latency
    simply finishes in the small pool — quality review never fires."""
    eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                     n_slots=1, streamed_params=STREAMED)
    req = _req(0, 8, 3)
    req.escalate_at = 32
    eng.submit(req)
    eng.run_until_drained(max_iters=50)
    assert len(eng.completed) == 1 and not eng.escalated
    assert req.escalations == 0


def test_overflow_eviction_clears_escalation_tag():
    """A misrouted giant prompt that hits the window ceiling before the
    quality monitor fires leaves through the overflow channel — and must
    not re-escalate out of the large pool it lands in."""
    eng = PoolEngine(None, None, window=16, profile=H100_LLAMA70B,
                     n_slots=1, streamed_params=STREAMED,
                     evict_on_overflow=True)
    req = _req(0, 14, 500)
    req.escalate_at = 32
    eng.submit(req)
    eng.run_until_drained(max_iters=50)
    (evicted,) = eng.overflowed
    assert evicted.escalate_at is None
    assert not eng.escalated


# --- ModelProfileRegistry wiring ----------------------------------------

def test_build_topology_binds_models_per_role():
    policy, plan, registry = build_topology(
        "semantic", AZURE, H100_LLAMA70B, LLAMA31_70B, b_short=4096)
    assert registry.for_role("small").model is LLAMA31_8B
    assert registry.for_role("large").model is LLAMA31_70B
    assert registry.heterogeneous
    small, large = sorted(plan.pools, key=lambda p: p.window)
    assert small.window == 4096          # semantic: no overflow headroom
    assert small.profile is not large.profile
    sim = FleetSim(policy, plan, registry=registry)
    assert sim.escalate_to == {"small": "large"}
    assert sim.overflow_to == {"small": "large"}
    # each pool's engines stream their own model's bytes
    assert sim.groups["small"].streamed_params \
        == LLAMA31_8B.streamed_params
    assert sim.groups["large"].streamed_params \
        == LLAMA31_70B.streamed_params


def test_semantic_fleetopt_gets_overflow_headroom():
    _, plan, _ = build_topology("semantic_fleetopt", AZURE, H100_LLAMA70B,
                                LLAMA31_70B, b_short=4096, gamma=2.0)
    small = min(plan.pools, key=lambda p: p.window)
    assert small.window == 8192          # serve at gamma * b_short


def test_misroute_and_dispatch_args_are_kind_checked():
    with pytest.raises(ValueError):
        build_topology("fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                       misroute_rate=0.1)
    with pytest.raises(ValueError):
        build_topology("semantic", AZURE, H100_LLAMA70B, LLAMA31_70B,
                       dispatch_ms=2.0)


# --- MoE dispatch floor --------------------------------------------------

def test_with_dispatch_floor_extends_tau_and_meter_attributes_it():
    prof = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    prof_d = with_dispatch_floor(prof, 10.0)
    assert prof_d.roofline.w_ms == pytest.approx(prof.roofline.w_ms + 10.0)
    m = EnergyMeter(prof_d)
    m.dispatch_s = 10e-3
    tau = m.charge_decode_step(4, 2048.0)
    assert tau > 10e-3                   # the floor is inside tau
    power = prof_d.power_w(4)
    assert m.dispatch_joules == pytest.approx(power * 10e-3)
    assert m.dispatch_joules < m.joules  # attribution, never extra energy


def test_moe_pool_engines_stream_active_params():
    prof = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    policy, plan, registry = build_topology(
        "moe_pool", AZURE, prof, QWEN3_235B_A22B, dispatch_ms=2.0)
    assert registry.default.dispatch_ms == 2.0
    (pool,) = plan.pools
    assert pool.profile.roofline.w_ms == pytest.approx(
        prof.roofline.w_ms + 2.0)
    sim = FleetSim(policy, plan, registry=registry)
    grp = sim.groups["moe"]
    assert grp.streamed_params == QWEN3_235B_A22B.n_active_params
    assert grp.dispatch_s == pytest.approx(2e-3)


# --- bandwidth-scaled prefill chunk -------------------------------------

def test_prefill_chunk_scales_with_memory_bandwidth():
    assert scaled_prefill_chunk(H100_LLAMA70B, 512) == 512
    assert scaled_prefill_chunk(B200_LLAMA70B_FLEET, 512) == \
        round(512 * 8.0e12 / 3.35e12)
    # slow chips scale down but never below the floor
    assert scaled_prefill_chunk(V5E_LLAMA70B, 512) == \
        max(round(512 * 819e9 / 3.35e12), 64)
    assert scaled_prefill_chunk(V5E_LLAMA70B, 100, floor=64) == 64


def test_fleetsim_applies_scaled_chunk_per_pool():
    policy, plan, registry = build_topology(
        "homo", AZURE, B200_LLAMA70B_FLEET, LLAMA31_70B)
    sim = FleetSim(policy, plan, registry=registry, prefill_chunk=512)
    assert sim.groups["homo"].prefill_chunk == \
        scaled_prefill_chunk(B200_LLAMA70B_FLEET, 512)


# --- fleet-level integration (the tentpole acceptance) ------------------

@pytest.fixture(scope="module")
def hetero_cells():
    prof_moe = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    cells = {kind: simulate_topology(
        kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, n_requests=8000, seed=0)
        for kind in ("semantic", "semantic_fleetopt")}
    cells["moe_pool"] = simulate_topology(
        "moe_pool", AZURE, prof_moe, QWEN3_235B_A22B,
        n_requests=8000, seed=0)
    return cells


def test_measured_within_tolerance_of_analytical(hetero_cells):
    """Acceptance gate: measured decode tok/W within 25% of the
    analytical core.routing.Semantic / core.moe provisioning at zero
    misroute and zero dispatch (observed at seed 0 / 8k requests:
    semantic -8%, semantic_fleetopt -7%, moe_pool -12%)."""
    for kind, cell in hetero_cells.items():
        assert abs(cell.delta_pct) < 25.0, (kind, cell.delta_pct)


def test_zero_misroute_fleet_has_no_escalations(hetero_cells):
    for kind, cell in hetero_cells.items():
        f = cell.report["fleet"]
        assert f["completed"] == 8000
        assert f["escalations"] == 0


def test_semantic_beats_homogeneous_70b(hetero_cells):
    """The §5.1 lever measured: serving the short 89% of Azure traffic
    with an 8B model beats the homogeneous 70B fleet on tok/W."""
    homo = simulate_topology("homo", AZURE, H100_LLAMA70B, LLAMA31_70B,
                             n_requests=8000, seed=0)
    sem = hetero_cells["semantic"]
    assert sem.sim_decode_tok_per_watt > 2.0 * homo.sim_decode_tok_per_watt


def test_misroute_sweep_monotone_and_never_double_counted():
    """Satellite acceptance: rising misroute rate monotonically degrades
    fleet tok/W (1% slack for integer re-sizing artifacts), escalations
    rise, every request still completes exactly once, and escalated
    requests' output is never double-counted — the fleet's lifetime decode
    token count equals the sum over completed requests of n_generated - 1
    (the first token of each serve comes out of prefill; every wasted
    pre-escalation token was backed out)."""
    rates = (0.0, 0.1, 0.2, 0.35)
    all_in, decode, esc = [], [], []
    for mr in rates:
        cell = simulate_topology(
            "semantic_fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
            b_short=4096, n_requests=2500, seed=0, misroute_rate=mr)
        f = cell.report["fleet"]
        assert f["completed"] == 2500
        all_in.append(cell.sim_tok_per_watt)
        decode.append(cell.sim_decode_tok_per_watt)
        esc.append(f["escalations"] + f["migrations"])
    assert all(b <= a * 1.01 for a, b in zip(all_in, all_in[1:])), all_in
    assert all(b <= a * 1.01 for a, b in zip(decode, decode[1:])), decode
    assert all_in[-1] < all_in[0] * 0.95         # the degradation is real
    assert all(b >= a for a, b in zip(esc, esc[1:])) and esc[-1] > esc[0]


def test_escalated_tokens_conserved_end_to_end():
    policy, plan, registry = build_topology(
        "semantic_fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, misroute_rate=0.25, misroute_seed=0)
    sim = FleetSim(policy, plan, registry=registry, rng_seed=0)
    from repro.serving import trace_requests
    reqs = trace_requests(AZURE, 1200, seed=0)
    rep = sim.run(reqs)
    assert rep["fleet"]["completed"] == 1200
    assert rep["fleet"]["escalations"] > 0
    metered = sum(grp.lifetime_tokens for grp in sim.groups.values())
    earned = sum(r.n_generated - 1 for grp in sim.groups.values()
                 for r in grp.completed)
    assert metered == earned
    # an escalated request finished exactly once, in the large pool
    escalated = [r for r in reqs if r.escalations]
    assert escalated
    assert all(r.pool.startswith("semantic-large") for r in escalated)
    assert all(r.finish_time >= 0 for r in escalated)
