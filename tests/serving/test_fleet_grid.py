"""CI smoke for the Table E grid bench (benchmarks/fleet_grid_bench.py):
a thin slice of the real grid through the exact path the full bench
takes — grid_cells composition, SHAPE_CLASSES grouping, the
run_fleet_grid stage-batched drains — cross-checked cell-for-cell
against the numpy oracle at the grid's 0.1% tok/W acceptance tolerance.

Marked `gridsmoke` (it compiles a handful of XLA drains, ~tens of
seconds on a CI core) so the plain tier-1 selection stays fast; the PR
workflow runs it as its own step.
"""
import pytest

jax = pytest.importorskip("jax")

from benchmarks import fleet_grid_bench as gb          # noqa: E402
from repro.core.workloads import AZURE                 # noqa: E402
from repro.serving import prepare_topology, run_fleet_grid  # noqa: E402

pytestmark = pytest.mark.gridsmoke

N_REQUESTS = 120


def _slice():
    """One cheap cell per distinct drain family, H100 only."""
    cells = [c for c in gb.grid_cells()]
    picks = {}
    for label, kind, prof, mdl, kw in cells:
        if label["generation"] != "H100" or kind in picks:
            continue
        picks[kind] = (label, kind, prof, mdl, kw)
    # moe_semantic is the grid's widest family; keep the smoke to three
    # structurally distinct topologies
    return [picks[k] for k in ("fleetopt", "multipool", "moe_pool")]


def _measure(engine):
    chunk = _slice()
    scenarios = [prepare_topology(kind, AZURE, prof, mdl,
                                  n_requests=N_REQUESTS, seed=0,
                                  engine=engine, **kw)
                 for _, kind, prof, mdl, kw in chunk]
    floors = gb.SHAPE_CLASSES if engine == "jax" else None
    out = {}
    for (label, *_), cell in zip(
            chunk, run_fleet_grid(scenarios, pad_floors=floors)):
        out[label["topology"]] = (cell.sim_decode_tok_per_watt,
                                  cell.sim_tok_per_watt,
                                  cell.report["fleet"]["completed"])
    return out


def test_grid_slice_jax_matches_numpy_oracle():
    ref = _measure("numpy")
    got = _measure("jax")
    assert set(got) == set(ref)
    for kind, (dec, allin, done) in ref.items():
        jdec, jallin, jdone = got[kind]
        assert jdone == done, kind
        assert jdec == pytest.approx(dec, rel=1e-3), kind
        assert jallin == pytest.approx(allin, rel=1e-3), kind


def test_grid_cells_shape():
    """260 cells, every family present on every chip, labels complete."""
    cells = gb.grid_cells()
    assert len(cells) == 260
    fams = {(label["generation"], kind) for label, kind, *_ in cells}
    for gen in ("H100", "H200", "B200", "GB200"):
        for kind in ("moe_semantic", "semantic_fleetopt", "fleetopt",
                     "moe_pool", "multipool"):
            assert (gen, kind) in fams
    for label, *_ in cells:
        assert set(label) >= {"table", "generation", "workload", "topology",
                              "dispatch_ms", "misroute_rate"}
