"""SLO-constrained sizing loop (core.slo): the measured FleetSim TTFT p99
is the provisioning authority.  Pins the loop's three contracts — it
converges to compliance, it never loosens the SLO (capacity is monotone
non-decreasing across the grow rounds), and the tok/W cost of compliance
is monotone — plus the trim phase (measured-compliant bisection of the
geometric step's overshoot), the e2e_p99_s constraint, the K >= 3
multipool path and the already-compliant fast path."""
import pytest

from repro.core import AZURE, H100_LLAMA70B, ladder_windows, size_to_slo
from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import B200_LLAMA70B_FLEET
from repro.core.slo import SLOSpec


@pytest.fixture(scope="module")
def fleetopt_slo():
    return size_to_slo("fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                       b_short=4096, n_requests=2000, seed=0)


def test_slo_loop_converges(fleetopt_slo):
    r = fleetopt_slo
    # the PR-1 defect is real: the unconstrained Eq. 4 fleet violates its
    # own SLO when actually run...
    assert r.rounds[0].ttft_p99_s > r.slo.ttft_p99_s
    # ...and the loop sizes it back into compliance
    assert r.compliant
    assert r.ttft_p99_s <= r.slo.ttft_p99_s
    assert len(r.rounds) >= 2
    assert r.instances_added > 0
    assert r.report["fleet"]["completed"] == 2000


def test_slo_never_loosened_capacity_monotone(fleetopt_slo):
    r = fleetopt_slo
    # the target itself never moved
    assert r.slo == SLOSpec(ttft_p99_s=0.5)
    assert r.rounds[-1].ttft_p99_s <= 0.5
    # capacity only ever grows, per pool and in total
    for prev, nxt in zip(r.rounds, r.rounds[1:]):
        for role, n in prev.instances.items():
            assert nxt.instances[role] >= n, (role, prev, nxt)
    assert r.plan.instances >= r.unconstrained.instances


def test_slo_tok_per_watt_cost_monotone(fleetopt_slo):
    r = fleetopt_slo
    tpw = [rd.analytical_tok_per_watt for rd in r.rounds]
    assert all(b <= a + 1e-9 for a, b in zip(tpw, tpw[1:])), tpw
    assert r.slo_tok_per_watt <= r.unconstrained.tok_per_watt
    assert r.compliance_cost_pct >= 0.0


def test_slo_calibrates_effective_prefill_mfu(fleetopt_slo):
    cal = fleetopt_slo.calibrated_prefill_mfu
    assert cal, "at least one pool must have been recalibrated"
    # backed off from the closed-form 0.8, never below the 2% floor
    assert all(0.02 <= v < 0.8 for v in cal.values()), cal


def test_slo_multipool_k3_end_to_end():
    r = size_to_slo("multipool", AZURE, H100_LLAMA70B, LLAMA31_70B,
                    windows=ladder_windows(3), n_requests=1500, seed=0)
    assert r.compliant
    assert r.ttft_p99_s <= 0.5
    roles = [k for k in r.report if k != "fleet"]
    assert len(roles) == 3, roles
    assert r.report["fleet"]["completed"] == 1500


def test_slo_trim_phase_shaves_overshoot(fleetopt_slo):
    """Satellite acceptance (ROADMAP open item): after compliance the
    bisection claws back part of the geometric step's capacity overshoot,
    and the trimmed fleet still measures p99-compliant."""
    r = fleetopt_slo
    assert r.instances_trimmed > 0
    assert r.trim_rounds >= 1
    # rounds stay the grow-only audit trail; the final plan sits between
    # the unconstrained sizing and the last grow round
    grown = sum(r.rounds[-1].instances.values())
    assert r.plan.instances == grown - r.instances_trimmed
    assert r.plan.instances >= r.unconstrained.instances
    # the trimmed fleet still meets the SLO, measured
    assert r.compliant and r.ttft_p99_s <= r.slo.ttft_p99_s
    # and trimming can only improve the analytical headline
    assert r.slo_tok_per_watt >= r.rounds[-1].analytical_tok_per_watt - 1e-9


def test_slo_trim_can_be_disabled():
    r = size_to_slo("fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                    b_short=4096, n_requests=2000, seed=0, trim=False)
    assert r.compliant
    assert r.trim_rounds == 0 and not r.trimmed
    assert r.plan.instances == sum(r.rounds[-1].instances.values())


def test_slo_e2e_constraint_attributes_to_decoding_pool():
    """With an e2e p99 constraint, violations attribute to the pool that
    decoded the request (capacity elsewhere cannot buy e2e latency).  The
    2 s target sits below the long tail's service floor, so the pin is
    the recorded measurement and the attribution, not compliance."""
    r = size_to_slo("fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                    b_short=4096, n_requests=1500, seed=0, max_rounds=2,
                    slo=SLOSpec(ttft_p99_s=0.5, e2e_p99_s=2.0))
    assert r.slo.e2e_p99_s == 2.0
    assert r.rounds[0].e2e_p99_s > 2.0
    assert sum(r.rounds[0].violators.values()) > 0
    # the long pool decodes the tail: it must be among the attributed
    assert r.rounds[0].violators["long"] > 0


def test_slo_already_compliant_fleet_untouched():
    """B200 homo meets the SLO at the unconstrained sizing: the loop must
    terminate in one round at zero cost — and the trim phase must not
    touch a fleet that never grew."""
    r = size_to_slo("homo", AZURE, B200_LLAMA70B_FLEET, LLAMA31_70B,
                    n_requests=1500, seed=0)
    assert r.compliant
    assert len(r.rounds) == 1
    assert r.instances_added == 0
    assert r.compliance_cost_pct == 0.0
    assert not r.overrides
    assert r.trim_rounds == 0 and not r.trimmed


def test_slo_disagg_grows_prefill_fleet_for_ttft():
    """Disaggregated serving: TTFT violations are attributed to the
    prefill pools (they drain the prompt), so the loop re-provisions the
    prefill fleet and leaves the decode fleet alone."""
    r = size_to_slo("disagg_fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                    b_short=4096, n_requests=1500, seed=0)
    assert r.compliant
    assert r.ttft_p99_s <= 0.5
    first, last = r.rounds[0].instances, r.rounds[-1].instances
    assert len(r.rounds) >= 2          # round 0 violates, the loop worked
    grown = {role for role in first if last[role] > first[role]}
    assert grown and all(role.startswith("prefill") for role in grown), \
        (first, last)
    for role in first:                 # decode fleets never grew
        if role.startswith("decode"):
            assert last[role] == first[role]


def test_slo_semantic_and_moe_kinds_end_to_end():
    """The model-heterogeneous kinds run through the full sizing loop:
    semantic routing with a nonzero misroute rate reaches compliance (at
    0.05 the misrouted-giant-prompt tail stays inside the 1% p99 budget;
    at 0.1 it alone overflows the budget and the SLO is service-time
    unattainable — see DESIGN.md §9), and the MoE pool with a 2 ms
    dispatch floor re-provisions into compliance."""
    r = size_to_slo("semantic_fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
                    b_short=4096, n_requests=1500, seed=0,
                    misroute_rate=0.05)
    assert r.compliant and r.ttft_p99_s <= 0.5
    assert set(r.rounds[0].instances) == {"small", "large"}
    assert r.report["fleet"]["escalations"] > 0

    from repro.core.hardware import H100
    from repro.core.modelspec import QWEN3_235B_A22B
    from repro.core.moe import moe_profile
    from repro.core.power import H100_POWER
    prof = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    m = size_to_slo("moe_pool", AZURE, prof, QWEN3_235B_A22B,
                    n_requests=1500, seed=0, dispatch_ms=2.0, trim=False)
    assert m.compliant and m.ttft_p99_s <= 0.5
    assert list(m.rounds[0].instances) == ["moe"]
    assert len(m.rounds) >= 2          # the dispatch floor forced growth


def test_slo_incremental_measurement_saves_full_sims(fleetopt_slo):
    """Tentpole acceptance: the sizing loop's measurement harness is
    incremental — one frozen CRN trace, memoized measure(), and per-pool
    warm-start replay — so across the grow rounds *and* the trim
    bisection it issues strictly fewer full-fleet simulations than
    measure() calls (pre-refactor, every call simulated every pool)."""
    s = fleetopt_slo.sim_stats
    assert s["measure_calls"] >= 2
    assert s["full_fleet_sims"] < s["measure_calls"], s
    # the warm start actually replayed pools (fleetopt: the short pool is
    # unchanged while the long pool grows/trims)
    assert s["pools_reused"] > 0, s
    # every measurement still covers every pool, simulated or replayed
    assert s["pool_sims"] + s["pools_reused"] == \
        2 * (s["measure_calls"] - s["memo_hits"])


def test_slo_converges_identically_to_per_engine_loop(fleetopt_slo):
    """The incremental harness must not change *what* the loop converges
    to — instance counts and SLO-feasible tok/W pinned to the values the
    pre-refactor full-resimulation loop produced on this config."""
    r = fleetopt_slo
    assert [rd.instances for rd in r.rounds] == \
        [{"short": 21, "long": 21}, {"short": 21, "long": 25}]
    assert r.trimmed == {"long": 3}
    assert {p.name: p.instances for p in r.plan.pools} == \
        {"fleetopt-short-8K": 21, "fleetopt-long-64K": 22}
    assert round(r.slo_tok_per_watt, 2) == 15.62


def test_slo_measures_hol_inflation_and_feeds_it_back():
    """ROADMAP gap closed: `size_to_slo` measures per-pool HOL queueing
    (occupied-slot population vs the hol=1 Little's-law population) and
    drives `PoolOverride.hol_inflation` from it.  On a prefill-heavy
    workload — slots held through long prompt drains the decode-
    population closed form never sees — the measured inflation exceeds 1
    and the calibrated value lands in the final plan's sizing."""
    import math
    from repro.core.workloads import Workload
    wl = Workload(name="prefill-heavy",
                  prompt_mix=((1.0, math.log(6000.0), 0.3),),
                  output_mu=math.log(8.0), output_sigma=0.3,
                  arrival_rate=400.0)
    r = size_to_slo("homo", wl, H100_LLAMA70B, LLAMA31_70B,
                    n_requests=1200, seed=0, max_rounds=4, trim=False)
    assert r.measured_hol["homo"] > 1.0
    o = r.overrides["homo"]
    assert o.hol_inflation is not None and 1.0 < o.hol_inflation <= 2.15
    assert o.hol_inflation == min(r.measured_hol["homo"], 2.15)
    # ...and it fed back into the closed-form sizing (core.fleet)
    (pool,) = r.plan.pools
    assert pool.hol_inflation == o.hol_inflation


def test_slo_azure_fleets_measure_no_hol_inflation(fleetopt_slo):
    """On the paper's Azure fleets the measured occupancy population sits
    *below* the closed form's tau(n_max) Little's-law prediction, so the
    measurement-driven knob correctly stays at its default — capacity
    growth is owed to prefill queueing (the MFU backoff), not HOL
    blocking.  Pinning this keeps the calibration honest: it must not
    double-count the queueing signal the instance ratchet already
    handles."""
    r = fleetopt_slo
    assert r.measured_hol, "violating rounds must record the measurement"
    assert all(v < 1.0 for v in r.measured_hol.values()), r.measured_hol
    assert all(o.hol_inflation is None for o in r.overrides.values())


def test_slo_tpot_violations_grow_decode_fleet():
    """With a TPOT p99 constraint in the SLOSpec, violations attribute to
    the decode pools (prefill capacity cannot buy TPOT).  6 ms sits below
    the physical tau floor, so the run is not expected to comply — the
    pin is the *attribution*: decode grows, prefill does not."""
    r = size_to_slo("disagg", AZURE, H100_LLAMA70B, LLAMA31_70B,
                    n_requests=1500, seed=0, max_rounds=2,
                    slo=SLOSpec(ttft_p99_s=0.5, tpot_p99_ms=6.0))
    r0, r1 = r.rounds[0].instances, r.rounds[1].instances
    assert r.rounds[0].violators["decode-64K"] > 0
    assert r1["decode-64K"] > r0["decode-64K"]
    assert r1["prefill-64K"] == r0["prefill-64K"]
    assert r.rounds[0].tpot_p99_ms > 6.0
