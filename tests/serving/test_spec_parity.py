"""Committed-baseline reproduction through the TopologySpec IR.

The CI perf gate diffs a fresh `fleet_sim_bench.py --quick` run against
the committed benchmarks/results/fleet_sim.json at 10% tolerance; these
tests pin the stronger property the IR refactor guarantees — EXACT
reproduction: rebuilding a committed quick-bench cell via
`TopologySpec.from_kind` + `simulate_spec` lands on the committed
tok/W to the digit (the baseline was recorded through the same spec
path, and every legacy kind compiles bit-identically).

Only the Azure unconstrained row per topology is re-simulated here
(n=1000 quick config, ~seconds); the full-table sweep remains the CI
bench's job.
"""
import json
import pathlib

import pytest

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.topospec import TopologySpec
from repro.core.workloads import AZURE
from repro.serving import simulate_spec

BASELINE = pathlib.Path(__file__).resolve().parents[2] / "benchmarks" \
    / "results" / "fleet_sim.json"
QUICK_N = 1000           # fleet_sim_bench --quick n_requests
B_SHORT_AZURE = 4096


def _committed_cells():
    data = json.loads(BASELINE.read_text())
    meta, rows = data["meta"], data["rows"]
    assert meta["quick"] and meta["n_requests"] == QUICK_N \
        and meta["seed"] == 0, \
        "committed baseline no longer the quick config this test pins"
    return {r["topology"]: r for r in rows
            if r["table"] == "unconstrained"
            and r["workload"] == AZURE.name}


@pytest.mark.parametrize("kind", ["homo", "two_pool", "fleetopt"])
def test_committed_quick_cell_reproduces_exactly(kind):
    want = _committed_cells()[kind]
    spec = TopologySpec.from_kind(kind, H100_LLAMA70B, LLAMA31_70B,
                                  b_short=B_SHORT_AZURE)
    cell = simulate_spec(spec, AZURE, n_requests=QUICK_N, seed=0)
    assert round(cell.sim_decode_tok_per_watt, 2) == want["simulated"]
    assert round(cell.analytical_tok_per_watt, 2) == want["analytical"]
    assert round(cell.sim_tok_per_watt, 2) == want["all_in"]


@pytest.mark.gridsmoke
def test_committed_quick_cell_reproduces_under_jax_engine():
    """--engine jax drains the same cells to the same digits (satellite:
    spec parity holds under the compiled grid engine too)."""
    want = _committed_cells()["fleetopt"]
    spec = TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                                  b_short=B_SHORT_AZURE)
    cell = simulate_spec(spec, AZURE, n_requests=QUICK_N, seed=0,
                         engine="jax")
    assert round(cell.sim_decode_tok_per_watt, 2) == want["simulated"]
