"""Preemption / eviction under memory pressure (paper §10.1 limitation,
implemented) + latency percentile tracking."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.profiles import H100_LLAMA70B
from repro.models import model as M
from repro.serving import PoolEngine, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("yi-6b").reduced()
    return cfg, M.init_params(jax.random.PRNGKey(0), cfg)


def test_preempted_requests_still_complete_correctly(small_model):
    """Eviction drops KV and re-prefills; final tokens must match the
    uninterrupted greedy generation (correctness under pressure)."""
    cfg, params = small_model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (5, 7, 9)]
    # uninterrupted reference
    ref_out = []
    for p in prompts:
        eng = PoolEngine(cfg, params, window=48, profile=H100_LLAMA70B,
                         n_slots=1, name="ref")
        eng.submit(Request(rid=0, prompt=p, max_new_tokens=6))
        eng.run_until_drained(max_iters=200)
        ref_out.append(eng.completed[0].generated[:6])
    # pressured engine: preempt mid-flight
    eng = PoolEngine(cfg, params, window=48, profile=H100_LLAMA70B,
                     n_slots=3, name="pressured")
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    eng.shrink(1)                       # memory pressure: evict 2 youngest
    assert eng.n_active == 1
    assert eng.preempted == 2
    eng.run_until_drained(max_iters=400)
    assert len(eng.completed) == 3
    by_rid = {r.rid: r for r in eng.completed}
    for i, expect in enumerate(ref_out):
        assert by_rid[i].generated[:6] == expect, i
    assert sum(r.preemptions for r in reqs) == 2


def test_preemption_costs_energy(small_model):
    """Eviction wastes the evicted work: same traffic, strictly more
    joules per output token than the unpressured run — quantifying the
    paper's 'analytical tok/W is an upper bound' caveat."""
    cfg, params = small_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=8) for _ in range(4)]

    def run(pressure: bool) -> float:
        eng = PoolEngine(cfg, params, window=48, profile=H100_LLAMA70B,
                         n_slots=4, name="x")
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=8))
        for _ in range(4):
            eng.step()
        if pressure:
            eng.shrink(2)
        eng.run_until_drained(max_iters=400)
        assert len(eng.completed) == 4
        return eng.meter.joules / eng.meter.tokens

    assert run(pressure=True) > run(pressure=False)


def test_latency_percentiles(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(5)
    eng = PoolEngine(cfg, params, window=48, profile=H100_LLAMA70B,
                     n_slots=2, name="lat")
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6),
                           max_new_tokens=5))
    eng.run_until_drained(max_iters=300)
    s = eng.stats()
    assert s["ttft_p99_s"] >= s["ttft_p50_s"] >= 0
    assert s["e2e_p99_s"] > s["ttft_p50_s"]   # decode takes time too
