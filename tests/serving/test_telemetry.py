"""FleetScope observability layer (DESIGN.md §14).

Covers the recorder's two channels end-to-end on a real fleet cell:
the zero-overhead-when-off guarantee (same seeded run with telemetry
attached produces a bit-identical report), energy reconciliation
between the charge channel and the meters, timeline binning mass
conservation, the Perfetto export shape, SLO violation forensics
(`core.slo.explain`), the empty-window strict_keys NaN contract, and
the `conservation_violations` meter audit — plus a hypothesis property
test fuzzing window-straddling charges through a scalar meter.
"""
import json
import math

import numpy as np
import pytest

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.slo import SLOSpec, explain
from repro.core.timeline import (EVENT_NAMES, SERIES_KEYS,
                                 TIMELINE_SCHEMA_VERSION,
                                 TRACE_SCHEMA_VERSION, bin_intervals)
from repro.core.topospec import TopologySpec
from repro.core.workloads import AZURE
from repro.serving import (EnergyMeter, TraceRecorder, build_timeline,
                           conservation_violations, phase_totals,
                           prepare_spec, reconcile_energy, to_perfetto)
from repro.serving.request import latency_percentiles_arrays

N_REQUESTS = 300


def _run_cell(telemetry=None, seed=0):
    spec = TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                                  b_short=4096)
    sim, reqs, _ = prepare_spec(spec, AZURE, n_requests=N_REQUESTS,
                                seed=seed, telemetry=telemetry)
    report = sim.run(reqs)
    return sim, report


@pytest.fixture(scope="module")
def detail_cell():
    rec = TraceRecorder(level="detail")
    sim, report = _run_cell(telemetry=rec)
    return rec, sim, report


def test_zero_overhead_when_off(detail_cell):
    """Tracing must be observation, not perturbation: the same seeded
    cell with a detail recorder attached reproduces the telemetry-off
    report bit-for-bit (json round-trip canonicalizes NaN)."""
    _, _, report_on = detail_cell
    _, report_off = _run_cell(telemetry=None)
    assert json.dumps(report_off, sort_keys=True, default=str) == \
        json.dumps(report_on, sort_keys=True, default=str)


def test_lifecycle_counts_match_report(detail_cell):
    rec, _, report = detail_cell
    counts = rec.counts()
    assert counts["arrive"] == N_REQUESTS
    assert counts["route"] >= N_REQUESTS        # re-entries add routes
    assert counts["complete"] == report["fleet"]["completed"]
    # detail level records admissions and per-chunk prefill progress
    assert counts["admit"] > 0 and counts["prefill"] > 0
    ts = [t for t, *_ in rec.golden_stream()]
    assert ts == sorted(ts)


def test_reconcile_energy_sub_tenth_percent(detail_cell):
    """The charge channel records the same float64 values the meters
    accumulate — reconciliation is float-rounding small, far inside the
    <0.1% gate the trace report enforces."""
    rec, sim, _ = detail_cell
    banks = [g.engine.bank for g in sim.groups.values()]
    rows = reconcile_energy(rec, banks)
    assert set(rows) == {"total", "decode", "prefill", "idle", "handoff",
                         "dispatch"}
    for phase, row in rows.items():
        assert row["rel_err"] < 1e-3, (phase, row)
    assert rows["total"]["meter_j"] > 0.0


def test_timeline_binning_conserves_mass(detail_cell):
    """Every joule in the charge channel lands in exactly one grid cell:
    summing the binned series recovers the meter lifetime totals (grid
    spans all charges, so nothing is clipped)."""
    rec, sim, _ = detail_cell
    t_lo = 0.0
    for _, _, _, start, _, _, _, _ in rec.charges:
        s = np.asarray(start, np.float64)
        if s.size:
            t_lo = min(t_lo, float(np.min(s)))
    tl = build_timeline(rec, t0=t_lo, n_bins=64)
    meter = phase_totals(g.engine.bank for g in sim.groups.values())
    binned = {k: float(tl.fleet(s).sum()) for k, s in
              (("total", "joules"), ("prefill", "prefill_j"),
               ("idle", "idle_j"), ("handoff", "handoff_j"),
               ("decode", "decode_j"), ("dispatch", "dispatch_j"))}
    for phase in ("total", "decode", "prefill", "idle", "handoff",
                  "dispatch"):
        assert binned[phase] == pytest.approx(meter[phase], rel=1e-9,
                                              abs=1e-9), phase
    # watts is the same mass divided by the bin width
    assert float(tl.fleet("watts").sum()) * tl.bin_s == \
        pytest.approx(meter["total"], rel=1e-9)


def test_timeline_to_json_schema(detail_cell):
    rec, _, _ = detail_cell
    doc = build_timeline(rec, n_bins=16).to_json()
    assert doc["schema_version"] == TIMELINE_SCHEMA_VERSION
    assert doc["n_bins"] == 16
    for series in doc["pools"].values():
        assert set(series) == set(SERIES_KEYS)
        assert all(len(col) == 16 for col in series.values())
    assert len(doc["fleet"]["tok_per_watt"]) == 16
    json.dumps(doc)          # strictly JSON-safe (NaN rendered as null)


def test_timeline_online_uses_registered_instances(detail_cell):
    rec, _, _ = detail_cell
    tl = build_timeline(rec, n_bins=8)
    for pid, name in enumerate(rec.pool_names):
        expect = rec.pool_instances.get(pid, 0)
        assert (tl.pools[name]["online"] == expect).all(), name


def test_empty_recorder_timeline():
    tl = build_timeline(TraceRecorder(level="detail"), n_bins=4)
    assert tl.t1 > tl.t0 and not tl.pools
    assert not tl.fleet("joules").any()


def test_bin_intervals_straddler_prorates():
    out = np.zeros(4)
    edges = np.linspace(0.0, 4.0, 5)
    bin_intervals([0.5], [2.0], [8.0], edges, out)      # spans bins 0-2
    assert out.tolist() == [2.0, 4.0, 2.0, 0.0]
    bin_intervals([2.0], [0.0], [1.0], edges, out)      # point charge
    assert out[2] == 3.0


def test_perfetto_doc_shape(detail_cell):
    rec, sim, _ = detail_cell
    doc = to_perfetto(rec, counter_bins=12)
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["otherData"]["schema_version"] == TRACE_SCHEMA_VERSION
    assert doc["otherData"]["pools"] == rec.pool_names
    phs = {ev["ph"] for ev in doc["traceEvents"]}
    assert phs <= {"X", "i", "C", "M"} and "X" in phs and "C" in phs
    # every pool appears as a named process
    procs = {ev["args"]["name"] for ev in doc["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "process_name"}
    assert procs == set(rec.pool_names)
    json.dumps(doc)


def test_explain_attributes_violations(detail_cell):
    _, sim, report = detail_cell
    rows = explain(sim, SLOSpec(ttft_p99_s=1e-9))       # everything late
    assert [set(r) >= {"role", "n_obs", "n_late", "late_frac",
                       "worst_ttft_s", "first_violation_s",
                       "last_violation_s", "peak_window_s",
                       "peak_window_late"} for r in rows]
    assert sorted(r["role"] for r in rows) == sorted(sim.order)
    lates = [r["n_late"] for r in rows]
    assert lates == sorted(lates, reverse=True) and sum(lates) > 0
    for r in rows:
        assert r["n_late"] == r["n_obs"]
        if r["n_late"]:
            lo, hi = r["peak_window_s"]
            assert lo <= hi and r["peak_window_late"] > 0
    # a generous SLO attributes nothing
    assert all(r["n_late"] == 0
               for r in explain(sim, SLOSpec(ttft_p99_s=1e9)))


def test_strict_keys_empty_window():
    empty = np.empty(0)
    out = latency_percentiles_arrays(empty, empty, empty, empty,
                                     strict_keys=True)
    assert set(out) == {"ttft_p50_s", "ttft_p99_s", "e2e_p99_s",
                        "tpot_p50_ms", "tpot_p99_ms"}
    assert all(math.isnan(v) for v in out.values())
    # legacy default keeps dropping the keys (callers .get with defaults)
    assert latency_percentiles_arrays(empty, empty, empty, empty) == {}


def test_conservation_violations_clean_and_corrupt(detail_cell):
    _, sim, _ = detail_cell
    for g in sim.groups.values():
        assert conservation_violations(g.engine.bank) == []
    m = EnergyMeter(H100_LLAMA70B)
    m.charge_prefill(512, streamed_params=1e9)
    m.charge_decode_step(4, 1000.0)
    m.charge_idle(0.5)
    assert conservation_violations(m) == []
    m.m_joules = m.joules + 5.0         # window cannot exceed lifetime
    bad = conservation_violations(m)
    assert bad and any("m_joules" in v for v in bad)


def test_invalid_trace_level_rejected():
    with pytest.raises(ValueError):
        TraceRecorder(level="verbose")


# --- property test: window-straddling charges stay conserved -------------

try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    ops = st.lists(
        st.tuples(st.sampled_from(["decode", "prefill", "idle",
                                   "handoff"]),
                  st.integers(1, 64),       # n_active / tokens / KB
                  st.floats(0.0, 2.0)),     # dt / overlap span
        min_size=1, max_size=40)

    @settings(max_examples=40, deadline=None)
    @given(ops=ops, t0=st.floats(0.0, 5.0), span=st.floats(0.0, 5.0),
           dispatch_s=st.sampled_from([0.0, 5e-4]))
    def test_property_straddling_charges_conserve(ops, t0, span,
                                                  dispatch_s):
        """Any charge sequence against any measurement window keeps the
        meter's accounting identities: windowed counters bounded by
        lifetime totals, non-negative decode residual, dispatch inside
        decode — and the trace charge channel reconciles with the meter
        to float rounding even when charges straddle the window."""
        rec = TraceRecorder(level="detail")
        m = EnergyMeter(H100_LLAMA70B, measure_t0=t0,
                        measure_t1=t0 + span, dispatch_s=dispatch_s)
        m.trace = rec
        m.trace_pool = rec.pool_id("p", instances=1)
        for kind, n, f in ops:
            if kind == "decode":
                m.charge_decode_step(n, 500.0 + 100.0 * n)
            elif kind == "prefill":
                m.charge_prefill(16 * n, streamed_params=1e9,
                                 overlap_s=0.5 * f)
            elif kind == "idle":
                m.charge_idle(f)
            else:
                m.charge_handoff(1024.0 * n, start_s=m.sim_time_s - f,
                                 duration_s=f, j_per_byte=2e-10)
        assert conservation_violations(m) == []
        for phase, row in reconcile_energy(rec, [m]).items():
            assert row["rel_err"] < 1e-9, (phase, row)
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev)")
    def test_property_straddling_charges_conserve():
        pass
