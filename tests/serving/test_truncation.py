"""Drain-cap truncation must fail loudly.  Before the fix, every drive
loop (`PoolEngine.run_until_drained`, `BatchedPoolEngine.run_until_drained`
and the router path over them) hit `max_iters` and *returned as if
drained*: queued requests silently vanished and the meters rolled
under-counted tokens/energy straight into fleet tok/W.  Now a busy pool
at the cap raises `DrainTruncatedError`."""
import math
import numpy as np
import pytest

from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.serving import (BatchedPoolEngine, ContextRouter,
                           DrainTruncatedError, PoolEngine, Request,
                           RouterPolicy)

STREAMED = LLAMA31_70B.streamed_params


def _reqs(n=40):
    return [Request(rid=i, prompt=np.broadcast_to(np.int64(0), (700,)),
                    max_new_tokens=60, arrival_time=0.01 * i)
            for i in range(n)]


def test_scalar_engine_raises_on_truncated_drain():
    eng = PoolEngine(None, None, profile=H100_LLAMA70B,
                     streamed_params=STREAMED, window=4096,
                     prefill_chunk=256, respect_arrival=True)
    for r in _reqs():
        eng.submit(r)
    with pytest.raises(DrainTruncatedError, match="max_iters=3"):
        eng.run_until_drained(max_iters=3)
    eng.run_until_drained(max_iters=200_000)   # recoverable: finish it
    assert not eng.busy


def test_batched_engine_raises_on_truncated_drain():
    eng = BatchedPoolEngine(instances=2, window=4096,
                            profile=H100_LLAMA70B,
                            streamed_params=STREAMED, prefill_chunk=256,
                            respect_arrival=True)
    for i, r in enumerate(_reqs()):
        eng.submit(r, i % 2)
    eng.sort_queues()
    with pytest.raises(DrainTruncatedError) as ei:
        eng.run_until_drained(max_iters=3)
    assert ei.value.max_iters == 3
    eng.run_until_drained(max_iters=200_000)
    assert not eng.busy


def test_router_propagates_truncation():
    pool = PoolEngine(None, None, profile=H100_LLAMA70B,
                      streamed_params=STREAMED, window=8192,
                      prefill_chunk=256, respect_arrival=True,
                      name="only")
    router = ContextRouter({"only": pool}, RouterPolicy(
        kind="homo", ladder=[("only", math.inf)]))
    with pytest.raises(DrainTruncatedError):
        router.run(_reqs(), max_iters=3)
