"""Disaggregated prefill/decode serving (core.disagg through FleetSim):
prefill-phase engine semantics, the KV-handoff hop with its interconnect
delay + energy, the disagg_fleetopt overflow re-prefill chain, and the
tentpole integration check — measured disagg decode tok/W within 25% of
the analytical decode-fleet sizing, with handoff energy nonzero and
accounted.  Deterministic seeds; no jax."""
import numpy as np
import pytest

from repro.core.disagg import HANDOFF_J_PER_BYTE
from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import AZURE
from repro.serving import (EnergyMeter, FleetSim, PoolEngine, Request,
                           build_topology, simulate_topology)

STREAMED = LLAMA31_70B.streamed_params


def _req(rid, plen, out, t=0.0, pred=None):
    return Request(rid=rid, prompt=np.zeros(plen, np.int64),
                   max_new_tokens=out, arrival_time=t,
                   predicted_output=pred)


# --- prefill-phase engine unit behaviour --------------------------------

def test_prefill_phase_engine_hands_off_without_decoding():
    eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED,
                     phase="prefill", prefill_chunk=128)
    for i in range(3):
        eng.submit(_req(i, 256, 5))
    eng.run_until_drained(max_iters=500)
    assert len(eng.completed) == 0          # prefill pools finish nothing
    assert len(eng.handoff) == 3 and len(eng.relayed) == 3
    for r in eng.handoff:
        assert r.prefill_done
        assert r.n_generated == 1 and len(r.generated) == 1
        assert r.first_token_time > 0       # TTFT set at prefill drain
        assert r.ready_time == r.first_token_time
    # no decode iterations ever ran: all energy is prefill compute
    assert eng.meter.tokens == 0
    assert eng.meter.prefill_tokens == 3 * 256
    assert eng.meter.prefill_joules == pytest.approx(eng.meter.joules)


def test_prefill_phase_is_fifo_across_slot_recycling():
    """A giant prompt admitted into a freed low-index slot must not starve
    an older, nearly-drained prompt in a higher slot."""
    eng = PoolEngine(None, None, window=8192, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED,
                     phase="prefill", prefill_chunk=128,
                     respect_arrival=True)
    eng.submit(_req(0, 64, 1, t=0.0))       # slot 0, drains fast
    eng.submit(_req(1, 4096, 1, t=0.0))     # slot 1, long
    eng.submit(_req(2, 4096, 1, t=0.001))   # recycles slot 0
    eng.run_until_drained(max_iters=2000)
    done = {r.rid: r.first_token_time for r in eng.relayed}
    assert done[0] < done[1] < done[2]      # oldest-first, not slot-index


def test_prefill_phase_defaults_unchunked_zero_to_a_real_chunk():
    """prefill_chunk=0 means 'unchunked' for decode engines; a prefill-
    phase engine must not take it literally (a 0 budget would spin
    without ever draining a prompt)."""
    eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                     n_slots=1, streamed_params=STREAMED,
                     phase="prefill", prefill_chunk=0)
    assert eng.prefill_chunk == 512
    eng.submit(_req(0, 64, 1))
    eng.run_until_drained(max_iters=50)
    assert len(eng.relayed) == 1


def test_prefill_phase_rejects_model_mode():
    with pytest.raises(ValueError):
        PoolEngine(object(), object(), window=64, profile=H100_LLAMA70B,
                   phase="prefill")
    with pytest.raises(ValueError):
        PoolEngine(None, None, window=64, profile=H100_LLAMA70B,
                   streamed_params=STREAMED, phase="nope")


def test_prefilled_admission_skips_prefill_charge():
    """A decode pool admitting a handed-off request must not re-run or
    re-charge prefill, must preserve the upstream TTFT, and decodes the
    remaining max_new - 1 tokens."""
    eng = PoolEngine(None, None, window=4096, profile=H100_LLAMA70B,
                     n_slots=2, streamed_params=STREAMED)
    req = _req(0, 100, 6)
    req.prefill_done = True
    req.generated = [7]
    req.n_generated = 1
    req.first_token_time = 0.123
    eng.submit(req)
    eng.run_until_drained(max_iters=100)
    assert len(eng.completed) == 1
    assert req.n_generated == 6
    assert req.first_token_time == 0.123    # set by the prefill pool
    assert eng.meter.prefill_joules == 0.0
    assert eng.meter.prefill_tokens == 0
    assert eng.meter.tokens == 5            # tokens 2..6 are decode steps


# --- KV-handoff energy metering -----------------------------------------

def test_charge_handoff_prorates_measurement_window():
    m = EnergyMeter(H100_LLAMA70B)
    e = m.charge_handoff(1e9, start_s=0.0, duration_s=1.0,
                         j_per_byte=HANDOFF_J_PER_BYTE)
    assert e == pytest.approx(1e9 * HANDOFF_J_PER_BYTE)
    assert m.handoff_joules == pytest.approx(e)
    assert m.m_handoff_joules == pytest.approx(e)   # (0, inf) window
    assert m.sim_time_s == 0.0     # transfers never advance the clock
    # half the transfer interval outside the window -> half attributed
    m2 = EnergyMeter(H100_LLAMA70B)
    m2.measure_t0, m2.measure_t1 = 0.0, 0.5
    m2.charge_handoff(1e9, start_s=0.0, duration_s=1.0,
                      j_per_byte=HANDOFF_J_PER_BYTE)
    assert m2.m_handoff_joules == pytest.approx(0.5 * e)
    assert m2.handoff_joules == pytest.approx(e)    # totals keep it all


# --- router / topology wiring -------------------------------------------

def test_disagg_topology_routes_into_prefill_pools():
    policy, plan, _registry = build_topology(
        "disagg_fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, gamma=2.0)
    roles = [p.name for p in sorted(plan.pools, key=lambda p: p.window)]
    assert roles == ["prefill-8K", "decode-8K", "prefill-64K", "decode-64K"]
    ladder = policy.admission_ladder(roles)
    assert ladder == [("prefill-8K", 8192.0), ("prefill-64K", float("inf"))]
    sim = FleetSim(policy, plan, model=LLAMA31_70B)
    assert sim.handoff_to == {"prefill-8K": "decode-8K",
                              "prefill-64K": "decode-64K"}
    assert sim.overflow_to == {"decode-8K": "prefill-64K"}
    assert sim.router.route(_req(0, 100, 10, pred=10)) == "prefill-8K"
    assert sim.router.route(_req(1, 9000, 10, pred=10)) == "prefill-64K"


def test_disagg_overflow_reprefills_in_long_slice():
    """disagg_fleetopt overflow chain: a mispredicted request evicted from
    decode-8K re-prefills in prefill-64K (its KV was dropped) and finishes
    in decode-64K — two KV handoffs, one migration."""
    policy, plan, _registry = build_topology(
        "disagg_fleetopt", AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, gamma=2.0)
    sim = FleetSim(policy, plan, model=LLAMA31_70B)
    chain = _req(0, 900, 8000, pred=100)    # predicted 1000 -> short slice
    rep = sim.run([chain])
    assert rep["fleet"]["completed"] == 1
    assert rep["fleet"]["migrations"] == 1
    assert rep["fleet"]["handoffs"] == 2    # original + post-evict re-entry
    assert chain.preemptions == 1
    assert chain.pool.startswith("decode-64K")
    assert chain.prefill_role == "prefill-64K"
    assert chain.n_generated == 8000


# --- fleet-level integration (the tentpole acceptance) ------------------

@pytest.fixture(scope="module")
def disagg_cells():
    return {kind: simulate_topology(
        kind, AZURE, H100_LLAMA70B, LLAMA31_70B,
        b_short=4096, n_requests=8000, seed=0)
        for kind in ("disagg", "disagg_fleetopt")}


def test_disagg_measured_within_tolerance_of_analytical(disagg_cells):
    """Stated tolerance: measured steady-state decode tok/W within 25% of
    the closed-form decode-fleet sizing (observed at seed 0 / 8k requests:
    disagg -17%, disagg_fleetopt -15%)."""
    for kind, cell in disagg_cells.items():
        assert abs(cell.delta_pct) < 25.0, (kind, cell.delta_pct)
        # the whole-fleet analytical number additionally pays the
        # dedicated prefill pools, so it sits strictly below decode-only
        assert cell.analytical_fleet_tok_per_watt \
            < cell.analytical_tok_per_watt


def test_disagg_handoff_energy_nonzero_and_accounted(disagg_cells):
    for kind, cell in disagg_cells.items():
        f = cell.report["fleet"]
        assert f["handoffs"] >= f["completed"] == 8000
        assert f["kv_handoff_joules"] > 0
        assert f["kv_handoff_gb"] > 0
        assert 0 < f["kv_handoff_energy_frac"] < 0.05   # real but small
        # windowed attribution can only be a share of the per-byte total
        total_j = f["kv_handoff_gb"] * 1e9 * HANDOFF_J_PER_BYTE
        assert f["kv_handoff_joules"] <= total_j * (1 + 1e-6)


def test_disagg_removes_prefill_interference_from_decode_pools(disagg_cells):
    """The measured finding the topology exists for: decode pools in a
    disaggregated fleet meter zero prefill energy (it all lives in the
    prefill pools), and every decode-pool TTFT is inherited from a
    prefill pool."""
    for kind, cell in disagg_cells.items():
        for role, s in cell.report.items():
            if role == "fleet":
                continue
            if s["phase"] == "decode":
                assert s["completed"] > 0 and s["relayed"] == 0
                assert s["m_prefill_joules"] == 0.0, (role, s)
            else:
                assert s["completed"] == 0 and s["relayed"] > 0
                assert s["m_prefill_joules"] > 0.0, (role, s)


def test_prefill_role_latency_includes_downstream_metrics(disagg_cells):
    """latency_by_role for a prefill-phase pool covers the requests it
    relayed — including the e2e/TPOT metrics their downstream decode
    pool filled in after the prefill pool drained (regression pin: the
    SoA summaries are snapshotted per pool at drain time, and prefill
    pools' percentiles must be refreshed once the fleet finishes)."""
    from repro.serving import FleetSim, build_topology, trace_requests
    policy, plan, reg = build_topology("disagg", AZURE, H100_LLAMA70B,
                                       LLAMA31_70B, b_short=4096)
    sim = FleetSim(policy, plan, registry=reg)
    sim.run(trace_requests(AZURE, 400, seed=2))
    for role, lat in sim.latency_by_role().items():
        assert {"ttft_p99_s", "e2e_p99_s", "tpot_p99_ms"} <= set(lat), \
            (role, lat)


def test_disagg_ttft_under_unconstrained_sizing(disagg_cells):
    """Dedicated prefill removes the interleave competition: plain disagg
    meets the 500 ms TTFT p99 already at the unconstrained Eq. 4 sizing
    (fleetopt at the same sizing violates it by ~3x — Table A)."""
    f = disagg_cells["disagg"].report["fleet"]
    assert f["ttft_p99_s"] <= 0.5, f["ttft_p99_s"]
