"""Autoscaler mechanics + FleetSim integration.

Two layers: the pure controller (`Autoscaler.plan_pool` turns arrival
times into per-incarnation online windows — deterministic, unit-tested
edge by edge) and the engine integration (online windows move engine
clocks, weight loads charge idle joules, an autoscaled run still
completes every request, and the autoscale=None path stays byte-for-
byte the steady-state simulator the committed baselines pinned)."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.autoscale import AutoscalePolicy
from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.topospec import TopologySpec
from repro.core.workloads import AZURE, DiurnalProfile
from repro.serving.autoscale import Autoscaler, InstanceSchedule
from repro.serving.fleetsim import prepare_spec
from repro.serving.request import sample_diurnal_trace
from repro.serving.soa import BatchedPoolEngine

POL = AutoscalePolicy(control_interval_s=10.0, target_utilization=0.8,
                      scaleup_lag_s=2.0, scaledown_delay_s=30.0,
                      min_frac=0.25, spare_instances=0)


def _times(rate, t0, t1):
    """Deterministic evenly spaced arrivals at `rate` over [t0, t1)."""
    n = int(round(rate * (t1 - t0)))
    return np.linspace(t0, t1, n, endpoint=False)


# --- controller ---------------------------------------------------------

def test_steady_low_rate_sheds_to_demand_after_hysteresis():
    ts = _times(2.0, 0.0, 300.0)    # 2/s vs 10 instances x 1/s capacity
    sched = Autoscaler(POL).plan_pool(ts, n_peak=10, rate_per_instance=1.0,
                                      horizon_s=300.0)
    assert sched.n_rows == 10                       # no scale-ups needed
    assert int(sched.online_at(np.array([0.0]))[0]) == 10
    # demand needs ceil(2/0.8)=3 > floor ceil(.25*10)=3; after the 30 s
    # hysteresis the pool sheds to exactly that
    assert int(sched.online_at(np.array([299.0]))[0]) == 3
    # LIFO: the shed rows are the last ones, the survivors stay open
    assert np.isinf(sched.online_until[:3]).all()


def test_step_up_scales_back_out_with_lag_and_load():
    ts = np.concatenate([_times(2.0, 0.0, 200.0),
                         _times(9.0, 200.0, 400.0)])
    sched = Autoscaler(POL).plan_pool(ts, n_peak=10, rate_per_instance=1.0,
                                      horizon_s=400.0, load_s=5.0)
    # shed overnight, then the step at t=200 forces re-adds
    assert sched.n_rows > 10
    new = sched.online_from[10:]
    # each scale-up decision lands at an epoch boundary after the step,
    # and comes online lag + load later
    np.testing.assert_allclose(
        (new - POL.scaleup_lag_s - 5.0) % POL.control_interval_s, 0.0,
        atol=1e-9)
    assert (new > 200.0).all()
    # the pool is back at full strength by the end (9/0.8 > 10 -> clip)
    assert int(sched.online_at(np.array([399.0]))[0]) == 10


def test_trend_extrapolation_scales_ahead_of_a_ramp():
    """On a steep ramp the trend-aware controller must hold more
    capacity than the naive rate/cap target at the same instant."""
    ramp = np.sqrt(np.linspace(0.0, 1.0, 4000)) * 400.0   # accelerating
    sched = Autoscaler(POL).plan_pool(np.sort(ramp), n_peak=20,
                                      rate_per_instance=1.0,
                                      horizon_s=400.0)
    t = 200.0
    rate_now = ((ramp >= t - 10.0) & (ramp < t)).sum() / 10.0
    naive = math.ceil(rate_now / 0.8)
    assert int(sched.online_at(np.array([t]))[0]) >= naive


def test_cancelled_incarnation_has_zero_length_window():
    """A spike shorter than its own actuation lag: the scale-up is
    reverted before coming online and must never charge."""
    pol = dataclasses.replace(POL, scaleup_lag_s=100.0,
                              scaledown_delay_s=0.0)
    ts = np.concatenate([_times(2.0, 0.0, 100.0),
                         _times(9.0, 100.0, 110.0),
                         _times(2.0, 110.0, 300.0)])
    sched = Autoscaler(pol).plan_pool(ts, n_peak=10, rate_per_instance=1.0,
                                      horizon_s=300.0)
    cancelled = sched.online_until <= sched.online_from
    assert cancelled[10:].any()
    assert sched.online_instance_seconds(0.0, 300.0) \
        < 10 * 300.0  # sheds really saved instance-seconds


def test_online_instance_seconds_matches_online_at_integral():
    ts = _times(3.0, 0.0, 200.0)
    sched = Autoscaler(POL).plan_pool(ts, n_peak=6, rate_per_instance=1.0,
                                      horizon_s=200.0)
    grid = np.linspace(0.0, 200.0, 20001)
    counts = sched.online_at(grid)
    numeric = float(np.sum((counts[:-1] + counts[1:]) / 2.0)
                    * (grid[1] - grid[0]))
    assert sched.online_instance_seconds(0.0, 200.0) \
        == pytest.approx(numeric, rel=2e-3)


# --- engine integration -------------------------------------------------

def _quick_spec(pol=None):
    spec = TopologySpec.from_kind("fleetopt", H100_LLAMA70B, LLAMA31_70B,
                                  b_short=4096)
    return spec if pol is None else dataclasses.replace(spec, autoscale=pol)


def _diurnal_inputs(peak=40.0, day=120.0):
    dprof = DiurnalProfile(peak_rate=peak, day_s=day)
    wl = dataclasses.replace(AZURE, arrival_rate=peak)
    trace = sample_diurnal_trace(wl, dprof, day, seed=0,
                                 max_total=_quick_spec().max_window)
    return wl, trace


def test_set_online_windows_moves_clocks_and_charges_load():
    eng = BatchedPoolEngine(window=4096, profile=H100_LLAMA70B,
                            instances=3, n_slots=8,
                            streamed_params=LLAMA31_70B.streamed_params)
    eng.bank.measure_t0, eng.bank.measure_t1 = 0.0, 100.0
    j0 = eng.bank.m_joules.sum()
    eng.set_online_windows(np.array([0.0, 10.0, 20.0]),
                           np.array([np.inf, np.inf, 15.0]), load_s=4.0)
    np.testing.assert_allclose(eng.bank.sim_time_s, [0.0, 10.0, 20.0])
    # row 1 (a live scale-up) paid 4 s of idle weight-load draw; row 2
    # was cancelled before opening (until < from) and pays nothing
    assert eng.bank.m_idle_joules[1] > 0.0
    assert eng.bank.m_idle_joules[2] == 0.0
    assert eng.bank.m_joules.sum() > j0


def test_autoscaled_run_completes_everything_and_saves_energy():
    # peak high enough that each pool gets several instances (a
    # single-instance pool can never shed below its floor of one)
    wl, trace = _diurnal_inputs(peak=200.0, day=120.0)
    # spare_instances=0: at this toy scale (a handful of instances per
    # pool) the default N+1 spare would hold the whole peak fleet online
    # through the trough and there would be nothing to measure
    pol = AutoscalePolicy(control_interval_s=6.0, target_utilization=0.7,
                          scaleup_lag_s=1.0, scaledown_delay_s=12.0,
                          min_frac=0.2, spare_instances=0)
    spec = _quick_spec(pol)
    sim_s, reqs_s, _ = prepare_spec(spec, wl, seed=0, trace=trace)
    rep_s = sim_s.run(reqs_s, warmup_frac=0.0)
    sim_a, reqs_a, _ = prepare_spec(spec, wl, seed=0, trace=trace,
                                    autoscale=True)
    rep_a = sim_a.run(reqs_a, warmup_frac=0.0)
    assert rep_a["fleet"]["completed"] == rep_s["fleet"]["completed"]
    assert rep_a["fleet"]["completed"] == len(trace)
    # the whole point: fewer instance-seconds powered, more tok/W
    assert sim_a.schedules and not sim_s.schedules
    assert rep_a["fleet"]["joules"] < rep_s["fleet"]["joules"]
    assert rep_a["fleet"]["tok_per_watt"] > rep_s["fleet"]["tok_per_watt"]
    # per-pool stats surface the measured average online fleet
    for role in sim_a.order:
        assert "avg_online_instances" in rep_a[role]
        assert "avg_online_instances" not in rep_s[role]


def test_autoscaled_run_is_deterministic():
    wl, trace = _diurnal_inputs(peak=25.0, day=80.0)
    pol = AutoscalePolicy(control_interval_s=5.0, scaleup_lag_s=1.0,
                          scaledown_delay_s=10.0)
    spec = _quick_spec(pol)

    def run():
        sim, reqs, _ = prepare_spec(spec, wl, seed=0, trace=trace,
                                    autoscale=True)
        f = sim.run(reqs, warmup_frac=0.0)["fleet"]
        return f["tok_per_watt"], f["joules"], f["completed"]

    assert run() == run()


def test_prepare_spec_defaults_to_spec_policy():
    """autoscale=True with no explicit policy uses the spec's knob."""
    wl, trace = _diurnal_inputs(peak=25.0, day=80.0)
    pol = AutoscalePolicy(control_interval_s=5.0, min_frac=0.5)
    sim, _, _ = prepare_spec(_quick_spec(pol), wl, seed=0, trace=trace,
                             autoscale=True)
    assert sim.autoscale is pol


def test_autoscale_requires_numpy_engine():
    wl, trace = _diurnal_inputs(peak=25.0, day=80.0)
    with pytest.raises(ValueError, match="numpy"):
        prepare_spec(_quick_spec(AutoscalePolicy()), wl, seed=0,
                     trace=trace, autoscale=True, engine="jax")
