"""Golden-value regression tests for the paper's Table 1 anchors and the
1/W halving property, via core.law + core.profiles only (no optional
deps — unlike tests/core/test_law.py these never skip)."""
import pytest

from repro.core.law import fit_one_over_w
from repro.core.profiles import H100_LLAMA70B


def test_table1_anchor_64k():
    """Paper Table 1, H100 @ 64K: n_max = 16, tok/W ~= 1.50."""
    assert H100_LLAMA70B.n_max(65536) == 16
    assert H100_LLAMA70B.tok_per_watt_at_window(65536) == \
        pytest.approx(1.50, rel=0.02)


def test_table1_anchor_4k():
    """Paper Table 1, H100 @ 4K: n_max = 256, tok/W ~= 17.6."""
    assert H100_LLAMA70B.n_max(4096) == 256
    assert H100_LLAMA70B.tok_per_watt_at_window(4096) == \
        pytest.approx(17.6, rel=0.02)


def test_one_over_w_halving_per_context_doubling():
    """The law itself: each context doubling roughly halves tok/W.

    The ratio drifts above 0.5 at long context (power saturates while
    throughput keeps falling — the paper's own Table 1 shows the same
    bend), so the per-doubling ratios live in a band around 0.5 and the
    fitted log-log slope sits near -1 with near-perfect linearity."""
    fit = fit_one_over_w(H100_LLAMA70B)
    assert fit.slope == pytest.approx(-1.0, abs=0.15)
    assert fit.r2 > 0.99
    for ratio in fit.halving_ratios:
        assert 0.42 < ratio < 0.65, fit.halving_ratios
