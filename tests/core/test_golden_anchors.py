"""Golden-value regression tests for the paper's Table 1 anchors, the
1/W halving property, the §10.3 disaggregated analytical provisioning,
and the model-heterogeneous provisioning (§5.1 Semantic, §3.2 MoE pool)
the serving simulator is measured against, via core only (no optional
deps — unlike tests/core/test_law.py these never skip)."""
import pytest

from repro.core.disagg import Disaggregated
from repro.core.fleet import PREFILL_SATURATION
from repro.core.hardware import H100
from repro.core.law import fit_one_over_w
from repro.core.modelspec import LLAMA31_8B, LLAMA31_70B, QWEN3_235B_A22B
from repro.core.moe import moe_profile
from repro.core.power import H100_POWER
from repro.core.profiles import H100_LLAMA70B, computed_profile
from repro.core.routing import Homogeneous, Semantic
from repro.core.workloads import AZURE


def test_table1_anchor_64k():
    """Paper Table 1, H100 @ 64K: n_max = 16, tok/W ~= 1.50."""
    assert H100_LLAMA70B.n_max(65536) == 16
    assert H100_LLAMA70B.tok_per_watt_at_window(65536) == \
        pytest.approx(1.50, rel=0.02)


def test_table1_anchor_4k():
    """Paper Table 1, H100 @ 4K: n_max = 256, tok/W ~= 17.6."""
    assert H100_LLAMA70B.n_max(4096) == 256
    assert H100_LLAMA70B.tok_per_watt_at_window(4096) == \
        pytest.approx(17.6, rel=0.02)


def test_one_over_w_halving_per_context_doubling():
    """The law itself: each context doubling roughly halves tok/W.

    The ratio drifts above 0.5 at long context (power saturates while
    throughput keeps falling — the paper's own Table 1 shows the same
    bend), so the per-doubling ratios live in a band around 0.5 and the
    fitted log-log slope sits near -1 with near-perfect linearity."""
    fit = fit_one_over_w(H100_LLAMA70B)
    assert fit.slope == pytest.approx(-1.0, abs=0.15)
    assert fit.r2 > 0.99
    for ratio in fit.halving_ratios:
        assert 0.42 < ratio < 0.65, fit.halving_ratios


def test_disagg_azure_h100_provisioning_anchor():
    """Golden pin for core.disagg analytical provisioning on Azure/H100
    (b_short=4096, gamma=2): per-pool instances, per-instance power and
    the fleet tok/W numbers the serving simulator is measured against."""
    rep = Disaggregated(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    pools = {p.name: p for p in rep.pools}
    assert {n: p.instances for n, p in pools.items()} == {
        "prefill-8K": 12, "decode-8K": 19,
        "prefill-64K": 26, "decode-64K": 21}
    # prefill pools draw near-saturated P_nom regardless of window
    nom = H100_LLAMA70B.power_model.p_nom_w * PREFILL_SATURATION
    assert pools["prefill-8K"].power_w_per_instance == pytest.approx(nom)
    assert pools["prefill-64K"].power_w_per_instance == pytest.approx(nom)
    assert pools["decode-8K"].power_w_per_instance == \
        pytest.approx(578.58, rel=1e-3)
    assert pools["decode-64K"].power_w_per_instance == \
        pytest.approx(417.92, rel=1e-3)
    # whole-fleet (prefill watts included) vs decode-fleet-only tok/W
    assert rep.instances == 78 and rep.gpus == 624
    assert rep.power_kw == pytest.approx(41.885, rel=1e-3)
    assert rep.tok_per_watt == pytest.approx(7.712, rel=1e-3)
    dec = [p for p in rep.pools if p.phase == "decode"]
    dec_tpw = (sum(p.tokens_per_s for p in dec)
               / sum(p.instances * p.power_w_per_instance for p in dec))
    assert dec_tpw == pytest.approx(16.339, rel=1e-3)


def _small_profile():
    return computed_profile(LLAMA31_8B, H100, H100_POWER, tp=1)


def test_semantic_azure_h100_provisioning_anchor():
    """Golden pin for core.routing.Semantic honest-routing provisioning
    on Azure/H100 (b_short=4096, 8B small pool at TP1): per-pool
    instances and the fleet tok/W the serving simulator's `semantic` /
    `semantic_fleetopt` kinds are measured against (zero misroute)."""
    sem = Semantic(b_short=4096, small_profile=_small_profile(),
                   small_model=LLAMA31_8B, gamma=1.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    assert {p.name: p.instances for p in sem.pools} == {
        "semantic-small-4K": 31, "semantic-large-64K": 26}
    assert sem.tok_per_watt == pytest.approx(11.357, rel=1e-3)
    # the gamma=2 serve-window variant packs the small pool worse
    # (n_max ~ 1/window) but absorbs output mispredictions in place
    semf = Semantic(b_short=4096, small_profile=_small_profile(),
                    small_model=LLAMA31_8B, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    assert {p.name: p.instances for p in semf.pools} == {
        "semantic-small-8K": 51, "semantic-large-64K": 26}
    assert semf.tok_per_watt == pytest.approx(8.625, rel=1e-3)


def test_semantic_misroute_degrades_analytical_tok_per_watt():
    """The misroute channel prices real waste: at a 30% classifier error
    the provisioned fleet's tok/W drops materially below the clean one."""
    kw = dict(b_short=4096, small_profile=_small_profile(),
              small_model=LLAMA31_8B, gamma=2.0)
    clean = Semantic(**kw).provision(AZURE, H100_LLAMA70B, LLAMA31_70B)
    noisy = Semantic(misroute_rate=0.3, **kw).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    assert noisy.tok_per_watt < 0.9 * clean.tok_per_watt


def test_moe_pool_azure_h100_provisioning_anchor():
    """Golden pin for the MoE fleet lever (§3.2 served): Qwen3-235B-A22B
    on H100/TP8 at the 64K homo window.  The paper's 5.1x per-GPU
    active-parameter upper bound collapses to ~1.23x at fleet level (the
    MoE's total weights crush its KV capacity: n_max = 5 vs the dense
    70B's 16), and *below* dense once expert dispatch is priced — the
    numbers the simulator's `moe_pool` kind is measured against."""
    prof = moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8)
    assert prof.n_max(65536) == 5
    assert prof.roofline.w_ms == pytest.approx(2.113, rel=1e-3)
    dense = Homogeneous().provision(AZURE, H100_LLAMA70B, LLAMA31_70B)
    assert dense.tok_per_watt == pytest.approx(5.294, rel=1e-3)
    expect = {0.0: (6.522, 1.232), 2.0: (3.496, 0.660), 10.0: (1.222, 0.231)}
    for d, (tpw, adv) in expect.items():
        rep = Homogeneous().provision(
            AZURE, moe_profile(QWEN3_235B_A22B, H100, H100_POWER, tp=8,
                               dispatch_ms=d), QWEN3_235B_A22B)
        assert rep.tok_per_watt == pytest.approx(tpw, rel=1e-3), d
        assert rep.tok_per_watt / dense.tok_per_watt == \
            pytest.approx(adv, abs=5e-3), d
