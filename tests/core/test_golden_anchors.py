"""Golden-value regression tests for the paper's Table 1 anchors, the
1/W halving property, and the §10.3 disaggregated analytical provisioning,
via core only (no optional deps — unlike tests/core/test_law.py these
never skip)."""
import pytest

from repro.core.disagg import Disaggregated
from repro.core.fleet import PREFILL_SATURATION
from repro.core.law import fit_one_over_w
from repro.core.modelspec import LLAMA31_70B
from repro.core.profiles import H100_LLAMA70B
from repro.core.workloads import AZURE


def test_table1_anchor_64k():
    """Paper Table 1, H100 @ 64K: n_max = 16, tok/W ~= 1.50."""
    assert H100_LLAMA70B.n_max(65536) == 16
    assert H100_LLAMA70B.tok_per_watt_at_window(65536) == \
        pytest.approx(1.50, rel=0.02)


def test_table1_anchor_4k():
    """Paper Table 1, H100 @ 4K: n_max = 256, tok/W ~= 17.6."""
    assert H100_LLAMA70B.n_max(4096) == 256
    assert H100_LLAMA70B.tok_per_watt_at_window(4096) == \
        pytest.approx(17.6, rel=0.02)


def test_one_over_w_halving_per_context_doubling():
    """The law itself: each context doubling roughly halves tok/W.

    The ratio drifts above 0.5 at long context (power saturates while
    throughput keeps falling — the paper's own Table 1 shows the same
    bend), so the per-doubling ratios live in a band around 0.5 and the
    fitted log-log slope sits near -1 with near-perfect linearity."""
    fit = fit_one_over_w(H100_LLAMA70B)
    assert fit.slope == pytest.approx(-1.0, abs=0.15)
    assert fit.r2 > 0.99
    for ratio in fit.halving_ratios:
        assert 0.42 < ratio < 0.65, fit.halving_ratios


def test_disagg_azure_h100_provisioning_anchor():
    """Golden pin for core.disagg analytical provisioning on Azure/H100
    (b_short=4096, gamma=2): per-pool instances, per-instance power and
    the fleet tok/W numbers the serving simulator is measured against."""
    rep = Disaggregated(b_short=4096, gamma=2.0).provision(
        AZURE, H100_LLAMA70B, LLAMA31_70B)
    pools = {p.name: p for p in rep.pools}
    assert {n: p.instances for n, p in pools.items()} == {
        "prefill-8K": 12, "decode-8K": 19,
        "prefill-64K": 26, "decode-64K": 21}
    # prefill pools draw near-saturated P_nom regardless of window
    nom = H100_LLAMA70B.power_model.p_nom_w * PREFILL_SATURATION
    assert pools["prefill-8K"].power_w_per_instance == pytest.approx(nom)
    assert pools["prefill-64K"].power_w_per_instance == pytest.approx(nom)
    assert pools["decode-8K"].power_w_per_instance == \
        pytest.approx(578.58, rel=1e-3)
    assert pools["decode-64K"].power_w_per_instance == \
        pytest.approx(417.92, rel=1e-3)
    # whole-fleet (prefill watts included) vs decode-fleet-only tok/W
    assert rep.instances == 78 and rep.gpus == 624
    assert rep.power_kw == pytest.approx(41.885, rel=1e-3)
    assert rep.tok_per_watt == pytest.approx(7.712, rel=1e-3)
    dec = [p for p in rep.pools if p.phase == "decode"]
    dec_tpw = (sum(p.tokens_per_s for p in dec)
               / sum(p.instances * p.power_w_per_instance for p in dec))
    assert dec_tpw == pytest.approx(16.339, rel=1e-3)
